//! §Perf — hot-path micro-benchmarks (EXPERIMENTS.md §Perf feeds from here).
//!
//! Measures, on the real PJRT path when artifacts exist:
//!   * per-bucket step latency (upload + execute + download),
//!   * eval latency,
//!   * merge arithmetic (weighted all-reduce) across model sizes,
//!   * batcher assembly,
//!   * data-plane throughput (composition policies, pooled vs fresh
//!     allocation) — recorded to `BENCH_pipeline.json` (`HS_BENCH_OUT`
//!     overrides the path),
//!   * Algorithm 1 + Algorithm 2 overhead (must be negligible vs a step),
//!   * dispatch-plan recomputation + pool-event processing (the per-
//!     mega-batch overhead the elastic pool adds to the hot path),
//!   * serving plane: snapshot publish/hot-swap/read cost and admission
//!     batch-formation throughput — recorded to `BENCH_serve.json`
//!     (`HS_BENCH_SERVE_OUT` overrides the path),
//!   * adaptive-sparsity lever: LSH build/query throughput, active-set
//!     step cost down the ratio ladder, pooled-vs-fresh step scratch —
//!     recorded to `BENCH_slide.json` (`HS_BENCH_SLIDE_OUT` overrides
//!     the path),
//!   * cluster plane: segment-agnostic all-reduce arithmetic, tier-2
//!     staleness-weighted merge, fabric link-cost scoring, and a full
//!     micro-cluster sim round loop — recorded to `BENCH_cluster.json`
//!     (`HS_BENCH_CLUSTER_OUT` overrides the path),
//!   * observability plane: enabled span emit, registry counter
//!     increment + by-name lookup, and the disabled-sink no-op that
//!     rides every call site — recorded to `BENCH_obs.json`
//!     (`HS_BENCH_OBS_OUT` overrides the path),
//!   * trace analysis plane: per-lane attribution, critical-path
//!     extraction, and report diff over a synthetic ~5k-event stream —
//!     recorded to `BENCH_analyze.json` (`HS_BENCH_ANALYZE_OUT`
//!     overrides the path),
//!   * scenario DSL: unified-grammar event parsing, compound-line
//!     routing, and fuzz-case generation — recorded to
//!     `BENCH_scenario.json` (`HS_BENCH_SCENARIO_OUT` overrides the
//!     path).

use std::sync::Arc;

use heterosparse::cluster::{run_cluster, ClusterPolicy, Fabric, ServerContribution};
use heterosparse::config::{
    CompositionPolicy, Config, DataConfig, DeviceConfig, MergeConfig, ModelDims, SgdConfig,
    Strategy,
};
use heterosparse::coordinator::{merge, plan_for_strategy, scaling, DevicePool};
use heterosparse::fleet::{
    fair_allocation, Arbiter, ArbiterConfig, LeaseBook, PriorityClass, TenantSpec,
};
use heterosparse::data::batcher::{Batcher, PaddedBatch};
use heterosparse::data::pipeline::{BufferPool, DataPlane, ShardedDataset};
use heterosparse::data::synthetic::Generator;
use heterosparse::model::reference::{sgd_step_ref, sgd_step_scratch, StepScratch};
use heterosparse::model::ModelState;
use heterosparse::obs::{ObsHandle, Subsystem};
use heterosparse::runtime::{CostModel, Runtime};
use heterosparse::slide::lsh::LshTables;
use heterosparse::slide::SparseStepper;
use heterosparse::serve::{Admission, SnapshotRegistry};
use heterosparse::tuning::{
    score_plan, CalibratedCosts, DeviceEstimator, EstimatorConfig, Observation,
};
use heterosparse::util::bench::{bench_fn, fmt_ns, BenchResult};
use heterosparse::util::json::Json;

fn main() {
    let cfg = Config::default();
    let (train, _) = {
        let gen = Generator::new(&cfg.model, &cfg.data);
        (gen.generate(4_000, 1), ())
    };
    let mut batcher = Batcher::new(&train, &cfg.model, 1);

    // ---- batcher ----------------------------------------------------------
    let r = bench_fn("batcher/next_batch(b=128)", 10, 200, || batcher.next_batch(128, 128));
    println!("{r}");

    // ---- data plane: composition policies + buffer recycling --------------
    // Throughput is batches/sec of synchronous assembly (the producer
    // thread's inner loop); the pooled-vs-fresh pair isolates the
    // allocation-recycling win.
    let sharded = Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples));
    let mut pipeline_results: Vec<(String, BenchResult, f64)> = Vec::new();
    for policy in CompositionPolicy::all() {
        let plane = DataPlane::new_sync(sharded.clone(), &cfg.model, policy, 1);
        let name = format!("pipeline/next_batch(b=128, {})", policy.name());
        let r = bench_fn(&name, 10, 200, || {
            let b = plane.next_batch(128, 128);
            plane.recycle(b);
        });
        let bps = r.throughput(1.0);
        println!("{r}  ({bps:.0} batches/s)");
        pipeline_results.push((format!("next_batch_{}", policy.name()), r, bps));
    }
    let k = cfg.model.max_nnz;
    let l = cfg.model.max_labels;
    let pool = BufferPool::new(8);
    let r = bench_fn("pipeline/alloc fresh(b=128)", 10, 500, || PaddedBatch::with_shape(128, k, l));
    let fresh_bps = r.throughput(1.0);
    println!("{r}  ({fresh_bps:.0} allocs/s)");
    pipeline_results.push(("alloc_fresh".to_string(), r, fresh_bps));
    let r = bench_fn("pipeline/alloc pooled(b=128)", 10, 500, || {
        let b = pool.get(128, k, l);
        pool.put(b);
    });
    let pooled_bps = r.throughput(1.0);
    println!("{r}  ({pooled_bps:.0} allocs/s)");
    pipeline_results.push(("alloc_pooled".to_string(), r, pooled_bps));
    append_baseline(
        "BENCH_pipeline.json",
        "HS_BENCH_OUT",
        "perf_hotpath/pipeline",
        &pipeline_results,
    );

    // ---- serving plane: snapshot hot-swap + admission formation ------------
    // Publish cost is dominated by the one model clone per publish (the
    // swap itself is a pointer store under a write lock); reads are an Arc
    // clone under a read lock and must stay nanosecond-scale — they sit on
    // the per-batch serving hot path.
    let mut serve_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let registry = SnapshotRegistry::with_history_cap(2);
    let model = ModelState::init(&cfg.model, 3);
    let r = bench_fn("serve/registry_publish(hot-swap)", 3, 50, || {
        registry.publish(model.clone(), Some(0), 0.0)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} publishes/s)");
    serve_results.push(("registry_publish".to_string(), r, per_sec));
    let r = bench_fn("serve/registry_current(read)", 100, 2000, || registry.current());
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} reads/s)");
    serve_results.push(("registry_current".to_string(), r, per_sec));

    let mut admission = Admission::new(sharded.clone(), &cfg.model, &cfg);
    let b = cfg.serve_max_batch();
    let mut next_id = 0u64;
    let r = bench_fn(&format!("serve/admission_form(b={b})"), 5, 200, || {
        for i in 0..b {
            admission.push(next_id, ((next_id as usize + i) % 4_000) as u32, 0.0);
            next_id += 1;
        }
        let formed = admission.pop_full(0.0).expect("queue is full");
        admission.recycle(formed.batch);
    });
    let per_sec = r.throughput(b as f64);
    println!("{r}  ({:.0} krequests/s)", per_sec / 1e3);
    serve_results.push(("admission_form".to_string(), r, per_sec));
    append_baseline("BENCH_serve.json", "HS_BENCH_SERVE_OUT", "perf_hotpath/serve", &serve_results);

    // ---- fleet scheduler: arbiter decisions + lease churn ------------------
    // The arbiter runs every decision window of the co-schedule; its
    // rebalance (fair allocation + SLO ledger + lease diff) and the lease
    // book's grant/revoke/expire cycle must stay microseconds-scale next
    // to mega-batches and serve micro-batches.
    let mut fleet_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let tenants = vec![
        TenantSpec::training(0, "train-a", 1.0),
        TenantSpec::training(1, "train-b", 1.0),
        TenantSpec::serve(2, "lane", 1.0),
    ];
    let mut arb = Arbiter::new(
        tenants.clone(),
        vec![1.0, 1.1, 1.21, 1.32],
        &[0, 1, 2, 3],
        ArbiterConfig::default(),
    );
    let mut tick_t = 0.0f64;
    let r = bench_fn("fleet/arbiter_rebalance(3 tenants, 4 devices)", 10, 2000, || {
        tick_t += 0.25;
        arb.rebalance(tick_t);
        arb.take_events().len()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} decisions/s)");
    fleet_results.push(("arbiter_rebalance".to_string(), r, per_sec));

    let devices8: Vec<(usize, f64)> =
        (0..8).map(|d| (d, 1.0 + 0.04 * d as f64)).collect();
    let r = bench_fn("fleet/fair_allocation(3 tenants, 8 devices)", 10, 2000, || {
        fair_allocation(&tenants, &devices8)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} allocations/s)");
    fleet_results.push(("fair_allocation".to_string(), r, per_sec));

    let mut book = LeaseBook::new(8, &(0..8).collect::<Vec<usize>>());
    let mut lease_t = 0.0f64;
    let r = bench_fn("fleet/lease_churn(grant+revoke+expire)", 10, 2000, || {
        lease_t += 1.0;
        let id = book.grant(0, 3, PriorityClass::Standard, lease_t).unwrap();
        book.revoke(id, 0.5, lease_t, "bench").unwrap();
        book.expire(lease_t + 1.0);
        book.take_events().len()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} churn cycles/s)");
    fleet_results.push(("lease_churn".to_string(), r, per_sec));
    append_baseline("BENCH_fleet.json", "HS_BENCH_FLEET_OUT", "perf_hotpath/fleet", &fleet_results);

    // ---- calibration plane: estimator, view swap, what-if ------------------
    // The estimator runs once per active device per mega-batch, the view
    // swap once per mega-batch, and what-if scoring on demand; all three
    // must stay far below a step (hundreds of µs).
    let mut cal_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let nominal_cost = CostModel::default();
    let mut est = DeviceEstimator::new(EstimatorConfig::default(), nominal_cost);
    let mut i = 0usize;
    let r = bench_fn("tuning/estimator_observe+estimate", 10, 2000, || {
        let b = 32 + 16 * (i % 4);
        let nnz = 12.0 * b as f64;
        i += 1;
        est.observe(Observation {
            bucket: b,
            nnz_per_batch: nnz,
            secs_per_batch: 1.2 * nominal_cost.step_time_parts(b, nnz as usize),
            ratio: 1.0,
        });
        est.estimate()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} observations/s)");
    cal_results.push(("estimator_observe".to_string(), r, per_sec));

    let costs = CalibratedCosts::new(vec![1.0, 1.1, 1.21, 1.32]);
    let sample = est.estimate().expect("estimator has observations");
    let r = bench_fn("tuning/view_update+read(4 devices)", 10, 2000, || {
        costs.update_devices(&[(1, sample)], 0.0);
        costs.current().speeds()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} swaps/s)");
    cal_results.push(("view_update_read".to_string(), r, per_sec));

    let whatif_plan = plan_for_strategy(
        &cfg,
        Strategy::Adaptive,
        &[0, 1, 2, 3],
        &[128, 96, 72, 48],
        &[0.05, 0.04, 0.03, 0.02],
        12.0,
    );
    let speeds = [1.0, 1.1, 1.9, 1.32];
    let r = bench_fn("tuning/whatif_score_plan(4 devices)", 10, 500, || {
        score_plan(&whatif_plan, &speeds, &nominal_cost)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} scorings/s)");
    cal_results.push(("whatif_score_plan".to_string(), r, per_sec));
    append_baseline(
        "BENCH_calibration.json",
        "HS_BENCH_CAL_OUT",
        "perf_hotpath/calibration",
        &cal_results,
    );

    // ---- adaptive-sparsity lever: LSH tables + active-set kernels ----------
    // Build amortizes over `rebuild_every` steps and query sits inside
    // every sparse step, so both must stay far below a dense step; the
    // ratio ladder is the compute knob itself — its cost curve is what the
    // scheduler trades against batch size.
    let mut slide_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let slide_sec = cfg.slide.clone();
    let mut slide_model = ModelState::init(&cfg.model, 11);
    let r = bench_fn("slide/lsh_build(w2)", 3, 30, || {
        LshTables::build(&slide_model, slide_sec.tables, slide_sec.bits, 7)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.1} builds/s)");
    slide_results.push(("lsh_build".to_string(), r, per_sec));

    let tables = LshTables::build(&slide_model, slide_sec.tables, slide_sec.bits, 7);
    let probe: Vec<f32> = (0..cfg.model.hidden).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut hits: Vec<u32> = Vec::new();
    let r = bench_fn("slide/lsh_query(hidden)", 10, 2000, || {
        tables.query_into(&probe, &mut hits);
        hits.len()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({:.0} kqueries/s)", per_sec / 1e3);
    slide_results.push(("lsh_query".to_string(), r, per_sec));

    let step_batch = batcher.next_batch(128, 128);
    let mut scratch = StepScratch::new();
    for ratio in [1.0f64, 0.25, 0.05] {
        let mut stepper = SparseStepper::new(&slide_sec, 99);
        stepper.set_ratio(ratio);
        let name = format!("slide/step(b=128, ratio={ratio})");
        let r = bench_fn(&name, 3, 30, || {
            stepper.step(&mut slide_model, &step_batch, 0.01, &mut scratch)
        });
        let per_sec = r.throughput(128.0);
        println!("{r}  ({:.1} ksamples/s)", per_sec / 1e3);
        slide_results.push((format!("step_ratio_{ratio}"), r, per_sec));
    }

    // Pooled vs fresh step buffers: the delta is the allocation the
    // StepScratch pool removes from every engine/serve step.
    let r = bench_fn("slide/step_scratch_pooled(b=128)", 3, 30, || {
        sgd_step_scratch(&mut slide_model, &step_batch, 0.01, &mut scratch)
    });
    let per_sec = r.throughput(128.0);
    println!("{r}  ({:.1} ksamples/s)", per_sec / 1e3);
    slide_results.push(("step_scratch_pooled".to_string(), r, per_sec));
    let r = bench_fn("slide/step_scratch_fresh(b=128)", 3, 30, || {
        sgd_step_ref(&mut slide_model, &step_batch, 0.01)
    });
    let per_sec = r.throughput(128.0);
    println!("{r}  ({:.1} ksamples/s)", per_sec / 1e3);
    slide_results.push(("step_scratch_fresh".to_string(), r, per_sec));
    append_baseline("BENCH_slide.json", "HS_BENCH_SLIDE_OUT", "perf_hotpath/slide", &slide_results);

    // ---- cluster plane: all-reduce arithmetic, fabric scoring, sim rounds --
    // The tier-2 merge and link-cost scoring run once per sync round;
    // both must stay far below the training work a round coordinates.
    let mut cluster_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let server_models: Vec<ModelState> =
        (0..3).map(|i| ModelState::init(&cfg.model, 40 + i)).collect();
    let params = server_models[0].param_count();

    // The partitioned weighted sum shared by intra-server all-reduce and
    // the inter-server fabric (segment-count agnostic by construction).
    let sum_weights = [0.5, 0.3, 0.2];
    let mut sum_out = ModelState::zeros(&cfg.model);
    let r = bench_fn("cluster/partitioned_weighted_sum(3 models)", 3, 50, || {
        let replica_segs: Vec<Vec<&[f32]>> =
            server_models.iter().map(|m| m.segments().to_vec()).collect();
        let mut out_segs = sum_out.segments_mut();
        heterosparse::allreduce::partitioned_weighted_sum(
            &mut out_segs,
            &replica_segs,
            &sum_weights,
            4,
        )
    });
    let per_sec = r.throughput(params as f64);
    println!("{r}  ({:.1} Mparam/s)", per_sec / 1e6);
    cluster_results.push(("partitioned_weighted_sum".to_string(), r, per_sec));

    let r = bench_fn("cluster/merge_servers(3 servers)", 3, 50, || {
        let contribs: Vec<ServerContribution> = server_models
            .iter()
            .enumerate()
            .map(|(s, m)| ServerContribution {
                model: m,
                weight: 1.0 + s as f64,
                staleness_mb: s,
            })
            .collect();
        heterosparse::cluster::merge_servers(&contribs)
    });
    let per_sec = r.throughput(params as f64);
    println!("{r}  ({:.1} Mparam/s)", per_sec / 1e6);
    cluster_results.push(("merge_servers".to_string(), r, per_sec));

    let throttle =
        vec![heterosparse::tuning::DriftEvent { at_mb: 4, device: 3, factor: 6.0, ramp: 2 }];
    let fabric =
        Fabric::new(8, 2e-3, 1e9, heterosparse::allreduce::Algo::Ring, 4, throttle);
    let participants: Vec<usize> = (0..8).collect();
    let sync_bytes = (params * 4) as f64;
    let mut w = 0usize;
    let r = bench_fn("cluster/fabric_sync_time(8 links)", 10, 2000, || {
        w += 1;
        fabric.sync_time(&participants, sync_bytes, w % 12)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} scorings/s)");
    cluster_results.push(("fabric_sync_time".to_string(), r, per_sec));

    // One full micro-cluster run (2 servers x 3 mega-batches on a tiny
    // model): the sim round loop end to end, dominated by the per-server
    // sessions it coordinates.
    let mut ccfg = Config::default();
    ccfg.model =
        ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 2 };
    ccfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 16,
        beta: 8,
        lr_bmax: 0.4,
        mega_batches: 6,
        num_mega_batches: 3,
        initial_batch: 16,
        seed: 7,
        ..Default::default()
    };
    ccfg.devices = DeviceConfig {
        count: 2,
        speed_factors: vec![1.0, 1.2],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    ccfg.data = DataConfig {
        train_samples: 400,
        test_samples: 100,
        avg_nnz: 4.0,
        ..Default::default()
    };
    ccfg.cluster.servers = 2;
    ccfg.cluster.sync_every = 1;
    ccfg.cluster.link_gbytes_per_sec = 0.1;
    ccfg.validate().unwrap();
    let rounds = ccfg.sgd.num_mega_batches as f64; // sync_every = 1
    let r = bench_fn("cluster/sim(2 servers x 3 mb)", 3, 3, || {
        run_cluster(&ccfg, ClusterPolicy { flat: false, adaptive: true }, "bench").unwrap()
    });
    let per_sec = r.throughput(rounds);
    println!("{r}  ({per_sec:.1} rounds/s)");
    cluster_results.push(("sim_round".to_string(), r, per_sec));
    append_baseline(
        "BENCH_cluster.json",
        "HS_BENCH_CLUSTER_OUT",
        "perf_hotpath/cluster",
        &cluster_results,
    );

    // ---- observability plane: span emit, registry, disabled no-op ----------
    // Spans ride every scheduling decision and the disabled branch rides
    // *every* call site, so all of these must stay nanosecond-scale.
    let mut obs_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let enabled_obs = ObsHandle::from_config(
        &heterosparse::config::ObsConfig { enabled: true, ..Default::default() },
        false,
    );
    let mut t = 0u64;
    let r = bench_fn("obs/span_emit(enabled)", 10, 2000, || {
        t += 1;
        enabled_obs.span(
            Subsystem::Train,
            "train.megabatch",
            0,
            t as f64 * 1e-3,
            1e-3,
            vec![("mb", heterosparse::obs::ArgVal::U(t))],
        )
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({:.2} Mspans/s)", per_sec / 1e6);
    obs_results.push(("span_emit_enabled".to_string(), r, per_sec));

    let counter = enabled_obs.counter("bench.counter");
    let r = bench_fn("obs/counter_inc(cached handle)", 100, 5000, || counter.inc());
    let per_sec = r.throughput(1.0);
    println!("{r}  ({:.1} Mincs/s)", per_sec / 1e6);
    obs_results.push(("counter_inc".to_string(), r, per_sec));

    let r = bench_fn("obs/counter_by_name(lookup + inc)", 10, 2000, || {
        enabled_obs.counter("bench.lookup").inc()
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} lookups/s)");
    obs_results.push(("counter_by_name".to_string(), r, per_sec));

    let disabled_obs = ObsHandle::disabled();
    let r = bench_fn("obs/span_emit(disabled no-op)", 100, 5000, || {
        disabled_obs.span(Subsystem::Train, "train.megabatch", 0, 0.0, 1e-3, Vec::new())
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({:.1} Mcalls/s)", per_sec / 1e6);
    obs_results.push(("span_emit_disabled".to_string(), r, per_sec));
    append_baseline("BENCH_obs.json", "HS_BENCH_OBS_OUT", "perf_hotpath/obs", &obs_results);

    // ---- trace analysis plane: attribution, critical path, diff ------------
    // `report` runs post-hoc, but CI runs it after every smoke and the
    // --diff gate sits on the PR path, so a realistic trace (~5k events:
    // 200 mega-batches x 4 devices with merges, tier-2 syncs, serve
    // batches, and decision instants) must analyze in milliseconds.
    let mut analyze_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let analyze_obs = ObsHandle::from_config(
        &heterosparse::config::ObsConfig {
            enabled: true,
            buffer_events: 1 << 16,
            ..Default::default()
        },
        false,
    );
    {
        let devs = 4u32;
        let mut t = 0.0f64;
        for mb in 0..200u64 {
            let mut end = t;
            for d in 0..devs {
                let mut cursor = t;
                for s in 0..5u64 {
                    let dur = 1e-3 * (1.0 + 0.1 * d as f64 + 0.01 * ((mb + s) % 7) as f64);
                    analyze_obs.span(
                        Subsystem::Engine,
                        "engine.step",
                        1 + d,
                        cursor,
                        dur,
                        vec![("batch", heterosparse::obs::ArgVal::U(128))],
                    );
                    cursor += dur;
                }
                end = end.max(cursor);
            }
            analyze_obs.span(
                Subsystem::Train,
                "train.merge",
                0,
                end,
                2e-4,
                Vec::new(),
            );
            analyze_obs.span(
                Subsystem::Train,
                "train.megabatch",
                0,
                t,
                end + 2e-4 - t,
                vec![("mb", heterosparse::obs::ArgVal::U(mb))],
            );
            if mb % 4 == 0 {
                analyze_obs.span(
                    Subsystem::Cluster,
                    "cluster.sync",
                    0,
                    end + 2e-4,
                    3e-4,
                    vec![("window", heterosparse::obs::ArgVal::U(mb / 4))],
                );
            }
            analyze_obs.span(
                Subsystem::Serve,
                "serve.batch",
                heterosparse::obs::chrome::SERVE_TID_BASE + (mb % devs as u64) as u32,
                t,
                8e-4,
                vec![("queued_s", heterosparse::obs::ArgVal::F(1e-4))],
            );
            if mb % 10 == 0 {
                analyze_obs.instant(
                    Subsystem::Train,
                    "train.pool",
                    0,
                    end,
                    vec![
                        ("device", heterosparse::obs::ArgVal::U(mb % devs as u64)),
                        ("action", heterosparse::obs::ArgVal::S("remove".into())),
                        ("reason", heterosparse::obs::ArgVal::S("bench".into())),
                    ],
                );
            }
            t = end + 2e-4 + 3e-4;
        }
    }
    let td = heterosparse::obs::analyze::TraceData::from_handle("bench", &analyze_obs);
    assert_eq!(td.dropped, 0, "bench ring must hold the synthetic stream");
    let n_events = td.events.len() as f64;

    let r = bench_fn("analyze/attribution(~5k events)", 3, 50, || {
        heterosparse::obs::analyze::attribute(&td.events)
    });
    let per_sec = r.throughput(n_events);
    println!("{r}  ({:.1} Mevents/s)", per_sec / 1e6);
    analyze_results.push(("attribution".to_string(), r, per_sec));

    let r = bench_fn("analyze/critical_path(~5k events)", 3, 50, || {
        heterosparse::obs::analyze::critical_path(&td.events)
    });
    let per_sec = r.throughput(n_events);
    println!("{r}  ({:.1} Mevents/s)", per_sec / 1e6);
    analyze_results.push(("critical_path".to_string(), r, per_sec));

    let report = heterosparse::obs::analyze::Report::from_trace(&td);
    let th = heterosparse::obs::analyze::DiffThresholds::default();
    let r = bench_fn("analyze/report_diff(self)", 10, 500, || {
        heterosparse::obs::analyze::diff(&report, &report, &th)
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} diffs/s)");
    analyze_results.push(("report_diff".to_string(), r, per_sec));
    append_baseline(
        "BENCH_analyze.json",
        "HS_BENCH_ANALYZE_OUT",
        "perf_hotpath/analyze",
        &analyze_results,
    );

    // ---- scenario DSL: grammar parse, compound routing, fuzz gen -----------
    // Every Config load/validate re-parses its event lists through the
    // unified grammar and `experiment fuzz` regenerates a full timeline
    // per case, so the tokenizer and the case generator must both stay
    // microseconds-scale.
    let mut scenario_results: Vec<(String, BenchResult, f64)> = Vec::new();
    let event_lines: Vec<String> = (0..64)
        .map(|i| match i % 4 {
            0 => format!("at_mb={} remove={}", i + 1, 1 + i % 3),
            1 => format!(
                "at_mb={} device={} factor={} ramp={}",
                i + 1,
                i % 4,
                2 + i % 5,
                i % 3
            ),
            2 => format!("at_mb={} link={} factor=4.0", i + 1, i % 2),
            _ => format!("at_mb={} server={} down", i + 1, 1 + i % 2),
        })
        .collect();
    let r = bench_fn("scenario/parse_event(64 mixed)", 10, 500, || {
        event_lines
            .iter()
            .map(|l| {
                heterosparse::scenario::parse_event(l, heterosparse::scenario::Mask::ALL).unwrap()
            })
            .count()
    });
    let per_sec = r.throughput(64.0);
    println!("{r}  ({:.1} klines/s)", per_sec / 1e3);
    scenario_results.push(("parse_event_mixed".to_string(), r, per_sec));

    let compound = "at_mb=2 remove=1; serve: add=1; \
                    calibration: at_mb=3 device=0 factor=2; \
                    cluster: at_mb=4 server=1 down";
    let r = bench_fn("scenario/route_line(4 clauses)", 10, 2000, || {
        heterosparse::scenario::route_line(compound).unwrap().len()
    });
    let per_sec = r.throughput(4.0);
    println!("{r}  ({:.1} kclauses/s)", per_sec / 1e3);
    scenario_results.push(("route_line_compound".to_string(), r, per_sec));

    let mut fuzz_index = 0usize;
    let r = bench_fn("scenario/fuzz_gen_case", 10, 2000, || {
        fuzz_index += 1;
        heterosparse::scenario::fuzz::gen_case(heterosparse::scenario::fuzz::case_seed(
            7, fuzz_index,
        ))
    });
    let per_sec = r.throughput(1.0);
    println!("{r}  ({per_sec:.0} cases/s)");
    scenario_results.push(("fuzz_gen_case".to_string(), r, per_sec));
    append_baseline(
        "BENCH_scenario.json",
        "HS_BENCH_SCENARIO_OUT",
        "perf_hotpath/scenario",
        &scenario_results,
    );

    // ---- coordinator algorithms -------------------------------------------
    let mut b = vec![128usize, 96, 72, 48];
    let mut lrs = vec![0.05f32; 4];
    let r = bench_fn("alg1/rescale(4 devices)", 10, 1000, || {
        scaling::rescale(&mut b, &mut lrs, &[12, 10, 9, 8], &cfg.sgd)
    });
    println!("{r}");

    let l2s = vec![0.01f64; 4];
    let r = bench_fn("alg2/compute_weights(4 devices)", 10, 1000, || {
        merge::compute_weights(&[12, 10, 9, 8], &[128, 96, 72, 48], &l2s, &MergeConfig::default())
    });
    println!("{r}");

    // ---- elastic pool: plan recomputation + event processing ---------------
    // Every mega-batch rebuilds the dispatch plan over the current active
    // subset; pool events make the subset change. Both must stay negligible
    // next to a step (hundreds of µs).
    let batch_sizes = vec![128usize, 96, 72, 48];
    let plan_lrs = vec![0.05f32, 0.04, 0.03, 0.02];
    let nnz_est = sharded.mean_nnz_clamped(cfg.model.max_nnz);
    let active: Vec<usize> = vec![0, 1, 2, 3];
    let r = bench_fn("pool/plan_rebuild(4 devices)", 10, 2000, || {
        plan_for_strategy(&cfg, Strategy::Adaptive, &active, &batch_sizes, &plan_lrs, nnz_est)
    });
    println!("{r}");
    let subset: Vec<usize> = vec![0, 2];
    let r = bench_fn("pool/plan_rebuild(active subset 2/4)", 10, 2000, || {
        plan_for_strategy(&cfg, Strategy::Adaptive, &subset, &batch_sizes, &plan_lrs, nnz_est)
    });
    println!("{r}");

    let mut elastic_cfg = cfg.clone();
    elastic_cfg.elastic.straggler_factor = 2.0;
    elastic_cfg.elastic.events =
        vec!["at_mb=1 remove=1".to_string(), "at_mb=2 add=1".to_string()];
    elastic_cfg.validate().unwrap();
    let mut pool = DevicePool::new(&elastic_cfg).unwrap();
    let mut mb = 0usize;
    let r = bench_fn("pool/begin_mega_batch+active_ids", 10, 2000, || {
        // Cycle through remove/add mega-batches so events actually fire.
        let ev = pool.begin_mega_batch(mb % 3);
        let ids = pool.active_ids();
        mb += 1;
        (ev, ids)
    });
    println!("{r}");

    // ---- merge arithmetic ---------------------------------------------------
    let models: Vec<ModelState> = (0..4).map(|i| ModelState::init(&cfg.model, i)).collect();
    let refs: Vec<&ModelState> = models.iter().collect();
    let weights = [0.3, 0.3, 0.2, 0.2];
    let mut out = ModelState::zeros(&cfg.model);
    let cost = CostModel::default();
    let params = out.param_count();
    let r = bench_fn("allreduce/ring-merge(4 models)", 3, 50, || {
        heterosparse::allreduce::allreduce_merge(
            &mut out,
            &refs,
            &weights,
            heterosparse::allreduce::Algo::Ring,
            4,
            &cost,
        )
    });
    println!("{r}  ({:.1} Mparam/s)", r.throughput(params as f64) / 1e6);

    // ---- PJRT step/eval (needs artifacts) -----------------------------------
    match Runtime::load(std::path::Path::new(&cfg.runtime.artifacts_dir)) {
        Ok(rt) if rt.manifest.check_config(&cfg).is_ok() => {
            let mut model = ModelState::init(&cfg.model, 7);
            for bucket in [16usize, 64, 128] {
                let batch = batcher.next_batch(bucket, bucket);
                // Warm compile + caches.
                rt.step(&mut model, &batch, 0.01).unwrap();
                let r = bench_fn(&format!("pjrt/step(b={bucket})"), 3, 30, || {
                    rt.step(&mut model, &batch, 0.01).unwrap()
                });
                println!(
                    "{r}  ({:.1} ksamples/s)",
                    r.throughput(bucket as f64) / 1e3
                );
            }
            let eval_b = rt.manifest.eval_batch;
            let test = Generator::new(&cfg.model, &cfg.data).generate(eval_b, 2);
            let eb = heterosparse::data::batcher::EvalBatches::new(&test, &cfg.model, eval_b);
            rt.eval(&model, &eb.batches[0]).unwrap();
            let r = bench_fn(&format!("pjrt/eval(b={eval_b})"), 3, 30, || {
                rt.eval(&model, &eb.batches[0]).unwrap()
            });
            println!("{r}");
            println!(
                "\ncumulative PJRT exec time {} over {} calls",
                fmt_ns(rt.exec_time.borrow().as_nanos() as f64),
                rt.exec_count.borrow()
            );
        }
        _ => println!("\n(pjrt step/eval skipped: artifacts missing or mismatched — run `make artifacts`)"),
    }
}

/// Record a bench section to its baseline JSON (default path overridable
/// via `env_var`) so the trajectory accumulates across PRs. Existing runs
/// are preserved; this run is appended.
fn append_baseline(
    default_path: &str,
    env_var: &str,
    bench_label: &str,
    results: &[(String, BenchResult, f64)],
) {
    let path = std::env::var(env_var).unwrap_or_else(|_| default_path.to_string());
    let path = std::path::Path::new(&path);
    let mut runs: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        match Json::parse(&text) {
            Ok(j) => runs = j.get("runs").as_arr().map(|a| a.to_vec()).unwrap_or_default(),
            Err(e) => {
                // Never clobber an unparseable trajectory: park it aside
                // and start a fresh one.
                let bak = path.with_extension("json.bak");
                let _ = std::fs::copy(path, &bak);
                println!(
                    "(existing {} unparseable ({e}); preserved at {})",
                    path.display(),
                    bak.display()
                );
            }
        }
    }
    runs.push(Json::obj(vec![
        (
            "results",
            Json::arr(results.iter().map(|(key, r, per_sec)| {
                Json::obj(vec![
                    ("name", Json::str(key.clone())),
                    ("median_ns", Json::num(r.median_ns)),
                    ("p10_ns", Json::num(r.p10_ns)),
                    ("p90_ns", Json::num(r.p90_ns)),
                    ("per_sec", Json::num(*per_sec)),
                ])
            })),
        ),
    ]));
    let doc = Json::obj(vec![
        ("bench", Json::str(bench_label)),
        ("schema", Json::str("runs[].results[]{name,median_ns,p10_ns,p90_ns,per_sec}")),
        ("runs", Json::arr(runs)),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("\n{bench_label} baseline appended to {}", path.display()),
        Err(e) => println!("\n(could not write {}: {e})", path.display()),
    }
}

//! §Perf — hot-path micro-benchmarks (EXPERIMENTS.md §Perf feeds from here).
//!
//! Measures, on the real PJRT path when artifacts exist:
//!   * per-bucket step latency (upload + execute + download),
//!   * eval latency,
//!   * merge arithmetic (weighted all-reduce) across model sizes,
//!   * batcher assembly,
//!   * Algorithm 1 + Algorithm 2 overhead (must be negligible vs a step).

use heterosparse::config::{Config, MergeConfig};
use heterosparse::coordinator::{merge, scaling};
use heterosparse::data::batcher::Batcher;
use heterosparse::data::synthetic::Generator;
use heterosparse::model::ModelState;
use heterosparse::runtime::{CostModel, Runtime};
use heterosparse::util::bench::{bench_fn, fmt_ns};

fn main() {
    let cfg = Config::default();
    let (train, _) = {
        let gen = Generator::new(&cfg.model, &cfg.data);
        (gen.generate(4_000, 1), ())
    };
    let mut batcher = Batcher::new(&train, &cfg.model, 1);

    // ---- batcher ----------------------------------------------------------
    let r = bench_fn("batcher/next_batch(b=128)", 10, 200, || batcher.next_batch(128, 128));
    println!("{r}");

    // ---- coordinator algorithms -------------------------------------------
    let mut b = vec![128usize, 96, 72, 48];
    let mut lrs = vec![0.05f32; 4];
    let r = bench_fn("alg1/rescale(4 devices)", 10, 1000, || {
        scaling::rescale(&mut b, &mut lrs, &[12, 10, 9, 8], &cfg.sgd)
    });
    println!("{r}");

    let l2s = vec![0.01f64; 4];
    let r = bench_fn("alg2/compute_weights(4 devices)", 10, 1000, || {
        merge::compute_weights(&[12, 10, 9, 8], &[128, 96, 72, 48], &l2s, &MergeConfig::default())
    });
    println!("{r}");

    // ---- merge arithmetic ---------------------------------------------------
    let models: Vec<ModelState> = (0..4).map(|i| ModelState::init(&cfg.model, i)).collect();
    let refs: Vec<&ModelState> = models.iter().collect();
    let weights = [0.3, 0.3, 0.2, 0.2];
    let mut out = ModelState::zeros(&cfg.model);
    let cost = CostModel::default();
    let params = out.param_count();
    let r = bench_fn("allreduce/ring-merge(4 models)", 3, 50, || {
        heterosparse::allreduce::allreduce_merge(
            &mut out,
            &refs,
            &weights,
            heterosparse::allreduce::Algo::Ring,
            4,
            &cost,
        )
    });
    println!("{r}  ({:.1} Mparam/s)", r.throughput(params as f64) / 1e6);

    // ---- PJRT step/eval (needs artifacts) -----------------------------------
    match Runtime::load(std::path::Path::new(&cfg.runtime.artifacts_dir)) {
        Ok(rt) if rt.manifest.check_config(&cfg).is_ok() => {
            let mut model = ModelState::init(&cfg.model, 7);
            for bucket in [16usize, 64, 128] {
                let batch = batcher.next_batch(bucket, bucket);
                // Warm compile + caches.
                rt.step(&mut model, &batch, 0.01).unwrap();
                let r = bench_fn(&format!("pjrt/step(b={bucket})"), 3, 30, || {
                    rt.step(&mut model, &batch, 0.01).unwrap()
                });
                println!(
                    "{r}  ({:.1} ksamples/s)",
                    r.throughput(bucket as f64) / 1e3
                );
            }
            let eval_b = rt.manifest.eval_batch;
            let test = Generator::new(&cfg.model, &cfg.data).generate(eval_b, 2);
            let eb = heterosparse::data::batcher::EvalBatches::new(&test, &cfg.model, eval_b);
            rt.eval(&model, &eb.batches[0]).unwrap();
            let r = bench_fn(&format!("pjrt/eval(b={eval_b})"), 3, 30, || {
                rt.eval(&model, &eb.batches[0]).unwrap()
            });
            println!("{r}");
            println!(
                "\ncumulative PJRT exec time {} over {} calls",
                fmt_ns(rt.exec_time.borrow().as_nanos() as f64),
                rt.exec_count.borrow()
            );
        }
        _ => println!("\n(pjrt step/eval skipped: artifacts missing or mismatched — run `make artifacts`)"),
    }
}

//! §Perf — hot-path micro-benchmarks (EXPERIMENTS.md §Perf feeds from here).
//!
//! Measures, on the real PJRT path when artifacts exist:
//!   * per-bucket step latency (upload + execute + download),
//!   * eval latency,
//!   * merge arithmetic (weighted all-reduce) across model sizes,
//!   * batcher assembly,
//!   * Algorithm 1 + Algorithm 2 overhead (must be negligible vs a step),
//!   * dispatch-plan recomputation + pool-event processing (the per-
//!     mega-batch overhead the elastic pool adds to the hot path).

use heterosparse::config::{Config, MergeConfig, Strategy};
use heterosparse::coordinator::{merge, plan_for_strategy, scaling, DevicePool};
use heterosparse::data::batcher::Batcher;
use heterosparse::data::synthetic::Generator;
use heterosparse::model::ModelState;
use heterosparse::runtime::{CostModel, Runtime};
use heterosparse::util::bench::{bench_fn, fmt_ns};

fn main() {
    let cfg = Config::default();
    let (train, _) = {
        let gen = Generator::new(&cfg.model, &cfg.data);
        (gen.generate(4_000, 1), ())
    };
    let mut batcher = Batcher::new(&train, &cfg.model, 1);

    // ---- batcher ----------------------------------------------------------
    let r = bench_fn("batcher/next_batch(b=128)", 10, 200, || batcher.next_batch(128, 128));
    println!("{r}");

    // ---- coordinator algorithms -------------------------------------------
    let mut b = vec![128usize, 96, 72, 48];
    let mut lrs = vec![0.05f32; 4];
    let r = bench_fn("alg1/rescale(4 devices)", 10, 1000, || {
        scaling::rescale(&mut b, &mut lrs, &[12, 10, 9, 8], &cfg.sgd)
    });
    println!("{r}");

    let l2s = vec![0.01f64; 4];
    let r = bench_fn("alg2/compute_weights(4 devices)", 10, 1000, || {
        merge::compute_weights(&[12, 10, 9, 8], &[128, 96, 72, 48], &l2s, &MergeConfig::default())
    });
    println!("{r}");

    // ---- elastic pool: plan recomputation + event processing ---------------
    // Every mega-batch rebuilds the dispatch plan over the current active
    // subset; pool events make the subset change. Both must stay negligible
    // next to a step (hundreds of µs).
    let batch_sizes = vec![128usize, 96, 72, 48];
    let plan_lrs = vec![0.05f32, 0.04, 0.03, 0.02];
    let active: Vec<usize> = vec![0, 1, 2, 3];
    let r = bench_fn("pool/plan_rebuild(4 devices)", 10, 2000, || {
        plan_for_strategy(&cfg, Strategy::Adaptive, &active, &batch_sizes, &plan_lrs)
    });
    println!("{r}");
    let subset: Vec<usize> = vec![0, 2];
    let r = bench_fn("pool/plan_rebuild(active subset 2/4)", 10, 2000, || {
        plan_for_strategy(&cfg, Strategy::Adaptive, &subset, &batch_sizes, &plan_lrs)
    });
    println!("{r}");

    let mut elastic_cfg = cfg.clone();
    elastic_cfg.elastic.straggler_factor = 2.0;
    elastic_cfg.elastic.events =
        vec!["at_mb=1 remove=1".to_string(), "at_mb=2 add=1".to_string()];
    elastic_cfg.validate().unwrap();
    let mut pool = DevicePool::new(&elastic_cfg).unwrap();
    let mut mb = 0usize;
    let r = bench_fn("pool/begin_mega_batch+active_ids", 10, 2000, || {
        // Cycle through remove/add mega-batches so events actually fire.
        let ev = pool.begin_mega_batch(mb % 3);
        let ids = pool.active_ids();
        mb += 1;
        (ev, ids)
    });
    println!("{r}");

    // ---- merge arithmetic ---------------------------------------------------
    let models: Vec<ModelState> = (0..4).map(|i| ModelState::init(&cfg.model, i)).collect();
    let refs: Vec<&ModelState> = models.iter().collect();
    let weights = [0.3, 0.3, 0.2, 0.2];
    let mut out = ModelState::zeros(&cfg.model);
    let cost = CostModel::default();
    let params = out.param_count();
    let r = bench_fn("allreduce/ring-merge(4 models)", 3, 50, || {
        heterosparse::allreduce::allreduce_merge(
            &mut out,
            &refs,
            &weights,
            heterosparse::allreduce::Algo::Ring,
            4,
            &cost,
        )
    });
    println!("{r}  ({:.1} Mparam/s)", r.throughput(params as f64) / 1e6);

    // ---- PJRT step/eval (needs artifacts) -----------------------------------
    match Runtime::load(std::path::Path::new(&cfg.runtime.artifacts_dir)) {
        Ok(rt) if rt.manifest.check_config(&cfg).is_ok() => {
            let mut model = ModelState::init(&cfg.model, 7);
            for bucket in [16usize, 64, 128] {
                let batch = batcher.next_batch(bucket, bucket);
                // Warm compile + caches.
                rt.step(&mut model, &batch, 0.01).unwrap();
                let r = bench_fn(&format!("pjrt/step(b={bucket})"), 3, 30, || {
                    rt.step(&mut model, &batch, 0.01).unwrap()
                });
                println!(
                    "{r}  ({:.1} ksamples/s)",
                    r.throughput(bucket as f64) / 1e3
                );
            }
            let eval_b = rt.manifest.eval_batch;
            let test = Generator::new(&cfg.model, &cfg.data).generate(eval_b, 2);
            let eb = heterosparse::data::batcher::EvalBatches::new(&test, &cfg.model, eval_b);
            rt.eval(&model, &eb.batches[0]).unwrap();
            let r = bench_fn(&format!("pjrt/eval(b={eval_b})"), 3, 30, || {
                rt.eval(&model, &eb.batches[0]).unwrap()
            });
            println!("{r}");
            println!(
                "\ncumulative PJRT exec time {} over {} calls",
                fmt_ns(rt.exec_time.borrow().as_nanos() as f64),
                rt.exec_count.borrow()
            );
        }
        _ => println!("\n(pjrt step/eval skipped: artifacts missing or mismatched — run `make artifacts`)"),
    }
}

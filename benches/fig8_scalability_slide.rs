//! Fig. 8 — Adaptive SGD scalability (1/2/4 devices) vs the SLIDE CPU
//! baseline.
//!
//! Shape to reproduce: more devices → faster time-to-accuracy and at least
//! as good accuracy; SLIDE performs many more model updates (superior
//! statistical efficiency) yet its wall-clock accuracy stays behind the
//! accelerator runs.

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn main() {
    let out = experiments::fig8(DataProfile::Amazon, Backend::Auto).expect("fig8 failed");
    let target = experiments::common_target(&out.gpu_logs);
    let tta = |name: &str| {
        out.gpu_logs
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, l)| l.time_to_accuracy(target))
    };
    if let (Some(t1), Some(t4)) = (tta("adaptive-1gpu"), tta("adaptive-4gpu")) {
        println!("\nTTA 1gpu {t1:.3}s vs 4gpu {t4:.3}s");
        if t4 > t1 {
            eprintln!("WARN: 4 devices did not beat 1 device on TTA");
        }
    }
    // SLIDE's statistical efficiency: far more updates than the GPU runs.
    let gpu_updates: u64 = out
        .gpu_logs
        .iter()
        .find(|(n, _)| n == "adaptive-4gpu")
        .map(|(_, l)| l.rows.iter().map(|r| r.updates.iter().sum::<u64>()).sum())
        .unwrap_or(0);
    println!("SLIDE updates {} vs adaptive-4gpu updates {}", out.slide_updates, gpu_updates);
    assert!(
        out.slide_updates > gpu_updates,
        "SLIDE (per-sample SGD) must perform more model updates"
    );
}

//! Table 1 — synthetic dataset profiles vs the paper's shape statistics.
//!
//! Paper: Amazon-670k (76 avg features, 5 avg labels), Delicious-200k
//! (302 avg features, 75 avg labels). Our profiles reproduce the *relative*
//! shape (Delicious denser in both features and labels) at reduced scale;
//! absolute targets come from the config and are asserted within tolerance.

fn main() {
    let rows = heterosparse::harness::experiments::table1().expect("table1 failed");
    let amazon = &rows[0];
    let delicious = &rows[1];
    assert!(
        (amazon.avg_nnz - amazon.target_nnz).abs() / amazon.target_nnz < 0.2,
        "amazon avg nnz off target"
    );
    assert!(
        (delicious.avg_nnz - delicious.target_nnz).abs() / delicious.target_nnz < 0.2,
        "delicious avg nnz off target"
    );
    // The paper's relative shape: Delicious is denser in features and labels.
    assert!(delicious.avg_nnz > amazon.avg_nnz);
    assert!(delicious.avg_labels > amazon.avg_labels);
    println!("\nshape check OK: delicious denser than amazon in features and labels (as in Table 1)");
}

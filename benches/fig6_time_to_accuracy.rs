//! Fig. 6 — time-to-accuracy: Adaptive vs Elastic vs CROSSBOW vs sync
//! gradient aggregation (TensorFlow analog), on 1/2/4 devices × 2 profiles.
//!
//! Shape to reproduce: Adaptive reaches the highest accuracy fastest on all
//! configurations; the synchronous TF analog is far slower; CROSSBOW is the
//! most variable.

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn check(profile: DataProfile) {
    let logs = experiments::fig6(profile, Backend::Auto).expect("fig6 failed");
    let target = experiments::common_target(&logs);

    // Adaptive-4gpu must achieve the best accuracy of the cohort (within
    // noise) and reach the common target at least as fast as the other
    // 4-gpu strategies.
    let best_overall = logs.iter().map(|(_, l)| l.best_accuracy()).fold(0.0, f64::max);
    let adaptive4 = logs.iter().find(|(n, _)| n == "adaptive-4gpu").unwrap();
    if adaptive4.1.best_accuracy() < best_overall - 0.02 {
        eprintln!(
            "WARN[{}]: adaptive-4gpu best {:.4} below cohort best {:.4}",
            profile.name(),
            adaptive4.1.best_accuracy(),
            best_overall
        );
    }
    let tta = |name: &str| {
        logs.iter().find(|(n, _)| n == name).and_then(|(_, l)| l.time_to_accuracy(target))
    };
    let a = tta("adaptive-4gpu");
    for rival in ["elastic-4gpu", "sync-4gpu", "crossbow-4gpu"] {
        match (a, tta(rival)) {
            (Some(a), Some(r)) if a > r * 1.1 => {
                eprintln!("WARN[{}]: adaptive TTA {a:.3}s slower than {rival} {r:.3}s", profile.name())
            }
            (None, Some(_)) => eprintln!("WARN[{}]: adaptive missed target, {rival} hit it", profile.name()),
            _ => {}
        }
    }
}

fn main() {
    check(DataProfile::Amazon);
    check(DataProfile::Delicious);
    println!("\nfig6 complete (see tables above; WARN lines flag shape deviations)");
}

//! Fig. 11 — perturbation threshold (a) and perturbation factor δ (b).
//!
//! Shape to reproduce: threshold effects are dataset-dependent with 0.10 a
//! robust middle; δ variants differ only slightly (only two replica weights
//! are modified).

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn main() {
    for profile in [DataProfile::Amazon, DataProfile::Delicious] {
        let a = experiments::fig11a(profile, Backend::Auto).expect("fig11a failed");
        // Higher threshold must not reduce activation frequency.
        let freq = |name: &str| {
            a.iter().find(|(n, _)| n == name).map(|(_, l)| l.perturbation_frequency()).unwrap_or(0.0)
        };
        let (lo, hi) = (freq("thr=0.05"), freq("thr=0.15"));
        println!("\n[{}] perturbation freq: thr=0.05 {:.2} vs thr=0.15 {:.2}", profile.name(), lo, hi);
        assert!(hi >= lo, "higher threshold cannot perturb less often");

        let b = experiments::fig11b(profile, Backend::Auto).expect("fig11b failed");
        let spread = {
            let best: Vec<f64> = b.iter().map(|(_, l)| l.best_accuracy()).collect();
            best.iter().copied().fold(0.0, f64::max) - best.iter().copied().fold(1.0, f64::min)
        };
        println!("[{}] δ sweep best-accuracy spread: {:.4} (paper: small)", profile.name(), spread);
    }
}

//! Fig. 9 — effect of the mega-batch size (model-merging frequency).
//!
//! Shape to reproduce: merging after only 4 batches (≈ gradient aggregation)
//! underperforms; 20+ works well; large mega-batches (100) still reach the
//! best accuracy while merging far less often.

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};
use heterosparse::metrics::RunLog;

/// Fraction of total clock spent inside model merges.
fn merge_overhead(log: &RunLog) -> f64 {
    let merge: f64 = log.rows.iter().map(|r| r.merge_time).sum();
    let clock = log.rows.last().map(|r| r.clock).unwrap_or(1.0);
    merge / clock
}

fn main() {
    for profile in [DataProfile::Amazon, DataProfile::Delicious] {
        let logs = experiments::fig9(profile, Backend::Auto).expect("fig9 failed");
        let get = |name: &str| logs.iter().find(|(n, _)| n == name).map(|(_, l)| l).unwrap();
        let (m4, m20, m100) = (get("mega=4"), get("mega=20"), get("mega=100"));

        // Reproduced claim: merging overhead is inversely proportional to the
        // mega-batch size — frequent merging (≈ gradient aggregation) burns a
        // large share of the clock at the barrier.
        let (o4, o20, o100) = (merge_overhead(m4), merge_overhead(m20), merge_overhead(m100));
        println!(
            "\n[{}] merge-overhead share of clock: mega=4 {:.1}%, mega=20 {:.1}%, mega=100 {:.1}%",
            profile.name(),
            o4 * 100.0,
            o20 * 100.0,
            o100 * 100.0
        );
        assert!(o4 > o20 && o20 > o100, "merge overhead must fall with mega-batch size");

        // Known deviation (EXPERIMENTS.md): at our reduced scale the
        // statistical benefit of frequent averaging outweighs the exploration
        // effect that makes mega=4 lose accuracy in the paper; we report the
        // accuracies and flag if the paper's ordering is not met.
        println!(
            "[{}] best P@1: mega=4 {:.4}, mega=20 {:.4}, mega=100 {:.4}",
            profile.name(),
            m4.best_accuracy(),
            m20.best_accuracy(),
            m100.best_accuracy()
        );
        if m20.best_accuracy().max(m100.best_accuracy()) < m4.best_accuracy() {
            eprintln!(
                "WARN[{}]: accuracy ordering deviates from the paper (documented in EXPERIMENTS.md §F9)",
                profile.name()
            );
        }
    }
}

//! Fig. 7 — statistical efficiency: accuracy vs number of mega-batches.
//!
//! Shape to reproduce: Adaptive needs the fewest mega-batches to its best
//! accuracy; the TF analog completes far fewer mega-batches in equal time
//! (here visible through its much larger clock per mega-batch).

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn main() {
    for profile in [DataProfile::Amazon, DataProfile::Delicious] {
        let logs = experiments::fig7(profile, Backend::Auto).expect("fig7 failed");
        // TF-analog hardware inefficiency: clock per mega-batch must exceed
        // adaptive's (it merges every round + framework overhead).
        let per_mb = |name: &str| {
            logs.iter()
                .find(|(n, _)| n == name)
                .and_then(|(_, l)| l.rows.last().map(|r| r.clock / l.rows.len() as f64))
        };
        if let (Some(sync), Some(adaptive)) = (per_mb("sync-4gpu"), per_mb("adaptive-4gpu")) {
            println!(
                "\nclock per mega-batch (4gpu, {}): sync {:.3}s vs adaptive {:.3}s",
                profile.name(),
                sync,
                adaptive
            );
            assert!(
                sync > adaptive,
                "sync gradient aggregation should cost more clock per mega-batch"
            );
        }
    }
}

//! Fig. 10 — initial batch size (a) and batch-size scaling factor β (b).
//!
//! Shape to reproduce: starting from b_max gives the fastest early accuracy
//! (smaller starts pay pure overhead); β variants differ only slightly with
//! a small edge to larger values.

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn main() {
    for profile in [DataProfile::Amazon, DataProfile::Delicious] {
        let a = experiments::fig10a(profile, Backend::Auto).expect("fig10a failed");
        // Early accuracy (first third of the run) should favor b0 = b_max.
        let early = |name: &str| {
            a.iter()
                .find(|(n, _)| n == name)
                .map(|(_, l)| {
                    let k = (l.rows.len() / 3).max(1);
                    l.rows[..k].iter().map(|r| r.accuracy).fold(0.0, f64::max)
                })
                .unwrap_or(0.0)
        };
        let (small, large) = (early("b0=16"), early("b0=128"));
        println!("\n[{}] early-phase best P@1: b0=16 {:.4} vs b0=128 {:.4}", profile.name(), small, large);
        if large < small {
            eprintln!("WARN[{}]: large initial batch should lead early", profile.name());
        }

        experiments::fig10b(profile, Backend::Auto).expect("fig10b failed");
    }
}

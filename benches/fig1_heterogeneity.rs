//! Fig. 1 — heterogeneity across devices on an identical batch.
//!
//! Paper: up to 32% gap between fastest and slowest of four identical V100s
//! on the same training batch. The simulated fleet is calibrated to that
//! gap; this bench verifies the epoch-time spread lands in the same range.

fn main() {
    let times = heterosparse::harness::experiments::fig1().expect("fig1 failed");
    let fastest = times.iter().copied().fold(f64::INFINITY, f64::min);
    let slowest = times.iter().copied().fold(0.0f64, f64::max);
    let gap = slowest / fastest - 1.0;
    println!("\nfastest↔slowest gap: {:.1}% (paper: ~32%)", gap * 100.0);
    assert!(
        (0.20..0.45).contains(&gap),
        "heterogeneity gap {gap} outside the paper's observed range"
    );
}

//! Fig. 12 — do batch-size scaling (a) and merge perturbation (b) actually
//! activate during training?
//!
//! Shape to reproduce: batch sizes start at b_max, fan out per device speed,
//! then stabilize; perturbation activates at a very high frequency once the
//! replicas are regularized.

use heterosparse::config::DataProfile;
use heterosparse::harness::{experiments, Backend};

fn main() {
    let log = experiments::fig12(DataProfile::Amazon, Backend::Auto).expect("fig12 failed");

    // (a) batch sizes must have differentiated at some point.
    let differentiated = log
        .rows
        .iter()
        .any(|r| r.batch_sizes.iter().any(|&b| b != r.batch_sizes[0]));
    assert!(differentiated, "batch size scaling never activated");

    // (b) perturbation fires frequently.
    let freq = log.perturbation_frequency();
    println!("\nbatch scaling activated: {differentiated}; perturbation frequency: {freq:.2}");
    if freq < 0.5 {
        eprintln!("WARN: perturbation frequency {freq:.2} lower than the paper's 'very high'");
    }
}

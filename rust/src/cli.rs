//! Command-line interface (in-tree arg parsing; clap is unavailable
//! offline). Subcommands:
//!
//! ```text
//! heterosparse train       [--config FILE] [--set k=v]... [--out DIR] [--verbose]
//! heterosparse gen-data    --out FILE [--set k=v]...
//! heterosparse experiment  NAME [--profile amazon|delicious] [--backend auto|pjrt|ref]
//! heterosparse calibrate   [--set k=v]...
//! heterosparse info        [--set k=v]...
//! heterosparse trace-check FILE
//! heterosparse report      FILE [--strict] [--top K] [--explain PAT] [--out FILE]
//! heterosparse report      --diff BASELINE CANDIDATE [--strict]
//! ```
//!
//! `train` and `experiment` accept `--trace out.json` to export a
//! Chrome-trace (Perfetto) timeline of the run; `trace-check` validates
//! such a file against the minimal trace_event schema (used by CI).
//! `report` analyzes a trace (or RunLog JSON) into a deterministic
//! markdown run report — lane attribution, critical path, decision
//! audit — and `report --diff` compares two such inputs against fixed
//! regression thresholds, exiting non-zero on regression (the CI gate).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::config::{CompositionPolicy, Config, DataProfile};
use crate::coordinator::trainer::TrainerOptions;
use crate::harness::{self, experiments, Backend};
use crate::Result;

pub fn main_with_args(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "gen-data" => cmd_gen_data(rest),
        "experiment" => cmd_experiment(rest),
        "calibrate" => cmd_calibrate(rest),
        "info" => cmd_info(rest),
        "trace-check" => cmd_trace_check(rest),
        "report" => cmd_report(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'heterosparse help')"),
    }
}

fn print_usage() {
    // The experiment list is generated from the registry in
    // `harness::experiments::EXPERIMENTS`, so it cannot drift from the
    // implementations again.
    let experiment_lines: String = experiments::EXPERIMENTS
        .iter()
        .map(|e| format!("\x20   {:<12} {}\n", e.name, e.about))
        .collect();
    println!(
        "heterosparse — adaptive elastic SGD for sparse deep learning on \
         heterogeneous multi-accelerator servers\n\n\
         USAGE:\n  heterosparse <COMMAND> [OPTIONS]\n\n\
         COMMANDS:\n\
         \x20 train        run one training session (strategy from config)\n\
         \x20 gen-data     write a synthetic XML dataset in libSVM format\n\
         \x20 experiment   regenerate a paper table/figure or run a study:\n\
         {experiment_lines}\
         \x20 calibrate    fit the cost model against live PJRT measurements\n\
         \x20 info         print resolved config + artifact status\n\
         \x20 trace-check  validate a --trace export against the trace_event schema\n\
         \x20 report       analyze a trace/RunLog JSON into a markdown run report\n\
         \x20              (lane attribution, critical path, decision audit);\n\
         \x20              report --diff A B gates on regressions (non-zero exit)\n\n\
         OPTIONS:\n\
         \x20 --config FILE      TOML config file\n\
         \x20 -c key=value       dotted-path config override layered over the\n\
         \x20                    TOML, e.g. -c sgd.b_max=256 (repeatable; typed\n\
         \x20                    values, unknown keys rejected; --set is an alias)\n\
         \x20 --seed S           fuzz: run seed (default 7)\n\
         \x20 --runs N           fuzz: generated cases (default 100)\n\
         \x20 --subsystems LIST  fuzz: comma list of invariant groups —\n\
         \x20                    train|data|serve|fleet|cluster|all (default all)\n\
         \x20 --out PATH         output file/directory\n\
         \x20 --backend KIND     auto | pjrt | ref\n\
         \x20 --profile NAME     amazon | delicious\n\
         \x20 --checkpoint PATH  save the global model after every mega-batch\n\
         \x20 --resume PATH      initialize from a saved checkpoint\n\
         \x20 --elastic EVENT    scripted pool event, e.g. \"at_mb=20 remove=2\"\n\
         \x20                    (repeatable; appends to [elastic] events)\n\
         \x20 --data-policy P    batch composition policy: shuffled |\n\
         \x20                    nnz_balanced | nnz_sorted (see [data.pipeline])\n\
         \x20 --trace PATH       export a Chrome-trace (Perfetto) timeline of the\n\
         \x20                    run (implies [obs] collection; load in\n\
         \x20                    ui.perfetto.dev)\n\
         \x20 --strict           report: fail (exit 1) on truncation warnings\n\
         \x20 --top K            report: critical-path table size (default 8)\n\
         \x20 --explain PAT      report: print only decisions matching PAT\n\
         \x20 --diff             report: compare two inputs (baseline candidate)\n\
         \x20 --verbose          progress output"
    );
}

/// Shared flag parsing: returns (config, out, backend, profile, verbose).
struct Parsed {
    cfg: Config,
    /// Whether `--config` or any `--set` was given (some experiments build
    /// their own scaled-down config only when the user supplied neither —
    /// explicit config input must never be silently discarded).
    had_config: bool,
    out: Option<PathBuf>,
    backend: Backend,
    profile: DataProfile,
    verbose: bool,
    checkpoint: Option<PathBuf>,
    resume: Option<PathBuf>,
    /// `--trace PATH`: export a Chrome-trace timeline after the run.
    trace: Option<PathBuf>,
    /// `report --strict`: truncation warnings become errors.
    strict: bool,
    /// `report --top K`: critical-path table size.
    top: Option<usize>,
    /// `report --explain PAT`: filter the decision audit.
    explain: Option<String>,
    /// `report --diff`: compare two inputs.
    diff: bool,
    /// `experiment fuzz --seed S`: fuzzer run seed.
    seed: Option<u64>,
    /// `experiment fuzz --runs N`: fuzzer case count.
    runs: Option<usize>,
    /// `experiment fuzz --subsystems LIST`: invariant groups to drive.
    subsystems: Option<crate::scenario::fuzz::Subsystems>,
    positional: Vec<String>,
}

impl Parsed {
    /// Build the obs handle from `[obs]` + `--trace` and install it as
    /// the process ambient, so `TrainerOptions::default()` and the
    /// experiment entry points pick it up without signature churn.
    /// Returns the handle for the final trace export.
    fn install_obs(&self) -> crate::obs::ObsHandle {
        let handle = crate::obs::ObsHandle::from_config(&self.cfg.obs, self.trace.is_some());
        crate::obs::install_ambient(handle.clone());
        handle
    }

    /// Write the collected trace (spans + registry counter tracks) if
    /// `--trace` was given, warning loudly when the ring truncated.
    fn export_trace(&self, obs: &crate::obs::ObsHandle) -> Result<()> {
        let Some(path) = &self.trace else { return Ok(()) };
        let path = path.to_string_lossy();
        crate::obs::chrome::write_trace_with_registry(obs.sink(), obs.registry(), &path)?;
        println!("wrote trace: {path} ({} events)", obs.sink().events().len());
        if obs.sink().dropped() > 0 {
            eprintln!(
                "warning: trace ring dropped {} events — raise [obs] buffer_events",
                obs.sink().dropped()
            );
        }
        let (opened, closed) = obs.sink().balance();
        if opened != closed {
            eprintln!("warning: span imbalance — {opened} opened vs {closed} closed");
        }
        Ok(())
    }
}

fn parse_flags(args: &[String]) -> Result<Parsed> {
    let mut config_path: Option<PathBuf> = None;
    let mut overrides: Vec<(String, String)> = Vec::new();
    let mut out = None;
    let mut backend = Backend::Auto;
    let mut profile = DataProfile::Amazon;
    let mut verbose = false;
    let mut checkpoint = None;
    let mut resume = None;
    let mut elastic_events: Vec<String> = Vec::new();
    let mut data_policy: Option<CompositionPolicy> = None;
    let mut trace = None;
    let mut strict = false;
    let mut top = None;
    let mut explain = None;
    let mut diff = false;
    let mut seed = None;
    let mut runs = None;
    let mut subsystems = None;
    let mut positional = Vec::new();

    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--config" => {
                config_path =
                    Some(PathBuf::from(it.next().context("--config needs a value")?))
            }
            "-c" | "--set" => {
                let kv = it.next().with_context(|| format!("{arg} needs key=value"))?;
                let (k, v) = kv.split_once('=').with_context(|| {
                    format!("{arg} expects key=value (dotted path, like sgd.b_max=256)")
                })?;
                overrides.push((k.to_string(), v.to_string()));
            }
            "--out" => out = Some(PathBuf::from(it.next().context("--out needs a value")?)),
            "--backend" => {
                backend = match it.next().context("--backend needs a value")?.as_str() {
                    "auto" => Backend::Auto,
                    "pjrt" => Backend::Pjrt,
                    "ref" | "reference" => Backend::Reference,
                    other => bail!("unknown backend '{other}'"),
                }
            }
            "--profile" => {
                profile = DataProfile::parse(it.next().context("--profile needs a value")?)?
            }
            "--checkpoint" => {
                checkpoint = Some(PathBuf::from(it.next().context("--checkpoint needs a value")?))
            }
            "--resume" => {
                resume = Some(PathBuf::from(it.next().context("--resume needs a value")?))
            }
            "--elastic" => {
                elastic_events.push(it.next().context("--elastic needs an event string")?.clone())
            }
            "--data-policy" => {
                let v = it.next().context("--data-policy needs a value")?;
                data_policy = Some(CompositionPolicy::parse(v)?)
            }
            "--trace" => {
                trace = Some(PathBuf::from(it.next().context("--trace needs a value")?))
            }
            "--seed" => {
                seed = Some(
                    it.next()
                        .context("--seed needs a value")?
                        .parse::<u64>()
                        .context("--seed expects an integer")?,
                )
            }
            "--runs" => {
                runs = Some(
                    it.next()
                        .context("--runs needs a value")?
                        .parse::<usize>()
                        .context("--runs expects an integer")?,
                )
            }
            "--subsystems" => {
                subsystems = Some(
                    crate::scenario::fuzz::Subsystems::parse(
                        it.next().context("--subsystems needs a comma list")?,
                    )?,
                )
            }
            "--strict" => strict = true,
            "--top" => {
                top = Some(
                    it.next()
                        .context("--top needs a value")?
                        .parse::<usize>()
                        .context("--top expects an integer")?,
                )
            }
            "--explain" => {
                explain = Some(it.next().context("--explain needs a pattern")?.clone())
            }
            "--diff" => diff = true,
            "--verbose" | "-v" => verbose = true,
            other if other.starts_with("--") => bail!("unknown flag '{other}'"),
            other => positional.push(other.to_string()),
        }
    }
    let had_config = config_path.is_some()
        || !overrides.is_empty()
        || data_policy.is_some()
        || !elastic_events.is_empty();
    let mut cfg = match config_path {
        Some(p) => Config::load(&p, &overrides)?,
        None => Config::from_overrides(&overrides)?,
    };
    if !elastic_events.is_empty() {
        cfg.elastic.events.extend(elastic_events);
        cfg.validate()?;
    }
    if let Some(policy) = data_policy {
        cfg.data.pipeline.policy = policy;
    }
    Ok(Parsed {
        cfg,
        had_config,
        out,
        backend,
        profile,
        verbose,
        checkpoint,
        resume,
        trace,
        strict,
        top,
        explain,
        diff,
        seed,
        runs,
        subsystems,
        positional,
    })
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let obs = p.install_obs();
    let init_model = match &p.resume {
        Some(path) => Some(crate::model::checkpoint::load(path)?),
        None => None,
    };
    let opts = TrainerOptions {
        verbose: p.verbose,
        checkpoint: p.checkpoint.clone(),
        init_model,
        ..Default::default()
    };
    println!(
        "training: strategy={} devices={} mode={:?} model={}param",
        p.cfg.strategy.kind.name(),
        p.cfg.devices.count,
        p.cfg.runtime.mode,
        p.cfg.model.param_count()
    );
    let log = harness::run_single(&p.cfg, p.backend, opts)?;
    println!(
        "done: {} mega-batches, best P@1 {:.4}, final clock {:.2}s",
        log.rows.len(),
        log.best_accuracy(),
        log.rows.last().map(|r| r.clock).unwrap_or(0.0)
    );
    if let Some(out) = p.out {
        std::fs::create_dir_all(&out)?;
        log.write_csv(&out.join(format!("{}.csv", log.name)))?;
        log.write_json(&out.join(format!("{}.json", log.name)))?;
        println!("wrote {}/{}.csv", out.display(), log.name);
    }
    p.export_trace(&obs)?;
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let out = p.out.context("gen-data requires --out FILE")?;
    let (train, test) = harness::make_data(&p.cfg);
    crate::data::libsvm::write(&out, &train)?;
    let test_path = out.with_extension("test.txt");
    crate::data::libsvm::write(&test_path, &test)?;
    println!(
        "wrote {} ({} samples, avg nnz {:.1}) and {} ({} samples)",
        out.display(),
        train.len(),
        train.avg_nnz(),
        test_path.display(),
        test.len()
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let name = p.positional.first().with_context(|| {
        format!("experiment name required: {}", experiments::experiment_names().join(" "))
    })?;
    if !experiments::is_experiment(name) {
        bail!(
            "unknown experiment '{name}' (registered: {})",
            experiments::experiment_names().join(" ")
        );
    }
    let obs = p.install_obs();
    match name.as_str() {
        "table1" => {
            experiments::table1()?;
        }
        "fig1" => {
            experiments::fig1()?;
        }
        "fig6" => {
            experiments::fig6(p.profile, p.backend)?;
        }
        "fig7" => {
            experiments::fig7(p.profile, p.backend)?;
        }
        "fig8" => {
            experiments::fig8(p.profile, p.backend)?;
        }
        "fig9" => {
            experiments::fig9(p.profile, p.backend)?;
        }
        "fig10a" => {
            experiments::fig10a(p.profile, p.backend)?;
        }
        "fig10b" => {
            experiments::fig10b(p.profile, p.backend)?;
        }
        "fig11a" => {
            experiments::fig11a(p.profile, p.backend)?;
        }
        "fig11b" => {
            experiments::fig11b(p.profile, p.backend)?;
        }
        "fig12" => {
            experiments::fig12(p.profile, p.backend)?;
        }
        "elastic" => {
            experiments::elastic(p.profile, p.backend)?;
        }
        "pipeline" => {
            experiments::pipeline(p.profile, p.backend)?;
        }
        "serve" => {
            experiments::serve(p.profile, p.backend, p.resume.as_deref())?;
        }
        "fleet" => {
            // With --config or --set the co-schedule runs exactly that
            // fleet; bare invocations get the bench-scale burst-overload
            // scenario.
            let base = p.had_config.then_some(&p.cfg);
            experiments::fleet(p.profile, base)?;
        }
        "calibration" => {
            experiments::calibration(p.profile, p.backend)?;
        }
        "slide" => {
            // Same convention as fleet: explicit config input drives the
            // scenario; bare invocations get the bench-scale setup.
            let base = p.had_config.then_some(&p.cfg);
            experiments::slide(p.profile, p.backend, base)?;
        }
        "cluster" => {
            // Same convention as fleet: explicit config input drives the
            // scenario; bare invocations get the bench-scale three-server
            // fabric with a scripted throttle + rack loss.
            let base = p.had_config.then_some(&p.cfg);
            experiments::cluster(p.profile, base)?;
        }
        "fuzz" => {
            let opts = crate::scenario::fuzz::FuzzOptions {
                seed: p.seed.unwrap_or(7),
                runs: p.runs.unwrap_or(100),
                subsystems: p
                    .subsystems
                    .unwrap_or_else(crate::scenario::fuzz::Subsystems::all),
                verbose: p.verbose,
            };
            experiments::fuzz(&opts, p.out.as_deref())?;
        }
        other => bail!(
            "experiment '{other}' is registered but has no dispatch arm — update \
             cli::cmd_experiment alongside harness::experiments::EXPERIMENTS"
        ),
    }
    p.export_trace(&obs)?;
    Ok(())
}

fn cmd_trace_check(args: &[String]) -> Result<()> {
    let file = args.first().context("trace-check requires a trace file path")?;
    let text =
        std::fs::read_to_string(file).with_context(|| format!("reading trace {file}"))?;
    let n = crate::obs::chrome::validate(&text)?;
    println!("{file}: OK ({n} trace events)");
    if let Ok(root) = crate::util::json::Json::parse(&text) {
        let dropped = root.get("droppedEvents").as_f64().unwrap_or(0.0);
        if dropped > 0.0 {
            eprintln!(
                "warning: {file} records {dropped} dropped events — the timeline is \
                 truncated (raise [obs] buffer_events)"
            );
        }
    }
    Ok(())
}

/// Load a `report` input: a `--trace` export (has `traceEvents`) or a
/// RunLog JSON (has `rows`).
fn load_report(file: &str) -> Result<crate::obs::analyze::Report> {
    use crate::obs::analyze::{Report, TraceData};
    let text = std::fs::read_to_string(file).with_context(|| format!("reading {file}"))?;
    let root = crate::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{file}: not valid JSON: {e}"))?;
    if root.get("traceEvents").as_arr().is_some() {
        Ok(Report::from_trace(&TraceData::parse_chrome(file, &root)?))
    } else {
        Report::from_run_json(file, &root)
    }
}

fn cmd_report(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let strict_gate = |r: &crate::obs::analyze::Report| -> Result<()> {
        let warnings = r.warnings();
        if p.strict && !warnings.is_empty() {
            bail!("--strict: {} ({})", warnings.join("; "), r.label);
        }
        for w in &warnings {
            eprintln!("warning: {w}");
        }
        Ok(())
    };
    if p.diff {
        let [a, b] = p.positional.as_slice() else {
            bail!("report --diff needs exactly two files: BASELINE CANDIDATE");
        };
        let before = load_report(a)?;
        let after = load_report(b)?;
        strict_gate(&before)?;
        strict_gate(&after)?;
        let th = crate::obs::analyze::DiffThresholds::default();
        let regs = crate::obs::analyze::diff(&before, &after, &th);
        print!("{}", crate::obs::analyze::render_diff(&before, &after, &regs, &th));
        if !regs.is_empty() {
            bail!("{} regression(s) over thresholds — see the diff above", regs.len());
        }
        return Ok(());
    }
    let file = p.positional.first().context("report requires a trace or RunLog JSON file")?;
    let report = load_report(file)?;
    if let Some(pattern) = &p.explain {
        let hits =
            crate::obs::analyze::explain_query(&report.decisions, pattern);
        if hits.is_empty() {
            println!("no decisions match {pattern:?} ({} in the log)", report.decisions.len());
        } else {
            for line in hits {
                println!("{line}");
            }
        }
        return strict_gate(&report);
    }
    let md = report.to_markdown(p.top.unwrap_or(8));
    match &p.out {
        Some(out) => {
            std::fs::write(out, &md).with_context(|| format!("writing {}", out.display()))?;
            println!("wrote report: {}", out.display());
        }
        None => print!("{md}"),
    }
    strict_gate(&report)
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let dir = Path::new(&p.cfg.runtime.artifacts_dir);
    let runtime = crate::runtime::Runtime::load(dir)?;
    runtime.manifest.check_config(&p.cfg)?;
    let buckets = p.cfg.bucket_grid();
    let probe: Vec<usize> = vec![buckets[0], buckets[buckets.len() / 2], buckets[buckets.len() - 1]];
    println!("calibrating cost model on buckets {probe:?}…");
    let model = crate::runtime::CostModel::calibrate(&runtime, &probe, 5)?;
    println!(
        "t_fixed = {:.1} µs\nt_per_nnz = {:.1} ns\nt_per_sample = {:.1} µs",
        model.t_fixed * 1e6,
        model.t_per_nnz * 1e9,
        model.t_per_sample * 1e6
    );
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let p = parse_flags(args)?;
    let cfg = &p.cfg;
    println!("model: {:?} ({} parameters)", cfg.model, cfg.model.param_count());
    println!("sgd: {:?}", cfg.sgd);
    println!("bucket grid: {:?}", cfg.bucket_grid());
    println!("merge: {:?}", cfg.merge);
    println!("devices: {:?}", cfg.devices);
    println!("strategy: {:?}", cfg.strategy);
    let dir = Path::new(&cfg.runtime.artifacts_dir);
    match crate::runtime::Manifest::load(dir) {
        Ok(m) => {
            let ok = m.check_config(cfg).is_ok();
            println!(
                "artifacts: {} buckets at {} (config match: {})",
                m.buckets.len(),
                dir.display(),
                if ok { "yes" } else { "NO — rerun make artifacts" }
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parse_flags_roundtrip() {
        let p = parse_flags(&s(&[
            "--set",
            "devices.count=2",
            "--backend",
            "ref",
            "--profile",
            "delicious",
            "--verbose",
            "fig6",
        ]))
        .unwrap();
        assert_eq!(p.cfg.devices.count, 2);
        assert_eq!(p.backend, Backend::Reference);
        assert_eq!(p.profile, DataProfile::Delicious);
        assert!(p.verbose);
        assert_eq!(p.positional, vec!["fig6"]);
    }

    #[test]
    fn rejects_unknown_flag_and_bad_set() {
        assert!(parse_flags(&s(&["--bogus"])).is_err());
        assert!(parse_flags(&s(&["--set", "novalue"])).is_err());
        assert!(main_with_args(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn elastic_flag_appends_validated_events() {
        let p = parse_flags(&s(&["--elastic", "at_mb=3 remove=1", "--elastic", "at_mb=5 add=1"]))
            .unwrap();
        assert_eq!(p.cfg.elastic.events.len(), 2);
        assert_eq!(p.cfg.elastic.parsed_events().unwrap()[0].at_mb, 3);
        assert!(parse_flags(&s(&["--elastic", "at_mb=3 explode=1"])).is_err());
        assert!(parse_flags(&s(&["--elastic"])).is_err());
    }

    #[test]
    fn data_policy_flag_overrides_config() {
        let p = parse_flags(&s(&["--data-policy", "nnz_balanced"])).unwrap();
        assert_eq!(p.cfg.data.pipeline.policy, CompositionPolicy::NnzBalanced);
        // The flag wins over --set (it is the more specific spelling).
        let p = parse_flags(&s(&[
            "--set",
            "data.pipeline.policy=shuffled",
            "--data-policy",
            "nnz_sorted",
        ]))
        .unwrap();
        assert_eq!(p.cfg.data.pipeline.policy, CompositionPolicy::NnzSorted);
        assert!(parse_flags(&s(&["--data-policy", "bogus"])).is_err());
        assert!(parse_flags(&s(&["--data-policy"])).is_err());
    }

    #[test]
    fn trace_flag_parses_and_trace_check_validates() {
        let p = parse_flags(&s(&["--trace", "/tmp/t.json", "cluster"])).unwrap();
        assert_eq!(p.trace.as_deref(), Some(Path::new("/tmp/t.json")));
        assert!(parse_flags(&s(&["--trace"])).is_err());

        // End-to-end: export a real (tiny) trace, then validate it
        // through the subcommand the CI smoke test uses.
        let h = crate::obs::ObsHandle::from_config(&crate::config::ObsConfig::default(), true);
        h.instant(crate::obs::Subsystem::Train, "train.pool", 0, 0.0, Vec::new());
        let dir = std::env::temp_dir().join("hs_cli_trace_check");
        std::fs::create_dir_all(&dir).unwrap();
        let ok = dir.join("ok.json");
        crate::obs::chrome::write_trace(h.sink(), ok.to_str().unwrap()).unwrap();
        main_with_args(&s(&["trace-check", ok.to_str().unwrap()])).unwrap();

        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{}").unwrap();
        assert!(main_with_args(&s(&["trace-check", bad.to_str().unwrap()])).is_err());
        assert!(main_with_args(&s(&["trace-check"])).is_err());
    }

    #[test]
    fn report_runs_and_self_diff_exits_zero() {
        // A small but real trace: one mega-batch window with two device
        // chains, a merge, and a decision instant.
        let h = crate::obs::ObsHandle::from_config(&crate::config::ObsConfig::default(), true);
        let emit = |h: &crate::obs::ObsHandle| {
            use crate::obs::Subsystem;
            h.span(Subsystem::Train, "train.megabatch", 0, 0.0, 1.0, Vec::new());
            h.span(Subsystem::Engine, "engine.step", 1, 0.0, 0.4, Vec::new());
            h.span(Subsystem::Engine, "engine.step", 2, 0.0, 0.9, Vec::new());
            h.span(Subsystem::Train, "train.merge", 0, 0.9, 0.1, Vec::new());
            h.instant(
                Subsystem::Train,
                "train.scale",
                0,
                1.0,
                vec![("mb", 0u64.into()), ("from", "64,64".into()), ("to", "96,32".into())],
            );
        };
        emit(&h);
        let dir = std::env::temp_dir().join("hs_cli_report");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.json");
        crate::obs::chrome::write_trace_with_registry(
            h.sink(),
            h.registry(),
            trace.to_str().unwrap(),
        )
        .unwrap();
        let t = trace.to_str().unwrap();
        main_with_args(&s(&["report", t])).unwrap();
        main_with_args(&s(&["report", t, "--strict", "--top", "3"])).unwrap();
        main_with_args(&s(&["report", t, "--explain", "scale"])).unwrap();
        // Self-diff: zero regressions, exit 0. Markdown lands via --out.
        main_with_args(&s(&["report", "--diff", t, t])).unwrap();
        let out = dir.join("report.md");
        main_with_args(&s(&["report", t, "--out", out.to_str().unwrap()])).unwrap();
        let md = std::fs::read_to_string(&out).unwrap();
        assert!(md.contains("## Critical path"));
        assert!(md.contains("server0/gpu1"), "slow chain gates: {md}");
        // Bad inputs fail loudly.
        assert!(main_with_args(&s(&["report"])).is_err());
        assert!(main_with_args(&s(&["report", "--diff", t])).is_err());
        assert!(main_with_args(&s(&["report", "/nonexistent.json"])).is_err());
    }

    #[test]
    fn strict_report_fails_on_a_truncated_ring() {
        use crate::config::ObsConfig;
        // A 4-slot ring overflows immediately; the export then carries
        // droppedEvents > 0 and --strict must gate on it.
        let cfg = ObsConfig { enabled: true, buffer_events: 4, ..Default::default() };
        let h = crate::obs::ObsHandle::from_config(&cfg, false);
        for i in 0..16 {
            h.span(crate::obs::Subsystem::Engine, "engine.step", 1, i as f64, 0.5, Vec::new());
        }
        assert!(h.sink().dropped() > 0);
        let dir = std::env::temp_dir().join("hs_cli_report_strict");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("truncated.json");
        crate::obs::chrome::write_trace(h.sink(), trace.to_str().unwrap()).unwrap();
        let t = trace.to_str().unwrap();
        // Plain report succeeds (warning only); --strict fails.
        main_with_args(&s(&["report", t])).unwrap();
        let err = main_with_args(&s(&["report", t, "--strict"])).unwrap_err();
        assert!(err.to_string().contains("dropped"), "{err}");
        // trace-check still validates the truncated file.
        main_with_args(&s(&["trace-check", t])).unwrap();
    }

    #[test]
    fn help_runs() {
        main_with_args(&s(&["help"])).unwrap();
        main_with_args(&[]).unwrap();
    }

    #[test]
    fn experiment_registry_backs_dispatch_and_errors() {
        assert!(experiments::is_experiment("calibration"));
        assert!(experiments::is_experiment("fig6"));
        assert!(!experiments::is_experiment("frobnicate"));
        assert_eq!(experiments::experiment_names().len(), experiments::EXPERIMENTS.len());
        // Unknown experiment names fail with the registry list, both with
        // and without a name.
        let err = main_with_args(&s(&["experiment", "frobnicate"])).unwrap_err();
        assert!(err.to_string().contains("calibration"), "{err}");
        let err = main_with_args(&s(&["experiment"])).unwrap_err();
        assert!(err.to_string().contains("fleet"), "{err}");
    }

    #[test]
    fn dashc_overrides_layer_typed_values() {
        // -c and --set are the same flag; -c is the documented spelling.
        let p = parse_flags(&s(&["-c", "devices.count=3", "--set", "sgd.b_max=256"])).unwrap();
        assert_eq!(p.cfg.devices.count, 3);
        assert_eq!(p.cfg.sgd.b_max, 256);
        assert!(p.had_config, "-c counts as explicit config input");
        // Later overrides win over earlier ones for the same key.
        let p = parse_flags(&s(&["-c", "sgd.b_max=128", "-c", "sgd.b_max=512"])).unwrap();
        assert_eq!(p.cfg.sgd.b_max, 512);
        assert!(parse_flags(&s(&["-c"])).is_err(), "-c needs key=value");
        assert!(parse_flags(&s(&["-c", "novalue"])).is_err());
    }

    #[test]
    fn dashc_rejects_unknown_keys_with_vocabulary() {
        let err = parse_flags(&s(&["-c", "sgd.b_maxx=1"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown config key 'sgd.b_maxx'"), "{msg}");
        assert!(msg.contains("sgd.b_max"), "suggests section vocabulary: {msg}");
        let err = parse_flags(&s(&["-c", "sgd.b_min=soon"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sgd.b_min must be a non-negative integer"), "{msg}");
    }

    #[test]
    fn dashc_routes_scenario_lines_to_their_subsystems() {
        let p = parse_flags(&s(&[
            "-c",
            "scenario.events=[\"at_mb=2 remove=1; serve: add=1; cluster: server=1 down\"]",
        ]))
        .unwrap();
        assert_eq!(p.cfg.elastic.events, vec!["at_mb=2 remove=1".to_string()]);
        assert_eq!(p.cfg.serve.events, vec!["at_mb=2 add=1".to_string()]);
        assert_eq!(p.cfg.cluster.events, vec!["at_mb=2 server=1 down".to_string()]);
    }

    #[test]
    fn fuzz_flags_parse_and_validate() {
        let p = parse_flags(&s(&[
            "--seed", "99", "--runs", "3", "--subsystems", "data,cluster", "fuzz",
        ]))
        .unwrap();
        assert_eq!(p.seed, Some(99));
        assert_eq!(p.runs, Some(3));
        let subs = p.subsystems.unwrap();
        assert!(subs.data && subs.cluster && !subs.train && !subs.serve && !subs.fleet);
        assert!(parse_flags(&s(&["--seed", "soon"])).is_err());
        assert!(parse_flags(&s(&["--runs"])).is_err());
        assert!(parse_flags(&s(&["--subsystems", "bogus"])).is_err());
    }

    #[test]
    fn experiment_fuzz_smoke_runs_clean_and_writes_empty_counterexamples() {
        let dir = std::env::temp_dir().join("hs_cli_fuzz");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("counterexamples.json");
        main_with_args(&s(&[
            "experiment",
            "fuzz",
            "--seed",
            "7",
            "--runs",
            "2",
            "--subsystems",
            "data",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = crate::util::json::Json::parse(&std::fs::read_to_string(&out).unwrap())
            .unwrap();
        assert_eq!(doc.get("cases_checked").as_usize(), Some(2));
        assert_eq!(doc.get("failures").as_arr().map(|a| a.len()), Some(0));
    }
}

//! Minimal TOML-subset parser for config files (offline `toml` replacement).
//!
//! Supported grammar — everything the shipped configs need:
//! `[section]` and `[section.subsection]` headers, `key = value` pairs with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and blank lines. Values land in a flat
//! `"section.key" -> TomlValue` map.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_arr(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Arr(items) => items.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }

    pub fn as_str_arr(&self) -> Option<Vec<String>> {
        match self {
            TomlValue::Arr(items) => {
                items.iter().map(|v| v.as_str().map(str::to_string)).collect()
            }
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse into a flat `"section.key" -> value` map. Keys outside any section
/// are stored bare.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, &format!("expected 'key = value' (in '{line}')")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, &format!("empty key (in '{line}')")));
        }
        // Value errors repeat the full line: configs are long arrays of
        // event strings, and "line 12" alone sends you counting.
        let value = parse_value(line[eq + 1..].trim(), lineno)
            .map_err(|e| err(lineno, &format!("{} (in '{line}')", e.msg)))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        map.insert(full, value);
    }
    Ok(map)
}

fn err(lineno: usize, msg: &str) -> TomlError {
    TomlError { line: lineno + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| err(lineno, "unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items = split_top_level(inner)
            .into_iter()
            .enumerate()
            .map(|(idx, item)| {
                parse_value(item.trim(), lineno)
                    .map_err(|e| err(lineno, &format!("array item {idx}: {}", e.msg)))
            })
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let src = r#"
# top comment
title = "heterosparse"

[model]
features = 8192
lr = 0.05          # inline comment
adaptive = true

[devices]
speed_factors = [1.0, 0.9, 0.85, 0.75]
names = ["a", "b"]
"#;
        let m = parse(src).unwrap();
        assert_eq!(m["title"].as_str(), Some("heterosparse"));
        assert_eq!(m["model.features"].as_usize(), Some(8192));
        assert_eq!(m["model.lr"].as_f64(), Some(0.05));
        assert_eq!(m["model.adaptive"].as_bool(), Some(true));
        assert_eq!(
            m["devices.speed_factors"].as_f64_arr().unwrap(),
            vec![1.0, 0.9, 0.85, 0.75]
        );
    }

    #[test]
    fn int_vs_float() {
        let m = parse("a = 3\nb = 3.0\nc = 1e3\nd = 1_000").unwrap();
        assert_eq!(m["a"], TomlValue::Int(3));
        assert_eq!(m["b"], TomlValue::Float(3.0));
        assert_eq!(m["c"], TomlValue::Float(1000.0));
        assert_eq!(m["d"], TomlValue::Int(1000));
        // Int is accessible as f64 too.
        assert_eq!(m["a"].as_f64(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse(r##"s = "a#b""##).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn string_arrays_parse() {
        let m = parse(r#"events = ["at_mb=3 remove=1", "at_mb=6 add=1"]"#).unwrap();
        assert_eq!(
            m["events"].as_str_arr().unwrap(),
            vec!["at_mb=3 remove=1".to_string(), "at_mb=6 add=1".to_string()]
        );
        // Mixed-type arrays yield None.
        let m = parse(r#"bad = ["a", 1]"#).unwrap();
        assert!(m["bad"].as_str_arr().is_none());
    }

    #[test]
    fn subsection_keys_are_flattened() {
        let m = parse("[a.b]\nc = 1").unwrap();
        assert_eq!(m["a.b.c"].as_i64(), Some(1));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, ").is_err());
    }

    #[test]
    fn errors_repeat_the_offending_line_and_array_index() {
        let e = parse("ok = 1\nbad line").unwrap_err();
        assert!(e.msg.contains("in 'bad line'"), "{}", e.msg);
        let e = parse("x = !!").unwrap_err();
        assert!(e.msg.contains("cannot parse value '!!'"), "{}", e.msg);
        assert!(e.msg.contains("in 'x = !!'"), "{}", e.msg);
        // Bad array items name their index, then the whole line.
        let e = parse("xs = [1, !!, 3]").unwrap_err();
        assert!(e.msg.contains("array item 1"), "{}", e.msg);
        assert!(e.msg.contains("cannot parse value '!!'"), "{}", e.msg);
        assert!(e.msg.contains("in 'xs = [1, !!, 3]'"), "{}", e.msg);
        // Nested arrays chain their indices outermost-first.
        let e = parse("xs = [[1], [2, !!]]").unwrap_err();
        assert!(e.msg.contains("array item 1: array item 1"), "{}", e.msg);
    }
}

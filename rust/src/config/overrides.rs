//! Dotted-path `-c key=value` CLI overrides layered over the TOML file
//! (the codex `config_override.rs` pattern): the value is parsed as a
//! typed TOML fragment with a bare-word string fallback, and — unlike the
//! lenient config-file path, which ignores keys it does not know — an
//! override naming an unknown key is rejected with the section's
//! vocabulary, because a typo'd `-c` silently doing nothing is the worst
//! possible failure mode for an experiment sweep.

use std::collections::BTreeMap;

use anyhow::bail;

use super::toml_mini::{self, TomlValue};
use crate::Result;

/// Every dotted key `Config::from_map` reads, grouped by section. This is
/// the unknown-key gate for `-c`; keep it in sync when adding a key to
/// `from_map` (the `-c` end-to-end tests exercise one key per section).
pub const KNOWN_KEYS: &[&str] = &[
    "model.features",
    "model.hidden",
    "model.classes",
    "model.max_nnz",
    "model.max_labels",
    "data.profile",
    "data.train_samples",
    "data.test_samples",
    "data.avg_nnz",
    "data.nnz_sigma",
    "data.avg_labels",
    "data.zipf_s",
    "data.seed",
    "data.pipeline.queue_depth",
    "data.pipeline.producer_threads",
    "data.pipeline.policy",
    "data.pipeline.shard_samples",
    "sgd.b_min",
    "sgd.b_max",
    "sgd.beta",
    "sgd.lr_bmax",
    "sgd.mega_batches",
    "sgd.num_mega_batches",
    "sgd.initial_batch",
    "sgd.warmup_mega_batches",
    "sgd.scaling_window",
    "sgd.scaling_cooldown",
    "sgd.seed",
    "merge.pert_thr",
    "merge.delta",
    "merge.momentum",
    "merge.perturbation",
    "merge.normalization",
    "devices.count",
    "devices.speed_factors",
    "devices.jitter",
    "devices.nnz_sensitivity",
    "devices.seed",
    "runtime.artifacts_dir",
    "runtime.mode",
    "strategy.kind",
    "strategy.batch_scaling",
    "strategy.crossbow_rate",
    "strategy.sync_overhead",
    "elastic.events",
    "elastic.spare_devices",
    "elastic.straggler_factor",
    "elastic.straggler_window",
    "elastic.quarantine_mega_batches",
    "elastic.min_devices",
    "serve.max_batch",
    "serve.max_delay",
    "serve.rate",
    "serve.duration",
    "serve.window",
    "serve.pattern",
    "serve.burst_factor",
    "serve.burst_period",
    "serve.burst_fraction",
    "serve.nnz_bias",
    "serve.publish_every",
    "serve.events",
    "serve.seed",
    "fleet.decision_window",
    "fleet.grace",
    "fleet.slo_p95_ms",
    "fleet.breach_windows",
    "fleet.clear_windows",
    "fleet.preemption",
    "fleet.serve_weight",
    "fleet.train_weights",
    "fleet.events",
    "calibration.enabled",
    "calibration.window",
    "calibration.alpha",
    "calibration.step_threshold",
    "calibration.step_obs",
    "calibration.events",
    "slide.threads",
    "slide.lr",
    "slide.tables",
    "slide.bits",
    "slide.random_negatives",
    "slide.rebuild_every",
    "slide.seed",
    "slide.adaptive",
    "slide.min_ratio",
    "slide.ratio_step",
    "slide.quality_discount",
    "slide.serve_ratio",
    "slide.serve_slo_ms",
    "cluster.servers",
    "cluster.sync_every",
    "cluster.adaptive",
    "cluster.min_sync_every",
    "cluster.max_sync_every",
    "cluster.comm_target",
    "cluster.link_latency_s",
    "cluster.link_gbytes_per_sec",
    "cluster.algo",
    "cluster.streams",
    "cluster.server_speed_factors",
    "cluster.events",
    "cluster.straggler_floor",
    "obs.enabled",
    "obs.level",
    "obs.subsystems",
    "obs.buffer_events",
    "scenario.events",
];

pub fn is_known(path: &str) -> bool {
    KNOWN_KEYS.contains(&path)
}

/// Validate a dotted config path: non-empty `[a-z0-9_]` segments.
fn check_path(raw: &str, path: &str) -> Result<()> {
    let valid = !path.is_empty()
        && path.split('.').all(|seg| {
            !seg.is_empty()
                && seg.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
        });
    if !valid {
        bail!("override '{raw}': '{path}' is not a dotted config path (like sgd.b_max)");
    }
    Ok(())
}

/// Parse an override value as a TOML fragment — so `-c sgd.b_max=256`,
/// `-c devices.jitter=0.05`, `-c merge.perturbation=true`, and
/// `-c 'fleet.train_weights=[1.0, 2.0]'` all arrive typed — falling back
/// to a plain string for bare words (`-c strategy.kind=elastic` needs no
/// quoting).
fn parse_value(value: &str) -> Result<TomlValue> {
    if value.contains('\n') {
        bail!("override values cannot span lines");
    }
    match toml_mini::parse(&format!("__override__ = {value}")) {
        Ok(map) if map.len() == 1 => {
            Ok(map.into_iter().next().expect("len checked").1)
        }
        _ => Ok(TomlValue::Str(value.to_string())),
    }
}

/// Closest-match hint for an unknown key: the section's vocabulary when
/// the section exists, the section list otherwise.
fn suggest(path: &str) -> String {
    let section = path.split('.').next().unwrap_or(path);
    let in_section: Vec<&str> = KNOWN_KEYS
        .iter()
        .copied()
        .filter(|k| k.split('.').next() == Some(section))
        .collect();
    if in_section.is_empty() {
        let mut sections: Vec<&str> =
            KNOWN_KEYS.iter().map(|k| k.split('.').next().unwrap_or(k)).collect();
        sections.dedup();
        format!("unknown section '{section}' (sections: {})", sections.join(", "))
    } else {
        format!("known [{section}] keys: {}", in_section.join(", "))
    }
}

/// Apply one `-c key=value` override onto the flat config map. The map
/// then flows through `Config::from_map` exactly like file-sourced keys,
/// so type errors carry the same messages either way.
pub fn apply(map: &mut BTreeMap<String, TomlValue>, key: &str, value: &str) -> Result<()> {
    let raw = format!("{key}={value}");
    let key = key.trim();
    check_path(&raw, key)?;
    if !is_known(key) {
        bail!("unknown config key '{key}' — {}", suggest(key));
    }
    map.insert(key.to_string(), parse_value(value.trim())?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn apply_one(key: &str, value: &str) -> Result<BTreeMap<String, TomlValue>> {
        let mut map = BTreeMap::new();
        apply(&mut map, key, value)?;
        Ok(map)
    }

    #[test]
    fn values_arrive_typed_with_bare_word_fallback() {
        assert_eq!(apply_one("sgd.b_max", "256").unwrap()["sgd.b_max"], TomlValue::Int(256));
        assert_eq!(
            apply_one("devices.jitter", "0.05").unwrap()["devices.jitter"],
            TomlValue::Float(0.05)
        );
        assert_eq!(
            apply_one("merge.perturbation", "true").unwrap()["merge.perturbation"],
            TomlValue::Bool(true)
        );
        // Bare words need no quoting; explicit quotes also work.
        assert_eq!(
            apply_one("strategy.kind", "elastic").unwrap()["strategy.kind"],
            TomlValue::Str("elastic".to_string())
        );
        assert_eq!(
            apply_one("strategy.kind", "\"elastic\"").unwrap()["strategy.kind"],
            TomlValue::Str("elastic".to_string())
        );
        match &apply_one("fleet.train_weights", "[1.0, 2.0]").unwrap()["fleet.train_weights"] {
            TomlValue::Arr(items) => assert_eq!(items.len(), 2),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn unknown_keys_are_rejected_with_vocabulary() {
        let err = format!("{:#}", apply_one("sgd.b_maxx", "1").unwrap_err());
        assert!(err.contains("unknown config key 'sgd.b_maxx'"), "{err}");
        assert!(err.contains("sgd.b_max"), "suggests the section vocabulary: {err}");
        let err = format!("{:#}", apply_one("sdg.b_max", "1").unwrap_err());
        assert!(err.contains("unknown section 'sdg'"), "{err}");
        assert!(err.contains("sgd"), "lists sections: {err}");
        let err = format!("{:#}", apply_one("sgd..b_max", "1").unwrap_err());
        assert!(err.contains("not a dotted config path"), "{err}");
    }

    #[test]
    fn type_errors_surface_through_from_map() {
        let o = |k: &str, v: &str| vec![(k.to_string(), v.to_string())];
        let err = format!("{:#}", Config::from_overrides(&o("sgd.b_min", "soon")).unwrap_err());
        assert!(err.contains("sgd.b_min must be a non-negative integer"), "{err}");
        let err =
            format!("{:#}", Config::from_overrides(&o("devices.jitter", "fast")).unwrap_err());
        assert!(err.contains("devices.jitter must be a number"), "{err}");
    }

    #[test]
    fn overrides_take_precedence_and_build_valid_configs() {
        let overrides = vec![
            ("sgd.b_max".to_string(), "256".to_string()),
            ("sgd.beta".to_string(), "8".to_string()),
            ("devices.count".to_string(), "3".to_string()),
        ];
        let cfg = Config::from_overrides(&overrides).unwrap();
        assert_eq!(cfg.sgd.b_max, 256);
        assert_eq!(cfg.devices.count, 3);
        // Scenario lines route through the override path too.
        let cfg = Config::from_overrides(&[(
            "scenario.events".to_string(),
            "[\"at_mb=2 remove=1; serve: add=1\"]".to_string(),
        )])
        .unwrap();
        assert_eq!(cfg.elastic.events, vec!["at_mb=2 remove=1".to_string()]);
        assert_eq!(cfg.serve.events, vec!["at_mb=2 add=1".to_string()]);
    }

    #[test]
    fn every_registered_key_is_accepted() {
        for key in KNOWN_KEYS {
            let mut map = BTreeMap::new();
            apply(&mut map, key, "1").unwrap_or_else(|e| panic!("{key}: {e:#}"));
        }
    }
}

//! Configuration system: typed config structs parsed from a TOML-subset
//! file ([`toml_mini`]) with dotted-path `-c key=value` CLI overrides
//! ([`overrides`]), validation, and defaults that match
//! `python/compile/aot.py`.

pub mod overrides;
pub mod toml_mini;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context};

use crate::Result;
use toml_mini::TomlValue;

/// Model dimensions — must agree with the AOT artifact manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDims {
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub max_nnz: usize,
    pub max_labels: usize,
}

impl Default for ModelDims {
    fn default() -> Self {
        // Must match the aot.py defaults ("small" profile).
        ModelDims { features: 8192, hidden: 64, classes: 1024, max_nnz: 32, max_labels: 8 }
    }
}

impl ModelDims {
    /// Total trainable parameters (w1 + b1 + w2 + b2).
    pub fn param_count(&self) -> usize {
        self.features * self.hidden
            + self.hidden
            + self.hidden * self.classes
            + self.classes
    }
}

/// Which synthetic dataset profile to generate (Table 1 substitutes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataProfile {
    /// Amazon-670k-like: few features/labels per sample, huge label space.
    Amazon,
    /// Delicious-200k-like: denser samples, many labels per sample.
    Delicious,
}

impl DataProfile {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "amazon" | "amazon-670k" => Ok(DataProfile::Amazon),
            "delicious" | "delicious-200k" => Ok(DataProfile::Delicious),
            other => bail!("unknown data profile '{other}' (amazon|delicious)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DataProfile::Amazon => "amazon",
            DataProfile::Delicious => "delicious",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DataConfig {
    pub profile: DataProfile,
    pub train_samples: usize,
    pub test_samples: usize,
    /// Mean/sigma of the log-normal nnz-per-sample distribution (clamped to
    /// [1, max_nnz]); Amazon ≈ 12, Delicious ≈ 24 at the default scale.
    pub avg_nnz: f64,
    pub nnz_sigma: f64,
    /// Mean labels per sample (>=1).
    pub avg_labels: f64,
    /// Zipf exponent for feature popularity.
    pub zipf_s: f64,
    pub seed: u64,
    /// Data-plane settings (`[data.pipeline]`).
    pub pipeline: PipelineConfig,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            profile: DataProfile::Amazon,
            train_samples: 20_000,
            test_samples: 2_000,
            avg_nnz: 12.0,
            nnz_sigma: 0.5,
            avg_labels: 2.0,
            zipf_s: 1.1,
            seed: 42,
            pipeline: PipelineConfig::default(),
        }
    }
}

/// How the data plane composes samples into batches (`[data.pipeline]
/// policy`, `--data-policy`). The paper's instability analysis traces back
/// to per-batch nnz variance, so composition is a first-class scheduling
/// knob rather than an afterthought.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompositionPolicy {
    /// Epoch-shuffled, nnz-oblivious — the classic baseline.
    Shuffled,
    /// Stratify samples by nnz quantile and interleave the strata, so any
    /// contiguous run of the epoch order (hence any batch) carries close to
    /// `batch_size × mean_nnz` non-zeros.
    NnzBalanced,
    /// Descending-nnz order — maximal batch-cost dispersion; the stress
    /// policy for scheduler experiments.
    NnzSorted,
}

impl CompositionPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "shuffled" => Ok(CompositionPolicy::Shuffled),
            "nnz_balanced" | "nnz-balanced" | "balanced" => Ok(CompositionPolicy::NnzBalanced),
            "nnz_sorted" | "nnz-sorted" | "sorted" => Ok(CompositionPolicy::NnzSorted),
            other => {
                bail!("unknown composition policy '{other}' (shuffled|nnz_balanced|nnz_sorted)")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompositionPolicy::Shuffled => "shuffled",
            CompositionPolicy::NnzBalanced => "nnz_balanced",
            CompositionPolicy::NnzSorted => "nnz_sorted",
        }
    }

    pub fn all() -> [CompositionPolicy; 3] {
        [CompositionPolicy::Shuffled, CompositionPolicy::NnzBalanced, CompositionPolicy::NnzSorted]
    }
}

/// Data-plane tuning (`[data.pipeline]`): sharded ingestion granularity,
/// prefetch queue shape, and the batch-composition policy.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Bounded prefetch queue depth per device slot (2 = double-buffered).
    pub queue_depth: usize,
    /// Background producer threads assembling batches ahead of the
    /// consumers. 0 disables prefetch; the virtual-time engine always runs
    /// synchronously regardless (determinism).
    pub producer_threads: usize,
    /// Batch composition policy.
    pub policy: CompositionPolicy,
    /// Samples per ingestion shard (each shard carries its own nnz
    /// histogram manifest).
    pub shard_samples: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            queue_depth: 2,
            producer_threads: 2,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 4096,
        }
    }
}

/// SGD hyperparameters (paper §5.1 methodology).
#[derive(Clone, Debug)]
pub struct SgdConfig {
    /// Minimum / maximum batch size — the batch-size grid endpoints.
    pub b_min: usize,
    pub b_max: usize,
    /// Batch-size scaling step (Algorithm 1's β); paper default b_min/2.
    pub beta: usize,
    /// Learning rate *at b_max*; other batch sizes follow linear scaling.
    pub lr_bmax: f32,
    /// Samples per mega-batch, expressed in batches of b_max
    /// (paper default: 100 batches).
    pub mega_batches: usize,
    /// How many mega-batches to train for.
    pub num_mega_batches: usize,
    /// Initial batch size (paper: b_max).
    pub initial_batch: usize,
    /// Learning-rate warmup horizon in mega-batches (0 disables; the paper
    /// cites Goyal et al.'s warmup as the fix for large-batch instability).
    pub warmup_mega_batches: usize,
    /// Batch-size history the scaling-frequency controller must accumulate
    /// before it judges oscillation (mega-batches, >= 4). The judgment
    /// itself always inspects the last 4 snapshots (the a,b,a,b pattern) —
    /// a larger window makes the controller *slower to judge*, not
    /// deeper-sighted.
    pub scaling_window: usize,
    /// How many merges Algorithm 1 stays paused after the controller
    /// detects stability or oscillation (>= 1).
    pub scaling_cooldown: usize,
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            b_min: 16,
            b_max: 128,
            beta: 8,
            lr_bmax: 0.05,
            mega_batches: 20,
            num_mega_batches: 10,
            initial_batch: 128,
            warmup_mega_batches: 0,
            scaling_window: 4,
            scaling_cooldown: 3,
            seed: 7,
        }
    }
}

impl SgdConfig {
    pub fn mega_batch_samples(&self) -> usize {
        self.mega_batches * self.b_max
    }
}

/// How merge weights are normalized when update counts differ (paper §3.3
/// discusses both; update-count-only is adopted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalization {
    /// `α_i ∝ u_i` — the paper's choice.
    Updates,
    /// `α_i ∝ u_i · b_i` — the alternative the paper evaluates and rejects
    /// ("no discernible improvement"); kept for the ablation benches.
    UpdatesTimesBatch,
}

impl Normalization {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "updates" => Ok(Normalization::Updates),
            "updates_x_batch" | "updatesxbatch" => Ok(Normalization::UpdatesTimesBatch),
            other => bail!("unknown normalization '{other}' (updates|updates_x_batch)"),
        }
    }
}

/// Algorithm 2 parameters.
#[derive(Clone, Debug)]
pub struct MergeConfig {
    /// Perturbation regularization threshold on L2-norm / |w| (default 0.1).
    pub pert_thr: f64,
    /// Perturbation factor δ (default 0.1).
    pub delta: f64,
    /// Momentum γ on the global model (default 0.9).
    pub momentum: f64,
    /// Disable perturbation entirely (ablations).
    pub perturbation: bool,
    /// Weight normalization for unequal update counts.
    pub normalization: Normalization,
}

impl Default for MergeConfig {
    fn default() -> Self {
        MergeConfig {
            pert_thr: 0.1,
            delta: 0.1,
            momentum: 0.9,
            perturbation: true,
            normalization: Normalization::Updates,
        }
    }
}

/// Simulated heterogeneous device fleet (substitutes the 4× V100 server).
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    pub count: usize,
    /// Persistent per-device speed factors (1.0 = nominal; smaller = faster).
    /// Paper Fig. 1 shows a ~32% fastest↔slowest gap on identical V100s.
    pub speed_factors: Vec<f64>,
    /// AR(1) multiplicative jitter amplitude (0 disables).
    pub jitter: f64,
    /// Extra per-nonzero sensitivity of step time (sparse-data heterogeneity).
    pub nnz_sensitivity: f64,
    pub seed: u64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            count: 4,
            speed_factors: vec![1.00, 1.10, 1.21, 1.32],
            jitter: 0.05,
            nnz_sensitivity: 1.0,
            seed: 17,
        }
    }
}

/// Runtime execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Threaded workers executing the PJRT step for real; wall-clock timing
    /// plus injected heterogeneity delays.
    Real,
    /// Discrete-event simulation: numerics still run through PJRT, but the
    /// schedule advances on a virtual clock driven by the cost model.
    /// Deterministic and fast — used by the figure benches.
    Virtual,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Ok(ExecMode::Real),
            "virtual" | "sim" => Ok(ExecMode::Virtual),
            other => bail!("unknown exec mode '{other}' (real|virtual)"),
        }
    }
}

/// Training strategy (the paper's Adaptive SGD + the three GPU baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// The paper's contribution: dynamic scheduling + batch-size scaling +
    /// normalized merging.
    Adaptive,
    /// Elastic (K-step) model averaging with static equal batches.
    Elastic,
    /// Synchronous gradient aggregation (TensorFlow-mirrored analog):
    /// merge after every round of one batch per device.
    SyncGradAgg,
    /// CROSSBOW-style synchronous model averaging with replica correction
    /// after every batch.
    Crossbow,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "adaptive" => Ok(Strategy::Adaptive),
            "elastic" => Ok(Strategy::Elastic),
            "sync" | "gradagg" | "tensorflow" => Ok(Strategy::SyncGradAgg),
            "crossbow" => Ok(Strategy::Crossbow),
            other => bail!("unknown strategy '{other}' (adaptive|elastic|sync|crossbow)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Adaptive => "adaptive",
            Strategy::Elastic => "elastic",
            Strategy::SyncGradAgg => "sync",
            Strategy::Crossbow => "crossbow",
        }
    }

    pub fn all() -> [Strategy; 4] {
        [Strategy::Adaptive, Strategy::Elastic, Strategy::SyncGradAgg, Strategy::Crossbow]
    }
}

#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    pub artifacts_dir: String,
    pub mode: ExecMode,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: "artifacts".to_string(), mode: ExecMode::Virtual }
    }
}

/// `[obs]` — the unified observability plane (PR 8): structured spans,
/// Chrome-trace export, and the counter registry. Inert by default: with
/// `enabled = false` and no `--trace` flag the sink is a no-op and every
/// output is byte-identical to a config that never mentions this section
/// (the registry itself is always on — migrated subsystem counters keep
/// their RunLog values regardless).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Collect spans/events. Also armed implicitly by the CLI `--trace`
    /// flag, so a trace can be captured without editing the config.
    pub enabled: bool,
    /// Event verbosity: `"info"` (decision-level timeline, the default)
    /// or `"debug"` (adds high-volume per-request detail).
    pub level: String,
    /// Subsystems to record (empty = all of them): any of `train`,
    /// `engine`, `data`, `serve`, `fleet`, `cluster`.
    pub subsystems: Vec<String>,
    /// Ring-buffer capacity in events; the oldest events are evicted
    /// beyond this (the eviction tally is exported in the trace).
    pub buffer_events: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            level: "info".to_string(),
            subsystems: Vec::new(),
            buffer_events: 65536,
        }
    }
}

/// The subsystem names accepted by `obs.subsystems`.
pub const OBS_SUBSYSTEMS: [&str; 6] = ["train", "engine", "data", "serve", "fleet", "cluster"];

/// Top-level configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub model: ModelDims,
    pub data: DataConfig,
    pub sgd: SgdConfig,
    pub merge: MergeConfig,
    pub devices: DeviceConfig,
    pub runtime: RuntimeConfig,
    pub strategy: StrategyConfig,
    pub elastic: ElasticConfig,
    pub serve: ServeConfig,
    pub fleet: FleetConfig,
    pub calibration: CalibrationConfig,
    pub slide: SlideConfig,
    pub cluster: ClusterConfig,
    pub obs: ObsConfig,
    pub scenario: ScenarioConfig,
}

/// The cross-subsystem `[scenario]` block: compound event lines in the
/// unified grammar, routed into the per-subsystem event lists at load
/// time by [`Config::apply_scenario`]. Clauses chain with `;` (inheriting
/// `at_mb`) and may carry a `target:` prefix, e.g.
/// `"at_mb=4 server=1 down; link=0 factor=6.0; serve: add=1"`.
#[derive(Clone, Debug, Default)]
pub struct ScenarioConfig {
    pub events: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct StrategyConfig {
    pub kind: Strategy,
    /// Elastic/Adaptive: disable batch scaling (ablation; Elastic == Adaptive
    /// with scaling+weighting off).
    pub batch_scaling: bool,
    /// CROSSBOW replica-correction rate.
    pub crossbow_rate: f64,
    /// Framework overhead multiplier for the TensorFlow-analog synchronous
    /// gradient aggregation (the paper attributes TF's slow curves partly to
    /// slower epoch execution + mirrored all-reduce; Fig. 6 discussion).
    pub sync_overhead: f64,
}

impl Default for StrategyConfig {
    fn default() -> Self {
        StrategyConfig {
            kind: Strategy::Adaptive,
            batch_scaling: true,
            crossbow_rate: 0.1,
            sync_overhead: 1.5,
        }
    }
}

/// One operation of a scripted elasticity trace (`[elastic] events`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticOp {
    /// Remove the `n` slowest active devices (bounded by `min_devices`).
    Remove(usize),
    /// Re-admit / hot-add `n` inactive devices (removed ones and spares).
    Add(usize),
    /// Remove one specific device by id.
    RemoveId(usize),
    /// Re-admit / hot-add one specific device by id.
    AddId(usize),
}

/// A scripted pool-membership change applied at a mega-batch boundary,
/// parsed from strings like `"at_mb=20 remove=2"` or `"at_mb=40 add_id=1"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElasticEvent {
    pub at_mb: usize,
    pub op: ElasticOp,
}

impl ElasticEvent {
    /// Thin view over the unified scenario grammar
    /// ([`crate::scenario::parse_event`]) under the pool-family mask: the
    /// accepted language — including the legacy rejection quirks
    /// (duplicate keys, two operations, `remove=0` no-ops) — is unchanged.
    pub fn parse(s: &str) -> Result<ElasticEvent> {
        match crate::scenario::parse_event(s, crate::scenario::Mask::POOL)? {
            crate::scenario::ScenarioEvent::Pool(ev) => Ok(ev),
            other => bail!("event '{s}' parsed as a non-pool event ({other:?})"),
        }
    }
}

/// Elastic device-pool control: scripted membership trace, hot-add spares,
/// and the straggler-quarantine policy.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Scripted trace, e.g. `["at_mb=20 remove=2", "at_mb=40 add=2"]`.
    pub events: Vec<String>,
    /// Speed factors of spare devices that can be hot-added by `add` events
    /// (they extend the roster but start outside the active pool).
    pub spare_devices: Vec<f64>,
    /// Quarantine a device whose windowed mean step time exceeds this
    /// multiple of the active fleet's median (0 disables the policy).
    pub straggler_factor: f64,
    /// Sliding window length (mega-batches) for straggler detection.
    pub straggler_window: usize,
    /// Auto-readmit a quarantined device after this many mega-batches.
    pub quarantine_mega_batches: usize,
    /// Never let policy or trace shrink the active pool below this.
    pub min_devices: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            events: Vec::new(),
            spare_devices: Vec::new(),
            straggler_factor: 0.0,
            straggler_window: 3,
            quarantine_mega_batches: 5,
            min_devices: 1,
        }
    }
}

impl ElasticConfig {
    /// Parse the scripted trace, sorted by mega-batch (stable for ties).
    /// Errors name the offending array index and full line.
    pub fn parsed_events(&self) -> Result<Vec<ElasticEvent>> {
        let mut events = crate::scenario::parse_trace_indexed(
            "elastic.events",
            &self.events,
            ElasticEvent::parse,
        )?;
        events.sort_by_key(|e| e.at_mb);
        Ok(events)
    }
}

/// Arrival process of the synthetic serving workload (`[serve] pattern`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePattern {
    /// Memoryless open-loop arrivals at the configured mean rate.
    Poisson,
    /// Periodic bursts: within each `burst_period`, the first
    /// `burst_fraction` runs at `burst_factor ×` the base rate.
    Bursty,
}

impl ServePattern {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Ok(ServePattern::Poisson),
            "bursty" | "burst" => Ok(ServePattern::Bursty),
            other => bail!("unknown serve pattern '{other}' (poisson|bursty)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServePattern::Poisson => "poisson",
            ServePattern::Bursty => "bursty",
        }
    }

    pub fn all() -> [ServePattern; 2] {
        [ServePattern::Poisson, ServePattern::Bursty]
    }
}

/// Online inference plane (`[serve]`): micro-batch admission, snapshot
/// publishing cadence, the synthetic workload, and scripted serving-pool
/// churn.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Largest serving micro-batch; must lie on the training bucket grid
    /// (the AOT executables only exist for grid shapes). 0 = `sgd.b_max`.
    pub max_batch: usize,
    /// Deadline (seconds) a request may wait in admission for batch
    /// formation; when the oldest pending request hits it, a partial batch
    /// flushes on the smallest grid bucket that fits.
    pub max_delay: f64,
    /// Mean request arrival rate (requests/second) of the generated trace.
    pub rate: f64,
    /// Trace duration (virtual seconds) for steady-state serving runs
    /// (train-while-serve spans the training clock instead).
    pub duration: f64,
    /// Telemetry window length (seconds) for the latency/throughput rows.
    pub window: f64,
    /// Arrival pattern of the generated trace.
    pub pattern: ServePattern,
    /// Burst rate multiplier (`Bursty` only).
    pub burst_factor: f64,
    /// Burst cycle length in seconds (`Bursty` only).
    pub burst_period: f64,
    /// Fraction of each cycle spent bursting, in (0, 1) (`Bursty` only).
    pub burst_fraction: f64,
    /// Tilt request sampling toward heavy (high-nnz) corpus samples:
    /// selection weight ∝ nnz^bias via the shard manifests (0 = corpus
    /// distribution).
    pub nnz_bias: f64,
    /// Publish the merged global model into the snapshot registry every k
    /// mega-batches (bounds served-snapshot staleness to k−1).
    pub publish_every: usize,
    /// Scripted serving-pool churn, same grammar as `[elastic] events` but
    /// indexed by telemetry *window* instead of mega-batch
    /// (e.g. `"at_mb=4 remove=1"` fires at the 4th window boundary).
    pub events: Vec<String>,
    /// Workload generator seed.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 0,
            max_delay: 0.002,
            rate: 8_000.0,
            duration: 2.0,
            window: 0.25,
            pattern: ServePattern::Poisson,
            burst_factor: 6.0,
            burst_period: 0.5,
            burst_fraction: 0.2,
            nnz_bias: 0.0,
            publish_every: 1,
            events: Vec::new(),
            seed: 99,
        }
    }
}

/// Multi-tenant fleet scheduler (`[fleet]`): arbiter cadence, lease grace,
/// the serve lane's latency SLO, preemption policy, tenant weights, and
/// scripted fleet churn.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Arbiter decision interval in fleet (virtual) seconds; SLO windows
    /// and scripted churn land on these boundaries.
    pub decision_window: f64,
    /// Grace (seconds) a revoked lease has to drain before the book
    /// force-releases it.
    pub grace: f64,
    /// Serve-lane SLO: windowed p95 latency target in milliseconds.
    pub slo_p95_ms: f64,
    /// Consecutive breached decision windows before preemption fires.
    pub breach_windows: usize,
    /// Consecutive clear decision windows before preempted capacity
    /// returns.
    pub clear_windows: usize,
    /// SLO-triggered preemption on/off (off = pure weighted fair share).
    pub preemption: bool,
    /// Fair-share weight of the serve lane.
    pub serve_weight: f64,
    /// One weight per training tenant — the length decides how many
    /// training tenants `experiment fleet` co-schedules.
    pub train_weights: Vec<f64>,
    /// Scripted fleet churn, same grammar as `[elastic] events` but
    /// indexed by *arbiter decision window* (e.g. `"at_mb=4 remove=1"`
    /// fires at the 4th decision boundary).
    pub events: Vec<String>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            decision_window: 0.25,
            grace: 0.5,
            slo_p95_ms: 5.0,
            breach_windows: 2,
            clear_windows: 2,
            preemption: true,
            serve_weight: 1.0,
            train_weights: vec![1.0, 1.0],
            events: Vec::new(),
        }
    }
}

/// Online cost-model calibration (`[calibration]`): the estimator knobs,
/// and scripted drift traces for throttle/recover experiments.
///
/// `events` describe the *physical* drift scenario and always apply to
/// the simulated devices; `enabled` decides whether the resulting
/// estimates (instead of the static `devices.speed_factors`) drive
/// dispatch, batch scaling, fleet fair share, and serve routing. With
/// `enabled = false` runs are bit-identical to the pre-calibration
/// behavior.
#[derive(Clone, Debug)]
pub struct CalibrationConfig {
    /// Close the scheduling loop on measured costs (default off).
    pub enabled: bool,
    /// Per-device observation window of the robust fit (>= 3).
    pub window: usize,
    /// EWMA smoothing factor across window fits, in (0, 1] — the slow
    /// tracking path for gradual drift.
    pub alpha: f64,
    /// Relative deviation from the smoothed prediction that counts as a
    /// step-drift outlier (> 0).
    pub step_threshold: f64,
    /// Consecutive outliers before a step change is declared and the
    /// estimate fast re-seeds (>= 1).
    pub step_obs: usize,
    /// Scripted drift trace, e.g.
    /// `["at_mb=10 device=0 factor=1.8 ramp=2"]` — device 0 throttles to
    /// 1.8× its configured factor over 2 mega-batches starting at 10.
    pub events: Vec<String>,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        CalibrationConfig {
            enabled: false,
            window: 6,
            alpha: 0.25,
            step_threshold: 0.25,
            step_obs: 2,
            events: Vec::new(),
        }
    }
}

impl CalibrationConfig {
    /// Parse the scripted drift trace, sorted by mega-batch. Errors name
    /// the offending array index and full line.
    pub fn parsed_events(&self) -> Result<Vec<crate::tuning::DriftEvent>> {
        let mut trace = crate::scenario::parse_trace_indexed(
            "calibration.events",
            &self.events,
            crate::tuning::DriftEvent::parse,
        )?;
        trace.sort_by_key(|e| e.at_mb);
        Ok(trace)
    }
}

/// The unified `[slide]` block: LSH active-class training — both the
/// standalone Fig. 8 CPU baseline (`slide::SlideTrainer`) and the
/// adaptive-sparsity compute lever the coordinator schedules
/// (`slide::SparseStepper`). One block so the two paths cannot drift.
///
/// With `adaptive = false` (the default) every training device runs the
/// exact dense step (`ratio = 1.0`, bit-identical to `sgd_step_ref`), and
/// serving stays exact unless `serve_slo_ms` engages — existing configs
/// see zero behavior change.
#[derive(Clone, Debug)]
pub struct SlideConfig {
    /// Hogwild trainer threads (standalone baseline only).
    pub threads: usize,
    /// Baseline learning rate; 0 = derive `sgd.lr_bmax / 4` (the
    /// historical Fig. 8 choice).
    pub lr: f64,
    /// LSH tables and bits per table (1..=31).
    pub tables: usize,
    pub bits: usize,
    /// Random negative classes added to every active set (>= 1).
    pub random_negatives: usize,
    /// Rebuild the LSH tables every this many updates/steps (>= 1) — the
    /// staleness bound of the candidate structure.
    pub rebuild_every: u64,
    pub seed: u64,
    /// Let batch scaling trade sparsity against batch size on slow
    /// devices (the tentpole lever; default off).
    pub adaptive: bool,
    /// Floor of the per-device sparsity ratio ladder, in (0, 1].
    pub min_ratio: f64,
    /// Ladder decrement per rung, in (0, 1): rungs are
    /// 1.0, 1.0 - step, 1.0 - 2·step, ..., min_ratio.
    pub ratio_step: f64,
    /// Merge-weight gradient-quality exponent: a device at ratio r gets
    /// its merge weight scaled by r^quality_discount (>= 0; 0 = no
    /// discount).
    pub quality_discount: f64,
    /// Sparsity ratio serve replicas drop to in approximate mode,
    /// in (0, 1].
    pub serve_ratio: f64,
    /// Serve latency SLO in milliseconds; replicas switch to approximate
    /// LSH top-k when windowed p95 nears this, back to exact when idle.
    /// 0 disables the switch (always exact).
    pub serve_slo_ms: f64,
}

impl Default for SlideConfig {
    fn default() -> Self {
        SlideConfig {
            threads: 4,
            lr: 0.0,
            tables: 8,
            bits: 9,
            random_negatives: 16,
            rebuild_every: 2_000,
            seed: 33,
            adaptive: false,
            min_ratio: 0.05,
            ratio_step: 0.25,
            quality_discount: 0.5,
            serve_ratio: 0.25,
            serve_slo_ms: 0.0,
        }
    }
}

impl SlideConfig {
    /// The sparsity ladder scaling walks down: `1.0, 1.0 - ratio_step,
    /// ...` clamped to end exactly at `min_ratio`. Strictly decreasing.
    pub fn ratio_ladder(&self) -> Vec<f64> {
        let mut ladder = Vec::new();
        let mut r = 1.0;
        while r > self.min_ratio {
            ladder.push(r);
            r -= self.ratio_step;
        }
        ladder.push(self.min_ratio);
        ladder
    }
}

/// The `[cluster]` block: multi-server scale-out over a simulated
/// inter-server fabric (`crate::cluster`).
///
/// With the block absent — or `servers = 1` — the cluster plane is fully
/// inert and every run is bit-identical to the single-server build; only
/// `experiment cluster` and `cluster::run_cluster` read these keys.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Servers in the simulated cluster (>= 1; 1 = the plane is inert).
    pub servers: usize,
    /// Initial inter-server sync cadence in mega-batches (>= 1).
    pub sync_every: usize,
    /// Adapt the cadence to the measured link speed (else fixed).
    pub adaptive: bool,
    /// Adaptive-cadence floor in mega-batches (>= 1).
    pub min_sync_every: usize,
    /// Adaptive-cadence ceiling in mega-batches (>= `min_sync_every`).
    pub max_sync_every: usize,
    /// Target fraction of wall time spent in inter-server syncs, in
    /// (0, 1) — the adaptive controller's setpoint.
    pub comm_target: f64,
    /// Nominal per-hop link latency in seconds (>= 0).
    pub link_latency_s: f64,
    /// Nominal per-link bandwidth in gigabytes per second (> 0).
    pub link_gbytes_per_sec: f64,
    /// Inter-server all-reduce schedule: `"ring"` or `"tree"`.
    pub algo: String,
    /// Pipelined fabric partitions per sync (>= 1).
    pub streams: usize,
    /// Per-server relative speed multipliers applied to every device on
    /// that server (all > 0; empty = homogeneous servers, exactly 1.0
    /// everywhere). Length must equal `servers` when non-empty — this is
    /// what makes a whole server a straggler.
    pub server_speed_factors: Vec<f64>,
    /// Scripted fabric scenario: link throttles
    /// (`"at_mb=N link=L factor=F [ramp=R]"`, window-indexed by sync
    /// round) and rack loss/recovery (`"at_mb=N server=S down|up"`).
    pub events: Vec<String>,
    /// Demote a server to asynchronous catch-up when its measured
    /// mega-batch rate falls below this fraction of the fastest server's,
    /// in [0, 1); 0 disables the straggler policy.
    pub straggler_floor: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            servers: 1,
            sync_every: 4,
            adaptive: true,
            min_sync_every: 1,
            max_sync_every: 16,
            comm_target: 0.1,
            link_latency_s: 5e-3,
            link_gbytes_per_sec: 1.0,
            algo: "ring".to_string(),
            streams: 4,
            server_speed_factors: Vec::new(),
            events: Vec::new(),
            straggler_floor: 0.0,
        }
    }
}

impl ClusterConfig {
    /// Parse the scripted cluster trace, sorted by mega-batch. Errors
    /// name the offending array index and full line.
    pub fn parsed_events(&self) -> Result<Vec<crate::cluster::ClusterEvent>> {
        let mut trace = crate::scenario::parse_trace_indexed(
            "cluster.events",
            &self.events,
            crate::cluster::ClusterEvent::parse,
        )?;
        trace.sort_by_key(|e| e.at_mb());
        Ok(trace)
    }
}

impl Config {
    /// Load from a TOML file then layer dotted-path `-c key=value`
    /// overrides over it ([`overrides::apply`]: typed TOML fragments with
    /// bare-word string fallback, unknown keys rejected).
    pub fn load(path: &Path, overrides: &[(String, String)]) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let mut map = toml_mini::parse(&text)?;
        for (k, v) in overrides {
            overrides::apply(&mut map, k, v)?;
        }
        Config::from_map(&map)
    }

    /// Build purely from `-c key=value` overrides on top of defaults.
    pub fn from_overrides(overrides: &[(String, String)]) -> Result<Config> {
        let mut map = BTreeMap::new();
        for (k, v) in overrides {
            overrides::apply(&mut map, k, v)?;
        }
        Config::from_map(&map)
    }

    pub fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Config> {
        let mut cfg = Config::default();

        let usize_of = |map: &BTreeMap<String, TomlValue>, key: &str, dst: &mut usize| -> Result<()> {
            if let Some(v) = map.get(key) {
                *dst = v.as_usize().with_context(|| format!("{key} must be a non-negative integer"))?;
            }
            Ok(())
        };
        let f64_of = |map: &BTreeMap<String, TomlValue>, key: &str, dst: &mut f64| -> Result<()> {
            if let Some(v) = map.get(key) {
                *dst = v.as_f64().with_context(|| format!("{key} must be a number"))?;
            }
            Ok(())
        };
        let u64_of = |map: &BTreeMap<String, TomlValue>, key: &str, dst: &mut u64| -> Result<()> {
            if let Some(v) = map.get(key) {
                *dst = v.as_i64().map(|i| i as u64).with_context(|| format!("{key} must be an integer"))?;
            }
            Ok(())
        };

        usize_of(map, "model.features", &mut cfg.model.features)?;
        usize_of(map, "model.hidden", &mut cfg.model.hidden)?;
        usize_of(map, "model.classes", &mut cfg.model.classes)?;
        usize_of(map, "model.max_nnz", &mut cfg.model.max_nnz)?;
        usize_of(map, "model.max_labels", &mut cfg.model.max_labels)?;

        if let Some(v) = map.get("data.profile") {
            cfg.data.profile = DataProfile::parse(v.as_str().context("data.profile must be a string")?)?;
            // Profile presets (may be overridden by explicit keys below).
            match cfg.data.profile {
                DataProfile::Amazon => {
                    cfg.data.avg_nnz = 12.0;
                    cfg.data.avg_labels = 2.0;
                }
                DataProfile::Delicious => {
                    cfg.data.avg_nnz = 24.0;
                    cfg.data.avg_labels = 6.0;
                }
            }
        }
        usize_of(map, "data.train_samples", &mut cfg.data.train_samples)?;
        usize_of(map, "data.test_samples", &mut cfg.data.test_samples)?;
        f64_of(map, "data.avg_nnz", &mut cfg.data.avg_nnz)?;
        f64_of(map, "data.nnz_sigma", &mut cfg.data.nnz_sigma)?;
        f64_of(map, "data.avg_labels", &mut cfg.data.avg_labels)?;
        f64_of(map, "data.zipf_s", &mut cfg.data.zipf_s)?;
        u64_of(map, "data.seed", &mut cfg.data.seed)?;

        usize_of(map, "data.pipeline.queue_depth", &mut cfg.data.pipeline.queue_depth)?;
        usize_of(map, "data.pipeline.producer_threads", &mut cfg.data.pipeline.producer_threads)?;
        if let Some(v) = map.get("data.pipeline.policy") {
            let s = v.as_str().context("data.pipeline.policy must be a string")?;
            cfg.data.pipeline.policy = CompositionPolicy::parse(s)?;
        }
        usize_of(map, "data.pipeline.shard_samples", &mut cfg.data.pipeline.shard_samples)?;

        usize_of(map, "sgd.b_min", &mut cfg.sgd.b_min)?;
        usize_of(map, "sgd.b_max", &mut cfg.sgd.b_max)?;
        usize_of(map, "sgd.beta", &mut cfg.sgd.beta)?;
        if let Some(v) = map.get("sgd.lr_bmax") {
            cfg.sgd.lr_bmax = v.as_f64().context("sgd.lr_bmax must be a number")? as f32;
        }
        usize_of(map, "sgd.mega_batches", &mut cfg.sgd.mega_batches)?;
        usize_of(map, "sgd.num_mega_batches", &mut cfg.sgd.num_mega_batches)?;
        cfg.sgd.initial_batch = cfg.sgd.b_max;
        usize_of(map, "sgd.initial_batch", &mut cfg.sgd.initial_batch)?;
        usize_of(map, "sgd.warmup_mega_batches", &mut cfg.sgd.warmup_mega_batches)?;
        usize_of(map, "sgd.scaling_window", &mut cfg.sgd.scaling_window)?;
        usize_of(map, "sgd.scaling_cooldown", &mut cfg.sgd.scaling_cooldown)?;
        u64_of(map, "sgd.seed", &mut cfg.sgd.seed)?;

        f64_of(map, "merge.pert_thr", &mut cfg.merge.pert_thr)?;
        f64_of(map, "merge.delta", &mut cfg.merge.delta)?;
        f64_of(map, "merge.momentum", &mut cfg.merge.momentum)?;
        if let Some(v) = map.get("merge.perturbation") {
            cfg.merge.perturbation = v.as_bool().context("merge.perturbation must be a bool")?;
        }
        if let Some(v) = map.get("merge.normalization") {
            cfg.merge.normalization =
                Normalization::parse(v.as_str().context("merge.normalization must be a string")?)?;
        }

        usize_of(map, "devices.count", &mut cfg.devices.count)?;
        if let Some(v) = map.get("devices.speed_factors") {
            cfg.devices.speed_factors =
                v.as_f64_arr().context("devices.speed_factors must be a number array")?;
        } else if cfg.devices.count != cfg.devices.speed_factors.len() {
            // Spread factors evenly up to the paper's ~32% gap.
            let n = cfg.devices.count;
            cfg.devices.speed_factors = (0..n)
                .map(|i| 1.0 + 0.32 * i as f64 / (n.max(2) - 1) as f64)
                .collect();
        }
        f64_of(map, "devices.jitter", &mut cfg.devices.jitter)?;
        f64_of(map, "devices.nnz_sensitivity", &mut cfg.devices.nnz_sensitivity)?;
        u64_of(map, "devices.seed", &mut cfg.devices.seed)?;

        if let Some(v) = map.get("runtime.artifacts_dir") {
            cfg.runtime.artifacts_dir =
                v.as_str().context("runtime.artifacts_dir must be a string")?.to_string();
        }
        if let Some(v) = map.get("runtime.mode") {
            cfg.runtime.mode = ExecMode::parse(v.as_str().context("runtime.mode must be a string")?)?;
        }

        if let Some(v) = map.get("strategy.kind") {
            cfg.strategy.kind = Strategy::parse(v.as_str().context("strategy.kind must be a string")?)?;
        }
        if let Some(v) = map.get("strategy.batch_scaling") {
            cfg.strategy.batch_scaling =
                v.as_bool().context("strategy.batch_scaling must be a bool")?;
        }
        f64_of(map, "strategy.crossbow_rate", &mut cfg.strategy.crossbow_rate)?;
        f64_of(map, "strategy.sync_overhead", &mut cfg.strategy.sync_overhead)?;

        if let Some(v) = map.get("elastic.events") {
            cfg.elastic.events =
                v.as_str_arr().context("elastic.events must be a string array")?;
        }
        if let Some(v) = map.get("elastic.spare_devices") {
            cfg.elastic.spare_devices =
                v.as_f64_arr().context("elastic.spare_devices must be a number array")?;
        }
        f64_of(map, "elastic.straggler_factor", &mut cfg.elastic.straggler_factor)?;
        usize_of(map, "elastic.straggler_window", &mut cfg.elastic.straggler_window)?;
        usize_of(map, "elastic.quarantine_mega_batches", &mut cfg.elastic.quarantine_mega_batches)?;
        usize_of(map, "elastic.min_devices", &mut cfg.elastic.min_devices)?;

        usize_of(map, "serve.max_batch", &mut cfg.serve.max_batch)?;
        f64_of(map, "serve.max_delay", &mut cfg.serve.max_delay)?;
        f64_of(map, "serve.rate", &mut cfg.serve.rate)?;
        f64_of(map, "serve.duration", &mut cfg.serve.duration)?;
        f64_of(map, "serve.window", &mut cfg.serve.window)?;
        if let Some(v) = map.get("serve.pattern") {
            cfg.serve.pattern =
                ServePattern::parse(v.as_str().context("serve.pattern must be a string")?)?;
        }
        f64_of(map, "serve.burst_factor", &mut cfg.serve.burst_factor)?;
        f64_of(map, "serve.burst_period", &mut cfg.serve.burst_period)?;
        f64_of(map, "serve.burst_fraction", &mut cfg.serve.burst_fraction)?;
        f64_of(map, "serve.nnz_bias", &mut cfg.serve.nnz_bias)?;
        usize_of(map, "serve.publish_every", &mut cfg.serve.publish_every)?;
        if let Some(v) = map.get("serve.events") {
            cfg.serve.events = v.as_str_arr().context("serve.events must be a string array")?;
        }
        u64_of(map, "serve.seed", &mut cfg.serve.seed)?;

        f64_of(map, "fleet.decision_window", &mut cfg.fleet.decision_window)?;
        f64_of(map, "fleet.grace", &mut cfg.fleet.grace)?;
        f64_of(map, "fleet.slo_p95_ms", &mut cfg.fleet.slo_p95_ms)?;
        usize_of(map, "fleet.breach_windows", &mut cfg.fleet.breach_windows)?;
        usize_of(map, "fleet.clear_windows", &mut cfg.fleet.clear_windows)?;
        if let Some(v) = map.get("fleet.preemption") {
            cfg.fleet.preemption = v.as_bool().context("fleet.preemption must be a bool")?;
        }
        f64_of(map, "fleet.serve_weight", &mut cfg.fleet.serve_weight)?;
        if let Some(v) = map.get("fleet.train_weights") {
            cfg.fleet.train_weights =
                v.as_f64_arr().context("fleet.train_weights must be a number array")?;
        }
        if let Some(v) = map.get("fleet.events") {
            cfg.fleet.events = v.as_str_arr().context("fleet.events must be a string array")?;
        }

        if let Some(v) = map.get("calibration.enabled") {
            cfg.calibration.enabled = v.as_bool().context("calibration.enabled must be a bool")?;
        }
        usize_of(map, "calibration.window", &mut cfg.calibration.window)?;
        f64_of(map, "calibration.alpha", &mut cfg.calibration.alpha)?;
        f64_of(map, "calibration.step_threshold", &mut cfg.calibration.step_threshold)?;
        usize_of(map, "calibration.step_obs", &mut cfg.calibration.step_obs)?;
        if let Some(v) = map.get("calibration.events") {
            cfg.calibration.events =
                v.as_str_arr().context("calibration.events must be a string array")?;
        }

        usize_of(map, "slide.threads", &mut cfg.slide.threads)?;
        f64_of(map, "slide.lr", &mut cfg.slide.lr)?;
        usize_of(map, "slide.tables", &mut cfg.slide.tables)?;
        usize_of(map, "slide.bits", &mut cfg.slide.bits)?;
        usize_of(map, "slide.random_negatives", &mut cfg.slide.random_negatives)?;
        u64_of(map, "slide.rebuild_every", &mut cfg.slide.rebuild_every)?;
        u64_of(map, "slide.seed", &mut cfg.slide.seed)?;
        if let Some(v) = map.get("slide.adaptive") {
            cfg.slide.adaptive = v.as_bool().context("slide.adaptive must be a bool")?;
        }
        f64_of(map, "slide.min_ratio", &mut cfg.slide.min_ratio)?;
        f64_of(map, "slide.ratio_step", &mut cfg.slide.ratio_step)?;
        f64_of(map, "slide.quality_discount", &mut cfg.slide.quality_discount)?;
        f64_of(map, "slide.serve_ratio", &mut cfg.slide.serve_ratio)?;
        f64_of(map, "slide.serve_slo_ms", &mut cfg.slide.serve_slo_ms)?;

        usize_of(map, "cluster.servers", &mut cfg.cluster.servers)?;
        usize_of(map, "cluster.sync_every", &mut cfg.cluster.sync_every)?;
        if let Some(v) = map.get("cluster.adaptive") {
            cfg.cluster.adaptive = v.as_bool().context("cluster.adaptive must be a bool")?;
        }
        usize_of(map, "cluster.min_sync_every", &mut cfg.cluster.min_sync_every)?;
        usize_of(map, "cluster.max_sync_every", &mut cfg.cluster.max_sync_every)?;
        f64_of(map, "cluster.comm_target", &mut cfg.cluster.comm_target)?;
        f64_of(map, "cluster.link_latency_s", &mut cfg.cluster.link_latency_s)?;
        f64_of(map, "cluster.link_gbytes_per_sec", &mut cfg.cluster.link_gbytes_per_sec)?;
        if let Some(v) = map.get("cluster.algo") {
            cfg.cluster.algo =
                v.as_str().context("cluster.algo must be a string (ring|tree)")?.to_string();
        }
        usize_of(map, "cluster.streams", &mut cfg.cluster.streams)?;
        if let Some(v) = map.get("cluster.server_speed_factors") {
            cfg.cluster.server_speed_factors = v
                .as_f64_arr()
                .context("cluster.server_speed_factors must be a number array")?;
        }
        if let Some(v) = map.get("cluster.events") {
            cfg.cluster.events =
                v.as_str_arr().context("cluster.events must be a string array")?;
        }
        f64_of(map, "cluster.straggler_floor", &mut cfg.cluster.straggler_floor)?;

        if let Some(v) = map.get("obs.enabled") {
            cfg.obs.enabled = v.as_bool().context("obs.enabled must be a bool")?;
        }
        if let Some(v) = map.get("obs.level") {
            cfg.obs.level =
                v.as_str().context("obs.level must be a string (info|debug)")?.to_string();
        }
        if let Some(v) = map.get("obs.subsystems") {
            cfg.obs.subsystems =
                v.as_str_arr().context("obs.subsystems must be a string array")?;
        }
        usize_of(map, "obs.buffer_events", &mut cfg.obs.buffer_events)?;

        if let Some(v) = map.get("scenario.events") {
            cfg.scenario.events =
                v.as_str_arr().context("scenario.events must be a string array")?;
        }
        cfg.apply_scenario()?;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Route the `[scenario]` block's compound lines into the
    /// per-subsystem event lists (canonical grammar form appended after
    /// any directly-configured events). `from_map` calls this once; call
    /// it yourself exactly once when populating `scenario.events` on a
    /// hand-built config.
    pub fn apply_scenario(&mut self) -> Result<()> {
        use crate::scenario::Target;
        for (i, line) in self.scenario.events.clone().iter().enumerate() {
            let routed = crate::scenario::route_line(line)
                .with_context(|| format!("scenario.events[{i}]: '{line}'"))?;
            for (target, ev) in routed {
                let list = match target {
                    Target::Elastic => &mut self.elastic.events,
                    Target::Calibration => &mut self.calibration.events,
                    Target::Serve => &mut self.serve.events,
                    Target::Fleet => &mut self.fleet.events,
                    Target::Cluster => &mut self.cluster.events,
                };
                list.push(ev.to_string());
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        let m = &self.model;
        if m.features == 0 || m.hidden == 0 || m.classes == 0 {
            bail!("model dims must be positive");
        }
        if m.max_nnz == 0 || m.max_labels == 0 {
            bail!("max_nnz / max_labels must be positive");
        }
        let s = &self.sgd;
        if s.b_min == 0 || s.b_max < s.b_min {
            bail!("need 0 < b_min <= b_max (got {} / {})", s.b_min, s.b_max);
        }
        if s.beta == 0 || (s.b_max - s.b_min) % s.beta != 0 {
            bail!("beta must divide b_max - b_min (got beta={} range={})", s.beta, s.b_max - s.b_min);
        }
        if s.initial_batch < s.b_min || s.initial_batch > s.b_max {
            bail!("initial_batch {} outside [{}, {}]", s.initial_batch, s.b_min, s.b_max);
        }
        if (s.initial_batch - s.b_min) % s.beta != 0 {
            bail!("initial_batch must lie on the batch-size grid");
        }
        if s.scaling_window < 4 {
            bail!(
                "sgd.scaling_window must be >= 4 (the oscillation pattern spans four \
                 snapshots; got {})",
                s.scaling_window
            );
        }
        if s.scaling_cooldown == 0 {
            bail!("sgd.scaling_cooldown must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.merge.momentum) {
            bail!("merge.momentum must be in [0, 1]");
        }
        if self.merge.delta < 0.0 || self.merge.delta >= 1.0 {
            bail!("merge.delta must be in [0, 1)");
        }
        if self.devices.count == 0 {
            bail!("devices.count must be positive");
        }
        if self.devices.speed_factors.len() != self.devices.count {
            bail!(
                "devices.speed_factors has {} entries for {} devices",
                self.devices.speed_factors.len(),
                self.devices.count
            );
        }
        if self.devices.speed_factors.iter().any(|&f| f <= 0.0) {
            bail!("speed factors must be positive");
        }
        if self.data.train_samples == 0 || self.data.test_samples == 0 {
            bail!("dataset sizes must be positive");
        }
        let p = &self.data.pipeline;
        if p.queue_depth == 0 {
            bail!("data.pipeline.queue_depth must be positive");
        }
        if p.producer_threads > 64 {
            bail!("data.pipeline.producer_threads must be <= 64 (got {})", p.producer_threads);
        }
        if p.shard_samples == 0 {
            bail!("data.pipeline.shard_samples must be positive");
        }
        let e = &self.elastic;
        let events = e.parsed_events()?;
        let roster = self.devices.count + e.spare_devices.len();
        for ev in &events {
            if let ElasticOp::RemoveId(id) | ElasticOp::AddId(id) = ev.op {
                if id >= roster {
                    bail!(
                        "elastic event targets device {id} but the roster has {roster} \
                         devices (devices.count + elastic.spare_devices)"
                    );
                }
            }
        }
        if e.spare_devices.iter().any(|&f| f <= 0.0) {
            bail!("elastic.spare_devices factors must be positive");
        }
        if e.straggler_factor < 0.0 {
            bail!("elastic.straggler_factor must be non-negative");
        }
        if e.straggler_factor > 0.0 && e.straggler_factor <= 1.0 {
            bail!("elastic.straggler_factor must exceed 1.0 (it multiplies the fleet median)");
        }
        if e.straggler_window == 0 {
            bail!("elastic.straggler_window must be positive");
        }
        if e.min_devices == 0 || e.min_devices > self.devices.count {
            bail!(
                "elastic.min_devices must be in [1, devices.count] (got {} of {})",
                e.min_devices,
                self.devices.count
            );
        }
        let sv = &self.serve;
        if sv.max_batch != 0 && !self.bucket_grid().contains(&sv.max_batch) {
            bail!(
                "serve.max_batch {} must lie on the batch-size grid {:?} (0 = b_max)",
                sv.max_batch,
                self.bucket_grid()
            );
        }
        if sv.max_delay <= 0.0 {
            bail!("serve.max_delay must be positive seconds");
        }
        if sv.rate <= 0.0 || sv.duration <= 0.0 || sv.window <= 0.0 {
            bail!("serve.rate / serve.duration / serve.window must be positive");
        }
        if sv.burst_factor < 1.0 {
            bail!("serve.burst_factor must be >= 1.0 (it multiplies the base rate)");
        }
        if sv.burst_period <= 0.0 || !(0.0..1.0).contains(&sv.burst_fraction)
            || sv.burst_fraction == 0.0
        {
            bail!("serve.burst_period must be positive and serve.burst_fraction in (0, 1)");
        }
        if sv.nnz_bias < 0.0 {
            bail!("serve.nnz_bias must be non-negative");
        }
        if sv.publish_every == 0 {
            bail!("serve.publish_every must be positive");
        }
        for (i, s) in sv.events.iter().enumerate() {
            let ev = ElasticEvent::parse(s)
                .with_context(|| format!("serve.events[{i}]: '{s}'"))?;
            if let ElasticOp::RemoveId(id) | ElasticOp::AddId(id) = ev.op {
                if id >= roster {
                    bail!(
                        "serve.events[{i}] targets device {id} but the roster has {roster} devices"
                    );
                }
            }
        }
        let fl = &self.fleet;
        if fl.decision_window <= 0.0 {
            bail!("fleet.decision_window must be positive seconds");
        }
        if fl.grace <= 0.0 {
            bail!("fleet.grace must be positive seconds");
        }
        if fl.slo_p95_ms <= 0.0 {
            bail!("fleet.slo_p95_ms must be positive milliseconds");
        }
        if fl.breach_windows == 0 || fl.clear_windows == 0 {
            bail!("fleet.breach_windows / fleet.clear_windows must be >= 1");
        }
        if fl.serve_weight <= 0.0 {
            bail!("fleet.serve_weight must be positive");
        }
        if fl.train_weights.is_empty() || fl.train_weights.iter().any(|&w| w <= 0.0) {
            bail!("fleet.train_weights must be a non-empty array of positive weights");
        }
        for (i, s) in fl.events.iter().enumerate() {
            let ev = ElasticEvent::parse(s)
                .with_context(|| format!("fleet.events[{i}]: '{s}'"))?;
            if let ElasticOp::RemoveId(id) | ElasticOp::AddId(id) = ev.op {
                if id >= roster {
                    bail!(
                        "fleet.events[{i}] targets device {id} but the roster has {roster} devices"
                    );
                }
            }
        }
        let cal = &self.calibration;
        if cal.window < 3 {
            bail!("calibration.window must be >= 3 (the robust fit needs history; got {})", cal.window);
        }
        if !(cal.alpha > 0.0 && cal.alpha <= 1.0) {
            bail!("calibration.alpha must be in (0, 1]");
        }
        if cal.step_threshold <= 0.0 {
            bail!("calibration.step_threshold must be positive");
        }
        if cal.step_obs == 0 {
            bail!("calibration.step_obs must be >= 1");
        }
        for ev in cal.parsed_events()? {
            if ev.device >= roster {
                bail!(
                    "calibration event targets device {} but the roster has {roster} devices",
                    ev.device
                );
            }
        }
        let sl = &self.slide;
        if sl.threads == 0 {
            bail!("slide.threads must be >= 1");
        }
        if sl.lr < 0.0 {
            bail!("slide.lr must be >= 0 (0 = derive from sgd.lr_bmax)");
        }
        if sl.tables == 0 {
            bail!("slide.tables must be >= 1");
        }
        if sl.bits == 0 || sl.bits > 31 {
            bail!("slide.bits must be in 1..=31 (got {})", sl.bits);
        }
        if sl.random_negatives == 0 {
            bail!("slide.random_negatives must be >= 1 (a lone label has zero gradient)");
        }
        if sl.rebuild_every == 0 {
            bail!("slide.rebuild_every must be >= 1");
        }
        if !(sl.min_ratio > 0.0 && sl.min_ratio <= 1.0) {
            bail!("slide.min_ratio must be in (0, 1]");
        }
        if !(sl.ratio_step > 0.0 && sl.ratio_step < 1.0) {
            bail!("slide.ratio_step must be in (0, 1)");
        }
        if sl.quality_discount < 0.0 {
            bail!("slide.quality_discount must be >= 0");
        }
        if !(sl.serve_ratio > 0.0 && sl.serve_ratio <= 1.0) {
            bail!("slide.serve_ratio must be in (0, 1]");
        }
        if sl.serve_slo_ms < 0.0 {
            bail!("slide.serve_slo_ms must be >= 0 (0 = always exact)");
        }
        let cl = &self.cluster;
        if cl.servers == 0 {
            bail!("cluster.servers must be >= 1 (1 = the cluster plane is inert)");
        }
        if cl.sync_every == 0 {
            bail!("cluster.sync_every must be >= 1 mega-batch");
        }
        if cl.min_sync_every == 0 {
            bail!("cluster.min_sync_every must be >= 1");
        }
        if cl.max_sync_every < cl.min_sync_every {
            bail!(
                "cluster.max_sync_every ({}) must be >= cluster.min_sync_every ({})",
                cl.max_sync_every,
                cl.min_sync_every
            );
        }
        if !(cl.comm_target > 0.0 && cl.comm_target < 1.0) {
            bail!("cluster.comm_target must be in (0, 1)");
        }
        if cl.link_latency_s < 0.0 {
            bail!("cluster.link_latency_s must be >= 0");
        }
        if cl.link_gbytes_per_sec <= 0.0 {
            bail!("cluster.link_gbytes_per_sec must be positive");
        }
        if cl.algo != "ring" && cl.algo != "tree" {
            bail!("cluster.algo '{}' must be \"ring\" or \"tree\"", cl.algo);
        }
        if cl.streams == 0 {
            bail!("cluster.streams must be >= 1");
        }
        if !cl.server_speed_factors.is_empty() {
            if cl.server_speed_factors.len() != cl.servers {
                bail!(
                    "cluster.server_speed_factors has {} entries for {} servers",
                    cl.server_speed_factors.len(),
                    cl.servers
                );
            }
            if cl.server_speed_factors.iter().any(|&f| f <= 0.0) {
                bail!("cluster.server_speed_factors entries must be positive");
            }
        }
        if !(0.0..1.0).contains(&cl.straggler_floor) {
            bail!("cluster.straggler_floor must be in [0, 1) (0 disables demotion)");
        }
        for ev in cl.parsed_events()? {
            match ev {
                crate::cluster::ClusterEvent::Link(d) if d.device >= cl.servers => bail!(
                    "cluster event throttles link {} but cluster.servers is {}",
                    d.device,
                    cl.servers
                ),
                crate::cluster::ClusterEvent::Rack { server, .. } if server >= cl.servers => {
                    bail!(
                        "cluster event targets server {server} but cluster.servers is {}",
                        cl.servers
                    )
                }
                _ => {}
            }
        }
        let ob = &self.obs;
        if ob.level != "info" && ob.level != "debug" {
            bail!("obs.level '{}' must be \"info\" or \"debug\"", ob.level);
        }
        for s in &ob.subsystems {
            if !OBS_SUBSYSTEMS.contains(&s.as_str()) {
                bail!("obs.subsystems entry '{s}' not one of {OBS_SUBSYSTEMS:?}");
            }
        }
        if ob.buffer_events == 0 {
            bail!("obs.buffer_events must be >= 1");
        }
        Ok(())
    }

    /// The serving micro-batch ceiling: `serve.max_batch`, defaulting to
    /// `sgd.b_max` when left at 0.
    pub fn serve_max_batch(&self) -> usize {
        if self.serve.max_batch == 0 {
            self.sgd.b_max
        } else {
            self.serve.max_batch
        }
    }

    /// The batch-size grid {b_min, b_min+beta, ..., b_max}.
    pub fn bucket_grid(&self) -> Vec<usize> {
        (self.sgd.b_min..=self.sgd.b_max).step_by(self.sgd.beta).collect()
    }

    /// Linear-scaling learning rate for batch size `b` (paper [19]).
    pub fn lr_for_batch(&self, b: usize) -> f32 {
        self.sgd.lr_bmax * b as f32 / self.sgd.b_max as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate_and_match_aot() {
        let cfg = Config::default();
        cfg.validate().unwrap();
        // Must match python/compile/aot.py defaults.
        assert_eq!(cfg.model.features, 8192);
        assert_eq!(cfg.model.hidden, 64);
        assert_eq!(cfg.model.classes, 1024);
        assert_eq!(cfg.bucket_grid().len(), 15);
        assert_eq!(cfg.bucket_grid()[0], 16);
        assert_eq!(*cfg.bucket_grid().last().unwrap(), 128);
    }

    #[test]
    fn linear_lr_scaling() {
        let cfg = Config::default();
        assert!((cfg.lr_for_batch(128) - 0.05).abs() < 1e-9);
        assert!((cfg.lr_for_batch(64) - 0.025).abs() < 1e-9);
        assert!((cfg.lr_for_batch(16) - 0.00625).abs() < 1e-9);
    }

    #[test]
    fn overrides_apply() {
        let cfg = Config::from_overrides(&[
            ("sgd.b_min".into(), "8".into()),
            ("sgd.b_max".into(), "64".into()),
            ("sgd.beta".into(), "8".into()),
            ("devices.count".into(), "2".into()),
            ("devices.speed_factors".into(), "[1.0, 1.3]".into()),
            ("strategy.kind".into(), "elastic".into()),
            ("data.profile".into(), "delicious".into()),
        ])
        .unwrap();
        assert_eq!(cfg.sgd.b_max, 64);
        assert_eq!(cfg.strategy.kind, Strategy::Elastic);
        assert_eq!(cfg.data.profile, DataProfile::Delicious);
        assert_eq!(cfg.data.avg_labels, 6.0);
        assert_eq!(cfg.sgd.initial_batch, 64, "initial batch follows b_max");
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Config::from_overrides(&[("sgd.beta".into(), "9".into())]).is_err());
        assert!(Config::from_overrides(&[("devices.count".into(), "0".into())]).is_err());
        assert!(Config::from_overrides(&[("merge.momentum".into(), "1.5".into())]).is_err());
        assert!(Config::from_overrides(&[
            ("devices.count".into(), "3".into()),
            ("devices.speed_factors".into(), "[1.0, 1.1]".into()),
        ])
        .is_err());
    }

    #[test]
    fn obs_config_parses_and_validates() {
        let cfg = Config::default();
        assert!(!cfg.obs.enabled, "obs is inert by default");
        assert_eq!(cfg.obs.level, "info");
        assert_eq!(cfg.obs.buffer_events, 65536);
        let cfg = Config::from_overrides(&[
            ("obs.enabled".into(), "true".into()),
            ("obs.level".into(), "debug".into()),
            ("obs.subsystems".into(), "[\"train\", \"cluster\"]".into()),
            ("obs.buffer_events".into(), "128".into()),
        ])
        .unwrap();
        assert!(cfg.obs.enabled);
        assert_eq!(cfg.obs.level, "debug");
        assert_eq!(cfg.obs.subsystems, vec!["train".to_string(), "cluster".to_string()]);
        assert_eq!(cfg.obs.buffer_events, 128);
        assert!(Config::from_overrides(&[("obs.level".into(), "verbose".into())]).is_err());
        assert!(
            Config::from_overrides(&[("obs.subsystems".into(), "[\"disk\"]".into())]).is_err()
        );
        assert!(Config::from_overrides(&[("obs.buffer_events".into(), "0".into())]).is_err());
    }

    #[test]
    fn scaling_controller_knobs_parse_and_validate() {
        let cfg = Config::default();
        assert_eq!((cfg.sgd.scaling_window, cfg.sgd.scaling_cooldown), (4, 3));
        let cfg = Config::from_overrides(&[
            ("sgd.scaling_window".into(), "6".into()),
            ("sgd.scaling_cooldown".into(), "1".into()),
        ])
        .unwrap();
        assert_eq!(cfg.sgd.scaling_window, 6);
        assert_eq!(cfg.sgd.scaling_cooldown, 1);
        assert!(Config::from_overrides(&[("sgd.scaling_window".into(), "3".into())]).is_err());
        assert!(Config::from_overrides(&[("sgd.scaling_cooldown".into(), "0".into())]).is_err());
    }

    #[test]
    fn elastic_events_parse_and_validate() {
        let ev = ElasticEvent::parse("at_mb=20 remove=2").unwrap();
        assert_eq!(ev, ElasticEvent { at_mb: 20, op: ElasticOp::Remove(2) });
        let ev = ElasticEvent::parse("add_id=3 at_mb=5").unwrap();
        assert_eq!(ev, ElasticEvent { at_mb: 5, op: ElasticOp::AddId(3) });
        assert!(ElasticEvent::parse("remove=1").is_err(), "missing at_mb");
        assert!(ElasticEvent::parse("at_mb=1").is_err(), "missing op");
        assert!(
            ElasticEvent::parse("at_mb=5 remove=1 add=1").is_err(),
            "one operation per event string"
        );
        assert!(ElasticEvent::parse("at_mb=5 at_mb=6 add=1").is_err(), "duplicate at_mb");
        assert!(ElasticEvent::parse("at_mb=1 remove=0").is_err(), "no-op count");
        assert!(ElasticEvent::parse("at_mb=x remove=1").is_err());
        assert!(ElasticEvent::parse("at_mb=1 explode=1").is_err());

        let cfg = Config::from_overrides(&[(
            "elastic.events".into(),
            "[\"at_mb=2 remove=1\", \"at_mb=4 add=1\"]".into(),
        )])
        .unwrap();
        assert_eq!(cfg.elastic.parsed_events().unwrap().len(), 2);
        // Events come back sorted by mega-batch.
        let cfg2 = Config::from_overrides(&[(
            "elastic.events".into(),
            "[\"at_mb=9 add=1\", \"at_mb=2 remove=1\"]".into(),
        )])
        .unwrap();
        assert_eq!(cfg2.elastic.parsed_events().unwrap()[0].at_mb, 2);
    }

    #[test]
    fn invalid_elastic_configs_rejected() {
        assert!(Config::from_overrides(&[(
            "elastic.events".into(),
            "[\"at_mb=1 frobnicate=2\"]".into(),
        )])
        .is_err());
        assert!(Config::from_overrides(&[(
            "elastic.events".into(),
            "[\"at_mb=1 remove_id=99\"]".into(),
        )])
        .is_err(), "out-of-roster device id");
        assert!(Config::from_overrides(&[("elastic.min_devices".into(), "0".into())]).is_err());
        assert!(Config::from_overrides(&[("elastic.min_devices".into(), "9".into())]).is_err());
        assert!(
            Config::from_overrides(&[("elastic.straggler_factor".into(), "0.5".into())]).is_err()
        );
        assert!(Config::from_overrides(&[("elastic.straggler_window".into(), "0".into())]).is_err());
        // Spares extend the addressable roster.
        assert!(Config::from_overrides(&[
            ("elastic.spare_devices".into(), "[1.2]".into()),
            ("elastic.events".into(), "[\"at_mb=1 add_id=4\"]".into()),
        ])
        .is_ok());
    }

    #[test]
    fn pipeline_section_parses_and_validates() {
        let cfg = Config::from_overrides(&[
            ("data.pipeline.queue_depth".into(), "4".into()),
            ("data.pipeline.producer_threads".into(), "3".into()),
            ("data.pipeline.policy".into(), "nnz_balanced".into()),
            ("data.pipeline.shard_samples".into(), "512".into()),
        ])
        .unwrap();
        assert_eq!(cfg.data.pipeline.queue_depth, 4);
        assert_eq!(cfg.data.pipeline.producer_threads, 3);
        assert_eq!(cfg.data.pipeline.policy, CompositionPolicy::NnzBalanced);
        assert_eq!(cfg.data.pipeline.shard_samples, 512);

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("data.pipeline.queue_depth", "0");
        reject("data.pipeline.shard_samples", "0");
        reject("data.pipeline.policy", "frobnicate");
        assert!(CompositionPolicy::parse("nnz-sorted").is_ok());
        for p in CompositionPolicy::all() {
            assert_eq!(CompositionPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let cfg = Config::from_overrides(&[
            ("serve.max_batch".into(), "64".into()),
            ("serve.max_delay".into(), "0.004".into()),
            ("serve.rate".into(), "12000".into()),
            ("serve.pattern".into(), "bursty".into()),
            ("serve.publish_every".into(), "3".into()),
            ("serve.nnz_bias".into(), "1.5".into()),
            ("serve.events".into(), "[\"at_mb=2 remove=1\"]".into()),
        ])
        .unwrap();
        assert_eq!(cfg.serve.max_batch, 64);
        assert_eq!(cfg.serve_max_batch(), 64);
        assert_eq!(cfg.serve.pattern, ServePattern::Bursty);
        assert_eq!(cfg.serve.publish_every, 3);
        assert_eq!(cfg.serve.events.len(), 1);
        // max_batch 0 resolves to b_max.
        assert_eq!(Config::default().serve_max_batch(), 128);

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("serve.max_batch", "100"); // off the 16..128 step-8 grid
        reject("serve.max_delay", "0");
        reject("serve.rate", "0");
        reject("serve.window", "-1");
        reject("serve.pattern", "fractal");
        reject("serve.burst_factor", "0.5");
        reject("serve.burst_fraction", "1.5");
        reject("serve.publish_every", "0");
        reject("serve.events", "[\"at_mb=1 remove_id=99\"]");
        for p in ServePattern::all() {
            assert_eq!(ServePattern::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn fleet_section_parses_and_validates() {
        let cfg = Config::from_overrides(&[
            ("fleet.decision_window".into(), "0.5".into()),
            ("fleet.grace".into(), "1.0".into()),
            ("fleet.slo_p95_ms".into(), "3.5".into()),
            ("fleet.breach_windows".into(), "3".into()),
            ("fleet.clear_windows".into(), "1".into()),
            ("fleet.preemption".into(), "false".into()),
            ("fleet.serve_weight".into(), "2.0".into()),
            ("fleet.train_weights".into(), "[1.0, 3.0, 1.0]".into()),
            ("fleet.events".into(), "[\"at_mb=4 remove=1\"]".into()),
        ])
        .unwrap();
        assert_eq!(cfg.fleet.decision_window, 0.5);
        assert_eq!(cfg.fleet.slo_p95_ms, 3.5);
        assert!(!cfg.fleet.preemption);
        assert_eq!(cfg.fleet.train_weights.len(), 3);
        assert_eq!(cfg.fleet.events.len(), 1);
        // Defaults: two equally-weighted training tenants, preemption on.
        let d = Config::default();
        assert_eq!(d.fleet.train_weights, vec![1.0, 1.0]);
        assert!(d.fleet.preemption);

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("fleet.decision_window", "0");
        reject("fleet.grace", "-1");
        reject("fleet.slo_p95_ms", "0");
        reject("fleet.breach_windows", "0");
        reject("fleet.clear_windows", "0");
        reject("fleet.serve_weight", "0");
        reject("fleet.train_weights", "[]");
        reject("fleet.train_weights", "[1.0, 0.0]");
        reject("fleet.events", "[\"at_mb=1 remove_id=99\"]");
        reject("fleet.events", "[\"garbage\"]");
    }

    #[test]
    fn calibration_section_parses_and_validates() {
        let cfg = Config::from_overrides(&[
            ("calibration.enabled".into(), "true".into()),
            ("calibration.window".into(), "8".into()),
            ("calibration.alpha".into(), "0.5".into()),
            ("calibration.step_threshold".into(), "0.3".into()),
            ("calibration.step_obs".into(), "1".into()),
            ("calibration.events".into(), "[\"at_mb=4 device=0 factor=1.8 ramp=2\"]".into()),
        ])
        .unwrap();
        assert!(cfg.calibration.enabled);
        assert_eq!(cfg.calibration.window, 8);
        assert_eq!(cfg.calibration.step_obs, 1);
        let trace = cfg.calibration.parsed_events().unwrap();
        assert_eq!(trace.len(), 1);
        assert_eq!(trace[0].device, 0);
        // Defaults: the plane is inert.
        let d = Config::default();
        assert!(!d.calibration.enabled);
        assert!(d.calibration.events.is_empty());

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("calibration.window", "2");
        reject("calibration.alpha", "0");
        reject("calibration.alpha", "1.5");
        reject("calibration.step_threshold", "0");
        reject("calibration.step_obs", "0");
        reject("calibration.events", "[\"at_mb=1 device=99 factor=2\"]");
        reject("calibration.events", "[\"garbage\"]");
        // Spares extend the addressable roster, as for elastic events.
        assert!(Config::from_overrides(&[
            ("elastic.spare_devices".into(), "[1.2]".into()),
            ("calibration.events".into(), "[\"at_mb=1 device=4 factor=2\"]".into()),
        ])
        .is_ok());
    }

    #[test]
    fn slide_section_parses_and_validates() {
        let cfg = Config::from_overrides(&[
            ("slide.threads".into(), "2".into()),
            ("slide.lr".into(), "0.2".into()),
            ("slide.tables".into(), "4".into()),
            ("slide.bits".into(), "7".into()),
            ("slide.random_negatives".into(), "8".into()),
            ("slide.rebuild_every".into(), "64".into()),
            ("slide.seed".into(), "17".into()),
            ("slide.adaptive".into(), "true".into()),
            ("slide.min_ratio".into(), "0.1".into()),
            ("slide.ratio_step".into(), "0.3".into()),
            ("slide.quality_discount".into(), "1.0".into()),
            ("slide.serve_ratio".into(), "0.5".into()),
            ("slide.serve_slo_ms".into(), "40".into()),
        ])
        .unwrap();
        assert_eq!(cfg.slide.threads, 2);
        assert_eq!(cfg.slide.bits, 7);
        assert_eq!(cfg.slide.rebuild_every, 64);
        assert!(cfg.slide.adaptive);
        assert_eq!(cfg.slide.serve_slo_ms, 40.0);
        // Defaults: the lever is inert (exact dense everywhere).
        let d = Config::default();
        assert!(!d.slide.adaptive);
        assert_eq!(d.slide.serve_slo_ms, 0.0);
        assert_eq!(d.slide.lr, 0.0, "0 = derive from sgd.lr_bmax");

        // Ladder: strictly decreasing from 1.0 to exactly min_ratio.
        let ladder = cfg.slide.ratio_ladder();
        assert_eq!(ladder.first(), Some(&1.0));
        assert_eq!(ladder.last(), Some(&0.1));
        assert!(ladder.windows(2).all(|w| w[0] > w[1]), "{ladder:?}");

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("slide.threads", "0");
        reject("slide.lr", "-0.1");
        reject("slide.tables", "0");
        reject("slide.bits", "0");
        reject("slide.bits", "32");
        reject("slide.random_negatives", "0");
        reject("slide.rebuild_every", "0");
        reject("slide.min_ratio", "0");
        reject("slide.min_ratio", "1.5");
        reject("slide.ratio_step", "0");
        reject("slide.ratio_step", "1.0");
        reject("slide.quality_discount", "-1");
        reject("slide.serve_ratio", "0");
        reject("slide.serve_slo_ms", "-5");
    }

    #[test]
    fn device_factors_autospread() {
        let cfg = Config::from_overrides(&[("devices.count".into(), "2".into())]).unwrap();
        assert_eq!(cfg.devices.speed_factors.len(), 2);
        assert!((cfg.devices.speed_factors[1] - 1.32).abs() < 1e-9);
    }

    #[test]
    fn cluster_section_parses_and_validates() {
        let d = Config::default();
        assert_eq!(d.cluster.servers, 1, "default is the inert single-server plane");
        assert!(d.cluster.events.is_empty());

        let cfg = Config::from_overrides(&[
            ("cluster.servers".into(), "3".into()),
            ("cluster.sync_every".into(), "2".into()),
            ("cluster.adaptive".into(), "false".into()),
            ("cluster.min_sync_every".into(), "2".into()),
            ("cluster.max_sync_every".into(), "8".into()),
            ("cluster.comm_target".into(), "0.2".into()),
            ("cluster.link_latency_s".into(), "0.002".into()),
            ("cluster.link_gbytes_per_sec".into(), "2.5".into()),
            ("cluster.algo".into(), "tree".into()),
            ("cluster.streams".into(), "2".into()),
            ("cluster.straggler_floor".into(), "0.5".into()),
            (
                "cluster.events".into(),
                "[\"at_mb=6 link=1 factor=4 ramp=2\", \"at_mb=4 server=2 down\"]".into(),
            ),
        ])
        .unwrap();
        assert_eq!(cfg.cluster.servers, 3);
        assert!(!cfg.cluster.adaptive);
        assert_eq!(cfg.cluster.algo, "tree");
        let trace = cfg.cluster.parsed_events().unwrap();
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].at_mb(), 4, "trace sorts by mega-batch");

        let reject = |key: &str, value: &str| {
            assert!(Config::from_overrides(&[(key.into(), value.into())]).is_err(), "{key}={value}");
        };
        reject("cluster.servers", "0");
        reject("cluster.sync_every", "0");
        reject("cluster.min_sync_every", "0");
        reject("cluster.max_sync_every", "0"); // < min_sync_every
        reject("cluster.comm_target", "0");
        reject("cluster.comm_target", "1.0");
        reject("cluster.link_latency_s", "-1");
        reject("cluster.link_gbytes_per_sec", "0");
        reject("cluster.algo", "butterfly");
        reject("cluster.streams", "0");
        reject("cluster.straggler_floor", "1.0");
        // Factors must match the server count and stay positive.
        reject("cluster.server_speed_factors", "[1.0, 2.0]"); // servers = 1
        assert!(Config::from_overrides(&[
            ("cluster.servers".into(), "2".into()),
            ("cluster.server_speed_factors".into(), "[1.0, 0.0]".into()),
        ])
        .is_err());
        assert!(Config::from_overrides(&[
            ("cluster.servers".into(), "2".into()),
            ("cluster.server_speed_factors".into(), "[1.0, 2.5]".into()),
        ])
        .is_ok());
        reject("cluster.events", "[\"garbage\"]");
        // Event ids must fit the cluster: servers defaults to 1.
        reject("cluster.events", "[\"at_mb=1 link=1 factor=2\"]");
        reject("cluster.events", "[\"at_mb=1 server=1 down\"]");
    }
}

//! Experiment harness: one entry point per paper table/figure.
//!
//! Used by `benches/` (plain binaries) and the `heterosparse experiment`
//! CLI subcommand. Every runner builds its workload from config, executes
//! through the same Trainer as production runs, and prints paper-style rows
//! via [`crate::util::bench::Table`]. Fast CI-scale defaults; `HS_FULL=1`
//! switches to full-scale runs.

use std::sync::Arc;

use crate::config::{Config, DataProfile, ExecMode, Strategy};
use crate::coordinator::backend::{PjrtBackend, RefBackend, StepBackend};
use crate::coordinator::engine_sim::SimEngine;
use crate::coordinator::engine_threaded::{BackendFactory, ThreadedEngine};
use crate::coordinator::trainer::{Trainer, TrainerOptions};
use crate::coordinator::DevicePool;
use crate::data::synthetic::Generator;
use crate::data::SparseDataset;
use crate::metrics::RunLog;
use crate::model::ModelState;
use crate::runtime::{CostModel, Runtime};
use crate::Result;

pub mod experiments;

/// How step numerics are provided for a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts through PJRT — requires `make artifacts` and the
    /// `pjrt` cargo feature.
    Pjrt,
    /// Pure-Rust reference twin — hermetic, no artifacts needed.
    Reference,
    /// PJRT when artifacts are present (and the feature is on), reference
    /// otherwise.
    Auto,
}

impl Backend {
    pub fn resolve(self, cfg: &Config) -> Backend {
        match self {
            Backend::Auto => {
                if !cfg!(feature = "pjrt") {
                    return Backend::Reference;
                }
                let manifest = std::path::Path::new(&cfg.runtime.artifacts_dir).join("manifest.json");
                if manifest.exists() {
                    // Only use PJRT when the artifacts actually match.
                    match crate::runtime::Manifest::load(std::path::Path::new(
                        &cfg.runtime.artifacts_dir,
                    )) {
                        Ok(m) if m.check_config(cfg).is_ok() => Backend::Pjrt,
                        _ => Backend::Reference,
                    }
                } else {
                    Backend::Reference
                }
            }
            other => other,
        }
    }
}

/// Generate the train/test splits for a config.
pub fn make_data(cfg: &Config) -> (SparseDataset, SparseDataset) {
    let gen = Generator::new(&cfg.model, &cfg.data);
    (gen.generate(cfg.data.train_samples, 1), gen.generate(cfg.data.test_samples, 2))
}

/// Run one full training session under `cfg`. This is the single funnel all
/// benches, examples and the CLI go through. Engines are sized to the
/// elastic pool's roster (configured fleet + hot-add spares).
pub fn run_single(cfg: &Config, backend: Backend, mut opts: TrainerOptions) -> Result<RunLog> {
    cfg.validate()?;
    let backend = backend.resolve(cfg);
    let (train, test) = make_data(cfg);
    let devices = DevicePool::roster(cfg);

    match (cfg.runtime.mode, backend) {
        (ExecMode::Virtual, Backend::Pjrt) => {
            let runtime = Runtime::load(std::path::Path::new(&cfg.runtime.artifacts_dir))?;
            runtime.manifest.check_config(cfg)?;
            opts.eval_bucket = Some(runtime.manifest.eval_batch);
            let be = PjrtBackend::new(runtime);
            let engine = Box::new(
                SimEngine::new(&be, devices, CostModel::default()).with_slide(&cfg.slide),
            );
            Trainer::new(cfg.clone(), engine, &be, opts).run(&train, &test)
        }
        (ExecMode::Virtual, _) => {
            let be = RefBackend;
            let engine = Box::new(
                SimEngine::new(&be, devices, CostModel::default()).with_slide(&cfg.slide),
            );
            Trainer::new(cfg.clone(), engine, &be, opts).run(&train, &test)
        }
        (ExecMode::Real, Backend::Pjrt) => {
            let dir = cfg.runtime.artifacts_dir.clone();
            let factory: BackendFactory = Arc::new(move |_dev| {
                let rt = Runtime::load(std::path::Path::new(&dir))?;
                Ok(Box::new(PjrtBackend::new(rt)) as Box<dyn StepBackend>)
            });
            let template = ModelState::init(&cfg.model, cfg.sgd.seed);
            let engine = Box::new(ThreadedEngine::spawn_with_slide(
                factory,
                devices,
                &template,
                cfg.slide.clone(),
            )?);
            // Eval through its own runtime on the coordinator thread.
            let eval_rt = Runtime::load(std::path::Path::new(&cfg.runtime.artifacts_dir))?;
            eval_rt.manifest.check_config(cfg)?;
            opts.eval_bucket = Some(eval_rt.manifest.eval_batch);
            let eval_be = PjrtBackend::new(eval_rt);
            Trainer::new(cfg.clone(), engine, &eval_be, opts).run(&train, &test)
        }
        (ExecMode::Real, _) => {
            let factory: BackendFactory =
                Arc::new(|_dev| Ok(Box::new(RefBackend) as Box<dyn StepBackend>));
            let template = ModelState::init(&cfg.model, cfg.sgd.seed);
            let engine = Box::new(ThreadedEngine::spawn_with_slide(
                factory,
                devices,
                &template,
                cfg.slide.clone(),
            )?);
            let eval_be = RefBackend;
            Trainer::new(cfg.clone(), engine, &eval_be, opts).run(&train, &test)
        }
    }
}

/// Baseline experiment config shared by the figure benches: small model,
/// virtual time, zero-jitter determinism, Amazon profile.
pub fn bench_config(profile: DataProfile, gpus: usize, strategy: Strategy) -> Config {
    let mut cfg = Config::default();
    // Small-profile model dims (must match `make artifacts` defaults so the
    // PJRT backend can be used when present).
    cfg.data.profile = profile;
    match profile {
        DataProfile::Amazon => {
            cfg.data.avg_nnz = 12.0;
            cfg.data.avg_labels = 2.0;
        }
        DataProfile::Delicious => {
            cfg.data.avg_nnz = 24.0;
            cfg.data.avg_labels = 6.0;
        }
    }
    cfg.data.train_samples = 12_000;
    cfg.data.test_samples = 1_500;
    cfg.sgd.lr_bmax = 0.1; // grid-searched per paper §5.1 (largest stable under momentum)
    cfg.sgd.mega_batches = 20;
    cfg.sgd.num_mega_batches = 12;
    cfg.devices.count = gpus;
    cfg.devices.speed_factors = (0..gpus)
        .map(|i| 1.0 + 0.32 * i as f64 / (gpus.max(2) - 1) as f64)
        .collect();
    cfg.devices.jitter = 0.03;
    cfg.strategy.kind = strategy;
    cfg.validate().expect("bench config must validate");
    cfg
}

/// Scale a bench config up when `HS_FULL=1`.
pub fn apply_full_scale(cfg: &mut Config) {
    if crate::util::bench::full_scale() {
        cfg.data.train_samples *= 4;
        cfg.data.test_samples *= 2;
        cfg.sgd.num_mega_batches *= 3;
    }
}

//! One runner per paper table/figure (DESIGN.md §5 experiment index).
//!
//! Every runner prints the paper-style rows and returns the raw logs so
//! benches/tests can assert the qualitative *shape* of the result (who
//! wins, ordering, activation frequencies) without baking in absolute
//! numbers that depend on the host.

use crate::config::{CompositionPolicy, Config, DataProfile, Strategy};
use crate::coordinator::trainer::TrainerOptions;
use crate::data::synthetic::Generator;
use crate::metrics::RunLog;
use crate::model::ModelState;
use crate::runtime::{CostModel, SimDevice};
use crate::slide::{SlideTrainer, SlideTrainerConfig};
use crate::util::bench::Table;
use crate::Result;

use super::{apply_full_scale, bench_config, make_data, run_single, Backend};

/// One entry of the experiment registry: the canonical name the CLI
/// dispatches on plus the one-line description `--help` prints.
pub struct ExperimentSpec {
    pub name: &'static str,
    pub about: &'static str,
}

/// The single source of truth for which experiments exist. The CLI's
/// usage text, its "experiment name required" hint, and its unknown-name
/// error are all generated from this table, so the hand-maintained list
/// can no longer drift from the implementations (it had).
pub const EXPERIMENTS: &[ExperimentSpec] = &[
    ExperimentSpec { name: "table1", about: "synthetic XML dataset profiles (Table 1)" },
    ExperimentSpec { name: "fig1", about: "heterogeneity on an identical batch (Fig. 1)" },
    ExperimentSpec { name: "fig6", about: "time-to-accuracy, all strategies (Fig. 6)" },
    ExperimentSpec { name: "fig7", about: "statistical efficiency (Fig. 7)" },
    ExperimentSpec { name: "fig8", about: "scalability + SLIDE CPU baseline (Fig. 8)" },
    ExperimentSpec { name: "fig9", about: "mega-batch size / merge frequency (Fig. 9)" },
    ExperimentSpec { name: "fig10a", about: "initial batch size sweep (Fig. 10a)" },
    ExperimentSpec { name: "fig10b", about: "batch-size scaling factor β sweep (Fig. 10b)" },
    ExperimentSpec { name: "fig11a", about: "perturbation threshold sweep (Fig. 11a)" },
    ExperimentSpec { name: "fig11b", about: "perturbation factor δ sweep (Fig. 11b)" },
    ExperimentSpec {
        name: "fig12",
        about: "batch-size traces + perturbation activations (Fig. 12)",
    },
    ExperimentSpec { name: "elastic", about: "elastic failover: lose devices mid-run, recover" },
    ExperimentSpec { name: "pipeline", about: "data-plane composition policies head to head" },
    ExperimentSpec {
        name: "serve",
        about: "serving plane: per-pattern latency + train-while-serve (--resume CKPT)",
    },
    ExperimentSpec {
        name: "fleet",
        about: "multi-tenant fleet: exclusive vs fair-share vs priority-preemption",
    },
    ExperimentSpec {
        name: "calibration",
        about: "static vs calibrated scheduling under a scripted throttle trace",
    },
    ExperimentSpec {
        name: "slide",
        about: "adaptive-sparsity lever: static vs batch-only vs sparsity-only vs joint",
    },
    ExperimentSpec {
        name: "cluster",
        about: "multi-server scale-out: flat vs hierarchical vs adaptive sync cadence",
    },
    ExperimentSpec {
        name: "fuzz",
        about: "seeded cross-subsystem scenario fuzzer: property-check global invariants",
    },
];

/// Every registered experiment name, in registry order.
pub fn experiment_names() -> Vec<&'static str> {
    EXPERIMENTS.iter().map(|e| e.name).collect()
}

/// Is `name` a registered experiment?
pub fn is_experiment(name: &str) -> bool {
    EXPERIMENTS.iter().any(|e| e.name == name)
}

fn fmt_opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "—".to_string())
}

fn fmt_opt_usize(v: Option<usize>) -> String {
    v.map(|x| x.to_string()).unwrap_or_else(|| "—".to_string())
}

/// A common accuracy target all runs are measured against: 85% of the best
/// accuracy any run achieved (the paper reports time to reach "a certain
/// level of accuracy").
pub fn common_target(logs: &[(String, RunLog)]) -> f64 {
    0.85 * logs.iter().map(|(_, l)| l.best_accuracy()).fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// Table 1 — dataset profiles
// ---------------------------------------------------------------------------

pub struct Table1Row {
    pub profile: &'static str,
    pub samples: usize,
    pub features: usize,
    pub classes: usize,
    pub avg_nnz: f64,
    pub avg_labels: f64,
    pub target_nnz: f64,
    pub target_labels: f64,
}

pub fn table1() -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for profile in [DataProfile::Amazon, DataProfile::Delicious] {
        let cfg = bench_config(profile, 4, Strategy::Adaptive);
        let ds = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        rows.push(Table1Row {
            profile: profile.name(),
            samples: ds.len(),
            features: ds.num_features,
            classes: ds.num_classes,
            avg_nnz: ds.avg_nnz(),
            avg_labels: ds.avg_labels(),
            target_nnz: cfg.data.avg_nnz,
            target_labels: cfg.data.avg_labels,
        });
    }
    let mut t = Table::new(&[
        "profile", "samples", "features", "classes", "avg nnz", "target", "avg labels", "target",
    ]);
    for r in &rows {
        t.row(&[
            r.profile.to_string(),
            r.samples.to_string(),
            r.features.to_string(),
            r.classes.to_string(),
            format!("{:.1}", r.avg_nnz),
            format!("{:.1}", r.target_nnz),
            format!("{:.2}", r.avg_labels),
            format!("{:.2}", r.target_labels),
        ]);
    }
    t.print("Table 1 — synthetic XML dataset profiles (shape statistics)");
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Fig. 1 — multi-GPU heterogeneity on an identical batch
// ---------------------------------------------------------------------------

pub fn fig1() -> Result<Vec<f64>> {
    let cfg = bench_config(DataProfile::Amazon, 4, Strategy::Adaptive);
    let (train, _) = make_data(&cfg);
    let mut batcher = crate::data::batcher::Batcher::new(&train, &cfg.model, 1);
    let batch = batcher.next_batch(cfg.sgd.b_max, cfg.sgd.b_max);
    let cost = CostModel::default();
    let mut devices = SimDevice::fleet(&cfg.devices);
    // One "epoch" = enough identical batches to cover the dataset once.
    let batches_per_epoch = train.len() / cfg.sgd.b_max;
    let mut epoch_times = Vec::new();
    for d in devices.iter_mut() {
        let t: f64 = (0..batches_per_epoch).map(|_| d.step_duration(&cost, &batch)).sum();
        epoch_times.push(t);
    }
    let fastest = epoch_times.iter().copied().fold(f64::INFINITY, f64::min);
    let mut t = Table::new(&["device", "speed factor", "epoch time (s)", "vs fastest"]);
    for (i, &et) in epoch_times.iter().enumerate() {
        t.row(&[
            format!("gpu{i}"),
            format!("{:.2}", cfg.devices.speed_factors[i]),
            format!("{et:.3}"),
            format!("+{:.1}%", (et / fastest - 1.0) * 100.0),
        ]);
    }
    t.print("Fig. 1 — heterogeneity on an identical batch (4 simulated devices)");
    Ok(epoch_times)
}

// ---------------------------------------------------------------------------
// Fig. 6 / Fig. 7 — time-to-accuracy and statistical efficiency
// ---------------------------------------------------------------------------

/// Paper §5.1 methodology: "we execute every algorithm for the same amount
/// of time". The budget is sized so the 4-device adaptive run completes its
/// configured mega-batches, then every run gets exactly that much clock.
pub fn equal_time_budget(profile: DataProfile, backend: Backend) -> Result<f64> {
    let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
    apply_full_scale(&mut cfg);
    let probe = run_single(&cfg, backend, TrainerOptions::default())?;
    // 2.5× the fast-fleet clock so the 1-device configurations also get
    // enough time to converge (the paper trains every algorithm to its
    // plateau within the common window).
    Ok(2.5 * probe.rows.last().map(|r| r.clock).unwrap_or(1.0))
}

pub fn fig6(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let budget = equal_time_budget(profile, backend)?;
    let opts = TrainerOptions { time_budget: Some(budget), ..Default::default() };
    let mut logs = Vec::new();
    for gpus in [1usize, 2, 4] {
        for strategy in Strategy::all() {
            // On one device Elastic == Adaptive (same update rule); skip the
            // duplicate like the paper's single curve.
            if gpus == 1 && strategy == Strategy::Elastic {
                continue;
            }
            let mut cfg = bench_config(profile, gpus, strategy);
            apply_full_scale(&mut cfg);
            // Cap mega-batches high; the time budget is the stop condition.
            cfg.sgd.num_mega_batches *= 8;
            let log = run_single(&cfg, backend, opts.clone())?;
            logs.push((format!("{}-{}gpu", strategy.name(), gpus), log));
        }
    }
    let target = common_target(&logs);
    let mut t = Table::new(&["run", "best P@1", "final P@1", &format!("TTA@{target:.3} (s)"), "clock (s)"]);
    for (name, log) in &logs {
        t.row(&[
            name.clone(),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            format!("{:.2}", log.rows.last().map(|r| r.clock).unwrap_or(0.0)),
        ]);
    }
    t.print(&format!("Fig. 6 — time-to-accuracy ({})", profile.name()));
    Ok(logs)
}

pub fn fig7(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let logs = fig6(profile, backend)?;
    let target = common_target(&logs);
    let mut t = Table::new(&["run", &format!("mega-batches to P@1≥{target:.3}"), "best P@1"]);
    for (name, log) in &logs {
        t.row(&[
            name.clone(),
            fmt_opt_usize(log.megabatches_to_accuracy(target)),
            format!("{:.4}", log.best_accuracy()),
        ]);
    }
    t.print(&format!("Fig. 7 — statistical efficiency ({})", profile.name()));
    Ok(logs)
}

// ---------------------------------------------------------------------------
// Fig. 8 — scalability + SLIDE CPU baseline
// ---------------------------------------------------------------------------

pub struct Fig8Outcome {
    pub gpu_logs: Vec<(String, RunLog)>,
    pub slide_acc: f64,
    pub slide_updates: u64,
    pub slide_seconds: f64,
}

pub fn fig8(profile: DataProfile, backend: Backend) -> Result<Fig8Outcome> {
    let budget = equal_time_budget(profile, backend)?;
    let opts = TrainerOptions { time_budget: Some(budget), ..Default::default() };
    let mut logs = Vec::new();
    for gpus in [1usize, 2, 4] {
        let mut cfg = bench_config(profile, gpus, Strategy::Adaptive);
        apply_full_scale(&mut cfg);
        cfg.sgd.num_mega_batches *= 8;
        let log = run_single(&cfg, backend, opts.clone())?;
        logs.push((format!("adaptive-{gpus}gpu"), log));
    }

    // SLIDE on the same data with the SAME time budget. Caveat recorded in
    // EXPERIMENTS.md: the accelerator clock is a calibrated simulation while
    // SLIDE burns real CPU seconds, so absolute cross-hardware time is only
    // meaningful up to that calibration.
    let cfg = bench_config(profile, 4, Strategy::Adaptive);
    let (train, test) = make_data(&cfg);
    let budget = budget.clamp(0.2, 30.0);
    let init = ModelState::init(&cfg.model, cfg.sgd.seed);
    // The baseline reads the same `[slide]` block the adaptive-sparsity
    // compute path uses (threads, tables, bits, negatives, rebuild cadence)
    // — one knob set, no drift between the two SLIDE consumers.
    let trainer = SlideTrainer::new(
        &cfg.model,
        &init,
        SlideTrainerConfig::from_section(&cfg.slide, cfg.sgd.lr_bmax),
    );
    let (_samples, updates, seconds) = trainer.train(&train, budget, u64::MAX)?;
    let snapshot = trainer.snapshot();
    let eval = crate::data::batcher::EvalBatches::new(&test, &cfg.model, 256.min(test.len()));
    let slide_acc = crate::eval::p_at_1(
        &crate::coordinator::backend::RefBackend,
        &snapshot,
        &eval,
        &test,
    )?;

    let target = common_target(&logs);
    let mut t = Table::new(&["run", "best P@1", &format!("TTA@{target:.3} (s)"), "updates"]);
    for (name, log) in &logs {
        t.row(&[
            name.clone(),
            format!("{:.4}", log.best_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            log.rows.iter().map(|r| r.updates.iter().sum::<u64>()).sum::<u64>().to_string(),
        ]);
    }
    t.row(&[
        "SLIDE-cpu".to_string(),
        format!("{slide_acc:.4}"),
        "—".to_string(),
        updates.to_string(),
    ]);
    t.print(&format!(
        "Fig. 8 — scalability vs SLIDE ({}; SLIDE ran {seconds:.1}s wall)",
        profile.name()
    ));
    Ok(Fig8Outcome { gpu_logs: logs, slide_acc, slide_updates: updates, slide_seconds: seconds })
}

// ---------------------------------------------------------------------------
// Fig. 9 — mega-batch size (model merging frequency)
// ---------------------------------------------------------------------------

pub fn fig9(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let budget = equal_time_budget(profile, backend)?;
    let opts = TrainerOptions { time_budget: Some(budget), ..Default::default() };
    let mut logs = Vec::new();
    for mega in [4usize, 20, 100] {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        cfg.sgd.mega_batches = mega;
        // Equal time budget (paper methodology); cap counts high and let the
        // clock decide — frequent merging now pays its barrier overhead.
        cfg.sgd.num_mega_batches = (2400 / mega).max(4);
        apply_full_scale(&mut cfg);
        let log = run_single(&cfg, backend, opts.clone())?;
        logs.push((format!("mega={mega}"), log));
    }
    let target = common_target(&logs);
    let mut t = Table::new(&["mega-batch (batches)", "best P@1", &format!("TTA@{target:.3} (s)"), "merges"]);
    for (name, log) in &logs {
        t.row(&[
            name.clone(),
            format!("{:.4}", log.best_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            log.rows.len().to_string(),
        ]);
    }
    t.print(&format!("Fig. 9 — merging frequency ({})", profile.name()));
    Ok(logs)
}

// ---------------------------------------------------------------------------
// Fig. 10 — initial batch size (a) and scaling factor β (b)
// ---------------------------------------------------------------------------

pub fn fig10a(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let mut logs = Vec::new();
    for b0 in [16usize, 64, 128] {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        cfg.sgd.initial_batch = b0;
        apply_full_scale(&mut cfg);
        let log = run_single(&cfg, backend, TrainerOptions::default())?;
        logs.push((format!("b0={b0}"), log));
    }
    print_param_table("Fig. 10a — initial batch size", &logs);
    Ok(logs)
}

pub fn fig10b(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let mut logs = Vec::new();
    for beta in [4usize, 8, 16] {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        cfg.sgd.beta = beta;
        apply_full_scale(&mut cfg);
        let log = run_single(&cfg, backend, TrainerOptions::default())?;
        logs.push((format!("beta={beta}"), log));
    }
    print_param_table("Fig. 10b — batch size scaling factor β", &logs);
    Ok(logs)
}

// ---------------------------------------------------------------------------
// Fig. 11 — perturbation threshold (a) and factor δ (b)
// ---------------------------------------------------------------------------

pub fn fig11a(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let mut logs = Vec::new();
    for thr in [0.05f64, 0.10, 0.15] {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        cfg.merge.pert_thr = thr;
        apply_full_scale(&mut cfg);
        let log = run_single(&cfg, backend, TrainerOptions::default())?;
        logs.push((format!("thr={thr}"), log));
    }
    print_param_table("Fig. 11a — perturbation threshold", &logs);
    Ok(logs)
}

pub fn fig11b(profile: DataProfile, backend: Backend) -> Result<Vec<(String, RunLog)>> {
    let mut logs = Vec::new();
    for delta in [0.05f64, 0.10, 0.15] {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        cfg.merge.delta = delta;
        apply_full_scale(&mut cfg);
        let log = run_single(&cfg, backend, TrainerOptions::default())?;
        logs.push((format!("delta={delta}"), log));
    }
    print_param_table("Fig. 11b — perturbation factor δ", &logs);
    Ok(logs)
}

fn print_param_table(title: &str, logs: &[(String, RunLog)]) {
    let target = common_target(logs);
    let mut t = Table::new(&["setting", "best P@1", "final P@1", &format!("TTA@{target:.3} (s)"), "pert freq"]);
    for (name, log) in logs {
        t.row(&[
            name.clone(),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            format!("{:.2}", log.perturbation_frequency()),
        ]);
    }
    t.print(title);
}

// ---------------------------------------------------------------------------
// Fig. 12 — do batch scaling and perturbation activate?
// ---------------------------------------------------------------------------

pub fn fig12(profile: DataProfile, backend: Backend) -> Result<RunLog> {
    let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
    cfg.sgd.num_mega_batches = 20;
    apply_full_scale(&mut cfg);
    let log = run_single(&cfg, backend, TrainerOptions::default())?;

    let mut t = Table::new(&["mega-batch", "b0", "b1", "b2", "b3", "updates", "perturbed"]);
    for r in &log.rows {
        t.row(&[
            r.mega_batch.to_string(),
            r.batch_sizes[0].to_string(),
            r.batch_sizes[1].to_string(),
            r.batch_sizes[2].to_string(),
            r.batch_sizes[3].to_string(),
            format!("{:?}", r.updates),
            if r.perturbed { "yes" } else { "no" }.to_string(),
        ]);
    }
    t.print(&format!("Fig. 12 — batch-size trace + perturbation activations ({})", profile.name()));
    println!(
        "perturbation frequency: {:.2} (paper: \"very high frequency\")",
        log.perturbation_frequency()
    );
    Ok(log)
}

// ---------------------------------------------------------------------------
// Elastic failover — beyond the paper (ROADMAP north-star): the pool loses
// devices mid-run and recovers, and training rides through it.
// ---------------------------------------------------------------------------

pub struct ElasticOutcome {
    pub static_log: RunLog,
    pub elastic_log: RunLog,
}

/// Static 4-device run vs the same run losing 2 devices a third of the way
/// in and regaining them at two thirds. Prints the device-count and P@1
/// trajectories side by side plus the pool-event log.
pub fn elastic(profile: DataProfile, backend: Backend) -> Result<ElasticOutcome> {
    let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
    apply_full_scale(&mut cfg);
    let static_log = run_single(&cfg, backend, TrainerOptions::default())?;

    let mut e_cfg = cfg.clone();
    let n = e_cfg.sgd.num_mega_batches;
    e_cfg.elastic.events =
        vec![format!("at_mb={} remove=2", n / 3), format!("at_mb={} add=2", 2 * n / 3)];
    e_cfg.validate()?;
    let elastic_log = run_single(&e_cfg, backend, TrainerOptions::default())?;

    let mut t = Table::new(&["mega-batch", "devices", "P@1 (elastic)", "P@1 (static)", "events"]);
    for (r, s) in elastic_log.rows.iter().zip(&static_log.rows) {
        let events: Vec<String> = r
            .pool_events
            .iter()
            .map(|e| format!("{} d{}", e.action, e.device))
            .collect();
        t.row(&[
            r.mega_batch.to_string(),
            r.active_devices.len().to_string(),
            format!("{:.4}", r.accuracy),
            format!("{:.4}", s.accuracy),
            events.join(" "),
        ]);
    }
    t.print(&format!("Elastic failover — remove 2 of 4 devices, then re-add ({})", profile.name()));
    println!(
        "final P@1: elastic {:.4} vs static {:.4} ({} pool events)",
        elastic_log.final_accuracy(),
        static_log.final_accuracy(),
        elastic_log.pool_events.len()
    );
    Ok(ElasticOutcome { static_log, elastic_log })
}

// ---------------------------------------------------------------------------
// Pipeline — beyond the paper: data-plane composition-policy comparison
// ---------------------------------------------------------------------------

pub struct PipelineOutcome {
    /// One (policy name, log) per composition policy.
    pub logs: Vec<(String, RunLog)>,
}

/// Compare the data plane's composition policies on a heavy-tailed corpus:
/// same model, same strategy, same sample budget — only the batch
/// composition differs. The headline column is the per-batch nnz CV
/// (batch-cost dispersion), which `nnz_balanced` exists to crush; clock
/// and accuracy show what that stability costs or buys end to end.
pub fn pipeline(profile: DataProfile, backend: Backend) -> Result<PipelineOutcome> {
    let mut logs = Vec::new();
    for policy in CompositionPolicy::all() {
        let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
        // Heavier tail than the stock profile so composition has real
        // variance to work against.
        cfg.data.nnz_sigma = 1.2;
        cfg.data.pipeline.policy = policy;
        apply_full_scale(&mut cfg);
        cfg.validate()?;
        let log = run_single(&cfg, backend, TrainerOptions::default())?;
        logs.push((policy.name().to_string(), log));
    }
    let mut t = Table::new(&[
        "policy", "nnz CV", "best P@1", "final P@1", "clock (s)", "starved", "pool hit%",
    ]);
    for (name, log) in &logs {
        let last = log.rows.last().expect("runs produce rows");
        let p = &last.pipeline;
        let gets = p.pool_hits + p.pool_misses;
        t.row(&[
            name.clone(),
            format!("{:.4}", log.mean_nnz_cv()),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            format!("{:.2}", last.clock),
            p.starved.to_string(),
            if gets == 0 {
                "—".to_string()
            } else {
                format!("{:.1}", 100.0 * p.pool_hits as f64 / gets as f64)
            },
        ]);
    }
    t.print(&format!(
        "Pipeline — batch composition policies on a heavy-tailed corpus ({})",
        profile.name()
    ));
    Ok(PipelineOutcome { logs })
}

// ---------------------------------------------------------------------------
// Serve — beyond the paper (ROADMAP north-star): the serving plane. Train,
// publish snapshots, then replay synthetic traffic against them — per-
// arrival-pattern latency/throughput plus a train-while-serve timeline
// where the accuracy of the *served* snapshot tracks the training curve.
// ---------------------------------------------------------------------------

pub struct ServeOutcome {
    pub train_log: RunLog,
    /// One steady-state log per arrival pattern.
    pub steady: Vec<(String, crate::serve::ServeLog)>,
    /// The train-while-serve replay over the training clock.
    pub train_while_serve: crate::serve::ServeLog,
}

/// `experiment serve`: brief training run with the publish hook on, then
/// (a) steady-state serving of the final snapshot under each arrival
/// pattern, and (b) a train-while-serve replay across the whole training
/// clock with snapshot hot-swaps at every publish. Pass a checkpoint to
/// also seed the registry from a saved artifact.
pub fn serve(
    profile: DataProfile,
    backend: Backend,
    resume: Option<&std::path::Path>,
) -> Result<ServeOutcome> {
    use crate::config::ServePattern;
    use crate::coordinator::backend::RefBackend;
    use crate::data::pipeline::ShardedDataset;
    use crate::serve::{replay, ReplayOptions, SnapshotRegistry};
    use std::sync::Arc;

    let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
    apply_full_scale(&mut cfg);

    let registry = Arc::new(SnapshotRegistry::new());
    // --resume: training continues from the artifact AND the artifact is
    // servable from t=0 — the trainer's warm-start publish (version 1)
    // pushes exactly this model into the registry before the first merge.
    let init_model = match resume {
        Some(path) => {
            let m = crate::model::checkpoint::load(path)?;
            println!("resuming from {} — served as the warm-start snapshot", path.display());
            Some(m)
        }
        None => None,
    };
    let opts =
        TrainerOptions { publish: Some(registry.clone()), init_model, ..Default::default() };
    let train_log = run_single(&cfg, backend, opts)?;
    let final_clock = train_log.rows.last().map(|r| r.clock).unwrap_or(1.0);

    // Requests draw from the training corpus (same feature space the model
    // was fitted on); serving numerics run the hermetic reference forward.
    let (train, _) = make_data(&cfg);
    let data = Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples));

    let mut steady = Vec::new();
    for pattern in ServePattern::all() {
        let log = replay(
            &cfg,
            data.clone(),
            &registry,
            &RefBackend,
            &ReplayOptions {
                pattern,
                duration: cfg.serve.duration,
                follow_clock: false,
                train_log: Some(&train_log),
                name: format!("{}-steady", pattern.name()),
                obs: crate::obs::ambient(),
            },
        )?;
        steady.push((pattern.name().to_string(), log));
    }

    // The train-while-serve timeline spans the training clock, so its
    // telemetry windows scale to it (~12 rows regardless of run length).
    let mut tws_cfg = cfg.clone();
    tws_cfg.serve.window = (final_clock / 12.0).max(1e-3);
    let tws = replay(
        &tws_cfg,
        data.clone(),
        &registry,
        &RefBackend,
        &ReplayOptions {
            pattern: cfg.serve.pattern,
            duration: final_clock,
            follow_clock: true,
            train_log: Some(&train_log),
            name: "train-while-serve".to_string(),
            obs: crate::obs::ambient(),
        },
    )?;

    let fmt_nan = |v: f64, prec: usize| {
        if v.is_finite() {
            format!("{v:.prec$}")
        } else {
            "—".to_string()
        }
    };
    let mut t = Table::new(&[
        "pattern", "requests", "batches", "p50 (ms)", "p95 (ms)", "p99 (ms)", "rps",
        "peak queue", "staleness (mb)", "P@1 (served)",
    ]);
    for (name, log) in &steady {
        t.row(&[
            name.clone(),
            log.total_requests().to_string(),
            log.batches.len().to_string(),
            fmt_nan(log.latency_percentile_ms(50.0), 3),
            fmt_nan(log.latency_percentile_ms(95.0), 3),
            fmt_nan(log.latency_percentile_ms(99.0), 3),
            format!("{:.0}", log.throughput()),
            log.max_queue_depth().to_string(),
            fmt_nan(log.mean_staleness(), 2),
            fmt_nan(log.served_accuracy(), 4),
        ]);
    }
    t.print(&format!(
        "Serve — steady-state latency per arrival pattern ({}, {} req/s, snapshot v{})",
        profile.name(),
        cfg.serve.rate,
        registry.latest_version()
    ));

    let mut t = Table::new(&[
        "window", "t (s)", "completed", "p99 (ms)", "staleness (mb)", "P@1 (served)",
        "P@1 (train)",
    ]);
    for r in &tws.rows {
        t.row(&[
            r.window.to_string(),
            format!("{:.2}–{:.2}", r.start, r.end),
            r.completed.to_string(),
            fmt_nan(r.p99_ms, 3),
            fmt_nan(r.mean_staleness, 2),
            fmt_nan(r.served_accuracy, 4),
            fmt_nan(r.train_accuracy, 4),
        ]);
    }
    t.print(&format!(
        "Serve — train-while-serve: served-snapshot accuracy vs the training curve \
         ({}, publish_every={})",
        profile.name(),
        cfg.serve.publish_every
    ));
    println!(
        "train-while-serve: {} requests, mean staleness {} mb, final served P@1 {} \
         (training best {:.4})",
        tws.total_requests(),
        fmt_nan(tws.mean_staleness(), 2),
        fmt_nan(tws.served_accuracy(), 4),
        train_log.best_accuracy()
    );

    Ok(ServeOutcome { train_log, steady, train_while_serve: tws })
}

// ---------------------------------------------------------------------------
// Fleet — beyond the paper (ROADMAP north-star): multi-tenant co-scheduling.
// Two training tenants plus one latency-SLO serve lane contend for one
// shared heterogeneous fleet under three policies: exclusive (every tenant
// alone — the no-contention reference), weighted fair share, and fair share
// with SLO-triggered priority preemption.
// ---------------------------------------------------------------------------

pub struct FleetExperimentOutcome {
    /// One exclusive-fleet baseline per training tenant.
    pub exclusive: Vec<crate::fleet::FleetOutcome>,
    /// Serve lane alone on the whole fleet (replaying tenant 0's publish
    /// timeline).
    pub exclusive_serve: crate::fleet::FleetOutcome,
    /// Co-scheduled, weighted fair share, preemption off.
    pub fair: crate::fleet::FleetOutcome,
    /// Co-scheduled with SLO-triggered priority preemption.
    pub preempt: crate::fleet::FleetOutcome,
}

/// `experiment fleet`. Pass `base` (e.g. from `--config`) to co-schedule
/// under an explicit config; `None` uses a bench-scale setup whose bursty
/// serve trace deliberately overloads the lane's fair-share capacity, so
/// the preemption scenario has an SLO breach to react to. Numerics run the
/// hermetic reference backend on the virtual clock regardless of backend
/// flags — the co-schedule must stay deterministic.
pub fn fleet(
    profile: DataProfile,
    base_override: Option<&Config>,
) -> Result<FleetExperimentOutcome> {
    use crate::config::ServePattern;
    use crate::data::pipeline::ShardedDataset;
    use crate::fleet::{co_schedule, FleetOutcome, TenantJob};
    use crate::serve::SnapshotRegistry;
    use std::sync::Arc;

    let mut base = match base_override {
        Some(cfg) => cfg.clone(),
        None => {
            let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
            apply_full_scale(&mut cfg);
            // A bursty lane sized to overload its 1-device fair share
            // (~1.5× a device's service capacity during bursts) while two
            // devices absorb it comfortably — the preemption story.
            cfg.serve.rate = 2_500.0;
            cfg.serve.pattern = ServePattern::Bursty;
            cfg.serve.burst_factor = 24.0;
            cfg.serve.burst_period = 0.5;
            cfg.serve.burst_fraction = 0.2;
            cfg.serve.max_delay = 0.001;
            cfg.serve.max_batch = 32;
            cfg.fleet.decision_window = 0.05;
            cfg.fleet.grace = 0.25;
            cfg.fleet.slo_p95_ms = 3.0;
            cfg.fleet.breach_windows = 2;
            cfg.fleet.clear_windows = 4;
            cfg
        }
    };
    // The co-schedule runs on the virtual clock; the threaded engine's
    // wall-clock nondeterminism has no place in it.
    base.runtime.mode = crate::config::ExecMode::Virtual;
    base.validate()?;

    // One training job per configured weight; distinct corpora and seeds.
    let mut jobs: Vec<TenantJob> = Vec::new();
    for (i, &w) in base.fleet.train_weights.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.sgd.seed = base.sgd.seed.wrapping_add(i as u64);
        cfg.data.seed = base.data.seed.wrapping_add(7 * i as u64);
        let (train, test) = make_data(&cfg);
        jobs.push(TenantJob {
            name: format!("train-{}", (b'a' + i as u8) as char),
            weight: w,
            train: Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples)),
            test: Arc::new(test),
            cfg,
        });
    }
    // Serve requests draw from tenant a's corpus — the model the lane
    // serves is fitted on that feature space.
    let serve_corpus = jobs[0].train.clone();

    // ---- exclusive baselines ----------------------------------------------
    let mut exclusive: Vec<FleetOutcome> = Vec::new();
    let reg_excl = Arc::new(SnapshotRegistry::new());
    for (i, job) in jobs.iter().enumerate() {
        // Tenant a's exclusive run also fills the registry the exclusive
        // serve baseline replays.
        let reg =
            if i == 0 { reg_excl.clone() } else { Arc::new(SnapshotRegistry::new()) };
        let out = co_schedule(
            &base,
            std::slice::from_ref(job),
            None,
            reg,
            &format!("exclusive-{}", job.name),
        )?;
        exclusive.push(out);
    }
    let exclusive_serve = co_schedule(
        &base,
        &[],
        Some(serve_corpus.clone()),
        reg_excl,
        "exclusive-serve",
    )?;

    // ---- co-scheduled scenarios -------------------------------------------
    let mut fair_base = base.clone();
    fair_base.fleet.preemption = false;
    let fair = co_schedule(
        &fair_base,
        &jobs,
        Some(serve_corpus.clone()),
        Arc::new(SnapshotRegistry::new()),
        "fair-share",
    )?;
    let mut pre_base = base.clone();
    pre_base.fleet.preemption = true;
    let preempt = co_schedule(
        &pre_base,
        &jobs,
        Some(serve_corpus),
        Arc::new(SnapshotRegistry::new()),
        "priority-preemption",
    )?;

    // ---- training comparison table ----------------------------------------
    let mean_devices = |log: &RunLog| {
        if log.rows.is_empty() {
            0.0
        } else {
            log.rows.iter().map(|r| r.active_devices.len()).sum::<usize>() as f64
                / log.rows.len() as f64
        }
    };
    let mut t = Table::new(&[
        "scenario", "tenant", "avg devices", "best P@1", "final P@1", "dP@1 vs excl",
        "clock (s)",
    ]);
    let scenarios: Vec<(&str, &FleetOutcome)> =
        vec![("fair-share", &fair), ("priority-preemption", &preempt)];
    for out in &exclusive {
        let (name, log) = &out.tenant_logs[0];
        t.row(&[
            "exclusive".to_string(),
            name.clone(),
            format!("{:.2}", mean_devices(log)),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            "—".to_string(),
            format!("{:.2}", log.rows.last().map(|r| r.clock).unwrap_or(0.0)),
        ]);
    }
    for (scen, out) in &scenarios {
        for (i, (name, log)) in out.tenant_logs.iter().enumerate() {
            let excl_final = exclusive[i].tenant_logs[0].1.final_accuracy();
            t.row(&[
                scen.to_string(),
                name.clone(),
                format!("{:.2}", mean_devices(log)),
                format!("{:.4}", log.best_accuracy()),
                format!("{:.4}", log.final_accuracy()),
                format!("{:+.4}", log.final_accuracy() - excl_final),
                format!("{:.2}", log.rows.last().map(|r| r.clock).unwrap_or(0.0)),
            ]);
        }
    }
    t.print(&format!(
        "Fleet — two training tenants sharing {} devices with a serve lane ({})",
        base.devices.count,
        profile.name()
    ));

    // ---- serve comparison table -------------------------------------------
    let fmt_nan = |v: f64, prec: usize| {
        if v.is_finite() {
            format!("{v:.prec$}")
        } else {
            "—".to_string()
        }
    };
    let mut t = Table::new(&[
        "scenario", "requests", "p95 (ms)", "p99 (ms)", "worst window p95", "preempts",
        "returns", "lease events", "conservation",
    ]);
    let all: Vec<(&str, &FleetOutcome)> = vec![
        ("exclusive-serve", &exclusive_serve),
        ("fair-share", &fair),
        ("priority-preemption", &preempt),
    ];
    for (scen, out) in &all {
        let serve = out.serve.as_ref().expect("scenario has a serve lane");
        let worst = out
            .slo_series
            .iter()
            .map(|&(_, p)| p)
            .filter(|p| p.is_finite())
            .fold(f64::NEG_INFINITY, f64::max);
        t.row(&[
            scen.to_string(),
            serve.total_requests().to_string(),
            fmt_nan(serve.latency_percentile_ms(95.0), 3),
            fmt_nan(serve.latency_percentile_ms(99.0), 3),
            fmt_nan(worst, 3),
            out.preemptions.to_string(),
            out.returns.to_string(),
            out.events.len().to_string(),
            format!("OK ({} checks)", out.conservation_checks),
        ]);
    }
    t.print(&format!(
        "Fleet — serve lane p95/p99 under contention (SLO p95 ≤ {:.1} ms, window {:.0} ms)",
        base.fleet.slo_p95_ms,
        base.fleet.decision_window * 1e3
    ));

    // ---- the preemption timeline ------------------------------------------
    if let Some(first) = preempt.events.iter().find(|e| e.action == "preempt") {
        let before = preempt
            .slo_series
            .iter()
            .rev()
            .find(|&&(t, p)| t <= first.at && p.is_finite())
            .map(|&(_, p)| p)
            .unwrap_or(f64::NAN);
        let after = preempt
            .slo_series
            .iter()
            .filter(|&&(t, p)| {
                t > first.at && t <= first.at + 6.0 * base.fleet.decision_window && p.is_finite()
            })
            .map(|&(_, p)| p)
            .fold(f64::INFINITY, f64::min);
        println!(
            "preemption at t={:.2}s: windowed p95 {} ms at the breach -> best {} ms within \
             6 windows after ({} preemptions, {} returns over the run)",
            first.at,
            fmt_nan(before, 3),
            fmt_nan(after, 3),
            preempt.preemptions,
            preempt.returns
        );
    } else {
        println!(
            "no SLO breach under this config — preemption scenario degenerated to fair share"
        );
    }
    if !preempt.churn.is_empty() {
        println!(
            "scripted fleet churn: {} events rode through with conservation intact",
            preempt.churn.len()
        );
    }

    Ok(FleetExperimentOutcome { exclusive, exclusive_serve, fair, preempt })
}

// ---------------------------------------------------------------------------
// Calibration — beyond the paper (ROADMAP north-star): drift-adaptive
// scheduling. One device throttles mid-run and later recovers; the same
// scenario runs once with static speed_factor scheduling and once with the
// calibration plane closing the loop on measured costs.
// ---------------------------------------------------------------------------

pub struct CalibrationOutcome {
    /// `[calibration] enabled = false`: the drift happens, scheduling
    /// keeps trusting the config constants.
    pub static_log: RunLog,
    /// `enabled = true`: estimates drive dispatch + batch re-targeting.
    pub calibrated_log: RunLog,
    /// Mean update balance (max/min per-device update count) over the
    /// throttled window: (static, calibrated). 1.0 is the paper's
    /// equal-update-rate goal.
    pub throttled_balance: (f64, f64),
    /// The mid-throttle dispatch plan scored under nominal vs estimated
    /// speeds (`tuning::whatif`).
    pub whatif: (crate::tuning::PlanScore, crate::tuning::PlanScore),
}

/// `experiment calibration`: device 0 (the fastest) throttles to 2.2× a
/// quarter of the way in and recovers at three quarters — the ABS-SGD
/// drift regime. The static run keeps scheduling on configured speed
/// factors (Algorithm 1's measured feedback is its only defense, and the
/// stability controller pauses it exactly when the fleet looked settled);
/// the calibrated run detects the step, re-seeds the batch grid from the
/// estimates, and dispatches on predicted completion times. Reports
/// per-window traces, the throttled-window update balance, time-to-
/// accuracy, and a what-if rescoring of the mid-throttle plan.
pub fn calibration(profile: DataProfile, backend: Backend) -> Result<CalibrationOutcome> {
    use crate::coordinator::plan_for_strategy;

    let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
    apply_full_scale(&mut cfg);
    // Zero jitter: the drift signal (and the bit-for-bit disabled claim
    // pinned by integration_calibration.rs) stays sharp.
    cfg.devices.jitter = 0.0;
    let n = cfg.sgd.num_mega_batches;
    let throttle_at = (n / 4).max(1);
    let recover_at = (3 * n / 4).max(throttle_at + 2);
    cfg.calibration.events = vec![
        format!("at_mb={throttle_at} device=0 factor=2.2 ramp=1"),
        format!("at_mb={recover_at} device=0 factor=1.0 ramp=1"),
    ];
    cfg.calibration.step_obs = 1; // react within one mega-batch window
    cfg.validate()?;

    let static_log = run_single(&cfg, backend, TrainerOptions::default())?;
    let mut cal_cfg = cfg.clone();
    cal_cfg.calibration.enabled = true;
    cal_cfg.validate()?;
    let calibrated_log = run_single(&cal_cfg, backend, TrainerOptions::default())?;

    // ---- per-window trace --------------------------------------------------
    let trace = cfg.calibration.parsed_events()?;
    let mut t = Table::new(&[
        "mega-batch", "drift d0", "est d0", "b (static)", "b (calibrated)", "u (static)",
        "u (calibrated)",
    ]);
    for (s, c) in static_log.rows.iter().zip(&calibrated_log.rows) {
        let est = c.cost_speed.first().copied().unwrap_or(0.0);
        t.row(&[
            s.mega_batch.to_string(),
            format!("{:.2}", crate::tuning::multiplier_at(&trace, 0, s.mega_batch)),
            if est > 0.0 { format!("{est:.2}") } else { "—".to_string() },
            format!("{:?}", s.batch_sizes),
            format!("{:?}", c.batch_sizes),
            format!("{:?}", s.updates),
            format!("{:?}", c.updates),
        ]);
    }
    t.print(&format!(
        "Calibration — device 0 throttles 2.2x at mb {throttle_at}, recovers at mb \
         {recover_at} ({})",
        profile.name()
    ));

    // ---- headline numbers --------------------------------------------------
    // Balance is judged once the detector could have reacted (one window
    // after the throttle) until the recovery starts.
    let b_static = static_log.window_balance(throttle_at + 1, recover_at);
    let b_cal = calibrated_log.window_balance(throttle_at + 1, recover_at);
    let named: [(&str, &RunLog, f64); 2] =
        [("static", &static_log, b_static), ("calibrated", &calibrated_log, b_cal)];
    let target =
        0.85 * named.iter().map(|(_, l, _)| l.best_accuracy()).fold(0.0, f64::max);
    let mut t = Table::new(&[
        "schedule", "throttled balance", "run balance", "best P@1",
        &format!("TTA@{target:.3} (s)"), "clock (s)",
    ]);
    for (name, log, tb) in &named {
        t.row(&[
            name.to_string(),
            format!("{tb:.2}"),
            format!("{:.2}", log.update_balance()),
            format!("{:.4}", log.best_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            format!("{:.2}", log.rows.last().map(|r| r.clock).unwrap_or(0.0)),
        ]);
    }
    t.print("Calibration — static speed_factor scheduling vs the calibration plane");

    // ---- what-if: the mid-throttle plan under nominal vs estimated costs ---
    let mid = calibrated_log
        .rows
        .iter()
        .find(|r| r.mega_batch == recover_at.saturating_sub(1))
        .or_else(|| calibrated_log.rows.last())
        .expect("run produced rows");
    let nnz_estimate = cfg.data.avg_nnz.min(cfg.model.max_nnz as f64);
    let plan = plan_for_strategy(
        &cfg,
        Strategy::Adaptive,
        &[0, 1, 2, 3],
        &mid.batch_sizes,
        &[cfg.sgd.lr_bmax; 4],
        nnz_estimate,
    );
    let estimated: Vec<f64> = mid
        .cost_speed
        .iter()
        .zip(&cfg.devices.speed_factors)
        .map(|(&e, &nom)| if e > 0.0 { e } else { nom })
        .collect();
    let (score_nom, score_est) = crate::tuning::compare(
        &plan,
        &cfg.devices.speed_factors,
        &estimated,
        &crate::runtime::CostModel::default(),
    );
    println!(
        "what-if (mid-throttle plan): nominal costs predict wall {:.3}s balance {:.2}; \
         estimated costs predict wall {:.3}s balance {:.2}",
        score_nom.wall, score_nom.balance, score_est.wall, score_est.balance
    );
    println!(
        "throttled-window update balance: static {b_static:.2} vs calibrated {b_cal:.2} \
         (1.0 = the paper's equal-update-rate goal)"
    );

    Ok(CalibrationOutcome {
        static_log,
        calibrated_log,
        throttled_balance: (b_static, b_cal),
        whatif: (score_nom, score_est),
    })
}

// ---------------------------------------------------------------------------
// Slide — beyond the paper: the adaptive-sparsity compute lever. A hard
// throttle hits the nominally fastest device — too hard for batch scaling
// alone to absorb (its equal-time batch lands below b_min) — and the same
// scenario runs under four policies: no reaction, batch-only re-targeting,
// sparsity-only re-targeting, and the joint two-knob trade.
// ---------------------------------------------------------------------------

pub struct SlideOutcome {
    /// One (policy, log) per scheduling policy, registry order:
    /// static, batch-only, sparsity-only, joint.
    pub logs: Vec<(String, RunLog)>,
    /// `(ratio, predicted step seconds)` down the configured ratio ladder
    /// on the throttled device — the lever's cost curve.
    pub ladder: Vec<(f64, f64)>,
    /// Throttled-window update balance per policy (parallel to `logs`;
    /// 1.0 = the paper's equal-update-rate goal).
    pub throttled_balance: Vec<f64>,
    /// Serve-side p99 (ms): exact-only replay vs the same trace with the
    /// latency SLO armed (approximate LSH top-k under pressure).
    pub serve_p99: (f64, f64),
}

/// `experiment slide`. Pass `base` (e.g. from `--config`) to run the
/// scenario under an explicit config; `None` uses the bench-scale setup.
pub fn slide(
    profile: DataProfile,
    backend: Backend,
    base_override: Option<&Config>,
) -> Result<SlideOutcome> {
    use crate::coordinator::backend::RefBackend;
    use crate::data::pipeline::ShardedDataset;
    use crate::serve::{replay, ReplayOptions, SnapshotRegistry};
    use std::sync::Arc;

    let mut cfg = match base_override {
        Some(c) => c.clone(),
        None => {
            let mut c = bench_config(profile, 4, Strategy::Adaptive);
            apply_full_scale(&mut c);
            c
        }
    };
    // Zero jitter keeps the drift signal sharp; 10x is past what the batch
    // grid can absorb (the equal-time batch falls below b_min), so the
    // ratio ladder is the only knob that can restore update balance.
    cfg.devices.jitter = 0.0;
    let n = cfg.sgd.num_mega_batches;
    let throttle_at = (n / 4).max(1);
    let recover_at = (3 * n / 4).max(throttle_at + 2);
    cfg.calibration.events = vec![
        format!("at_mb={throttle_at} device=0 factor=10.0 ramp=1"),
        format!("at_mb={recover_at} device=0 factor=1.0 ramp=1"),
    ];
    cfg.calibration.step_obs = 1;
    cfg.validate()?;

    // ---- the lever's cost curve on the throttled device --------------------
    let cost = CostModel::default();
    let nnz_estimate = cfg.data.avg_nnz.min(cfg.model.max_nnz as f64);
    let b = cfg.sgd.b_max;
    let ladder: Vec<(f64, f64)> = cfg
        .slide
        .ratio_ladder()
        .iter()
        .map(|&r| {
            (r, 10.0 * cost.step_time_parts_at(b, (nnz_estimate * b as f64) as usize, r))
        })
        .collect();

    // ---- four policies over the identical throttle trace -------------------
    // (name, calibration, batch_scaling, slide.adaptive)
    let policies: [(&str, bool, bool, bool); 4] = [
        ("static", false, true, false),
        ("batch-only", true, true, false),
        ("sparsity-only", true, false, true),
        ("joint", true, true, true),
    ];
    let registry = Arc::new(SnapshotRegistry::new());
    let mut logs: Vec<(String, RunLog)> = Vec::new();
    for (name, cal, batch_scaling, adaptive) in policies {
        let mut c = cfg.clone();
        c.calibration.enabled = cal;
        c.strategy.batch_scaling = batch_scaling;
        c.slide.adaptive = adaptive;
        c.validate()?;
        // The joint run also feeds the serve-side comparison below.
        let opts = if name == "joint" {
            TrainerOptions { publish: Some(registry.clone()), ..Default::default() }
        } else {
            TrainerOptions::default()
        };
        let log = run_single(&c, backend, opts)?;
        logs.push((name.to_string(), log));
    }

    // ---- serve: exact-only vs the SLO-armed approximate mode ---------------
    let (train, _) = make_data(&cfg);
    let data = Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples));
    let mut exact_cfg = cfg.clone();
    exact_cfg.slide.serve_slo_ms = 0.0;
    let serve_opts = |name: &str| ReplayOptions {
        pattern: cfg.serve.pattern,
        duration: cfg.serve.duration,
        follow_clock: false,
        train_log: None,
        name: name.to_string(),
        obs: crate::obs::ambient(),
    };
    let exact =
        replay(&exact_cfg, data.clone(), &registry, &RefBackend, &serve_opts("slide-exact"))?;
    let mut slo_cfg = cfg.clone();
    if slo_cfg.slide.serve_slo_ms <= 0.0 {
        // No SLO configured: arm it at the exact replay's median so the
        // same trace exerts pressure (windowed p95 crosses 0.9·SLO).
        let p50 = exact.latency_percentile_ms(50.0);
        slo_cfg.slide.serve_slo_ms = if p50.is_finite() && p50 > 0.0 { p50 } else { 1.0 };
    }
    let approx = replay(&slo_cfg, data.clone(), &registry, &RefBackend, &serve_opts("slide-slo"))?;
    let serve_p99 = (exact.latency_percentile_ms(99.0), approx.latency_percentile_ms(99.0));

    // ---- report ------------------------------------------------------------
    let mut t = Table::new(&["ratio", "step (ms, throttled)", "vs dense"]);
    let dense = ladder.first().map(|&(_, s)| s).unwrap_or(1.0);
    for &(r, s) in &ladder {
        t.row(&[
            format!("{r:.2}"),
            format!("{:.3}", s * 1e3),
            format!("{:.0}%", 100.0 * s / dense),
        ]);
    }
    t.print("Slide — per-step cost down the ratio ladder (device 0 at 10x throttle)");

    let throttled_balance: Vec<f64> =
        logs.iter().map(|(_, l)| l.window_balance(throttle_at + 1, recover_at)).collect();
    let target = common_target(&logs);
    let mut t = Table::new(&[
        "policy", "throttled balance", "best P@1", "final P@1",
        &format!("TTA@{target:.3} (s)"), "clock (s)", "mean ratio d0",
    ]);
    for ((name, log), tb) in logs.iter().zip(&throttled_balance) {
        // Device 0's mean commanded ratio across the throttled window.
        let window: Vec<&crate::metrics::MegaBatchRow> = log
            .rows
            .iter()
            .filter(|r| r.mega_batch > throttle_at && r.mega_batch < recover_at)
            .collect();
        let mean_ratio = if window.is_empty() {
            1.0
        } else {
            window.iter().map(|r| r.sparsity_ratio[0]).sum::<f64>() / window.len() as f64
        };
        t.row(&[
            name.clone(),
            format!("{tb:.2}"),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            fmt_opt(log.time_to_accuracy(target)),
            format!("{:.2}", log.rows.last().map(|r| r.clock).unwrap_or(0.0)),
            format!("{mean_ratio:.2}"),
        ]);
    }
    t.print(&format!(
        "Slide — scheduling policies under a 10x throttle at mb {throttle_at}, recovery at \
         mb {recover_at} ({})",
        profile.name()
    ));
    println!(
        "serve p99: exact {:.3} ms vs SLO-armed {:.3} ms (slo {:.3} ms, serve_ratio {:.2})",
        serve_p99.0, serve_p99.1, slo_cfg.slide.serve_slo_ms, slo_cfg.slide.serve_ratio
    );

    Ok(SlideOutcome { logs, ladder, throttled_balance, serve_p99 })
}

// ---------------------------------------------------------------------------
// Cluster — beyond the paper (ROADMAP north-star): multi-server scale-out.
// Three servers train over a simulated inter-server fabric while the
// scripted scenario throttles one uplink mid-run and takes a whole rack
// down and back up. The same physical scenario runs under three sync
// policies: flat averaging at a fixed cadence, hierarchical
// (staleness-weighted) merging at a fixed cadence, and hierarchical
// merging with the cadence adapting to the measured link speed.
// ---------------------------------------------------------------------------

pub struct ClusterExperimentOutcome {
    /// Flat tier-2 average (equal server weights, staleness ignored),
    /// fixed cadence.
    pub flat: crate::cluster::ClusterOutcome,
    /// Hierarchical staleness-weighted merge, fixed cadence.
    pub fixed: crate::cluster::ClusterOutcome,
    /// Hierarchical merge with link-calibrated adaptive cadence.
    pub adaptive: crate::cluster::ClusterOutcome,
}

/// `experiment cluster`. Pass `base` (e.g. from `--config`) to run under
/// an explicit config; `None` uses a bench-scale three-server scenario.
/// When the supplied config has no multi-server `[cluster]` block
/// (`servers < 2`), the default scenario block is applied on top — the
/// experiment always has a fabric to degrade. Numerics run the hermetic
/// reference backend on the virtual clock; every arm is deterministic.
pub fn cluster(
    profile: DataProfile,
    base_override: Option<&Config>,
) -> Result<ClusterExperimentOutcome> {
    use crate::cluster::{run_cluster, ClusterEvent, ClusterPolicy};

    let mut base = match base_override {
        Some(cfg) => cfg.clone(),
        None => {
            let mut cfg = bench_config(profile, 4, Strategy::Adaptive);
            apply_full_scale(&mut cfg);
            cfg.devices.jitter = 0.0;
            cfg
        }
    };
    if base.cluster.servers < 2 {
        // The default scenario: three servers, a mid-run 6x throttle on
        // server 1's uplink (window-indexed by sync round), and a
        // whole-rack loss + recovery on server 2. Bandwidth is set low
        // enough that a sync costs real time against the virtual clock —
        // otherwise there is nothing for the cadence to adapt to.
        let n = base.sgd.num_mega_batches;
        base.cluster.servers = 3;
        base.cluster.sync_every = 2;
        base.cluster.min_sync_every = 1;
        base.cluster.max_sync_every = 8;
        base.cluster.link_latency_s = 2e-3;
        base.cluster.link_gbytes_per_sec = 0.05;
        base.cluster.straggler_floor = 0.5;
        // Server 2 is 1.6x slower across the board *and* the one that
        // loses its rack — the staleness-weighted merge has something to
        // discount, without tripping the 0.5 demotion floor.
        base.cluster.server_speed_factors = vec![1.0, 1.0, 1.6];
        base.cluster.events = vec![
            "at_mb=2 link=1 factor=6.0".to_string(),
            "at_mb=5 link=1 factor=1.0".to_string(),
            format!("at_mb={} server=2 down", (n / 2).max(1)),
            format!("at_mb={} server=2 up", (3 * n / 4).max(n / 2 + 1)),
        ];
    }
    base.runtime.mode = crate::config::ExecMode::Virtual;
    base.validate()?;

    let flat = run_cluster(&base, ClusterPolicy { flat: true, adaptive: false }, "flat")?;
    let fixed =
        run_cluster(&base, ClusterPolicy { flat: false, adaptive: false }, "hier-fixed")?;
    let adaptive =
        run_cluster(&base, ClusterPolicy { flat: false, adaptive: true }, "hier-adaptive")?;

    // The throttled window, in sync rounds, straight from the scripted
    // trace (balance is judged where the fabric was actually degraded).
    let trace = base.cluster.parsed_events()?;
    let link_windows: Vec<usize> = trace
        .iter()
        .filter_map(|e| match e {
            ClusterEvent::Link(d) => Some(d.at_mb),
            ClusterEvent::Rack { .. } => None,
        })
        .collect();
    let rounds_run = adaptive.rounds.len().max(fixed.rounds.len());
    let (w_lo, w_hi) = match (link_windows.iter().min(), link_windows.iter().max()) {
        (Some(&lo), Some(&hi)) if hi > lo => (lo, hi),
        (Some(&lo), _) => (lo, rounds_run),
        _ => (0, rounds_run),
    };

    // ---- the adaptive arm's per-round trace --------------------------------
    let mut t = Table::new(&[
        "round", "target mb", "cadence", "sync (s)", "clock (s)", "up", "demoted",
        "completed",
    ]);
    for r in &adaptive.rounds {
        let mark = |v: &[bool]| -> String {
            v.iter().map(|&b| if b { '1' } else { '0' }).collect()
        };
        t.row(&[
            r.round.to_string(),
            r.target_mb.to_string(),
            r.sync_every.to_string(),
            format!("{:.4}", r.sync_secs),
            format!("{:.2}", r.clock),
            mark(&r.up),
            mark(&r.demoted),
            format!("{:?}", r.completed),
        ]);
    }
    t.print(&format!(
        "Cluster — adaptive arm round trace: {} servers, link 1 throttled over sync \
         windows [{w_lo}, {w_hi}) ({})",
        base.cluster.servers,
        profile.name()
    ));

    // ---- policy comparison --------------------------------------------------
    let arms: [(&str, &crate::cluster::ClusterOutcome); 3] =
        [("flat", &flat), ("hier-fixed", &fixed), ("hier-adaptive", &adaptive)];
    let target = 0.85
        * arms
            .iter()
            .flat_map(|(_, o)| o.logs.iter().map(|l| l.best_accuracy()))
            .fold(0.0, f64::max);
    let mut t = Table::new(&[
        "policy", "syncs", "sync total (s)", "throttled balance", "mean final P@1",
        &format!("TTA@{target:.3} (s)"), "clock (s)",
    ]);
    for (name, out) in &arms {
        t.row(&[
            name.to_string(),
            out.syncs.to_string(),
            format!("{:.3}", out.total_sync_secs),
            format!("{:.2}", out.round_balance(w_lo, w_hi)),
            format!("{:.4}", out.mean_final_accuracy()),
            fmt_opt(out.time_to_accuracy(target)),
            format!("{:.2}", out.clock),
        ]);
    }
    t.print("Cluster — flat vs hierarchical vs adaptive-cadence time-to-accuracy");

    // ---- fabric telemetry (adaptive arm) -----------------------------------
    let mut t = Table::new(&["link", "MB moved", "sync (s)", "mean staleness (mb)"]);
    for row in &adaptive.link_stats {
        t.row(&[
            row.link.to_string(),
            format!("{:.2}", row.bytes_transferred / 1e6),
            format!("{:.3}", row.sync_seconds),
            format!("{:.2}", row.staleness_mb),
        ]);
    }
    t.print("Cluster — per-link fabric telemetry (adaptive arm)");

    let racks = adaptive
        .sync_events
        .iter()
        .filter(|e| e.action == "rack-down" || e.action == "rack-up")
        .count();
    let cadence_moves =
        adaptive.sync_events.iter().filter(|e| e.action == "cadence").count();
    println!(
        "adaptive cadence moved {cadence_moves} time(s); {racks} rack transition(s) rode \
         through; cross-server sync log has {} events",
        adaptive.sync_events.len()
    );

    Ok(ClusterExperimentOutcome { flat, fixed, adaptive })
}

/// `experiment fuzz` — drive the seeded cross-subsystem scenario fuzzer
/// ([`crate::scenario::fuzz`]) and report every invariant violation with
/// a shrunk counterexample plus the exact replay command. When `out` is
/// given the counterexamples are also written as JSON (an empty array on
/// a clean run, so CI can always upload the artifact). Fails — returns
/// `Err` after printing — if any case violated an invariant, so the
/// process exits non-zero under CI.
pub fn fuzz(
    opts: &crate::scenario::fuzz::FuzzOptions,
    out: Option<&std::path::Path>,
) -> Result<crate::scenario::fuzz::FuzzReport> {
    use crate::util::json::Json;
    use anyhow::Context as _;

    println!(
        "fuzz: seed={} runs={} subsystems={}",
        opts.seed,
        opts.runs,
        opts.subsystems.label()
    );
    let report = crate::scenario::fuzz::run(opts);
    println!(
        "fuzz: {} case(s) checked, {} violation(s)",
        report.cases_checked,
        report.failures.len()
    );
    for f in &report.failures {
        println!();
        println!("FAIL case #{} (case seed 0x{:016x})", f.case_index, f.case_seed);
        println!("  invariant: {}", f.message);
        println!("  case: {}", f.case.describe());
        println!(
            "  replay: experiment fuzz --seed {} --runs 1 --subsystems {}",
            f.case_seed,
            opts.subsystems.label()
        );
    }
    if let Some(path) = out {
        let failures = Json::arr(report.failures.iter().map(|f| {
            Json::obj(vec![
                ("case_index", Json::int(f.case_index as i64)),
                // Seeds travel as hex strings: u64 does not survive the
                // f64 round-trip a JSON number would force on it.
                ("case_seed_hex", Json::str(format!("{:016x}", f.case_seed))),
                ("message", Json::str(f.message.clone())),
                ("case", Json::str(f.case.describe())),
            ])
        }));
        let doc = Json::obj(vec![
            ("bench", Json::str("experiment/fuzz")),
            ("seed_hex", Json::str(format!("{:016x}", report.seed))),
            ("runs", Json::int(report.runs as i64)),
            ("subsystems", Json::str(opts.subsystems.label())),
            ("cases_checked", Json::int(report.cases_checked as i64)),
            ("failures", failures),
        ]);
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing fuzz counterexamples to {}", path.display()))?;
        println!("fuzz: wrote {} counterexample(s) to {}", report.failures.len(), path.display());
    }
    if !report.failures.is_empty() {
        anyhow::bail!(
            "{} of {} fuzz cases violated invariants",
            report.failures.len(),
            report.cases_checked
        );
    }
    Ok(report)
}

/// Config helper shared with `Config::from_overrides` users.
pub fn profile_of(cfg: &Config) -> DataProfile {
    cfg.data.profile
}

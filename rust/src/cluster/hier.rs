//! Two-tier hierarchical merging — the paper's §4 normalized weighted
//! average composed one level up, with staleness-weighted server scales.
//!
//! Tier 1 is the intra-server merge the [`TrainerSession`] already
//! performs (per-device weights normalized within the server); tier 2
//! averages the per-server consensus models with weights
//!
//! ```text
//! S_s  =  W_s · scale(staleness_s)        W_s = Σ_i w_si  (device mass)
//! S'_s =  S_s / Σ_t S_t                   scale(k) = 1 / (1 + k)
//! ```
//!
//! so a server that merged more device-updates counts for more, and a
//! stale (demoted or catching-up) server's contribution is discounted by
//! how many mega-batches it lags — the same normalization idea that
//! weights devices by update count within a server, applied across
//! servers.
//!
//! **Exact composition.** With all scales equal, the two-tier average is
//! algebraically the flat weighted average over every device:
//! `Σ_s (W_s/ΣW) Σ_i (w_si/W_s) m_si = Σ_si (w_si/ΣW) m_si`. To keep that
//! identity *numerically* (the property test pins it at 1e-10), every
//! accumulation in this module runs in f64 — f32 two-tier round-trips
//! would reintroduce ~1e-7 error.
//!
//! [`TrainerSession`]: crate::coordinator::trainer::TrainerSession

use crate::model::ModelState;

/// Staleness discount for a server lagging `staleness_mb` mega-batches
/// behind the sync target: `1 / (1 + k)`. Fresh servers are undiscounted.
pub fn staleness_scale(staleness_mb: usize) -> f64 {
    1.0 / (1.0 + staleness_mb as f64)
}

/// One server's contribution to a tier-2 merge.
#[derive(Clone, Copy, Debug)]
pub struct ServerContribution<'a> {
    /// The server's intra-merged consensus model (tier 1 output).
    pub model: &'a ModelState,
    /// The server's device mass `W_s` (> 0) — e.g. its summed merge
    /// weights or active-device count.
    pub weight: f64,
    /// Mega-batches this server lags behind the sync target.
    pub staleness_mb: usize,
}

/// Tier-2 merge: staleness-weighted f64 average of the per-server
/// consensus models, written back as a (f32) [`ModelState`]. Panics on an
/// empty contribution list or a non-positive weight.
pub fn merge_servers(contribs: &[ServerContribution]) -> ModelState {
    assert!(!contribs.is_empty(), "tier-2 merge needs at least one server");
    let weights: Vec<f64> = contribs
        .iter()
        .map(|c| {
            assert!(c.weight > 0.0, "server weight must be positive");
            c.weight * staleness_scale(c.staleness_mb)
        })
        .collect();
    let models: Vec<&ModelState> = contribs.iter().map(|c| c.model).collect();
    let segs = weighted_sum_f64(&models, &normalized(&weights));
    to_model(&contribs[0].model.dims, &segs)
}

/// Normalize weights to sum 1 (in f64).
pub fn normalized(weights: &[f64]) -> Vec<f64> {
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive total");
    weights.iter().map(|w| w / total).collect()
}

/// Per-segment f64 weighted sum `Σ_i weights[i] · models[i]` — the
/// reference arithmetic both tiers and the flat baseline share.
pub fn weighted_sum_f64(models: &[&ModelState], weights: &[f64]) -> Vec<Vec<f64>> {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty());
    let n_seg = models[0].segments().len();
    let mut out: Vec<Vec<f64>> = models[0]
        .segments()
        .iter()
        .map(|s| vec![0.0f64; s.len()])
        .collect();
    for (m, &w) in models.iter().zip(weights) {
        let segs = m.segments();
        assert_eq!(segs.len(), n_seg);
        for (acc, src) in out.iter_mut().zip(segs.iter()) {
            for (a, &x) in acc.iter_mut().zip(src.iter()) {
                *a += w * x as f64;
            }
        }
    }
    out
}

/// The flat (single-tier) normalized weighted average over every device —
/// the property-test reference the hierarchical path must match.
pub fn flat_average_f64(models: &[&ModelState], weights: &[f64]) -> Vec<Vec<f64>> {
    weighted_sum_f64(models, &normalized(weights))
}

/// The hierarchical (two-tier) average in f64: per-server normalized
/// intra-merge, then a server-mass (× staleness-scale) weighted tier-2
/// average. `servers[s]` lists server `s`'s device models,
/// `device_weights[s]` their (unnormalized) merge weights, `scales[s]`
/// the server's staleness discount (1.0 = fresh).
pub fn hierarchical_average_f64(
    servers: &[Vec<&ModelState>],
    device_weights: &[Vec<f64>],
    scales: &[f64],
) -> Vec<Vec<f64>> {
    assert_eq!(servers.len(), device_weights.len());
    assert_eq!(servers.len(), scales.len());
    assert!(!servers.is_empty());
    // Tier 1: per-server normalized merges (f64).
    let tier1: Vec<Vec<Vec<f64>>> = servers
        .iter()
        .zip(device_weights)
        .map(|(models, w)| weighted_sum_f64(models, &normalized(w)))
        .collect();
    // Tier 2: server mass × staleness scale, normalized.
    let masses: Vec<f64> = device_weights
        .iter()
        .zip(scales)
        .map(|(w, &sc)| w.iter().sum::<f64>() * sc)
        .collect();
    let sw = normalized(&masses);
    let mut out: Vec<Vec<f64>> =
        tier1[0].iter().map(|seg| vec![0.0f64; seg.len()]).collect();
    for (server, &w) in tier1.iter().zip(&sw) {
        for (acc, seg) in out.iter_mut().zip(server.iter()) {
            for (a, &x) in acc.iter_mut().zip(seg.iter()) {
                *a += w * x;
            }
        }
    }
    out
}

/// Largest absolute difference between two per-segment f64 buffers.
pub fn max_abs_diff_f64(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    a.iter()
        .zip(b.iter())
        .flat_map(|(x, y)| x.iter().zip(y.iter()))
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Cast per-segment f64 buffers back into a (f32) [`ModelState`].
pub fn to_model(dims: &crate::config::ModelDims, segs: &[Vec<f64>]) -> ModelState {
    let mut m = ModelState::zeros(dims);
    {
        let out = m.segments_mut();
        assert_eq!(out.len(), segs.len());
        for (dst, src) in out.into_iter().zip(segs.iter()) {
            assert_eq!(dst.len(), src.len());
            for (d, &x) in dst.iter_mut().zip(src.iter()) {
                *d = x as f32;
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { features: 48, hidden: 8, classes: 12, max_nnz: 6, max_labels: 3 }
    }

    #[test]
    fn two_tier_equals_flat_when_fresh() {
        let d = dims();
        let models: Vec<ModelState> =
            (0..5).map(|i| ModelState::init(&d, i as u64 + 1)).collect();
        let weights = [3.0, 1.0, 2.0, 5.0, 4.0];
        let refs: Vec<&ModelState> = models.iter().collect();
        let flat = flat_average_f64(&refs, &weights);
        // Partition {0,1} | {2} | {3,4}.
        let servers = vec![
            vec![&models[0], &models[1]],
            vec![&models[2]],
            vec![&models[3], &models[4]],
        ];
        let dw = vec![vec![3.0, 1.0], vec![2.0], vec![5.0, 4.0]];
        let hier = hierarchical_average_f64(&servers, &dw, &[1.0, 1.0, 1.0]);
        assert!(max_abs_diff_f64(&flat, &hier) < 1e-10);
    }

    #[test]
    fn staleness_discounts_a_lagging_server() {
        let d = dims();
        let a = ModelState::init(&d, 1);
        let b = ModelState::init(&d, 2);
        let fresh = merge_servers(&[
            ServerContribution { model: &a, weight: 1.0, staleness_mb: 0 },
            ServerContribution { model: &b, weight: 1.0, staleness_mb: 0 },
        ]);
        let stale_b = merge_servers(&[
            ServerContribution { model: &a, weight: 1.0, staleness_mb: 0 },
            ServerContribution { model: &b, weight: 1.0, staleness_mb: 3 },
        ]);
        // With b discounted 4×, the merge sits closer to a.
        let closer =
            stale_b.max_abs_diff(&a) < fresh.max_abs_diff(&a);
        assert!(closer, "staleness discount must pull the merge toward fresh servers");
        // scale(0) = 1, scale(3) = 1/4.
        assert_eq!(staleness_scale(0), 1.0);
        assert_eq!(staleness_scale(3), 0.25);
    }

    #[test]
    fn merge_servers_matches_the_f64_reference() {
        let d = dims();
        let a = ModelState::init(&d, 7);
        let b = ModelState::init(&d, 8);
        let merged = merge_servers(&[
            ServerContribution { model: &a, weight: 2.0, staleness_mb: 0 },
            ServerContribution { model: &b, weight: 1.0, staleness_mb: 1 },
        ]);
        // Effective weights 2 and 0.5, normalized 0.8 / 0.2.
        let expect = flat_average_f64(&[&a, &b], &[0.8, 0.2]);
        let got = weighted_sum_f64(&[&merged], &[1.0]);
        assert!(max_abs_diff_f64(&expect, &got) < 1e-7, "f32 storage rounds once");
    }
}

//! Scripted cluster scenarios — reproducible link-throttle and rack-loss
//! traces for the cluster experiments (`[cluster] events`).
//!
//! Two event kinds share one trace, distinguished by their key:
//!
//! * **Link throttle** — `"at_mb=N link=L factor=F [ramp=R]"`: uplink `L`
//!   slows to `F`× its configured transfer time starting at sync window
//!   `N`, optionally ramping over `R` windows. Exactly the
//!   [`DriftEvent`] grammar with `link` in place of `device`; link
//!   throttles are in fact *stored* as [`DriftEvent`]s (the link id in the
//!   device slot) so [`multiplier_at`](crate::tuning::multiplier_at)'s
//!   ramp-chaining semantics carry over verbatim.
//! * **Rack event** — `"at_mb=N server=S down"` / `"at_mb=N server=S up"`:
//!   whole-rack loss and recovery. A down server steps no mega-batches
//!   and joins no syncs (every device lease on that rack is gone at
//!   once); on `up` it resynchronizes from the cluster consensus and
//!   resumes, behind, with its staleness priced into the merge weights.
//!
//! Like drift traces, cluster traces describe the *physical* scenario —
//! they apply whether the sync cadence is fixed or adaptive, which is what
//! lets `experiment cluster` compare the two under identical fabric
//! behavior.

use anyhow::bail;

use crate::tuning::DriftEvent;
use crate::Result;

/// One scripted cluster event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClusterEvent {
    /// A link throttle/recover ramp; the [`DriftEvent::device`] field
    /// holds the uplink (server) id.
    Link(DriftEvent),
    /// A whole-rack loss or recovery.
    Rack {
        /// Mega-batch at which the rack changes state.
        at_mb: usize,
        /// Cluster server id.
        server: usize,
        /// `true` = the rack comes (back) up, `false` = it goes down.
        up: bool,
    },
}

impl ClusterEvent {
    /// Mega-batch at which the event lands.
    pub fn at_mb(&self) -> usize {
        match self {
            ClusterEvent::Link(e) => e.at_mb,
            ClusterEvent::Rack { at_mb, .. } => *at_mb,
        }
    }

    /// Parse one event string (see the module docs for the grammar).
    ///
    /// Thin view over the unified scenario grammar
    /// ([`crate::scenario::parse_event`]) under the link+rack mask; the
    /// accepted language — including the cross-verb exclusions (`up`/
    /// `down` only with `server=`, `factor`/`ramp` only with `link=`,
    /// never both `link=` and `server=`) — is the legacy one, unchanged.
    pub fn parse(s: &str) -> Result<ClusterEvent> {
        match crate::scenario::parse_event(s, crate::scenario::Mask::CLUSTER)? {
            crate::scenario::ScenarioEvent::Link(ev) => Ok(ClusterEvent::Link(ev)),
            crate::scenario::ScenarioEvent::Rack { at_mb, server, up } => {
                Ok(ClusterEvent::Rack { at_mb, server, up })
            }
            other => bail!("event '{s}' parsed as a non-cluster event ({other:?})"),
        }
    }
}

/// Parse a whole `[cluster] events` trace, sorted by `at_mb` (stable for
/// ties). Errors name the offending array index and full line.
pub fn parse_trace(events: &[String]) -> Result<Vec<ClusterEvent>> {
    let mut trace =
        crate::scenario::parse_trace_indexed("events", events, ClusterEvent::parse)?;
    trace.sort_by_key(|e| e.at_mb());
    Ok(trace)
}

/// The link-throttle subset of a trace, as [`DriftEvent`]s (link id in the
/// device slot) ready for [`multiplier_at`](crate::tuning::multiplier_at).
pub fn link_trace(trace: &[ClusterEvent]) -> Vec<DriftEvent> {
    trace
        .iter()
        .filter_map(|e| match e {
            ClusterEvent::Link(d) => Some(*d),
            ClusterEvent::Rack { .. } => None,
        })
        .collect()
}

/// Whether `server` is up at mega-batch `mb`: the latest rack event at or
/// before `mb` decides; servers start up.
pub fn rack_up(trace: &[ClusterEvent], server: usize, mb: usize) -> bool {
    let mut up = true;
    for e in trace {
        if let ClusterEvent::Rack { at_mb, server: s, up: u } = e {
            if *s == server && *at_mb <= mb {
                up = *u;
            }
        }
    }
    up
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_link_throttles_into_drift_events() {
        let e = ClusterEvent::parse("at_mb=6 link=0 factor=4.0 ramp=2").unwrap();
        assert_eq!(
            e,
            ClusterEvent::Link(DriftEvent { at_mb: 6, device: 0, factor: 4.0, ramp: 2 })
        );
        assert!(ClusterEvent::parse("at_mb=6 link=0").is_err(), "missing factor");
        assert!(ClusterEvent::parse("at_mb=6 link=0 factor=0").is_err());
        assert!(ClusterEvent::parse("at_mb=6 link=0 factor=2 down").is_err());
    }

    #[test]
    fn parses_rack_events() {
        let e = ClusterEvent::parse("at_mb=4 server=2 down").unwrap();
        assert_eq!(e, ClusterEvent::Rack { at_mb: 4, server: 2, up: false });
        let e = ClusterEvent::parse("at_mb=9 server=2 up").unwrap();
        assert_eq!(e, ClusterEvent::Rack { at_mb: 9, server: 2, up: true });
        assert!(ClusterEvent::parse("at_mb=4 server=2").is_err(), "missing state");
        assert!(ClusterEvent::parse("at_mb=4 server=2 factor=2 down").is_err());
        assert!(ClusterEvent::parse("at_mb=4 link=0 server=2 down").is_err());
        assert!(ClusterEvent::parse("at_mb=4 down").is_err(), "missing target");
        assert!(ClusterEvent::parse("server=2 down").is_err(), "missing at_mb");
        assert!(ClusterEvent::parse("at_mb=4 server=2 down up").is_err());
        assert!(ClusterEvent::parse("at_mb=4 explode=1").is_err());
    }

    #[test]
    fn rack_state_follows_the_latest_event() {
        let trace = parse_trace(&[
            "at_mb=8 server=1 up".to_string(),
            "at_mb=3 server=1 down".to_string(),
        ])
        .unwrap();
        assert_eq!(trace[0].at_mb(), 3, "trace sorts by at_mb");
        assert!(rack_up(&trace, 1, 0));
        assert!(rack_up(&trace, 1, 2));
        assert!(!rack_up(&trace, 1, 3));
        assert!(!rack_up(&trace, 1, 7));
        assert!(rack_up(&trace, 1, 8));
        assert!(rack_up(&trace, 0, 5), "other servers untouched");
    }

    #[test]
    fn link_trace_extracts_throttles_only() {
        let trace = parse_trace(&[
            "at_mb=3 server=1 down".to_string(),
            "at_mb=5 link=0 factor=3.0".to_string(),
        ])
        .unwrap();
        let links = link_trace(&trace);
        assert_eq!(links.len(), 1);
        assert_eq!(links[0].device, 0);
        assert_eq!(crate::tuning::multiplier_at(&links, 0, 6), 3.0);
    }
}

//! `ClusterSim` — deterministic discrete-event training over a simulated
//! multi-server fabric.
//!
//! Each server runs the full single-server stack — its own
//! [`TrainerSession`] stepping a heterogeneous [`DevicePool`] roster with
//! Algorithm 2's normalized intra-server merge — over its own shard of
//! the corpus. Time advances in **sync rounds**: every `sync_every`
//! mega-batches the servers meet at a fabric barrier, exchange their
//! consensus models through the inter-server all-reduce
//! ([`Fabric::sync_time`] prices it at the bottleneck link), and install
//! the staleness-weighted tier-2 average ([`merge_servers`]) back into
//! every participant. The whole schedule is a pure function of the config
//! — same inputs, bit-identical outcome.
//!
//! Per round, in order:
//!
//! 1. **Rack events** land at the round's starting mega-batch: a down
//!    server steps nothing and joins no sync (whole-rack loss — every
//!    device lease on that server is gone at once); a recovering server
//!    resynchronizes from the last cluster consensus and resumes, behind.
//! 2. **Full-speed servers** step to the round's target mega-batch; the
//!    barrier time is the slowest participant's clock.
//! 3. **Demoted stragglers** catch up asynchronously: they step only
//!    while their clock stays below the barrier, so they never stretch
//!    it. Whatever they reach, their lag is priced into the merge as
//!    staleness.
//! 4. **Sync**: tier-2 merge + fabric charge; every participant's next
//!    step starts at `barrier + sync_secs`.
//! 5. **Straggler policy**: each server's measured mega-batch rate over
//!    the round (its calibrated aggregate speed — rates come from
//!    observed step timings, not config constants) is compared against
//!    `straggler_floor ×` the fastest server's; below the floor demotes,
//!    at or above it promotes back.
//! 6. **Adaptive cadence** (when enabled): the next round's `sync_every`
//!    is chosen so the *measured* sync cost stays near `comm_target` of
//!    wall time — a throttled link inflates the measured cost and
//!    stretches the interval; recovery tightens it again.
//!
//! [`TrainerSession`]: crate::coordinator::trainer::TrainerSession
//! [`DevicePool`]: crate::coordinator::DevicePool

use std::sync::Arc;

use anyhow::{bail, ensure};

use crate::allreduce::Algo;
use crate::config::Config;
use crate::coordinator::backend::RefBackend;
use crate::coordinator::engine_sim::SimEngine;
use crate::coordinator::trainer::{TrainerOptions, TrainerSession};
use crate::coordinator::DevicePool;
use crate::data::pipeline::ShardedDataset;
use crate::data::synthetic::Generator;
use crate::metrics::{LinkStatRow, RunLog, SyncEventRow};
use crate::model::ModelState;
use crate::runtime::CostModel;
use crate::Result;

use super::events::{link_trace, parse_trace, rack_up, ClusterEvent};
use super::fabric::Fabric;
use super::hier::{merge_servers, ServerContribution};

/// Which merge/cadence policy a cluster run uses — the experiment's arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterPolicy {
    /// Tier-2 merge with equal server weights and no staleness discount
    /// (the flat-average baseline) instead of the hierarchical
    /// update-mass × staleness weighting.
    pub flat: bool,
    /// Adapt `sync_every` to the measured link speed (else the configured
    /// cadence is fixed for the whole run).
    pub adaptive: bool,
}

impl ClusterPolicy {
    /// The policy the config asks for (hierarchical weighting; cadence
    /// adaptivity per `[cluster] adaptive`).
    pub fn from_config(cfg: &Config) -> ClusterPolicy {
        ClusterPolicy { flat: false, adaptive: cfg.cluster.adaptive }
    }
}

/// One sync round's summary.
#[derive(Clone, Debug)]
pub struct RoundRow {
    /// Round index (also the fabric's throttle window).
    pub round: usize,
    /// Mega-batch target the round stepped toward.
    pub target_mb: usize,
    /// Cadence in effect during the round.
    pub sync_every: usize,
    /// Cluster clock after the round's sync.
    pub clock: f64,
    /// Fabric time the sync cost (0 when it degenerated to one server).
    pub sync_secs: f64,
    /// Servers that joined the sync.
    pub participants: Vec<usize>,
    /// Per-server completed mega-batches after the round.
    pub completed: Vec<usize>,
    /// Per-server demotion state after the round.
    pub demoted: Vec<bool>,
    /// Per-server rack state during the round.
    pub up: Vec<bool>,
}

/// Everything a cluster run produced.
pub struct ClusterOutcome {
    /// Run name.
    pub name: String,
    /// One training log per server (cluster-clock aligned), each carrying
    /// its own sync events and its uplink's telemetry row.
    pub logs: Vec<RunLog>,
    /// Per-round summaries.
    pub rounds: Vec<RoundRow>,
    /// The full cross-server sync event log, time-ordered.
    pub sync_events: Vec<SyncEventRow>,
    /// Per-link fabric telemetry.
    pub link_stats: Vec<LinkStatRow>,
    /// Total seconds spent in inter-server syncs.
    pub total_sync_secs: f64,
    /// Inter-server syncs performed.
    pub syncs: usize,
    /// Final cluster clock.
    pub clock: f64,
}

impl ClusterOutcome {
    /// Mean final accuracy across servers that finished at least one row.
    pub fn mean_final_accuracy(&self) -> f64 {
        let accs: Vec<f64> = self
            .logs
            .iter()
            .filter(|l| !l.rows.is_empty())
            .map(|l| l.final_accuracy())
            .collect();
        if accs.is_empty() {
            0.0
        } else {
            accs.iter().sum::<f64>() / accs.len() as f64
        }
    }

    /// Earliest cluster-clock time at which any server's log reached the
    /// target accuracy (the cluster's time-to-accuracy).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.logs
            .iter()
            .filter_map(|l| l.time_to_accuracy(target))
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Mean max/min ratio of per-server mega-batch progress over rounds
    /// whose index lies in `[from, to)`, counting only up servers — the
    /// cluster-level analog of [`RunLog::window_balance`]. 1.0 when every
    /// up server advanced equally (or the range is empty).
    pub fn round_balance(&self, from: usize, to: usize) -> f64 {
        let mut prev: Vec<usize> = vec![0; self.logs.len()];
        let mut ratios = Vec::new();
        for r in &self.rounds {
            let delta: Vec<usize> = r
                .completed
                .iter()
                .zip(&prev)
                .zip(&r.up)
                .filter(|(_, &up)| up)
                .map(|((&c, &p), _)| c - p)
                .collect();
            if (from..to).contains(&r.round) {
                let worked: Vec<usize> = delta.iter().copied().filter(|&d| d > 0).collect();
                if worked.len() >= 2 {
                    let hi = *worked.iter().max().unwrap() as f64;
                    let lo = *worked.iter().min().unwrap() as f64;
                    ratios.push(hi / lo);
                } else {
                    ratios.push(1.0);
                }
            }
            prev = r.completed.clone();
        }
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }
}

struct ServerState<'b> {
    session: TrainerSession<'b>,
    active: Vec<usize>,
    demoted: bool,
    up: bool,
}

/// The multi-server discrete-event simulation (see the module docs for
/// the round schedule). Holds every server's live [`TrainerSession`];
/// consumed by [`ClusterSim::run`].
pub struct ClusterSim<'b> {
    cfg: Config,
    policy: ClusterPolicy,
    name: String,
    servers: Vec<ServerState<'b>>,
    fabric: Fabric,
    trace: Vec<ClusterEvent>,
    obs: crate::obs::ObsHandle,
}

impl<'b> ClusterSim<'b> {
    /// Build the per-server sessions (reference backend, virtual clocks).
    /// Server `s` trains on its own deterministic shard of the synthetic
    /// corpus; all servers share one test split.
    pub fn new(
        cfg: &Config,
        policy: ClusterPolicy,
        backend: &'b RefBackend,
        name: &str,
    ) -> Result<ClusterSim<'b>> {
        ClusterSim::new_with(cfg, policy, backend, name, crate::obs::ambient())
    }

    /// [`ClusterSim::new`] with an explicit observability handle: server
    /// `s`'s spans group under trace pid `s` (one process lane per
    /// server), tier-2 syncs land as `cluster.sync` spans with the
    /// decision reason, and fabric link telemetry mirrors into the
    /// shared registry.
    pub fn new_with(
        cfg: &Config,
        policy: ClusterPolicy,
        backend: &'b RefBackend,
        name: &str,
        obs: crate::obs::ObsHandle,
    ) -> Result<ClusterSim<'b>> {
        cfg.validate()?;
        let c = &cfg.cluster;
        ensure!(c.servers >= 1, "cluster.servers must be at least 1");
        let trace = parse_trace(&c.events)?;
        let algo = match c.algo.as_str() {
            "ring" => Algo::Ring,
            "tree" => Algo::Tree,
            other => bail!("cluster.algo '{other}' must be \"ring\" or \"tree\""),
        };
        let fabric = Fabric::new_obs(
            c.servers,
            c.link_latency_s,
            c.link_gbytes_per_sec * 1e9,
            algo,
            c.streams,
            link_trace(&trace),
            &obs,
        );

        let gen = Generator::new(&cfg.model, &cfg.data);
        let test = Arc::new(gen.generate(cfg.data.test_samples, 2));
        let mut servers = Vec::with_capacity(c.servers);
        for s in 0..c.servers {
            // Server 0 trains the same shard a single-server run would
            // (seed 1); later servers get disjointly-seeded shards.
            let seed = 1 + 9973 * s as u64;
            let train_ds = gen.generate(cfg.data.train_samples, seed);
            let train = Arc::new(ShardedDataset::from_dataset(
                &train_ds,
                cfg.data.pipeline.shard_samples,
            ));
            // A heterogeneous cluster: the server's relative speed scales
            // every device on it (multiplying by 1.0 is bit-exact, so a
            // homogeneous cluster is unchanged).
            let mut scfg = cfg.clone();
            if let Some(&f) = c.server_speed_factors.get(s) {
                for sf in &mut scfg.devices.speed_factors {
                    *sf *= f;
                }
                for sf in &mut scfg.elastic.spare_devices {
                    *sf *= f;
                }
            }
            let engine = Box::new(
                SimEngine::new(backend, DevicePool::roster(&scfg), CostModel::default())
                    .with_slide(&scfg.slide),
            );
            let active = DevicePool::new(&scfg)?.active_ids();
            let session = TrainerSession::new(
                scfg,
                engine,
                backend,
                TrainerOptions {
                    // One trace process lane per server.
                    obs: obs.for_pid(s as u32),
                    ..TrainerOptions::default()
                },
                train,
                test.clone(),
                format!("{name}/server{s}"),
            )?;
            servers.push(ServerState { session, active, demoted: false, up: true });
        }
        Ok(ClusterSim {
            cfg: cfg.clone(),
            policy,
            name: name.to_string(),
            servers,
            fabric,
            trace,
            obs,
        })
    }

    /// Run the simulation to completion.
    pub fn run(mut self) -> Result<ClusterOutcome> {
        let total = self.cfg.sgd.num_mega_batches;
        let c = self.cfg.cluster.clone();
        let mut sync_every = c.sync_every;
        let mut cluster_clock = 0.0f64;
        let mut target = 0usize;
        let mut consensus: Option<ModelState> = None;
        let mut rounds: Vec<RoundRow> = Vec::new();
        let mut sync_events: Vec<SyncEventRow> = Vec::new();
        let mut total_sync_secs = 0.0f64;
        let mut syncs = 0usize;
        // Rounds are bounded: the target advances every round, and a
        // full-down round still advances it, so this only guards against
        // a future scheduling bug, not a reachable state.
        const MAX_ROUNDS: usize = 100_000;

        for round in 0..MAX_ROUNDS {
            if self.servers.iter().all(|s| s.session.done()) {
                break;
            }
            let start_mb = target;
            target = (target + sync_every).min(total);

            // ---- rack events at the round boundary -------------------------
            for s in 0..self.servers.len() {
                let up = rack_up(&self.trace, s, start_mb);
                if up != self.servers[s].up {
                    let mb = self.servers[s].session.completed_mega_batches();
                    if up {
                        // Recover: resync from the cluster consensus
                        // before stepping again.
                        if let Some(m) = &consensus {
                            self.servers[s].session.install_global(m.clone());
                        }
                        sync_events.push(SyncEventRow {
                            at: cluster_clock,
                            mega_batch: mb,
                            server: s,
                            action: "rack-up".to_string(),
                            reason: "resynced from cluster consensus".to_string(),
                        });
                    } else {
                        sync_events.push(SyncEventRow {
                            at: cluster_clock,
                            mega_batch: mb,
                            server: s,
                            action: "rack-down".to_string(),
                            reason: "whole-rack loss: every device lease released"
                                .to_string(),
                        });
                    }
                    self.obs.for_pid(s as u32).instant(
                        crate::obs::Subsystem::Cluster,
                        if up { "cluster.rack_up" } else { "cluster.rack_down" },
                        0,
                        cluster_clock,
                        vec![("server", s.into()), ("mega_batch", mb.into())],
                    );
                    self.servers[s].up = up;
                }
            }

            // ---- step full-speed servers to the target ---------------------
            let mut mb_before = Vec::with_capacity(self.servers.len());
            let mut clock_before = Vec::with_capacity(self.servers.len());
            for s in self.servers.iter() {
                mb_before.push(s.session.completed_mega_batches());
                clock_before.push(s.session.clock());
            }
            let mut barrier = cluster_clock;
            let mut any_full_speed = false;
            for s in self.servers.iter_mut() {
                if !s.up || s.demoted || s.session.done() {
                    continue;
                }
                any_full_speed = true;
                while !s.session.done() && s.session.completed_mega_batches() < target {
                    let active = s.active.clone();
                    s.session.step(&active, cluster_clock, Vec::new())?;
                }
                barrier = barrier.max(s.session.clock());
            }

            // ---- demoted stragglers catch up off the barrier ---------------
            // While full-speed servers set a barrier, a demoted server only
            // steps inside it (it never stretches the sync). Once *only*
            // demoted servers remain unfinished there is no barrier left to
            // protect, so they run to the target like anyone else — which
            // is also what guarantees the loop terminates.
            for s in self.servers.iter_mut() {
                if !s.up || !s.demoted || s.session.done() {
                    continue;
                }
                while !s.session.done()
                    && s.session.completed_mega_batches() < target
                    && (!any_full_speed || s.session.clock() < barrier)
                {
                    let active = s.active.clone();
                    s.session.step(&active, cluster_clock, Vec::new())?;
                }
                if !any_full_speed {
                    barrier = barrier.max(s.session.clock());
                }
            }

            // ---- tier-2 sync ----------------------------------------------
            let participants: Vec<usize> = (0..self.servers.len())
                .filter(|&s| self.servers[s].up)
                .collect();
            let stepped = self
                .servers
                .iter()
                .enumerate()
                .any(|(i, s)| s.session.completed_mega_batches() > mb_before[i]);
            let mut sync_secs = 0.0;
            if participants.len() >= 2 && stepped {
                let staleness: Vec<usize> = participants
                    .iter()
                    .map(|&s| target - self.servers[s].session.completed_mega_batches().min(target))
                    .collect();
                let bytes =
                    (self.servers[0].session.global_model().param_count() * 4) as f64;
                sync_secs = self.fabric.sync_time(&participants, bytes, round);
                let merged = {
                    let contribs: Vec<ServerContribution<'_>> = participants
                        .iter()
                        .zip(&staleness)
                        .map(|(&s, &lag)| {
                            let sess = &self.servers[s].session;
                            let (weight, lag) = if self.policy.flat {
                                (1.0, 0)
                            } else {
                                (update_mass(sess, mb_before[s]).max(1.0), lag)
                            };
                            ServerContribution {
                                model: sess.global_model(),
                                weight,
                                staleness_mb: lag,
                            }
                        })
                        .collect();
                    merge_servers(&contribs)
                };
                self.fabric.record_sync(&participants, &staleness, bytes, round);
                for (&s, &lag) in participants.iter().zip(&staleness) {
                    self.servers[s].session.install_global(merged.clone());
                    sync_events.push(SyncEventRow {
                        at: barrier + sync_secs,
                        mega_batch: self.servers[s].session.completed_mega_batches(),
                        server: s,
                        action: "sync".to_string(),
                        reason: format!("window={round} cadence={sync_every} stale={lag}"),
                    });
                    // Tier-2 barrier span on each participant's coordinator
                    // lane: [barrier, barrier + sync_secs], reason attached.
                    self.obs.for_pid(s as u32).span(
                        crate::obs::Subsystem::Cluster,
                        "cluster.sync",
                        0,
                        barrier,
                        sync_secs,
                        vec![
                            ("window", round.into()),
                            ("cadence", sync_every.into()),
                            ("stale", lag.into()),
                            ("participants", participants.len().into()),
                        ],
                    );
                }
                self.obs.counter("cluster.syncs").inc();
                consensus = Some(merged);
                total_sync_secs += sync_secs;
                syncs += 1;
            }
            let round_start_clock = cluster_clock;
            cluster_clock = barrier + sync_secs;

            // ---- straggler policy: measured aggregate speed vs floor -------
            if c.straggler_floor > 0.0 {
                let rates: Vec<Option<f64>> = self
                    .servers
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let dmb = s.session.completed_mega_batches() - mb_before[i];
                        let dt = s.session.clock() - clock_before[i];
                        (s.up && dmb > 0 && dt > 0.0).then(|| dmb as f64 / dt)
                    })
                    .collect();
                let max_rate = rates.iter().flatten().fold(0.0f64, |a, &b| a.max(b));
                if max_rate > 0.0 {
                    for (i, rate) in rates.iter().enumerate() {
                        let Some(rate) = rate else { continue };
                        let floor = c.straggler_floor * max_rate;
                        let srv = &mut self.servers[i];
                        if !srv.demoted && *rate < floor {
                            srv.demoted = true;
                            sync_events.push(SyncEventRow {
                                at: cluster_clock,
                                mega_batch: srv.session.completed_mega_batches(),
                                server: i,
                                action: "demote".to_string(),
                                reason: format!(
                                    "measured {rate:.3} mb/s < floor {floor:.3}: async catch-up"
                                ),
                            });
                            self.obs.for_pid(i as u32).instant(
                                crate::obs::Subsystem::Cluster,
                                "cluster.demote",
                                0,
                                cluster_clock,
                                vec![("rate", (*rate).into()), ("floor", floor.into())],
                            );
                        } else if srv.demoted && *rate >= floor {
                            srv.demoted = false;
                            sync_events.push(SyncEventRow {
                                at: cluster_clock,
                                mega_batch: srv.session.completed_mega_batches(),
                                server: i,
                                action: "promote".to_string(),
                                reason: format!(
                                    "measured {rate:.3} mb/s >= floor {floor:.3}: rejoins barrier"
                                ),
                            });
                            self.obs.for_pid(i as u32).instant(
                                crate::obs::Subsystem::Cluster,
                                "cluster.promote",
                                0,
                                cluster_clock,
                                vec![("rate", (*rate).into()), ("floor", floor.into())],
                            );
                        }
                    }
                }
            }

            // ---- adaptive cadence ------------------------------------------
            if self.policy.adaptive && sync_secs > 0.0 && target > start_mb {
                let per_mb =
                    (barrier - round_start_clock).max(1e-12) / (target - start_mb) as f64;
                // sync/(sync + n·per_mb) = comm_target  =>  n.
                let n = sync_secs * (1.0 - c.comm_target) / (c.comm_target * per_mb);
                let new_every =
                    (n.ceil() as usize).clamp(c.min_sync_every, c.max_sync_every);
                if new_every != sync_every {
                    let bottleneck = self.fabric.bottleneck_slowdown(&participants);
                    sync_events.push(SyncEventRow {
                        at: cluster_clock,
                        mega_batch: target,
                        server: participants[0],
                        action: "cadence".to_string(),
                        reason: format!(
                            "sync {sync_secs:.4}s vs {per_mb:.4}s/mb: cadence {sync_every} -> \
                             {new_every} (bottleneck x{bottleneck:.2})"
                        ),
                    });
                    self.obs.for_pid(participants[0] as u32).instant(
                        crate::obs::Subsystem::Cluster,
                        "cluster.cadence",
                        0,
                        cluster_clock,
                        vec![
                            ("from", sync_every.into()),
                            ("to", new_every.into()),
                            ("sync_secs", sync_secs.into()),
                            ("per_mb", per_mb.into()),
                            ("comm_target", c.comm_target.into()),
                            ("bottleneck", bottleneck.into()),
                        ],
                    );
                    sync_every = new_every;
                }
            }

            rounds.push(RoundRow {
                round,
                target_mb: target,
                sync_every,
                clock: cluster_clock,
                sync_secs,
                participants,
                completed: self
                    .servers
                    .iter()
                    .map(|s| s.session.completed_mega_batches())
                    .collect(),
                demoted: self.servers.iter().map(|s| s.demoted).collect(),
                up: self.servers.iter().map(|s| s.up).collect(),
            });

            // A fully-down, unfinished cluster with no future rack
            // recovery would spin; rack traces are finite, so once the
            // target passes the last event with nobody up, stop.
            if self.servers.iter().all(|s| !s.up || s.session.done())
                && self.servers.iter().any(|s| !s.session.done())
                && target >= total
                && self.trace.iter().all(|e| e.at_mb() <= start_mb)
            {
                break;
            }
        }

        let link_stats = self.fabric.stats();
        let mut logs = Vec::with_capacity(self.servers.len());
        for (s, srv) in self.servers.into_iter().enumerate() {
            let mut log = srv.session.into_log();
            log.sync_events =
                sync_events.iter().filter(|e| e.server == s).cloned().collect();
            log.link_stats = vec![link_stats[s].clone()];
            logs.push(log);
        }
        Ok(ClusterOutcome {
            name: self.name,
            logs,
            rounds,
            sync_events,
            link_stats,
            total_sync_secs,
            syncs,
            clock: cluster_clock,
        })
    }
}

/// A server's update mass since `from_mb` — the sum of its per-device
/// update counts over the rows it merged this round (the tier-2 analog of
/// Algorithm 2's update-count weighting).
fn update_mass(session: &TrainerSession<'_>, from_mb: usize) -> f64 {
    session
        .log()
        .rows
        .iter()
        .filter(|r| r.mega_batch >= from_mb)
        .map(|r| r.updates.iter().sum::<u64>() as f64)
        .sum()
}

/// Run one cluster simulation under `cfg` with the given policy
/// (hermetic reference backend, virtual clocks).
pub fn run_cluster(cfg: &Config, policy: ClusterPolicy, name: &str) -> Result<ClusterOutcome> {
    let backend = RefBackend;
    ClusterSim::new(cfg, policy, &backend, name)?.run()
}

/// [`run_cluster`] with an explicit observability handle (see
/// [`ClusterSim::new_with`]) — what the trace-determinism tests drive so
/// they can inspect the sink without touching the process-wide ambient
/// handle.
pub fn run_cluster_with(
    cfg: &Config,
    policy: ClusterPolicy,
    name: &str,
    obs: crate::obs::ObsHandle,
) -> Result<ClusterOutcome> {
    let backend = RefBackend;
    ClusterSim::new_with(cfg, policy, &backend, name, obs)?.run()
}

//! The inter-server fabric — a network cost model for cross-server
//! all-reduce, with scripted degradation and online link calibration.
//!
//! Each server owns one uplink into the fabric, described by a nominal
//! per-hop latency and bandwidth (`[cluster] link_latency_s` /
//! `link_gbytes_per_sec`). An inter-server sync runs the same staged
//! schedule as the intra-server [`crate::allreduce`] module — ring
//! `2(G-1)` stages or tree `2·ceil(log2 G)` stages with fan-in-2
//! contention, `streams` partitions pipelined with a `streams - 1` fill —
//! but each stage's hop is priced at the **bottleneck link** among the
//! participants (a synchronous stage moves at the slowest hop), which is
//! what makes one throttled uplink drag the whole barrier.
//!
//! Scripted link throttles (`[cluster] events`, window-indexed by sync
//! round) multiply a link's effective latency and per-byte time through
//! [`multiplier_at`]'s ramp semantics. Every sync also feeds one
//! [`LinkEstimator`] observation per participating link, so the cadence
//! controller reads *measured* link speed, not the script.

use crate::allreduce::Algo;
use crate::metrics::LinkStatRow;
use crate::obs::ObsHandle;
use crate::tuning::{multiplier_at, DriftEvent, EstimatorConfig, LinkEstimate, LinkEstimator};

/// Effective cost of one fabric hop over one link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Per-hop propagation latency (seconds).
    pub latency: f64,
    /// Transfer time per byte (seconds; 1 / bandwidth).
    pub secs_per_byte: f64,
}

impl LinkSpec {
    /// Seconds for one hop moving `bytes` over this link.
    pub fn hop_secs(&self, bytes: f64) -> f64 {
        self.latency + self.secs_per_byte * bytes
    }
}

/// Per-link running telemetry (exported as [`LinkStatRow`]s).
#[derive(Clone, Copy, Debug, Default)]
struct LinkTally {
    bytes: f64,
    secs: f64,
    staleness_sum: f64,
    syncs: u64,
}

/// The simulated multi-server fabric: one uplink per server.
#[derive(Clone, Debug)]
pub struct Fabric {
    nominal: LinkSpec,
    throttle: Vec<DriftEvent>,
    estimators: Vec<LinkEstimator>,
    tallies: Vec<LinkTally>,
    algo: Algo,
    streams: usize,
    /// Mirrors each completed sync's per-link tallies into the registry
    /// under `cluster.link{l}.*` dotted names.
    obs: ObsHandle,
}

impl Fabric {
    /// A fabric of `servers` identical uplinks (`latency` seconds/hop,
    /// `bytes_per_sec` bandwidth) degraded by the scripted `throttle`
    /// trace (link id in the [`DriftEvent::device`] slot).
    pub fn new(
        servers: usize,
        latency: f64,
        bytes_per_sec: f64,
        algo: Algo,
        streams: usize,
        throttle: Vec<DriftEvent>,
    ) -> Fabric {
        Fabric::new_obs(servers, latency, bytes_per_sec, algo, streams, throttle, &ObsHandle::disabled())
    }

    /// [`Fabric::new`] with per-link telemetry mirrored into `obs`'s
    /// registry (the cluster simulator passes its handle).
    #[allow(clippy::too_many_arguments)]
    pub fn new_obs(
        servers: usize,
        latency: f64,
        bytes_per_sec: f64,
        algo: Algo,
        streams: usize,
        throttle: Vec<DriftEvent>,
        obs: &ObsHandle,
    ) -> Fabric {
        assert!(servers >= 1, "a fabric needs at least one server");
        assert!(bytes_per_sec > 0.0, "link bandwidth must be positive");
        let cfg = EstimatorConfig { step_obs: 1, ..EstimatorConfig::default() };
        Fabric {
            nominal: LinkSpec { latency, secs_per_byte: 1.0 / bytes_per_sec },
            throttle,
            estimators: (0..servers)
                .map(|_| LinkEstimator::new(cfg, latency, bytes_per_sec))
                .collect(),
            tallies: vec![LinkTally::default(); servers],
            algo,
            streams: streams.max(1),
            obs: obs.clone(),
        }
    }

    /// Number of uplinks (= servers).
    pub fn links(&self) -> usize {
        self.estimators.len()
    }

    /// The effective cost of `link` at sync window `window`: the nominal
    /// spec times the scripted throttle multiplier (both the latency and
    /// the per-byte term slow down — "the link is F× slower").
    pub fn effective(&self, link: usize, window: usize) -> LinkSpec {
        let f = multiplier_at(&self.throttle, link, window);
        LinkSpec {
            latency: self.nominal.latency * f,
            secs_per_byte: self.nominal.secs_per_byte * f,
        }
    }

    /// Simulated wall time of one inter-server all-reduce among
    /// `participants` moving `bytes` of model state, at sync window
    /// `window`. Mirrors [`crate::allreduce::simulated_time`]'s stage
    /// math, with every stage priced at the bottleneck participant link.
    pub fn sync_time(&self, participants: &[usize], bytes: f64, window: usize) -> f64 {
        let g = participants.len();
        if g <= 1 {
            return 0.0;
        }
        let part = bytes / self.streams as f64;
        let hop = participants
            .iter()
            .map(|&l| self.effective(l, window).hop_secs(part))
            .fold(0.0, f64::max);
        let stages = match self.algo {
            Algo::Ring => 2 * (g - 1),
            Algo::Tree => {
                let levels = (g as f64).log2().ceil() as usize;
                2 * levels * 2 // fan-in-2 contention doubles per-stage traffic
            }
        };
        (stages + self.streams - 1) as f64 * hop
    }

    /// Record one completed sync: accumulate per-link telemetry and feed
    /// each participating link's estimator with its measured hop (the
    /// link's own effective cost — links see their local speed, the
    /// barrier sees the bottleneck). `staleness[i]` is participant `i`'s
    /// mega-batch lag at the merge.
    pub fn record_sync(
        &mut self,
        participants: &[usize],
        staleness: &[usize],
        bytes: f64,
        window: usize,
    ) {
        debug_assert_eq!(participants.len(), staleness.len());
        let g = participants.len();
        if g <= 1 {
            return;
        }
        let part = bytes / self.streams as f64;
        // Ring traffic per member: each of the 2(G-1) stages moves one
        // partition per stream; per-link bytes ≈ 2·(G-1)/G · total.
        let link_bytes = 2.0 * (g - 1) as f64 / g as f64 * bytes;
        let sync_secs = self.sync_time(participants, bytes, window);
        for (&l, &lag) in participants.iter().zip(staleness) {
            let hop = self.effective(l, window).hop_secs(part);
            let t = &mut self.tallies[l];
            t.bytes += link_bytes;
            t.secs += sync_secs;
            t.staleness_sum += lag as f64;
            t.syncs += 1;
            self.estimators[l].observe(part, hop);
            // Mirror into the registry (sync-rate path, not per-step hot).
            self.obs.gauge(&format!("cluster.link{l}.bytes")).add(link_bytes);
            self.obs.gauge(&format!("cluster.link{l}.secs")).add(sync_secs);
            self.obs.gauge(&format!("cluster.link{l}.staleness")).add(lag as f64);
            self.obs.counter(&format!("cluster.link{l}.syncs")).inc();
        }
    }

    /// The measured slowdown of `link` (1.0 until calibrated) — what the
    /// adaptive cadence reads instead of the scripted trace.
    pub fn link_slowdown(&self, link: usize) -> f64 {
        self.estimators[link].slowdown()
    }

    /// The worst measured slowdown across a participant set (1.0 when
    /// empty) — the cadence controller's summary of fabric health.
    pub fn bottleneck_slowdown(&self, participants: &[usize]) -> f64 {
        participants.iter().map(|&l| self.link_slowdown(l)).fold(1.0, f64::max)
    }

    /// The current calibrated estimate for `link` (None until it has
    /// carried a sync).
    pub fn link_estimate(&self, link: usize) -> Option<LinkEstimate> {
        self.estimators[link].estimate()
    }

    /// Per-link telemetry rows for the run log.
    pub fn stats(&self) -> Vec<LinkStatRow> {
        self.tallies
            .iter()
            .enumerate()
            .map(|(link, t)| LinkStatRow {
                link,
                bytes_transferred: t.bytes,
                sync_seconds: t.secs,
                staleness_mb: if t.syncs == 0 {
                    0.0
                } else {
                    t.staleness_sum / t.syncs as f64
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(servers: usize, throttle: Vec<DriftEvent>) -> Fabric {
        // 1 ms hops, 1 GB/s, ring, 4 streams.
        Fabric::new(servers, 1e-3, 1e9, Algo::Ring, 4, throttle)
    }

    #[test]
    fn single_server_sync_is_free() {
        let f = fabric(1, Vec::new());
        assert_eq!(f.sync_time(&[0], 1e6, 0), 0.0);
    }

    #[test]
    fn ring_stage_math_matches_the_allreduce_model() {
        let f = fabric(3, Vec::new());
        let bytes = 4e6;
        let hop = 1e-3 + bytes / 4.0 / 1e9;
        let expect = (2.0 * 2.0 + 3.0) * hop; // 2(G-1) stages + (streams-1) fill
        assert!((f.sync_time(&[0, 1, 2], bytes, 0) - expect).abs() < 1e-12);
    }

    #[test]
    fn one_throttled_link_drags_the_whole_barrier() {
        let throttle =
            vec![DriftEvent { at_mb: 2, device: 1, factor: 5.0, ramp: 0 }];
        let f = fabric(3, throttle);
        let before = f.sync_time(&[0, 1, 2], 1e6, 1);
        let during = f.sync_time(&[0, 1, 2], 1e6, 2);
        assert!((during / before - 5.0).abs() < 1e-9, "bottleneck pricing");
        // Excluding the throttled link restores the nominal time.
        assert!((f.sync_time(&[0, 2], 1e6, 2) - f.sync_time(&[0, 2], 1e6, 1)).abs() < 1e-15);
    }

    #[test]
    fn calibration_reads_the_throttle_from_measurements() {
        let throttle =
            vec![DriftEvent { at_mb: 3, device: 0, factor: 4.0, ramp: 0 }];
        let mut f = fabric(2, throttle);
        for w in 0..3 {
            f.record_sync(&[0, 1], &[0, 0], 1e6, w);
        }
        assert!((f.link_slowdown(0) - 1.0).abs() < 0.05);
        for w in 3..6 {
            f.record_sync(&[0, 1], &[0, 0], 1e6, w);
        }
        assert!((f.link_slowdown(0) - 4.0).abs() < 0.4, "got {}", f.link_slowdown(0));
        assert!((f.link_slowdown(1) - 1.0).abs() < 0.05, "link 1 is untouched");
        assert!((f.bottleneck_slowdown(&[0, 1]) - 4.0).abs() < 0.4);
    }

    #[test]
    fn sync_telemetry_mirrors_into_the_registry() {
        let obs = ObsHandle::disabled(); // registry counts even when tracing is off
        let mut f = Fabric::new_obs(2, 1e-3, 1e9, Algo::Ring, 4, Vec::new(), &obs);
        f.record_sync(&[0, 1], &[1, 0], 1e6, 0);
        let rows = obs.registry().snapshot();
        let syncs = rows.iter().find(|r| r.name == "cluster.link0.syncs").unwrap();
        assert_eq!((syncs.kind, syncs.value), ("counter", 1.0));
        let stale = rows.iter().find(|r| r.name == "cluster.link0.staleness").unwrap();
        assert_eq!((stale.kind, stale.value), ("gauge", 1.0));
        assert!(rows.iter().any(|r| r.name == "cluster.link1.bytes"));
    }

    #[test]
    fn telemetry_accumulates_per_link() {
        let mut f = fabric(3, Vec::new());
        f.record_sync(&[0, 1], &[0, 2], 1e6, 0);
        f.record_sync(&[0, 1, 2], &[0, 0, 1], 1e6, 1);
        let stats = f.stats();
        assert_eq!(stats.len(), 3);
        assert!(stats[0].bytes_transferred > stats[2].bytes_transferred);
        assert!(stats[0].sync_seconds > 0.0);
        assert!((stats[1].staleness_mb - 1.0).abs() < 1e-12, "mean of 2 and 0");
        assert_eq!(stats[2].staleness_mb, 1.0);
    }
}

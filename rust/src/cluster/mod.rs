//! The cluster scale-out plane — two-tier hierarchical merging over a
//! simulated multi-server fabric.
//!
//! Everything below the server boundary is the existing single-server
//! stack, unchanged: each server runs a [`TrainerSession`] over its own
//! heterogeneous [`DevicePool`] with the paper's normalized intra-server
//! merge. This module adds the tier above it:
//!
//! ```text
//!   server 0 ─┐                       ┌─ server 0
//!   server 1 ─┼─ inter-server fabric ─┼─ server 1     tier 2: staleness-
//!   server 2 ─┘   (bottleneck-priced  └─ server 2     weighted consensus
//!      │            all-reduce)                        every sync_every mb
//!      └ tier 1: per-server normalized device merge
//! ```
//!
//! * [`hier`] — the tier-2 merge arithmetic: f64 staleness-weighted
//!   averaging that composes *exactly* (1e-10) to the flat per-device
//!   average when every server is fresh.
//! * [`fabric`] — the network cost model: per-link latency + bandwidth,
//!   scripted degradation, bottleneck-priced sync time, and online
//!   [`LinkEstimator`](crate::tuning::LinkEstimator) calibration feeding
//!   the adaptive cadence.
//! * [`events`] — the scripted scenario grammar: link throttles
//!   (`at_mb=N link=L factor=F [ramp=R]`) and whole-rack loss/recovery
//!   (`at_mb=N server=S down|up`).
//! * [`sim`] — [`ClusterSim`]: the deterministic round-based
//!   discrete-event loop tying it together (barrier sync, straggler
//!   demotion to asynchronous catch-up, rack failures, measured-cost
//!   adaptive cadence).
//!
//! Configured by the `[cluster]` block; with it absent (or
//! `servers = 1`) nothing in this module runs and every existing
//! experiment is bit-identical to the single-server build.
//!
//! [`TrainerSession`]: crate::coordinator::trainer::TrainerSession
//! [`DevicePool`]: crate::coordinator::DevicePool

// Same bar as `tuning`: a new subsystem documents every public item.
#![warn(missing_docs)]

pub mod events;
pub mod fabric;
pub mod hier;
pub mod sim;

pub use events::{link_trace, parse_trace, rack_up, ClusterEvent};
pub use fabric::{Fabric, LinkSpec};
pub use hier::{merge_servers, staleness_scale, ServerContribution};
pub use sim::{run_cluster, run_cluster_with, ClusterOutcome, ClusterPolicy, ClusterSim, RoundRow};

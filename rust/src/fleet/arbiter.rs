//! The fleet arbiter — grant/revoke decisions over the shared roster.
//!
//! The arbiter runs at fixed decision-window boundaries of the fleet
//! clock. Each `rebalance` tick:
//!
//! 1. expires overdue drains (the lease book's grace bound),
//! 2. computes the weighted max-min fair target allocation over the
//!    currently-active roster ([`super::tenant::fair_allocation`]),
//! 3. overlays the SLO ledger: a serve lane whose windowed p95 breached
//!    its target for `breach_windows` consecutive ticks **preempts** one
//!    device from the lowest-priority training tenant (repeatable while
//!    the breach persists); `clear_windows` consecutive clear ticks hand
//!    one back,
//! 4. diffs target vs held: surplus leases are revoked with the grace
//!    window (the holder finishes its in-flight mega-batch), free target
//!    devices are granted.
//!
//! A device moving between tenants therefore takes one revoke tick plus
//! the holder's drain (bounded by `grace`) before the grant lands — there
//! is never a moment where it is leased twice, which is exactly the
//! conservation invariant `integration_fleet.rs` hammers on.

use anyhow::bail;

use crate::metrics::LeaseEventRow;
use crate::Result;

use super::lease::{LeaseBook, LeaseState, TenantId};
use super::tenant::{fair_allocation, TenantKind, TenantSpec};

/// Arbiter policy knobs (a projection of `[fleet]` config).
#[derive(Clone, Copy, Debug)]
pub struct ArbiterConfig {
    /// Grace window (seconds) a revoked lease has to drain.
    pub grace: f64,
    /// Serve-lane SLO: windowed p95 latency target in milliseconds.
    pub slo_p95_ms: f64,
    /// Consecutive breached decision windows before a preemption fires.
    pub breach_windows: usize,
    /// Consecutive clear decision windows before a preempted device
    /// returns.
    pub clear_windows: usize,
    /// Master switch for SLO-triggered preemption (off = pure fair share).
    pub preemption: bool,
}

impl Default for ArbiterConfig {
    fn default() -> Self {
        ArbiterConfig {
            grace: 0.5,
            slo_p95_ms: 5.0,
            breach_windows: 2,
            clear_windows: 2,
            preemption: true,
        }
    }
}

/// Per-serve-lane SLO streak tracking.
#[derive(Clone, Copy, Debug, Default)]
struct SloState {
    breach_streak: usize,
    clear_streak: usize,
    /// Devices currently held beyond fair share by preemption. Clamped
    /// every tick to what the overlay could actually move, so it never
    /// outgrows the movable surplus.
    extra: usize,
    /// Last tick's overlay found no training tenant above its floor —
    /// further escalation would be a paper preemption, so it pauses until
    /// capacity reappears.
    victimless: bool,
    last_p95_ms: f64,
}

/// The decision loop over tenants, leases, and SLO feedback.
///
/// # Invariants
///
/// * After every [`rebalance`](Arbiter::rebalance) the lease book's
///   conservation invariant holds ([`check_conservation`]
///   audits it each tick in the co-scheduler): no device leased twice,
///   leases only on pool-active devices, drains bounded by grace.
/// * Preemption only flows downhill in priority and never below a
///   tenant's `min_devices` floor; a preemption is only *counted* once a
///   device actually moved.
/// * Decisions are a deterministic function of the observation sequence
///   (no clocks, no randomness) — co-schedules are bit-reproducible.
///
/// [`check_conservation`]: Arbiter::check_conservation
pub struct Arbiter {
    tenants: Vec<TenantSpec>,
    /// Parallel to `tenants`: false once departed.
    present: Vec<bool>,
    slo: Vec<SloState>,
    book: LeaseBook,
    speed_factors: Vec<f64>,
    active_roster: Vec<usize>,
    cfg: ArbiterConfig,
    /// Arbiter-level annotations (preempt / return) merged with the lease
    /// book's grant/revoke/release rows on `take_events`.
    events: Vec<LeaseEventRow>,
    /// Preemptions / returns fired so far (experiment headline counters).
    pub preemptions: usize,
    pub returns: usize,
    /// Per-tenant device-count targets from the last `rebalance`
    /// (post-preemption-overlay) — the decision input the fleet loop
    /// attaches to its `fleet.lease` audit instants.
    last_targets: Vec<usize>,
}

impl Arbiter {
    /// `speed_factors` is roster-indexed (the same order as
    /// `DevicePool::roster`); `initially_active` the starting membership.
    pub fn new(
        tenants: Vec<TenantSpec>,
        speed_factors: Vec<f64>,
        initially_active: &[usize],
        cfg: ArbiterConfig,
    ) -> Arbiter {
        for (i, t) in tenants.iter().enumerate() {
            assert_eq!(t.id, i, "tenant ids must be their table index");
        }
        let n = tenants.len();
        Arbiter {
            present: vec![true; n],
            slo: vec![SloState { last_p95_ms: f64::NAN, ..Default::default() }; n],
            book: LeaseBook::new(speed_factors.len(), initially_active),
            active_roster: initially_active.to_vec(),
            speed_factors,
            tenants,
            cfg,
            events: Vec::new(),
            preemptions: 0,
            returns: 0,
            last_targets: vec![0; n],
        }
    }

    pub fn book(&self) -> &LeaseBook {
        &self.book
    }

    pub fn tenants(&self) -> &[TenantSpec] {
        &self.tenants
    }

    /// Tenant arrival: joins the table; the next rebalance carves out its
    /// fair share.
    pub fn add_tenant(&mut self, spec: TenantSpec) -> TenantId {
        assert_eq!(spec.id, self.tenants.len(), "tenant ids must be dense");
        let id = spec.id;
        self.tenants.push(spec);
        self.present.push(true);
        self.slo.push(SloState { last_p95_ms: f64::NAN, ..Default::default() });
        id
    }

    /// Tenant departure (or a training job finishing): every lease it
    /// holds is released immediately and redistributed next tick.
    pub fn remove_tenant(&mut self, id: TenantId, now: f64) {
        self.present[id] = false;
        let held: Vec<_> =
            self.book.leases().iter().filter(|l| l.tenant == id).map(|l| l.id).collect();
        for lease in held {
            self.book.release(lease, now, "tenant departed").expect("lease is live");
        }
    }

    /// Physical churn from the device pool: leases on departed devices are
    /// force-released (the fleet shrank under the tenants).
    pub fn on_pool_churn(&mut self, active: &[usize], now: f64) {
        self.active_roster = active.to_vec();
        self.book.set_roster_active(active, now);
    }

    /// Refresh the capacity model with calibrated speed estimates
    /// (`[calibration]` plane): fair allocation weights devices by
    /// `1/speed`, so a throttled device counts for less capacity at the
    /// next `rebalance`. `speeds` is roster-indexed, same convention as
    /// the configured factors this replaces. The fleet co-scheduler calls
    /// this every decision window from the shared
    /// [`CostsView`](crate::tuning::CostsView).
    pub fn update_speed_factors(&mut self, speeds: &[f64]) {
        assert_eq!(
            speeds.len(),
            self.speed_factors.len(),
            "speed update must cover the whole roster"
        );
        assert!(speeds.iter().all(|&s| s > 0.0), "speeds must be positive");
        self.speed_factors = speeds.to_vec();
    }

    /// One windowed-p95 observation for a serve lane. NaN means no
    /// completed requests in the window — that is *no data*, not evidence
    /// either way, so both streaks hold: an idle lane never breaches, and
    /// a lane in a total outage never "clears" its way into giving
    /// preempted capacity back. The shared definition of "windowed p95"
    /// lives in `util::stats::trailing_percentile`; callers must use it.
    pub fn on_slo_sample(&mut self, tenant: TenantId, p95_ms: f64) {
        debug_assert_eq!(self.tenants[tenant].kind, TenantKind::Serve);
        let s = &mut self.slo[tenant];
        s.last_p95_ms = p95_ms;
        if !p95_ms.is_finite() {
            return;
        }
        if p95_ms > self.cfg.slo_p95_ms {
            s.breach_streak += 1;
            s.clear_streak = 0;
        } else {
            s.clear_streak += 1;
            s.breach_streak = 0;
        }
    }

    /// A training tenant reached its merge barrier: draining leases are
    /// acked and released (the in-flight mega-batch is done). Returns the
    /// devices given back.
    pub fn note_barrier(&mut self, tenant: TenantId, now: f64) -> Vec<usize> {
        let draining: Vec<_> = self
            .book
            .leases()
            .iter()
            .filter(|l| l.tenant == tenant && matches!(l.state, LeaseState::Draining { .. }))
            .map(|l| (l.id, l.device))
            .collect();
        let mut freed = Vec::new();
        for (id, device) in draining {
            self.book.release(id, now, "drain acked at barrier").expect("lease is live");
            freed.push(device);
        }
        freed
    }

    /// The tenant's schedulable devices (Active plus still-draining —
    /// in-flight work may finish on a draining device).
    pub fn leased_devices(&self, tenant: TenantId) -> Vec<usize> {
        self.book.devices_of(tenant, true)
    }

    /// Devices the tenant firmly holds (Active only) — what the *next*
    /// mega-batch / routing window may use.
    pub fn firm_devices(&self, tenant: TenantId) -> Vec<usize> {
        self.book.devices_of(tenant, false)
    }

    /// Last observed windowed p95 for a serve lane (NaN before traffic).
    pub fn last_p95_ms(&self, tenant: TenantId) -> f64 {
        self.slo[tenant].last_p95_ms
    }

    /// Devices a serve lane currently holds beyond fair share.
    pub fn preempted_extra(&self, tenant: TenantId) -> usize {
        self.slo[tenant].extra
    }

    /// All ownership events since the last call (lease book rows merged
    /// with the arbiter's preempt/return annotations, time-ordered).
    pub fn take_events(&mut self) -> Vec<LeaseEventRow> {
        let mut out = self.book.take_events();
        out.append(&mut self.events);
        out.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        out
    }

    /// Audit the conservation invariant (post-`rebalance` it must hold).
    pub fn check_conservation(&self, now: f64) -> Result<()> {
        self.book.check_conservation(now)?;
        // Every lease belongs to a present tenant.
        for l in self.book.leases() {
            if !self.present[l.tenant] {
                bail!("{} held by departed tenant {}", l.id, l.tenant);
            }
        }
        Ok(())
    }

    /// One decision tick at fleet time `now`.
    pub fn rebalance(&mut self, now: f64) {
        self.book.expire(now);

        // ---- SLO ledger: escalate / de-escalate preemption ----------------
        // Escalation here is an *intent*; the preempt event and counter are
        // recorded by the overlay below only once a device actually moved —
        // a floor-bound fleet must not report phantom preemptions.
        let mut escalated = vec![false; self.tenants.len()];
        for t in 0..self.tenants.len() {
            if !self.present[t] || self.tenants[t].kind != TenantKind::Serve {
                continue;
            }
            let (breach, clear) = {
                let s = &self.slo[t];
                (s.breach_streak, s.clear_streak)
            };
            if self.cfg.preemption && breach >= self.cfg.breach_windows && !self.slo[t].victimless
            {
                self.slo[t].extra += 1;
                self.slo[t].breach_streak = 0;
                escalated[t] = true;
            } else if self.slo[t].extra > 0 && clear >= self.cfg.clear_windows {
                self.slo[t].extra -= 1;
                self.slo[t].clear_streak = 0;
                self.returns += 1;
                self.events.push(LeaseEventRow {
                    at: now,
                    tenant: t,
                    device: usize::MAX,
                    action: "return".to_string(),
                    reason: format!(
                        "breach clear for {} windows; returning capacity",
                        self.cfg.clear_windows
                    ),
                });
            }
        }

        // ---- target allocation --------------------------------------------
        let present: Vec<TenantSpec> =
            self.tenants.iter().filter(|t| self.present[t.id]).cloned().collect();
        if present.is_empty() {
            return;
        }
        let devices: Vec<(usize, f64)> =
            self.active_roster.iter().map(|&d| (d, self.speed_factors[d])).collect();
        let shares = fair_allocation(&present, &devices);
        // Scatter back to dense tenant-id indexing.
        let mut target: Vec<Vec<usize>> = vec![Vec::new(); self.tenants.len()];
        for (spec, share) in present.iter().zip(shares) {
            target[spec.id] = share;
        }

        // ---- preemption overlay: move `extra` devices to breaching lanes --
        for s in 0..self.tenants.len() {
            if !self.present[s] || self.tenants[s].kind != TenantKind::Serve {
                continue;
            }
            let want = self.slo[s].extra;
            let mut moved = 0usize;
            let mut last_moved: Option<usize> = None;
            while moved < want {
                // Victim: lowest priority class among training tenants that
                // can still give a device up (stays at/above its floor);
                // ties → larger share, then higher id.
                let victim = (0..self.tenants.len())
                    .filter(|&v| {
                        self.present[v]
                            && self.tenants[v].kind == TenantKind::Training
                            && target[v].len() > self.tenants[v].min_devices
                    })
                    .min_by(|&a, &b| {
                        self.tenants[a]
                            .priority
                            .cmp(&self.tenants[b].priority)
                            .then(target[b].len().cmp(&target[a].len()))
                            .then(b.cmp(&a))
                    });
                let Some(v) = victim else { break };
                // Take the victim's slowest device (ties → higher id).
                let (i, &d) = target[v]
                    .iter()
                    .enumerate()
                    .max_by(|(_, &x), (_, &y)| {
                        self.speed_factors[x]
                            .partial_cmp(&self.speed_factors[y])
                            .unwrap()
                            .then(x.cmp(&y))
                    })
                    .expect("victim has a device above its floor");
                target[v].remove(i);
                target[s].push(d);
                last_moved = Some(d);
                moved += 1;
            }
            // A fresh escalation only counts once its device really moved.
            if escalated[s] && moved >= want {
                self.preemptions += 1;
                self.events.push(LeaseEventRow {
                    at: now,
                    tenant: s,
                    device: last_moved.expect("moved >= want >= 1 on escalation"),
                    action: "preempt".to_string(),
                    reason: format!(
                        "p95 {:.2}ms > SLO {:.2}ms for {} windows",
                        self.slo[s].last_p95_ms, self.cfg.slo_p95_ms, self.cfg.breach_windows
                    ),
                });
            }
            // Clamp to reality: paper preemptions do not accumulate, and
            // escalation pauses while every training tenant sits at its
            // floor (re-armed the moment a victim reappears).
            self.slo[s].extra = moved;
            self.slo[s].victimless = (0..self.tenants.len()).all(|v| {
                !self.present[v]
                    || self.tenants[v].kind != TenantKind::Training
                    || target[v].len() <= self.tenants[v].min_devices
            });
            target[s].sort_unstable();
        }

        // ---- diff: reinstate flapped drains, revoke surplus, grant --------
        // A draining lease whose device is back in its *holder's* target
        // (a preempt/return flap inside one grace window) goes straight
        // back to Active — no release/regrant round-trip, no idle device.
        for t in 0..self.tenants.len() {
            if !self.present[t] {
                continue;
            }
            let draining: Vec<_> = self
                .book
                .leases()
                .iter()
                .filter(|l| {
                    l.tenant == t
                        && matches!(l.state, LeaseState::Draining { .. })
                        && target[t].contains(&l.device)
                })
                .map(|l| l.id)
                .collect();
            for id in draining {
                self.book
                    .reinstate(id, now, "rebalance: holder keeps the device")
                    .expect("lease is draining");
            }
        }
        for t in 0..self.tenants.len() {
            if !self.present[t] {
                continue;
            }
            let held = self.book.devices_of(t, false);
            for d in held {
                if !target[t].contains(&d) {
                    let id = self.book.lease_on(d).expect("held implies leased").id;
                    self.book
                        .revoke(id, self.cfg.grace, now, "rebalance: device reassigned")
                        .expect("lease is live");
                }
            }
        }
        for t in 0..self.tenants.len() {
            if !self.present[t] {
                continue;
            }
            for &d in &target[t] {
                if !self.book.is_leased(d) {
                    self.book
                        .grant(t, d, self.tenants[t].priority, now)
                        .expect("unleased active device");
                }
            }
        }
        self.last_targets = target.iter().map(|v| v.len()).collect();
    }

    /// Device-count target the last `rebalance` computed for `tenant`
    /// (0 before the first tick or for late arrivals).
    pub fn target_share(&self, tenant: TenantId) -> usize {
        self.last_targets.get(tenant).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arb(preemption: bool) -> Arbiter {
        let tenants = vec![
            TenantSpec::training(0, "train-a", 1.0),
            TenantSpec::training(1, "train-b", 1.0),
            TenantSpec::serve(2, "lane", 1.0),
        ];
        let cfg = ArbiterConfig { preemption, grace: 0.5, ..Default::default() };
        Arbiter::new(tenants, vec![1.0, 1.1, 1.21, 1.32], &[0, 1, 2, 3], cfg)
    }

    #[test]
    fn first_rebalance_grants_fair_shares() {
        let mut a = arb(false);
        a.rebalance(0.0);
        a.check_conservation(0.0).unwrap();
        // Everyone holds something; the fleet is fully leased.
        let total: usize = (0..3).map(|t| a.firm_devices(t).len()).sum();
        assert_eq!(total, 4);
        assert!(!a.firm_devices(2).is_empty(), "serve floor first");
        assert!(a.take_events().iter().all(|e| e.action == "grant"));
    }

    #[test]
    fn slo_breach_preempts_and_clear_returns() {
        let mut a = arb(true);
        a.rebalance(0.0);
        let serve_before = a.firm_devices(2).len();
        // Two breached windows escalate one preemption.
        a.on_slo_sample(2, 9.0);
        a.rebalance(0.25);
        a.on_slo_sample(2, 9.5);
        a.rebalance(0.5);
        assert_eq!(a.preemptions, 1);
        assert_eq!(a.preempted_extra(2), 1);
        // The victim's surplus lease drains; once acked, the grant lands.
        let victim = (0..2)
            .find(|&t| {
                a.book()
                    .leases()
                    .iter()
                    .any(|l| l.tenant == t && matches!(l.state, LeaseState::Draining { .. }))
            })
            .expect("a training lease is draining");
        a.note_barrier(victim, 0.6);
        a.rebalance(0.75);
        a.check_conservation(0.75).unwrap();
        assert_eq!(a.firm_devices(2).len(), serve_before + 1, "serve grew by one");
        let ev = a.take_events();
        assert!(ev.iter().any(|e| e.action == "preempt"));

        // Two clear windows return the device.
        a.on_slo_sample(2, 1.0);
        a.rebalance(1.0);
        a.on_slo_sample(2, 1.0);
        a.rebalance(1.25);
        assert_eq!(a.returns, 1);
        assert_eq!(a.preempted_extra(2), 0);
        // Serve's extra lease drains back; training re-grants next tick.
        a.note_barrier(2, 1.3);
        a.rebalance(1.5);
        a.check_conservation(1.5).unwrap();
        assert_eq!(a.firm_devices(2).len(), serve_before);
        assert!(a.take_events().iter().any(|e| e.action == "return"));
    }

    #[test]
    fn preemption_respects_training_floors() {
        // 2 devices, 2 training tenants + serve: everyone at the floor, so
        // a breach cannot preempt anyone.
        let tenants = vec![
            TenantSpec::training(0, "a", 1.0),
            TenantSpec::training(1, "b", 1.0),
            TenantSpec::serve(2, "lane", 1.0),
        ];
        let cfg = ArbiterConfig { preemption: true, ..Default::default() };
        let mut a = Arbiter::new(tenants, vec![1.0, 1.1, 1.2], &[0, 1, 2], cfg);
        a.rebalance(0.0);
        for k in 1..=4 {
            a.on_slo_sample(2, 50.0);
            a.rebalance(k as f64 * 0.25);
        }
        a.check_conservation(1.0).unwrap();
        // Extra escalated but no victim exists: training keeps its floors.
        assert!(!a.firm_devices(0).is_empty());
        assert!(!a.firm_devices(1).is_empty());
        assert_eq!(a.firm_devices(2).len(), 1);
    }

    #[test]
    fn flapped_revocation_reinstates_without_a_round_trip() {
        let mut a = arb(true);
        a.rebalance(0.0);
        // Breach → preempt: the victim's lease starts draining.
        a.on_slo_sample(2, 9.0);
        a.rebalance(0.25);
        a.on_slo_sample(2, 9.5);
        a.rebalance(0.5);
        let victim = (0..2)
            .find(|&t| a.firm_devices(t).len() < a.leased_devices(t).len())
            .expect("a training lease is draining");
        // Breach clears fast (clear_windows = 2): the return fires before
        // the drain ever acked, and the same rebalance hands the device
        // straight back — Draining → Active, no release/regrant gap.
        a.on_slo_sample(2, 0.5);
        a.on_slo_sample(2, 0.5);
        a.rebalance(0.75);
        a.check_conservation(0.75).unwrap();
        assert_eq!(
            a.firm_devices(victim).len(),
            a.leased_devices(victim).len(),
            "no lease left draining after the flap"
        );
        let ev = a.take_events();
        assert!(ev.iter().any(|e| e.action == "reinstate"), "{ev:?}");
    }

    #[test]
    fn pool_churn_shrinks_shares_and_departure_redistributes() {
        let mut a = arb(false);
        a.rebalance(0.0);
        // Device 3 dies: its lease force-releases, next tick rebalances.
        a.on_pool_churn(&[0, 1, 2], 0.25);
        a.check_conservation(0.25).unwrap();
        a.rebalance(0.25);
        let total: usize = (0..3).map(|t| a.firm_devices(t).len()).sum();
        assert!(total <= 3);
        a.check_conservation(0.25).unwrap();

        // Tenant 1 departs: eventually tenant 0 + serve split the fleet.
        a.remove_tenant(1, 0.5);
        a.rebalance(0.5);
        // Drains (if any) ack, then the next tick completes the handoff.
        a.note_barrier(0, 0.6);
        a.note_barrier(2, 0.6);
        a.rebalance(0.75);
        a.check_conservation(0.75).unwrap();
        assert!(a.firm_devices(1).is_empty());
        let total: usize = [0, 2].iter().map(|&t| a.firm_devices(t).len()).sum();
        assert_eq!(total, 3, "departed tenant's share redistributed");
    }

    #[test]
    fn calibrated_speeds_retilt_the_fair_shares() {
        // Nominally homogeneous fleet, two training tenants: 2/2 split.
        // The calibration plane then reports devices 1–3 throttled to 3x:
        // device 0 is now worth three of the others, so equal-capacity
        // fair share becomes 1 device vs 3 — a reallocation no count-based
        // scheduler would make.
        let tenants =
            vec![TenantSpec::training(0, "a", 1.0), TenantSpec::training(1, "b", 1.0)];
        let cfg = ArbiterConfig { preemption: false, ..Default::default() };
        let mut a = Arbiter::new(tenants, vec![1.0, 1.0, 1.0, 1.0], &[0, 1, 2, 3], cfg);
        a.rebalance(0.0);
        assert_eq!(a.firm_devices(0).len(), 2);
        assert_eq!(a.firm_devices(1).len(), 2);

        a.update_speed_factors(&[1.0, 3.0, 3.0, 3.0]);
        a.rebalance(0.25);
        // Surplus leases drain; barriers ack; the next tick completes the
        // handoff.
        a.note_barrier(0, 0.3);
        a.note_barrier(1, 0.3);
        a.rebalance(0.5);
        a.check_conservation(0.5).unwrap();
        assert_eq!(a.firm_devices(0), vec![0], "fast device alone matches the floor tenant");
        assert_eq!(a.firm_devices(1), vec![1, 2, 3], "three throttled devices balance it");
    }

    #[test]
    fn nan_p95_holds_both_streaks() {
        let mut a = arb(true);
        a.rebalance(0.0);
        a.on_slo_sample(2, f64::NAN);
        a.on_slo_sample(2, f64::NAN);
        a.rebalance(0.5);
        assert_eq!(a.preemptions, 0, "an idle lane never breaches");

        // Mid-breach NaN (total outage) must not count toward "clear":
        // one breached window, then silence, then another breached window
        // still completes the 2-window breach streak.
        a.on_slo_sample(2, 9.0);
        a.rebalance(0.75);
        a.on_slo_sample(2, f64::NAN);
        a.rebalance(1.0);
        assert_eq!(a.preemptions, 0);
        a.on_slo_sample(2, 9.0);
        a.rebalance(1.25);
        assert_eq!(a.preemptions, 1, "NaN held the breach streak");
    }
}

//! Device leases — the ownership overlay the fleet arbiter maintains on
//! top of the physical [`crate::coordinator::DevicePool`].
//!
//! A lease binds one roster device to one tenant at one priority. The
//! [`LeaseBook`] is the single ledger of every live lease and enforces the
//! **conservation invariant** the whole fleet plane rests on:
//!
//! 1. no device is ever leased to two tenants at once,
//! 2. every live lease covers a device inside the *active* roster,
//! 3. a revoked lease drains within its grace bound — the holder may
//!    finish in-flight work (its current mega-batch / routed batches), but
//!    at `deadline` the book force-releases regardless.
//!
//! Revocation is therefore two-phase: `revoke` moves a lease to
//! [`LeaseState::Draining`] with `deadline = now + grace`; the holder acks
//! at its next barrier via `release`, or [`LeaseBook::expire`] forces the
//! release when the deadline passes. Physical churn is harsher: a device
//! leaving the active roster force-releases its lease immediately
//! (invariant 2 beats the grace window — the hardware is gone).

use std::fmt;

use anyhow::bail;

use crate::metrics::LeaseEventRow;
use crate::Result;

/// Tenant handle (index into the arbiter's tenant table).
pub type TenantId = usize;

/// Scheduling priority of a lease / tenant. Preemption only ever flows
/// downhill: a breaching serve lane takes from the lowest class first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PriorityClass {
    /// Preempt-me-first batch work.
    BestEffort,
    /// Normal training jobs.
    Standard,
    /// Latency-SLO serve lanes; never preempted.
    Critical,
}

impl PriorityClass {
    /// Human-readable class name (event logs and tables).
    pub fn name(&self) -> &'static str {
        match self {
            PriorityClass::BestEffort => "best-effort",
            PriorityClass::Standard => "standard",
            PriorityClass::Critical => "critical",
        }
    }
}

/// Unique lease handle (monotone, never reused).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LeaseId(pub u64);

impl fmt::Display for LeaseId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lease#{}", self.0)
    }
}

/// Lifecycle of a live lease.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeaseState {
    /// Held; the tenant schedules work on the device.
    Active,
    /// Revoked with a grace window: in-flight work may finish, no new work
    /// should start, and the book force-releases at `deadline`.
    Draining { deadline: f64 },
}

/// One live lease.
#[derive(Clone, Debug)]
pub struct Lease {
    /// Unique handle (monotone per book).
    pub id: LeaseId,
    /// Tenant holding the lease.
    pub tenant: TenantId,
    /// Roster device the lease covers.
    pub device: usize,
    /// Scheduling priority the lease was granted at.
    pub priority: PriorityClass,
    /// Fleet clock when the grant landed.
    pub granted_at: f64,
    /// Current lifecycle state.
    pub state: LeaseState,
}

/// The lease ledger. All mutation goes through grant / revoke / release /
/// expire / set_roster_active, each of which appends to the event log, so
/// the history of ownership is fully reconstructible.
///
/// # Invariants
///
/// Lease conservation, enforced at the mutators (a violating call fails,
/// it is never recorded) and auditable via
/// [`check_conservation`](LeaseBook::check_conservation):
///
/// 1. no device is ever covered by two live leases,
/// 2. every live lease covers a device inside the active roster
///    (physical churn force-releases instantly — hardware beats grace),
/// 3. every drain is bounded: a `Draining` lease never outlives its
///    deadline once [`expire`](LeaseBook::expire) has run at that time.
pub struct LeaseBook {
    /// Live leases, ascending by device (at most one per device).
    leases: Vec<Lease>,
    /// Roster-indexed active mask (the physical membership the invariant
    /// is checked against).
    active: Vec<bool>,
    next_id: u64,
    events: Vec<LeaseEventRow>,
}

impl LeaseBook {
    /// A book over a roster of `roster_len` devices, of which
    /// `initially_active` are in the pool.
    pub fn new(roster_len: usize, initially_active: &[usize]) -> LeaseBook {
        let mut active = vec![false; roster_len];
        for &d in initially_active {
            assert!(d < roster_len, "active device outside the roster");
            active[d] = true;
        }
        LeaseBook { leases: Vec::new(), active, next_id: 1, events: Vec::new() }
    }

    /// Number of roster devices this book covers (active or not).
    pub fn roster_len(&self) -> usize {
        self.active.len()
    }

    /// Every live lease (Active and Draining), ascending by device.
    pub fn leases(&self) -> &[Lease] {
        &self.leases
    }

    /// The live lease covering `device`, if any.
    pub fn lease_on(&self, device: usize) -> Option<&Lease> {
        self.leases.iter().find(|l| l.device == device)
    }

    /// The live lease with this id, if any.
    pub fn lease(&self, id: LeaseId) -> Option<&Lease> {
        self.leases.iter().find(|l| l.id == id)
    }

    /// Devices leased to `tenant` in the given states. `include_draining`
    /// is the tenant's view (it may finish in-flight work on a draining
    /// device); pass false for the arbiter's "firmly held" view.
    pub fn devices_of(&self, tenant: TenantId, include_draining: bool) -> Vec<usize> {
        self.leases
            .iter()
            .filter(|l| {
                l.tenant == tenant
                    && (include_draining || matches!(l.state, LeaseState::Active))
            })
            .map(|l| l.device)
            .collect()
    }

    /// Is `device` covered by any live lease?
    pub fn is_leased(&self, device: usize) -> bool {
        self.lease_on(device).is_some()
    }

    /// Ownership-change history since construction.
    pub fn events(&self) -> &[LeaseEventRow] {
        &self.events
    }

    /// Drain the recorded events (the sim collects them per tick).
    pub fn take_events(&mut self) -> Vec<LeaseEventRow> {
        std::mem::take(&mut self.events)
    }

    /// Grant `device` to `tenant`. Fails when the device is outside the
    /// active roster or already leased — conservation is enforced at the
    /// door, not audited after the fact.
    pub fn grant(
        &mut self,
        tenant: TenantId,
        device: usize,
        priority: PriorityClass,
        now: f64,
    ) -> Result<LeaseId> {
        if device >= self.active.len() || !self.active[device] {
            bail!("device {device} is outside the active roster");
        }
        if let Some(l) = self.lease_on(device) {
            bail!("device {device} is already leased to tenant {} ({})", l.tenant, l.id);
        }
        let id = LeaseId(self.next_id);
        self.next_id += 1;
        let lease = Lease {
            id,
            tenant,
            device,
            priority,
            granted_at: now,
            state: LeaseState::Active,
        };
        let at = self.leases.partition_point(|l| l.device < device);
        self.leases.insert(at, lease);
        self.push_event(now, tenant, device, "grant", format!("{priority:?} lease {id}"));
        Ok(id)
    }

    /// Two-phase revocation: the lease enters `Draining` with
    /// `deadline = now + grace`. Revoking a draining lease only ever
    /// *tightens* its deadline (a second revocation cannot extend the
    /// original grace bound).
    pub fn revoke(&mut self, id: LeaseId, grace: f64, now: f64, reason: &str) -> Result<()> {
        assert!(grace >= 0.0, "grace must be non-negative");
        let lease = self
            .leases
            .iter_mut()
            .find(|l| l.id == id)
            .ok_or_else(|| anyhow::anyhow!("{id} is not live"))?;
        let deadline = match lease.state {
            LeaseState::Active => now + grace,
            LeaseState::Draining { deadline } => deadline.min(now + grace),
        };
        lease.state = LeaseState::Draining { deadline };
        let (tenant, device) = (lease.tenant, lease.device);
        self.push_event(
            now,
            tenant,
            device,
            "revoke",
            format!("{reason}; drains by {deadline:.3}s"),
        );
        Ok(())
    }

    /// Cancel a drain: the arbiter decided the holder keeps the device
    /// after all (e.g. a preempt/return flap within one grace window), so
    /// the lease goes straight back to `Active` with no release/regrant
    /// round-trip.
    pub fn reinstate(&mut self, id: LeaseId, now: f64, reason: &str) -> Result<()> {
        let lease = self
            .leases
            .iter_mut()
            .find(|l| l.id == id)
            .ok_or_else(|| anyhow::anyhow!("{id} is not live"))?;
        match lease.state {
            LeaseState::Draining { .. } => lease.state = LeaseState::Active,
            LeaseState::Active => bail!("{id} is not draining"),
        }
        let (tenant, device) = (lease.tenant, lease.device);
        self.push_event(now, tenant, device, "reinstate", reason.to_string());
        Ok(())
    }

    /// The holder gives the lease back (drain acked at a barrier, or a
    /// voluntary release on tenant departure).
    pub fn release(&mut self, id: LeaseId, now: f64, reason: &str) -> Result<()> {
        let at = self
            .leases
            .iter()
            .position(|l| l.id == id)
            .ok_or_else(|| anyhow::anyhow!("{id} is not live"))?;
        let lease = self.leases.remove(at);
        self.push_event(now, lease.tenant, lease.device, "release", reason.to_string());
        Ok(())
    }

    /// Force-release every draining lease whose deadline has passed —
    /// the grace bound of invariant 3. Returns the expired leases.
    pub fn expire(&mut self, now: f64) -> Vec<Lease> {
        let mut expired = Vec::new();
        let mut i = 0;
        while i < self.leases.len() {
            match self.leases[i].state {
                LeaseState::Draining { deadline } if now >= deadline => {
                    let lease = self.leases.remove(i);
                    self.push_event(
                        now,
                        lease.tenant,
                        lease.device,
                        "force-release",
                        format!("grace expired ({:.3}s)", deadline),
                    );
                    expired.push(lease);
                }
                _ => i += 1,
            }
        }
        expired
    }

    /// Apply a physical-membership change. Leases on devices that left the
    /// active roster are force-released immediately (the hardware is gone;
    /// invariant 2 beats any grace window). Returns the released leases.
    pub fn set_roster_active(&mut self, ids: &[usize], now: f64) -> Vec<Lease> {
        self.active.fill(false);
        for &d in ids {
            assert!(d < self.active.len(), "active device outside the roster");
            self.active[d] = true;
        }
        let mut released = Vec::new();
        let mut i = 0;
        while i < self.leases.len() {
            if !self.active[self.leases[i].device] {
                let lease = self.leases.remove(i);
                self.push_event(
                    now,
                    lease.tenant,
                    lease.device,
                    "force-release",
                    "device left the pool".to_string(),
                );
                released.push(lease);
            } else {
                i += 1;
            }
        }
        released
    }

    /// Audit the conservation invariant. `now` bounds invariant 3: no
    /// draining lease may outlive its deadline once `expire(now)` ran.
    pub fn check_conservation(&self, now: f64) -> Result<()> {
        for w in self.leases.windows(2) {
            if w[0].device == w[1].device {
                bail!("device {} leased twice ({} and {})", w[0].device, w[0].id, w[1].id);
            }
        }
        for l in &self.leases {
            if !self.active[l.device] {
                bail!("{} covers device {} outside the active roster", l.id, l.device);
            }
            if let LeaseState::Draining { deadline } = l.state {
                if now > deadline {
                    bail!("{} overstayed its drain deadline ({deadline:.3}s < {now:.3}s)", l.id);
                }
            }
        }
        Ok(())
    }

    fn push_event(
        &mut self,
        at: f64,
        tenant: TenantId,
        device: usize,
        action: &str,
        reason: String,
    ) {
        self.events.push(LeaseEventRow {
            at,
            tenant,
            device,
            action: action.to_string(),
            reason,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book4() -> LeaseBook {
        LeaseBook::new(4, &[0, 1, 2, 3])
    }

    #[test]
    fn grant_is_exclusive_and_roster_bound() {
        let mut book = book4();
        let a = book.grant(0, 1, PriorityClass::Standard, 0.0).unwrap();
        assert!(book.is_leased(1));
        assert_eq!(book.lease_on(1).unwrap().tenant, 0);
        // Double-lease is refused at the door.
        assert!(book.grant(1, 1, PriorityClass::Critical, 0.1).is_err());
        // Outside the roster / inactive devices are refused.
        assert!(book.grant(0, 9, PriorityClass::Standard, 0.1).is_err());
        let mut small = LeaseBook::new(4, &[0, 1]);
        assert!(small.grant(0, 3, PriorityClass::Standard, 0.0).is_err());
        book.check_conservation(0.2).unwrap();
        book.release(a, 0.3, "done").unwrap();
        assert!(!book.is_leased(1));
        assert!(book.release(a, 0.4, "twice").is_err());
    }

    #[test]
    fn revoke_drains_within_grace_and_expire_forces() {
        let mut book = book4();
        let id = book.grant(2, 0, PriorityClass::BestEffort, 0.0).unwrap();
        book.revoke(id, 0.5, 1.0, "rebalance").unwrap();
        assert!(matches!(
            book.lease(id).unwrap().state,
            LeaseState::Draining { deadline } if (deadline - 1.5).abs() < 1e-12
        ));
        // Tenant still sees the draining device; the arbiter's firm view
        // does not.
        assert_eq!(book.devices_of(2, true), vec![0]);
        assert!(book.devices_of(2, false).is_empty());
        // Within grace: conservation holds, nothing expires.
        assert!(book.expire(1.2).is_empty());
        book.check_conservation(1.2).unwrap();
        // Past the deadline the book force-releases.
        let expired = book.expire(1.6);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].device, 0);
        assert!(!book.is_leased(0));
        book.check_conservation(1.6).unwrap();
        let actions: Vec<&str> = book.events().iter().map(|e| e.action.as_str()).collect();
        assert_eq!(actions, vec!["grant", "revoke", "force-release"]);
    }

    #[test]
    fn reinstate_cancels_a_drain() {
        let mut book = book4();
        let id = book.grant(0, 1, PriorityClass::Standard, 0.0).unwrap();
        assert!(book.reinstate(id, 0.1, "not draining").is_err());
        book.revoke(id, 0.5, 0.2, "r").unwrap();
        book.reinstate(id, 0.4, "flap").unwrap();
        assert!(matches!(book.lease(id).unwrap().state, LeaseState::Active));
        // The cancelled deadline no longer expires the lease.
        assert!(book.expire(9.0).is_empty());
        book.check_conservation(9.0).unwrap();
        let actions: Vec<&str> = book.events().iter().map(|e| e.action.as_str()).collect();
        assert_eq!(actions, vec!["grant", "revoke", "reinstate"]);
    }

    #[test]
    fn second_revoke_only_tightens_the_deadline() {
        let mut book = book4();
        let id = book.grant(0, 2, PriorityClass::Standard, 0.0).unwrap();
        book.revoke(id, 1.0, 0.0, "first").unwrap();
        book.revoke(id, 5.0, 0.5, "looser grace must not extend").unwrap();
        match book.lease(id).unwrap().state {
            LeaseState::Draining { deadline } => assert!((deadline - 1.0).abs() < 1e-12),
            s => panic!("{s:?}"),
        }
        book.revoke(id, 0.1, 0.5, "tighter grace wins").unwrap();
        match book.lease(id).unwrap().state {
            LeaseState::Draining { deadline } => assert!((deadline - 0.6).abs() < 1e-12),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn pool_churn_force_releases_departed_devices() {
        let mut book = book4();
        book.grant(0, 0, PriorityClass::Standard, 0.0).unwrap();
        book.grant(1, 3, PriorityClass::Critical, 0.0).unwrap();
        // Device 3 leaves the pool: its lease dies with it, grace or not.
        let released = book.set_roster_active(&[0, 1, 2], 1.0);
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].device, 3);
        assert_eq!(released[0].tenant, 1);
        assert!(book.is_leased(0));
        book.check_conservation(1.0).unwrap();
        // A grant on the departed device now fails; re-adding it re-enables.
        assert!(book.grant(1, 3, PriorityClass::Critical, 1.1).is_err());
        book.set_roster_active(&[0, 1, 2, 3], 2.0);
        assert!(book.grant(1, 3, PriorityClass::Critical, 2.1).is_ok());
    }

    #[test]
    fn conservation_audit_catches_overstayed_drains() {
        let mut book = book4();
        let id = book.grant(0, 1, PriorityClass::Standard, 0.0).unwrap();
        book.revoke(id, 0.25, 0.0, "r").unwrap();
        book.check_conservation(0.25).unwrap();
        // Without expire() the audit flags the overstay — the sim must
        // call expire before checking.
        assert!(book.check_conservation(0.3).is_err());
        book.expire(0.3);
        book.check_conservation(0.3).unwrap();
    }
}

//! Tenant descriptors and the fair-share allocator.
//!
//! A tenant is either a **training job** (a [`TrainerSession`] the fleet
//! sim steps one mega-batch at a time) or a **serve lane** (a latency-SLO
//! inference stream). Each carries a weight and device quotas; the
//! arbiter's target allocation is **weighted max-min fair over
//! heterogeneous capacity**: device capacity is `1 / speed_factor` (the
//! [`CostModel`](crate::runtime::CostModel) convention — a factor of 1.32
//! runs ~32% slower than nominal), and devices are handed out greedily,
//! fastest first, each to the tenant whose `capacity / weight` ratio is
//! currently smallest. That is progressive filling — the discrete analog
//! of weighted max-min water-filling — and is fully deterministic (ties
//! break toward the lower tenant id, devices toward the lower device id).
//!
//! [`TrainerSession`]: crate::coordinator::trainer::TrainerSession

use super::lease::{PriorityClass, TenantId};

/// What kind of work a tenant schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantKind {
    /// An elastic training job (pauses/resumes on lease churn).
    Training,
    /// A latency-SLO serve lane (may preempt training on breach).
    Serve,
}

impl TenantKind {
    /// Human-readable kind name (event logs and tables).
    pub fn name(&self) -> &'static str {
        match self {
            TenantKind::Training => "training",
            TenantKind::Serve => "serve",
        }
    }
}

/// One tenant of the shared fleet.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Dense index into the arbiter's tenant table.
    pub id: TenantId,
    /// Display name (logs and tables).
    pub name: String,
    /// Training job or latency-SLO serve lane.
    pub kind: TenantKind,
    /// Fair-share weight (> 0): target capacity share ∝ weight.
    pub weight: f64,
    /// The allocator satisfies these floors first (priority order).
    pub min_devices: usize,
    /// Hard ceiling on concurrently-leased devices (`usize::MAX` = none).
    pub max_devices: usize,
    pub priority: PriorityClass,
}

impl TenantSpec {
    /// A standard-priority training tenant with a 1-device floor.
    pub fn training(id: TenantId, name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec {
            id,
            name: name.into(),
            kind: TenantKind::Training,
            weight,
            min_devices: 1,
            max_devices: usize::MAX,
            priority: PriorityClass::Standard,
        }
    }

    /// A critical-priority serve lane with a 1-device floor (never
    /// preempted; preempts downhill on SLO breach).
    pub fn serve(id: TenantId, name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec {
            id,
            name: name.into(),
            kind: TenantKind::Serve,
            weight,
            min_devices: 1,
            max_devices: usize::MAX,
            priority: PriorityClass::Critical,
        }
    }
}

/// Weighted max-min fair integral allocation of `devices` (pairs of
/// `(device id, speed_factor)`) across `tenants`. Returns one device list
/// per tenant, parallel to `tenants`; lists are disjoint and their union
/// is all devices (unless every tenant hit `max_devices`).
///
/// Two phases, both deterministic:
/// 1. **floors** — in descending priority (ties → lower id), every tenant
///    receives up to `min_devices`, fastest devices first;
/// 2. **water-filling** — remaining devices go one at a time (fastest
///    first) to the unsaturated tenant with the smallest
///    `assigned_capacity / weight` (ties → lower id).
///
/// The speed factors come from the arbiter's capacity model — the
/// configured `devices.speed_factors`, or the calibration plane's live
/// estimates once [`Arbiter::update_speed_factors`] has been fed
/// (DESIGN.md §9); the allocation algebra is identical either way.
///
/// # Invariants
///
/// * Returned shares are pairwise disjoint, and their union is the whole
///   device list unless every tenant hit `max_devices`.
/// * Deterministic: identical inputs produce the identical allocation
///   (all ties break by index), so fleet co-schedules replay exactly.
///
/// [`Arbiter::update_speed_factors`]: super::arbiter::Arbiter::update_speed_factors
pub fn fair_allocation(tenants: &[TenantSpec], devices: &[(usize, f64)]) -> Vec<Vec<usize>> {
    assert!(tenants.iter().all(|t| t.weight > 0.0), "tenant weights must be positive");
    let mut shares: Vec<Vec<usize>> = vec![Vec::new(); tenants.len()];
    let mut capacity: Vec<f64> = vec![0.0; tenants.len()];

    // Capacity-descending device order: fastest (lowest speed factor)
    // first, ties toward the lower device id.
    let mut order: Vec<(usize, f64)> = devices.to_vec();
    order.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    let mut queue: std::collections::VecDeque<(usize, f64)> = order.into_iter().collect();

    // Phase 1: floors, in descending priority then ascending id.
    let mut floor_order: Vec<usize> = (0..tenants.len()).collect();
    floor_order.sort_by(|&a, &b| {
        tenants[b].priority.cmp(&tenants[a].priority).then(tenants[a].id.cmp(&tenants[b].id))
    });
    for &t in &floor_order {
        while shares[t].len() < tenants[t].min_devices.min(tenants[t].max_devices) {
            match queue.pop_front() {
                Some((d, sf)) => {
                    shares[t].push(d);
                    capacity[t] += 1.0 / sf;
                }
                None => return finish(shares),
            }
        }
    }

    // Phase 2: progressive filling on normalized capacity.
    while let Some((d, sf)) = queue.pop_front() {
        let next = (0..tenants.len())
            .filter(|&t| shares[t].len() < tenants[t].max_devices)
            .min_by(|&a, &b| {
                let ka = capacity[a] / tenants[a].weight;
                let kb = capacity[b] / tenants[b].weight;
                ka.partial_cmp(&kb).unwrap().then(a.cmp(&b))
            });
        match next {
            Some(t) => {
                shares[t].push(d);
                capacity[t] += 1.0 / sf;
            }
            None => break, // every tenant saturated; leave the rest idle
        }
    }
    finish(shares)
}

fn finish(mut shares: Vec<Vec<usize>>) -> Vec<Vec<usize>> {
    for s in &mut shares {
        s.sort_unstable();
    }
    shares
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet4() -> Vec<(usize, f64)> {
        vec![(0, 1.00), (1, 1.10), (2, 1.21), (3, 1.32)]
    }

    #[test]
    fn equal_weights_split_the_fleet_evenly() {
        let tenants = vec![TenantSpec::training(0, "a", 1.0), TenantSpec::training(1, "b", 1.0)];
        let shares = fair_allocation(&tenants, &fleet4());
        assert_eq!(shares[0].len(), 2);
        assert_eq!(shares[1].len(), 2);
        // Disjoint cover of the fleet.
        let mut all: Vec<usize> = shares.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
        // Fastest device seeds tenant 0's floor, second-fastest tenant 1's.
        assert!(shares[0].contains(&0));
        assert!(shares[1].contains(&1));
    }

    #[test]
    fn weights_tilt_capacity_not_just_counts() {
        // 3:1 weights over four devices: the heavy tenant takes three.
        let tenants =
            vec![TenantSpec::training(0, "heavy", 3.0), TenantSpec::training(1, "light", 1.0)];
        let shares = fair_allocation(&tenants, &fleet4());
        assert_eq!(shares[0].len(), 3, "{shares:?}");
        assert_eq!(shares[1].len(), 1);
    }

    #[test]
    fn serve_priority_claims_the_fastest_floor_device() {
        let tenants = vec![
            TenantSpec::training(0, "train-a", 1.0),
            TenantSpec::training(1, "train-b", 1.0),
            TenantSpec::serve(2, "lane", 1.0),
        ];
        let shares = fair_allocation(&tenants, &fleet4());
        // Critical floor is satisfied first → serve holds device 0.
        assert!(shares[2].contains(&0), "{shares:?}");
        assert!(shares.iter().all(|s| !s.is_empty()), "floors guarantee one each");
        let total: usize = shares.iter().map(|s| s.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn quotas_cap_and_floors_truncate_gracefully() {
        let mut heavy = TenantSpec::training(0, "capped", 10.0);
        heavy.max_devices = 1;
        let tenants = vec![heavy, TenantSpec::training(1, "rest", 1.0)];
        let shares = fair_allocation(&tenants, &fleet4());
        assert_eq!(shares[0].len(), 1, "max_devices caps the heavy tenant");
        assert_eq!(shares[1].len(), 3);

        // More floor demand than devices: priority order wins, no panic.
        let mut a = TenantSpec::training(0, "a", 1.0);
        a.min_devices = 3;
        let mut b = TenantSpec::serve(1, "b", 1.0);
        b.min_devices = 3;
        let shares = fair_allocation(&[a, b], &[(0, 1.0), (1, 1.0)]);
        assert_eq!(shares[1].len(), 2, "critical floor first");
        assert_eq!(shares[0].len(), 0);
    }

    #[test]
    fn heterogeneous_capacity_balances_speed_not_count() {
        // One very fast device vs three slow ones: with equal weights the
        // tenant holding the fast device needs fewer devices for the same
        // capacity, so the other tenant gets more units.
        let devices = vec![(0, 0.25), (1, 2.0), (2, 2.0), (3, 2.0)];
        let tenants = vec![TenantSpec::training(0, "a", 1.0), TenantSpec::training(1, "b", 1.0)];
        let shares = fair_allocation(&tenants, &devices);
        assert!(shares[0].contains(&0), "floor hands the fastest to tenant 0");
        assert_eq!(shares[0].len(), 1, "fast device ≈ 4 slow ones: {shares:?}");
        assert_eq!(shares[1].len(), 3);
    }

    #[test]
    fn allocation_is_deterministic() {
        let tenants = vec![
            TenantSpec::training(0, "a", 1.0),
            TenantSpec::training(1, "b", 2.0),
            TenantSpec::serve(2, "s", 1.0),
        ];
        let a = fair_allocation(&tenants, &fleet4());
        let b = fair_allocation(&tenants, &fleet4());
        assert_eq!(a, b);
    }
}

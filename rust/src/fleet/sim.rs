//! Deterministic discrete-event co-scheduling of multiple training
//! tenants and a serve lane on one shared heterogeneous fleet.
//!
//! This is where the two previously-independent planes genuinely contend:
//! every device belongs to exactly one tenant at a time (the lease book's
//! conservation invariant), training tenants advance one mega-batch per
//! [`TrainerSession::step`] on whatever subset the arbiter granted them,
//! and the serve lane admits/routes requests on *its* leased subset — all
//! on one shared virtual clock, so the whole co-schedule is
//! bit-reproducible.
//!
//! Event sources, processed in time order (ties: tick, then training
//! barrier, then arrival, then admission deadline — an arrival tying with
//! a deadline is admitted first so the flush sees the full queue, same as
//! `serve::replay`):
//!
//! * **arbiter ticks** every `[fleet] decision_window` seconds — scripted
//!   fleet churn (`[fleet] events`, window-indexed through the same
//!   [`DevicePool`] machinery as training), drain acks for idle tenants,
//!   the SLO sample (`util::stats::trailing_percentile` over the last
//!   window's completed requests), and one [`Arbiter::rebalance`];
//! * **training barriers** — a tenant's in-flight mega-batch completes:
//!   draining leases ack, a finished tenant departs (its share
//!   redistributes), and idle tenants with firm leases start their next
//!   mega-batch immediately;
//! * **serve arrivals / admission deadlines** — exactly the
//!   `serve::replay` loop, but capacity is the serve lane's *lease*, not
//!   the raw roster, and a lane that momentarily holds no devices queues
//!   instead of routing (the outage shows up as latency, which is what
//!   trips the SLO detector and triggers preemption).
//!
//! Modeling simplifications, on purpose: a mega-batch in flight when its
//! lease's grace expires still completes (the book force-releases the
//! device; configure `grace` at or above a mega-batch duration to avoid
//! double-booking), and the serve lane serves the snapshot that was
//! *published by* the request's formation time (`snapshot_at_clock`), so
//! causality holds even though sessions compute whole mega-batches
//! atomically.
//!
//! [`TrainerSession::step`]: crate::coordinator::trainer::TrainerSession::step
//! [`DevicePool`]: crate::coordinator::DevicePool

use std::sync::Arc;

use crate::config::{Config, ServePattern};
use crate::coordinator::backend::RefBackend;
use crate::coordinator::engine_sim::SimEngine;
use crate::coordinator::trainer::{TrainerOptions, TrainerSession};
use crate::coordinator::DevicePool;
use crate::data::pipeline::ShardedDataset;
use crate::data::SparseDataset;
use crate::metrics::{LeaseEventRow, PoolEventRow, RunLog};
use crate::runtime::CostModel;
use crate::serve::{
    Admission, Arrival, BatchRecord, RequestRecord, Router, ServeLog, SnapshotRegistry,
};
use crate::tuning::CalibratedCosts;
use crate::util::stats;
use crate::Result;

use super::arbiter::{Arbiter, ArbiterConfig};
use super::lease::TenantId;
use super::tenant::TenantSpec;

/// One training tenant of a co-schedule: its own config (model dims and
/// the `[devices]` section must match the shared fleet's), corpus, and
/// fair-share weight.
pub struct TenantJob {
    /// Tenant display name (logs, tables, lease events).
    pub name: String,
    /// The job's own config (`[devices]`/spares must match the fleet's).
    pub cfg: Config,
    /// Fair-share weight (> 0).
    pub weight: f64,
    /// Sharded training corpus.
    pub train: Arc<ShardedDataset>,
    /// Evaluation split.
    pub test: Arc<SparseDataset>,
}

/// Everything a co-schedule produced.
pub struct FleetOutcome {
    pub name: String,
    /// One (tenant name, training log) per training tenant. Row clocks are
    /// on the shared fleet clock.
    pub tenant_logs: Vec<(String, RunLog)>,
    /// Serve-lane telemetry (None when no serve lane was scheduled).
    pub serve: Option<ServeLog>,
    /// (tick time, windowed p95 ms) — the arbiter's SLO samples.
    pub slo_series: Vec<(f64, f64)>,
    /// Every lease-ownership change, time-ordered.
    pub events: Vec<LeaseEventRow>,
    /// Scripted physical churn that fired (window-indexed).
    pub churn: Vec<PoolEventRow>,
    /// Conservation audits that ran (every tick) — all passed, or
    /// `co_schedule` would have erred.
    pub conservation_checks: usize,
    pub preemptions: usize,
    pub returns: usize,
    /// Fleet time when the last training tenant finished (serve duration).
    pub horizon: f64,
}

/// Chunked open-loop arrival generation: the co-schedule's horizon is not
/// known up front (it ends when the last tenant finishes), so traces are
/// generated `serve.duration`-sized chunks at a time, each chunk seeded
/// from the base seed and its index — still fully deterministic.
struct ArrivalStream {
    pattern: ServePattern,
    chunk_len: f64,
    chunk: usize,
    buf: Vec<Arrival>,
    idx: usize,
    exhausted: bool,
}

impl ArrivalStream {
    const MAX_EMPTY_CHUNKS: usize = 10_000;

    fn new(cfg: &Config) -> ArrivalStream {
        ArrivalStream {
            pattern: cfg.serve.pattern,
            chunk_len: cfg.serve.duration,
            chunk: 0,
            buf: Vec::new(),
            idx: 0,
            exhausted: false,
        }
    }

    /// Arrival time of the next request (`f64::INFINITY` once exhausted).
    fn peek(&mut self, cfg: &Config, data: &ShardedDataset) -> f64 {
        let mut empties = 0;
        while !self.exhausted && self.idx >= self.buf.len() {
            let offset = self.chunk as f64 * self.chunk_len;
            let seed = cfg.serve.seed.wrapping_add((self.chunk as u64).wrapping_mul(0x9E37));
            let len = self.chunk_len;
            self.buf = crate::serve::traffic::generate(self.pattern, &cfg.serve, data, len, seed);
            for a in &mut self.buf {
                a.at += offset;
            }
            self.idx = 0;
            self.chunk += 1;
            if self.buf.is_empty() {
                empties += 1;
                if empties >= Self::MAX_EMPTY_CHUNKS {
                    self.exhausted = true;
                }
            }
        }
        if self.exhausted {
            f64::INFINITY
        } else {
            self.buf[self.idx].at
        }
    }

    fn pop(&mut self) -> Arrival {
        let a = self.buf[self.idx];
        self.idx += 1;
        a
    }
}

struct TrainTenant<'b> {
    id: TenantId,
    name: String,
    session: TrainerSession<'b>,
    barrier_at: f64,
    running: bool,
    finished: bool,
}

/// Run one co-schedule. `base` supplies the shared fleet (`[devices]` +
/// `[elastic] spare_devices`), the serve workload (`[serve]`), and the
/// arbiter policy (`[fleet]`); `jobs` the training tenants;
/// `serve_corpus` the request corpus of the serve lane (None = no lane).
/// The serve lane serves `registry` — the first job publishes into it
/// (warm-start + every `publish_every` mega-batches), so pre-seed it (e.g.
/// from a checkpoint) when scheduling a lane without training tenants.
///
/// Deterministic: same inputs → bit-identical outcome. Numerics run the
/// hermetic reference backend on the virtual clock.
pub fn co_schedule(
    base: &Config,
    jobs: &[TenantJob],
    serve_corpus: Option<Arc<ShardedDataset>>,
    registry: Arc<SnapshotRegistry>,
    name: &str,
) -> Result<FleetOutcome> {
    co_schedule_with(base, jobs, serve_corpus, registry, name, crate::obs::ambient())
}

/// [`co_schedule`] with an explicit observability handle: arbiter lease
/// decisions land as `fleet.lease` instants (device lanes, reason
/// attached), each tenant's session re-lanes its spans under its own
/// trace pid via [`ObsHandle::for_pid`](crate::obs::ObsHandle::for_pid),
/// and the serve lane's admission/router counters register in the shared
/// registry.
pub fn co_schedule_with(
    base: &Config,
    jobs: &[TenantJob],
    serve_corpus: Option<Arc<ShardedDataset>>,
    registry: Arc<SnapshotRegistry>,
    name: &str,
    obs: crate::obs::ObsHandle,
) -> Result<FleetOutcome> {
    let roster = DevicePool::roster(base);
    let speed_factors: Vec<f64> = roster.iter().map(|d| d.speed_factor).collect();
    let dw = base.fleet.decision_window;
    anyhow::ensure!(
        !jobs.is_empty() || serve_corpus.is_some(),
        "a co-schedule needs at least one tenant"
    );
    for job in jobs {
        anyhow::ensure!(
            job.cfg.model == base.model,
            "tenant '{}' model dims differ from the fleet's",
            job.name
        );
        anyhow::ensure!(
            job.cfg.devices.count == base.devices.count
                && job.cfg.devices.speed_factors == base.devices.speed_factors
                && job.cfg.elastic.spare_devices == base.elastic.spare_devices,
            "tenant '{}' devices/spares differ from the fleet's (the session roster, the \
             arbiter's speed model, and the shared pool must describe the same hardware)",
            job.name
        );
        // Calibration is a fleet-level decision: a tenant whose own config
        // disagrees would silently skip publishing into the shared view
        // (or drift on different hardware), so mismatches are errors, not
        // no-ops.
        anyhow::ensure!(
            job.cfg.calibration.enabled == base.calibration.enabled
                && job.cfg.calibration.events == base.calibration.events,
            "tenant '{}' [calibration] enabled/events differ from the fleet's (the shared \
             costs view and the drift scenario must describe the same physical fleet)",
            job.name
        );
    }
    if serve_corpus.is_some() {
        anyhow::ensure!(
            !jobs.is_empty() || !registry.is_empty(),
            "the serve lane has nothing to serve: no training tenant publishes and the \
             registry is empty"
        );
    }

    // ---- tenant table -----------------------------------------------------
    let mut specs: Vec<TenantSpec> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| TenantSpec::training(i, j.name.clone(), j.weight))
        .collect();
    let serve_id: Option<TenantId> = serve_corpus.as_ref().map(|_| {
        let id = specs.len();
        specs.push(TenantSpec::serve(id, "serve-lane", base.fleet.serve_weight));
        id
    });

    // ---- calibration plane (shared across every tenant + the lane) --------
    // One view for the whole co-schedule: every training session publishes
    // its device estimates into it, the arbiter weights capacity by it,
    // and the serve router routes on it. Scripted drift reaches serving
    // devices at tick boundaries (training devices get the same trace at
    // their own mega-batch boundaries, via each session).
    let calibration: Option<Arc<CalibratedCosts>> = if base.calibration.enabled {
        Some(Arc::new(CalibratedCosts::new(speed_factors.clone())))
    } else {
        None
    };
    let drift_trace = base.calibration.parsed_events()?;

    // ---- physical fleet + arbiter -----------------------------------------
    let mut pool = DevicePool::with_trace(base, &base.fleet.events)?;
    let acfg = ArbiterConfig {
        grace: base.fleet.grace,
        slo_p95_ms: base.fleet.slo_p95_ms,
        breach_windows: base.fleet.breach_windows,
        clear_windows: base.fleet.clear_windows,
        preemption: base.fleet.preemption,
    };
    let mut arbiter = Arbiter::new(specs, speed_factors.clone(), &pool.active_ids(), acfg);

    // ---- training sessions ------------------------------------------------
    let backend = RefBackend;
    let mut tenants: Vec<TrainTenant<'_>> = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.iter().enumerate() {
        let engine =
            Box::new(
            SimEngine::new(&backend, DevicePool::roster(&job.cfg), CostModel::default())
                .with_slide(&job.cfg.slide),
        );
        let opts = TrainerOptions {
            // The first tenant always feeds the snapshot registry — the
            // serve lane reads it live, and a lane-less (exclusive) run
            // leaves behind a publish timeline a later serve-only
            // co-schedule can replay.
            publish: (i == 0).then(|| registry.clone()),
            // Every tenant publishes into the one shared costs view.
            costs: calibration.clone(),
            // Each tenant gets its own trace pid so its spans group as a
            // separate process lane in the exported timeline.
            obs: obs.for_pid(i as u32),
            ..Default::default()
        };
        let session = TrainerSession::new(
            job.cfg.clone(),
            engine,
            &backend,
            opts,
            job.train.clone(),
            job.test.clone(),
            job.name.clone(),
        )?;
        tenants.push(TrainTenant {
            id: i,
            name: job.name.clone(),
            session,
            barrier_at: 0.0,
            running: false,
            finished: false,
        });
    }

    // ---- serve lane -------------------------------------------------------
    let mut serve = serve_corpus.map(|data| ServeLane {
        admission: Admission::new_obs(data.clone(), &base.model, base, &obs),
        router: Router::new_obs(
            DevicePool::roster(base),
            pool.active_ids(),
            CostModel::default(),
            &obs,
        ),
        stream: ArrivalStream::new(base),
        data,
        has_capacity: false,
        requests: Vec::new(),
        batches: Vec::new(),
        depth_samples: Vec::new(),
        lat_events: Vec::new(),
        next_id: 0,
    });

    let lease_counter = obs.counter("fleet.lease_events");
    let mut events: Vec<LeaseEventRow> = Vec::new();
    let mut churn: Vec<PoolEventRow> = Vec::new();
    let mut slo_series: Vec<(f64, f64)> = Vec::new();
    let mut conservation_checks = 0usize;
    let mut tick = 0usize;
    let mut now = 0.0f64;
    let mut horizon = 0.0f64;
    // Consecutive ticks on which unfinished training tenants held no work
    // at all — a fleet that can never cover the tenant floors (e.g. churned
    // down to one device that the serve lane's Critical floor claims) would
    // otherwise tick forever.
    let mut starved_ticks = 0usize;
    const MAX_STARVED_TICKS: usize = 1_000;

    // A serve-only co-schedule (no training tenants) runs an open-loop
    // trace of the configured `serve.duration` instead of following the
    // training horizon.
    let serve_only = jobs.is_empty();

    loop {
        let training_done = tenants.iter().all(|t| t.finished);
        let backlog = serve.as_ref().is_some_and(|s| s.admission.queue_depth() > 0);

        // ---- candidate event times ----------------------------------------
        let t_tick = tick as f64 * dw;
        let t_barrier = tenants
            .iter()
            .filter(|t| t.running)
            .map(|t| t.barrier_at)
            .fold(f64::INFINITY, f64::min);
        let (mut t_arr, t_dead) = match serve.as_mut() {
            Some(s) => {
                let arr = s.stream.peek(base, &s.data);
                let dead = if s.has_capacity {
                    s.admission.deadline().unwrap_or(f64::INFINITY)
                } else {
                    f64::INFINITY // no capacity: queue builds until a grant
                };
                (arr, dead)
            }
            None => (f64::INFINITY, f64::INFINITY),
        };
        // Admissions close when training ends (the co-schedule's horizon)
        // or, serve-only, at the configured trace duration.
        if (serve_only && t_arr >= base.serve.duration) || (!serve_only && training_done) {
            t_arr = f64::INFINITY;
        }
        if training_done && t_arr.is_infinite() && !backlog {
            break;
        }

        // Tie order: tick, barrier, arrival, deadline.
        if t_tick <= t_barrier && t_tick <= t_arr && t_tick <= t_dead {
            // ---- arbiter tick ---------------------------------------------
            now = now.max(t_tick);
            // Scripted physical churn lands on decision boundaries.
            let pool_events = pool.begin_mega_batch(tick);
            if !pool_events.is_empty() {
                arbiter.on_pool_churn(&pool.active_ids(), now);
                churn.extend(pool_events.iter().map(crate::coordinator::trainer::pool_event_row));
            }
            // Idle holders have no in-flight work: drains ack instantly.
            if let Some(sid) = serve_id {
                arbiter.note_barrier(sid, now);
            }
            for t in &tenants {
                if !t.running && !t.finished {
                    arbiter.note_barrier(t.id, now);
                }
            }
            // SLO sample over the closing window (NaN = no data: the
            // arbiter holds both streaks).
            if let (Some(sid), Some(s)) = (serve_id, serve.as_mut()) {
                let p95 = stats::trailing_percentile(&s.lat_events, now, dw, 95.0);
                arbiter.on_slo_sample(sid, p95);
                slo_series.push((now, p95));
                // The detector only looks one window back: events at or
                // before `now` can never enter a later (now', now'+dw]
                // window, so drop them instead of rescanning forever.
                s.lat_events.retain(|&(t, _)| t > now);
            }
            // Calibrated capacity: refresh the arbiter's speed model and
            // the router's view from the shared estimates before deciding,
            // and land scripted drift on the serving devices. Drift is
            // window-indexed per plane — arbiter ticks here, each
            // session's own mega-batches on the training side — so align
            // decision_window with the mega-batch duration when a
            // scenario needs both planes throttling in step.
            if !drift_trace.is_empty() {
                if let Some(s) = serve.as_mut() {
                    for d in 0..speed_factors.len() {
                        s.router.set_drift(d, crate::tuning::multiplier_at(&drift_trace, d, tick));
                    }
                }
            }
            if let Some(costs) = &calibration {
                let view = costs.current();
                arbiter.update_speed_factors(&view.speeds());
                if let Some(s) = serve.as_mut() {
                    s.router.set_cost_view(Some(view));
                }
            }
            arbiter.rebalance(now);
            arbiter.check_conservation(now)?;
            // Cross-check the pool's lease-aware view against the ledger:
            // grantable ∪ leased must cover the active roster exactly.
            let mut covered = pool.available_ids(|d| arbiter.book().is_leased(d));
            covered.extend(arbiter.book().leases().iter().map(|l| l.device));
            covered.sort_unstable();
            anyhow::ensure!(
                covered == pool.active_ids(),
                "lease-aware pool view diverged from the lease book at t={now:.3}"
            );
            conservation_checks += 1;
            if let (Some(sid), Some(s)) = (serve_id, serve.as_mut()) {
                s.update_capacity(&arbiter, sid);
            }
            start_idle_tenants(&mut tenants, &mut arbiter, now)?;
            if !training_done && tenants.iter().all(|t| !t.running || t.finished) {
                starved_ticks += 1;
                anyhow::ensure!(
                    starved_ticks <= MAX_STARVED_TICKS,
                    "training tenants starved of leases for {MAX_STARVED_TICKS} consecutive \
                     decision windows — the active fleet cannot cover the tenant floors \
                     (shrink tenants, raise elastic.min_devices, or soften [fleet] events)"
                );
            } else {
                starved_ticks = 0;
            }
            tick += 1;
        } else if t_barrier <= t_arr && t_barrier <= t_dead {
            // ---- training barrier -----------------------------------------
            now = now.max(t_barrier);
            let i = tenants
                .iter()
                .position(|t| t.running && t.barrier_at == t_barrier)
                .expect("a running tenant owns this barrier");
            tenants[i].running = false;
            arbiter.note_barrier(tenants[i].id, now);
            if tenants[i].session.done() {
                tenants[i].finished = true;
                horizon = horizon.max(now);
                arbiter.remove_tenant(tenants[i].id, now);
            }
            start_idle_tenants(&mut tenants, &mut arbiter, now)?;
        } else if t_arr <= t_dead {
            // ---- request arrival ------------------------------------------
            now = now.max(t_arr);
            let s = serve.as_mut().expect("arrivals imply a serve lane");
            let a = s.stream.pop();
            let id = s.next_id;
            s.next_id += 1;
            s.admission.push(id, a.sample_id, a.at);
            s.depth_samples.push((a.at, s.admission.queue_depth()));
            if s.has_capacity {
                while let Some(ab) = s.admission.pop_full(now) {
                    s.dispatch(ab, &registry, &backend, now)?;
                }
            }
        } else if t_dead.is_finite() {
            // ---- admission deadline flush ---------------------------------
            // `now` (not `t_dead`): a deadline deferred through a
            // no-capacity outage flushes the moment capacity returned, so
            // the batch forms — and queueing latency accrues — at the real
            // fleet time.
            now = now.max(t_dead);
            let s = serve.as_mut().expect("deadlines imply a serve lane");
            if let Some(ab) = s.admission.flush(now) {
                s.dispatch(ab, &registry, &backend, now)?;
            }
        } else {
            // Nothing schedulable but tenants unfinished: the next tick
            // will re-grant (t_tick was the minimum; unreachable).
            unreachable!("no schedulable event");
        }
        let fresh = arbiter.take_events();
        lease_counter.add(fresh.len() as u64);
        for e in &fresh {
            // One instant per arbiter decision, on the device's lane
            // (deviceless "return" annotations land on the coordinator
            // lane), carrying the reason plus the fair-share target that
            // drove the move — a full decision record for `report`.
            let tid = if e.device == usize::MAX { 0 } else { 1 + e.device as u32 };
            let device: i64 = if e.device == usize::MAX { -1 } else { e.device as i64 };
            obs.instant(
                crate::obs::Subsystem::Fleet,
                "fleet.lease",
                tid,
                e.at,
                vec![
                    ("tenant", e.tenant.into()),
                    ("device", device.into()),
                    ("target", arbiter.target_share(e.tenant).into()),
                    ("action", e.action.as_str().into()),
                    ("reason", e.reason.as_str().into()),
                ],
            );
        }
        events.extend(fresh);
    }

    let horizon = if serve_only {
        base.serve.duration
    } else if horizon > 0.0 {
        horizon
    } else {
        now
    };
    let tenant_logs: Vec<(String, RunLog)> =
        tenants.into_iter().map(|t| (t.name, t.session.into_log())).collect();
    let serve_log = serve.map(|s| {
        let train_log = tenant_logs.first().map(|(_, l)| l);
        ServeLog::summarize(
            format!("{name}-serve"),
            horizon,
            dw,
            s.requests,
            s.batches,
            &s.depth_samples,
            Vec::new(),
            train_log,
        )
    });

    Ok(FleetOutcome {
        name: name.to_string(),
        tenant_logs,
        serve: serve_log,
        slo_series,
        events,
        churn,
        conservation_checks,
        preemptions: arbiter.preemptions,
        returns: arbiter.returns,
        horizon,
    })
}

/// Start every idle, unfinished tenant that holds at least one firm lease.
fn start_idle_tenants(
    tenants: &mut [TrainTenant<'_>],
    arbiter: &mut Arbiter,
    now: f64,
) -> Result<()> {
    for t in tenants.iter_mut() {
        if t.running || t.finished {
            continue;
        }
        if t.session.done() {
            // Degenerate zero-mega-batch job: departs without ever running,
            // releasing its share instead of squatting on it.
            t.finished = true;
            arbiter.remove_tenant(t.id, now);
            continue;
        }
        let firm = arbiter.firm_devices(t.id);
        if firm.is_empty() {
            continue; // paused: no lease, no work — resumes on a grant
        }
        let row = t.session.step(&firm, now, Vec::new())?;
        t.barrier_at = row.clock;
        t.running = true;
    }
    Ok(())
}

/// The serve lane's moving parts (admission, routing, telemetry).
struct ServeLane {
    admission: Admission,
    router: Router,
    stream: ArrivalStream,
    data: Arc<ShardedDataset>,
    has_capacity: bool,
    requests: Vec<RequestRecord>,
    batches: Vec<BatchRecord>,
    depth_samples: Vec<(f64, usize)>,
    /// (completion, latency ms) — the SLO detector's event feed.
    lat_events: Vec<(f64, f64)>,
    next_id: u64,
}

impl ServeLane {
    /// Re-derive routing capacity from the lane's *firm* leases only — a
    /// draining lease must not take new work (the lease contract; its
    /// in-flight batches still drain on the router's virtual timeline). A
    /// firm-less lane pauses dispatch entirely, and the resulting queueing
    /// is real latency the SLO detector is supposed to see.
    fn update_capacity(&mut self, arbiter: &Arbiter, id: TenantId) {
        let firm = arbiter.firm_devices(id);
        if firm.is_empty() {
            self.has_capacity = false;
        } else {
            self.router.set_active(&firm);
            self.has_capacity = true;
        }
    }

    /// Route one admitted batch and record per-request telemetry. Serves
    /// the snapshot that was *published by* formation time — causally
    /// correct on the shared clock even though training mega-batches are
    /// computed atomically.
    fn dispatch(
        &mut self,
        ab: crate::serve::AdmittedBatch,
        registry: &SnapshotRegistry,
        backend: &RefBackend,
        now: f64,
    ) -> Result<()> {
        use crate::coordinator::backend::StepBackend;
        let snap = registry
            .snapshot_at_clock(now)
            .expect("co_schedule guarantees a non-empty registry");
        let routed = self.router.route(ab.formed_at, &ab.batch);
        let preds = backend.eval(&snap.model, &ab.batch)?;
        for (row, (&rid, &arrival)) in ab.request_ids.iter().zip(&ab.arrivals).enumerate() {
            let sample_id = ab.batch.sample_ids[row] as usize;
            let hit = self.data.sample(sample_id).labels.contains(&(preds[row].max(0) as u32));
            self.requests.push(RequestRecord {
                id: rid,
                arrival,
                completion: routed.completion,
                hit,
            });
            self.lat_events.push((routed.completion, (routed.completion - arrival) * 1e3));
        }
        self.batches.push(BatchRecord {
            formed_at: ab.formed_at,
            start: routed.start,
            completion: routed.completion,
            device: routed.device,
            bucket: ab.batch.bucket,
            valid: ab.batch.valid,
            version: snap.version,
            staleness: None,
        });
        self.admission.recycle(ab.batch);
        Ok(())
    }
}

//! The fleet scheduler — multi-tenant arbitration of the shared
//! heterogeneous device fleet.
//!
//! Everything below the elastic machinery assumes *one* job owns the
//! roster; this subsystem removes that assumption. Many training jobs and
//! latency-SLO serve lanes share one fleet through **device leases**:
//!
//! * [`lease`] — the [`LeaseBook`](lease::LeaseBook) ledger: priority-
//!   classed leases, two-phase revocation with a bounded grace drain, and
//!   the conservation invariant (no device leased twice, leases ⊆ active
//!   roster, drains bounded by grace).
//! * [`tenant`] — tenant descriptors (training jobs, serve lanes) with
//!   weights and quotas, and weighted max-min fair allocation over
//!   heterogeneous device capacity.
//! * [`arbiter`] — the decision loop: fair-share targets recomputed on
//!   tenant arrival/departure and pool churn, plus SLO feedback — a serve
//!   lane whose windowed p95 breaches its target preempts the lowest-
//!   priority training lease and returns it when the breach clears. With
//!   the calibration plane on ([`crate::tuning`]), capacity weights come
//!   from live per-device estimates instead of configured speed factors.
//! * [`sim`] — the deterministic discrete-event co-schedule interleaving
//!   [`TrainerSession`](crate::coordinator::trainer::TrainerSession)s and
//!   a serve lane on the shared virtual clock (`experiment fleet`).
//!
//! Training rides through lease churn via the paper's own elastic path:
//! a revoked lease shrinks the session's active subset at the next merge
//! barrier and Algorithm 2's weights renormalize over what remains — the
//! normalized-merging machinery applied to an externally-imposed roster.

pub mod arbiter;
pub mod lease;
pub mod sim;
pub mod tenant;

pub use arbiter::{Arbiter, ArbiterConfig};
pub use lease::{Lease, LeaseBook, LeaseId, LeaseState, PriorityClass, TenantId};
pub use sim::{co_schedule, co_schedule_with, FleetOutcome, TenantJob};
pub use tenant::{fair_allocation, TenantKind, TenantSpec};

//! `heterosparse` binary — see `cli.rs` for the command surface.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = heterosparse::cli::main_with_args(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

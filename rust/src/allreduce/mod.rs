//! Multi-stream all-reduce model merging — the HeteroGPU §4 substrate.
//!
//! The paper replaces NCCL with custom tree- and ring-based all-reduce
//! functions: the model is split into a fixed number of partitions, each
//! assigned to its own GPU stream starting from a different device, so model
//! transfer overlaps reduction compute. We reproduce both the *arithmetic*
//! (weighted average over partitions — verified exactly against a direct
//! weighted sum) and a *transfer-time model* capturing the paper's findings:
//!
//! * multi-stream overlap beats single-stream,
//! * with multiple streams the ring variant beats the tree variant (inner
//!   tree nodes serve two children, doubling their per-stage traffic),
//! * the optimal stream count equals the number of devices.
//!
//! The trainer charges the returned simulated time to the training clock at
//! every merge.

use crate::model::ModelState;
use crate::runtime::CostModel;

/// All-reduce algorithm variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Ring,
    Tree,
}

/// Outcome of a merge: where the weighted average landed + simulated time.
#[derive(Debug)]
pub struct MergeStats {
    pub seconds: f64,
    pub streams: usize,
    pub algo: Algo,
}

/// Weighted-average all-reduce over `replicas` with `weights`, writing the
/// result into `out`. The computation walks partition-by-partition exactly
/// like the streamed implementation would (one running partial per
/// partition — the paper's memory optimization), so the arithmetic is the
/// partitioned one, not a shortcut.
pub fn allreduce_merge(
    out: &mut ModelState,
    replicas: &[&ModelState],
    weights: &[f64],
    algo: Algo,
    streams: usize,
    cost: &CostModel,
) -> MergeStats {
    assert_eq!(replicas.len(), weights.len());
    assert!(!replicas.is_empty());
    let streams = streams.max(1);

    // ---- arithmetic: partitioned weighted average -------------------------
    {
        let replica_segs: Vec<Vec<&[f32]>> =
            replicas.iter().map(|r| r.segments().to_vec()).collect();
        let mut out_segs = out.segments_mut();
        partitioned_weighted_sum(&mut out_segs, &replica_segs, weights, streams);
    }

    // ---- transfer-time model ----------------------------------------------
    let params = out.param_count();
    let seconds = simulated_time(algo, replicas.len(), streams, params, cost);
    MergeStats { seconds, streams, algo }
}

/// The partitioned weighted-average core shared by [`allreduce_merge`] and
/// the cluster fabric's inter-server reduce.
///
/// Each segment's flat parameter space is split into `streams` chunks; each
/// chunk accumulates its weighted partial in ring order starting from a
/// different device (order does not change the result, but we mirror the
/// schedule to keep the code honest to the design). The segment count is
/// whatever the caller hands in — nothing here assumes the 4-segment MLP
/// layout, so the arithmetic survives model-shape changes.
///
/// Panics if any replica's segment list does not match `out_segs` in count
/// or per-segment length.
pub fn partitioned_weighted_sum(
    out_segs: &mut [&mut [f32]],
    replica_segs: &[Vec<&[f32]>],
    weights: &[f64],
    streams: usize,
) {
    assert_eq!(replica_segs.len(), weights.len());
    assert!(!replica_segs.is_empty());
    let devices = replica_segs.len();
    let streams = streams.max(1);
    for (seg, dst_seg) in out_segs.iter_mut().enumerate() {
        let seg_len = dst_seg.len();
        let chunk = seg_len.div_ceil(streams);
        for s in 0..streams {
            let lo = s * chunk;
            if lo >= seg_len {
                break;
            }
            let hi = (lo + chunk).min(seg_len);
            // Stream s starts its ring at device (s % devices).
            let start = s % devices;
            let dst = &mut dst_seg[lo..hi];
            dst.fill(0.0);
            for d in 0..devices {
                let dev = (start + d) % devices;
                let src = &replica_segs[dev][seg][lo..hi];
                let w = weights[dev] as f32;
                for (o, &x) in dst.iter_mut().zip(src) {
                    *o += w * x;
                }
            }
        }
    }
}

/// Simulated all-reduce time.
///
/// Per-partition hop cost is `t(params/streams)`. Ring: `2(G-1)` pipeline
/// stages plus `streams-1` fill; tree: `2·ceil(log2 G)` stages but every
/// stage moves twice the traffic through the fan-in-2 inner nodes.
pub fn simulated_time(
    algo: Algo,
    devices: usize,
    streams: usize,
    params: usize,
    cost: &CostModel,
) -> f64 {
    if devices <= 1 {
        return 0.0;
    }
    let part = params.div_ceil(streams);
    let hop = cost.transfer_time(part);
    let stages = match algo {
        Algo::Ring => 2 * (devices - 1),
        Algo::Tree => {
            let levels = (devices as f64).log2().ceil() as usize;
            2 * levels * 2 // fan-in-2 contention doubles per-stage traffic
        }
    };
    cost.t_merge_fixed + (stages + streams - 1) as f64 * hop
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { features: 32, hidden: 8, classes: 16, max_nnz: 4, max_labels: 2 }
    }

    fn models(n: usize) -> Vec<ModelState> {
        (0..n).map(|i| ModelState::init(&dims(), i as u64 + 1)).collect()
    }

    #[test]
    fn matches_direct_weighted_sum_exactly() {
        let ms = models(4);
        let refs: Vec<&ModelState> = ms.iter().collect();
        let weights = [0.4, 0.3, 0.2, 0.1];
        let cost = CostModel::default();

        let mut direct = ModelState::zeros(&dims());
        direct.set_weighted_sum(&refs, &weights);

        for algo in [Algo::Ring, Algo::Tree] {
            for streams in [1, 2, 4, 7] {
                let mut out = ModelState::zeros(&dims());
                allreduce_merge(&mut out, &refs, &weights, algo, streams, &cost);
                assert!(
                    out.max_abs_diff(&direct) < 1e-6,
                    "{algo:?}/{streams} streams diverged from direct sum"
                );
            }
        }
    }

    #[test]
    fn multi_stream_beats_single_stream() {
        let cost = CostModel::default();
        let params = 1_000_000;
        for algo in [Algo::Ring, Algo::Tree] {
            let t1 = simulated_time(algo, 4, 1, params, &cost);
            let t4 = simulated_time(algo, 4, 4, params, &cost);
            assert!(t4 < t1, "{algo:?}: {t4} !< {t1}");
        }
    }

    #[test]
    fn multistream_ring_beats_multistream_tree() {
        // The paper's empirical result, used to justify ring throughout.
        // Holds at single-server scale (the paper's testbed is 4 GPUs); at
        // larger G the tree's O(log G) stage count wins asymptotically,
        // which is also why NCCL prefers trees across servers.
        let cost = CostModel::default();
        for g in [2usize, 4] {
            let ring = simulated_time(Algo::Ring, g, g, 1_000_000, &cost);
            let tree = simulated_time(Algo::Tree, g, g, 1_000_000, &cost);
            assert!(ring <= tree, "G={g}: ring {ring} !<= tree {tree}");
        }
        // Crossover: by G=16 the tree is ahead.
        let ring16 = simulated_time(Algo::Ring, 16, 16, 1_000_000, &cost);
        let tree16 = simulated_time(Algo::Tree, 16, 16, 1_000_000, &cost);
        assert!(tree16 < ring16);
    }

    #[test]
    fn optimal_stream_count_is_device_count() {
        // Diminishing/negative returns past streams == devices is not part
        // of this simple model, but the paper tunes streams == G; check G
        // streams is no worse than fewer.
        let cost = CostModel::default();
        let t2 = simulated_time(Algo::Ring, 4, 2, 1_000_000, &cost);
        let t4 = simulated_time(Algo::Ring, 4, 4, 1_000_000, &cost);
        assert!(t4 <= t2);
    }

    #[test]
    fn single_device_is_free() {
        let cost = CostModel::default();
        assert_eq!(simulated_time(Algo::Ring, 1, 4, 1_000_000, &cost), 0.0);
    }

    #[test]
    fn arithmetic_survives_non_four_segment_states() {
        // The merge core must not assume the MLP's 4-segment layout: run it
        // over 2-, 3- and 6-segment parameter lists (ragged lengths, one
        // empty) and check against a direct weighted sum.
        for seg_lens in [vec![5usize, 17], vec![8, 0, 3], vec![1, 2, 3, 4, 5, 33]] {
            let devices = 3usize;
            let weights = [0.5, 0.3, 0.2];
            let replicas: Vec<Vec<Vec<f32>>> = (0..devices)
                .map(|d| {
                    seg_lens
                        .iter()
                        .enumerate()
                        .map(|(s, &n)| {
                            (0..n).map(|i| (d * 131 + s * 17 + i) as f32 * 0.01).collect()
                        })
                        .collect()
                })
                .collect();
            let replica_segs: Vec<Vec<&[f32]>> = replicas
                .iter()
                .map(|r| r.iter().map(|s| s.as_slice()).collect())
                .collect();
            let mut out: Vec<Vec<f32>> =
                seg_lens.iter().map(|&n| vec![0.0; n]).collect();
            {
                let mut out_segs: Vec<&mut [f32]> =
                    out.iter_mut().map(|s| s.as_mut_slice()).collect();
                partitioned_weighted_sum(&mut out_segs, &replica_segs, &weights, 3);
            }
            for (seg, &n) in seg_lens.iter().enumerate() {
                for i in 0..n {
                    let direct: f32 = (0..devices)
                        .map(|d| weights[d] as f32 * replicas[d][seg][i])
                        .sum();
                    assert!(
                        (out[seg][i] - direct).abs() < 1e-6,
                        "segments {seg_lens:?}: seg {seg} idx {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn partition_edge_cases() {
        // streams > param segments still exact.
        let ms = models(2);
        let refs: Vec<&ModelState> = ms.iter().collect();
        let cost = CostModel::default();
        let mut direct = ModelState::zeros(&dims());
        direct.set_weighted_sum(&refs, &[0.5, 0.5]);
        let mut out = ModelState::zeros(&dims());
        allreduce_merge(&mut out, &refs, &[0.5, 0.5], Algo::Ring, 64, &cost);
        assert!(out.max_abs_diff(&direct) < 1e-6);
    }
}

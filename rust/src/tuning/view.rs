//! The shared calibrated-costs view — versioned, immutable snapshots with
//! atomic hot-swap (the [`SnapshotRegistry`] pattern applied to costs).
//!
//! Writers (training sessions feeding [`DeviceEstimator`]s) publish
//! per-device estimate updates; readers (dispatch planning, the fleet
//! arbiter, the serve router) clone one `Arc<CostsView>` and see a
//! coherent roster-wide picture for the duration of their decision. A
//! device with no estimate yet falls back to its *nominal* configured
//! speed factor, so consumers never special-case cold starts.
//!
//! # Invariants
//!
//! * A published [`CostsView`] is immutable — readers can never observe a
//!   torn update, no matter how many publishes race past them.
//! * `version` is strictly monotone across updates; `version == 0` is the
//!   nominal-only view.
//! * [`CostsView::speed`] is always positive (estimates are clamped at
//!   the estimator; nominal factors are validated positive by config).
//!
//! [`SnapshotRegistry`]: crate::serve::SnapshotRegistry
//! [`DeviceEstimator`]: super::estimator::DeviceEstimator

use std::fmt;
use std::sync::{Arc, RwLock};

use super::estimator::DeviceEstimate;

/// One immutable, versioned snapshot of the fleet's calibrated costs.
#[derive(Clone, Debug)]
pub struct CostsView {
    /// Monotone update counter (0 = nominal-only, nothing calibrated yet).
    pub version: u64,
    /// Training/fleet clock of the most recent update folded in.
    pub updated_clock: f64,
    /// Roster-indexed configured speed factors — the fallback.
    pub nominal: Vec<f64>,
    /// Roster-indexed current estimates (None until a device has been
    /// observed).
    pub estimates: Vec<Option<DeviceEstimate>>,
}

impl CostsView {
    /// Number of roster devices this view covers.
    pub fn roster_len(&self) -> usize {
        self.nominal.len()
    }

    /// Effective speed multiplier for `device`: the calibrated estimate
    /// when one exists, the configured nominal factor otherwise.
    pub fn speed(&self, device: usize) -> f64 {
        match self.estimates[device] {
            Some(e) => e.speed,
            None => self.nominal[device],
        }
    }

    /// Effective speed multipliers for the whole roster (estimate where
    /// available, nominal elsewhere) — the drop-in replacement for a
    /// `speed_factors` vector.
    pub fn speeds(&self) -> Vec<f64> {
        (0..self.nominal.len()).map(|d| self.speed(d)).collect()
    }

    /// The calibrated estimate for `device`, if any.
    pub fn estimate(&self, device: usize) -> Option<DeviceEstimate> {
        self.estimates[device]
    }
}

/// Thread-safe holder of the current [`CostsView`]: one atomic pointer,
/// clone-modify-swap updates.
pub struct CalibratedCosts {
    current: RwLock<Arc<CostsView>>,
}

impl fmt::Debug for CalibratedCosts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let v = self.current();
        f.debug_struct("CalibratedCosts")
            .field("version", &v.version)
            .field("roster_len", &v.roster_len())
            .finish()
    }
}

impl CalibratedCosts {
    /// A fresh view over `nominal` (roster-indexed configured speed
    /// factors), version 0, no estimates.
    pub fn new(nominal: Vec<f64>) -> CalibratedCosts {
        assert!(!nominal.is_empty(), "calibrated costs need a non-empty roster");
        assert!(nominal.iter().all(|&f| f > 0.0), "nominal speed factors must be positive");
        let n = nominal.len();
        CalibratedCosts {
            current: RwLock::new(Arc::new(CostsView {
                version: 0,
                updated_clock: 0.0,
                nominal,
                estimates: vec![None; n],
            })),
        }
    }

    /// The current view (cheap: one `Arc` clone under a read lock).
    pub fn current(&self) -> Arc<CostsView> {
        self.current.read().unwrap().clone()
    }

    /// Current version without cloning the view.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// Merge per-device estimate updates into a new view and swap it in.
    /// Devices not mentioned keep their previous estimates, so concurrent
    /// sessions observing disjoint device subsets compose instead of
    /// clobbering each other. Returns the new version.
    pub fn update_devices(&self, updates: &[(usize, DeviceEstimate)], clock: f64) -> u64 {
        let mut guard = self.current.write().unwrap();
        let mut next = (**guard).clone();
        for &(d, e) in updates {
            assert!(d < next.estimates.len(), "estimate update outside the roster");
            next.estimates[d] = Some(e);
        }
        next.version += 1;
        next.updated_clock = clock;
        let version = next.version;
        *guard = Arc::new(next);
        version
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(speed: f64) -> DeviceEstimate {
        DeviceEstimate {
            speed,
            t_fixed: 300e-6,
            slope: speed,
            residual_rel: 0.01,
            observations: 5,
            drift_events: 0,
            sparsity_floor: 0.1,
        }
    }

    #[test]
    fn falls_back_to_nominal_until_estimated() {
        let costs = CalibratedCosts::new(vec![1.0, 1.1, 1.21, 1.32]);
        let v = costs.current();
        assert_eq!(v.version, 0);
        assert_eq!(v.speeds(), vec![1.0, 1.1, 1.21, 1.32]);
        assert!(v.estimate(2).is_none());
    }

    #[test]
    fn updates_merge_and_version_monotonically() {
        let costs = CalibratedCosts::new(vec![1.0, 1.1, 1.21, 1.32]);
        assert_eq!(costs.update_devices(&[(0, est(1.5))], 1.0), 1);
        // A second writer updating a disjoint device keeps device 0.
        assert_eq!(costs.update_devices(&[(3, est(2.0))], 2.0), 2);
        let v = costs.current();
        assert_eq!(v.version, 2);
        assert_eq!(v.updated_clock, 2.0);
        assert_eq!(v.speed(0), 1.5);
        assert_eq!(v.speed(1), 1.1, "unobserved device stays nominal");
        assert_eq!(v.speed(3), 2.0);
    }

    #[test]
    fn readers_hold_an_immutable_snapshot_across_swaps() {
        let costs = CalibratedCosts::new(vec![1.0, 1.0]);
        let before = costs.current();
        costs.update_devices(&[(1, est(3.0))], 5.0);
        assert_eq!(before.speed(1), 1.0, "the old Arc is untouched");
        assert_eq!(costs.current().speed(1), 3.0);
    }
}

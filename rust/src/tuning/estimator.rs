//! Per-device online cost estimation — windowed robust regression with
//! EWMA smoothing and a step-drift detector.
//!
//! Each device's observed step times are fitted against the *nominal*
//! [`CostModel`]'s variable cost `x = t_per_nnz·nnz + t_per_sample·b`, so
//! an estimate has the same shape as the cost model it replaces:
//!
//! ```text
//! t(b, nnz) ≈ t_fixed_est + slope_est · x(b, nnz)
//! ```
//!
//! with `slope_est` absorbing the device's effective speed multiplier
//! (configured `speed_factor` × whatever drift the hardware is really
//! doing). Fitting is Theil–Sen over a bounded observation window —
//! median-of-pairwise-slopes, so a single jittered outlier cannot bend
//! the estimate — then EWMA-smoothed across windows for gradual-drift
//! tracking.
//!
//! Drift handling is two-speed, mirroring the throttle regimes of
//! ABS-SGD (arXiv:2308.15164): **gradual** drift (clock oscillation,
//! slow thermal creep) flows through the slow EWMA; a **step** change
//! (sudden throttle, a co-tenant landing on the device) is detected when
//! `step_obs` consecutive observations deviate from the smoothed
//! prediction by more than `step_threshold` relative — the stale window
//! is then discarded and the estimate re-seeds from the post-step
//! observations alone (fast re-estimate).
//!
//! # Invariants
//!
//! * Estimates are deterministic functions of the observation sequence —
//!   no clocks, no randomness — so calibrated runs stay bit-reproducible.
//! * `t_fixed` and `slope` are clamped non-negative; `speed` is clamped
//!   positive, so a consumer can always divide by it.
//! * The window never exceeds `EstimatorConfig::window` observations.

use crate::runtime::CostModel;

/// Estimator knobs (a projection of the `[calibration]` config block).
#[derive(Clone, Copy, Debug)]
pub struct EstimatorConfig {
    /// Observation-window length per device (>= 3): how much history the
    /// robust fit sees.
    pub window: usize,
    /// EWMA smoothing factor in (0, 1] applied across window fits — the
    /// *slow* tracking path for gradual drift (1.0 = no smoothing).
    pub alpha: f64,
    /// Relative deviation of an observation from the smoothed prediction
    /// that counts as a step-drift outlier (> 0).
    pub step_threshold: f64,
    /// Consecutive outliers before the detector declares a step change
    /// and fast re-estimates (>= 1).
    pub step_obs: usize,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig { window: 6, alpha: 0.25, step_threshold: 0.25, step_obs: 2 }
    }
}

/// One per-device timing observation: the mean over one mega-batch of
/// that device's dispatched batches (both engines already report these).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Observation {
    /// Padded batch size (bucket-grid value) the device ran.
    pub bucket: usize,
    /// Mean true non-zeros per batch.
    pub nnz_per_batch: f64,
    /// Mean observed seconds per batch (simulated or stretched wall).
    pub secs_per_batch: f64,
    /// Active-class sparsity ratio the device stepped at (1.0 = exact
    /// dense). The fit scales its nominal workload term accordingly, so
    /// cheap approximate steps don't read as the device speeding up.
    pub ratio: f64,
}

/// The current calibrated estimate for one device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceEstimate {
    /// Effective slowdown multiplier vs the nominal [`CostModel`] at the
    /// window's mean workload — directly comparable to (and a drop-in
    /// replacement for) the configured `speed_factor`. Always > 0.
    pub speed: f64,
    /// Estimated fixed per-step overhead in seconds (>= 0).
    pub t_fixed: f64,
    /// Estimated multiplier on the nominal variable cost (>= 0).
    pub slope: f64,
    /// Median relative residual of the window under the smoothed estimate
    /// — the estimate's own quality signal (small = trustworthy).
    pub residual_rel: f64,
    /// Observations consumed so far.
    pub observations: u64,
    /// Step-drift re-estimates fired so far.
    pub drift_events: u64,
    /// This device's fitted cost-vs-sparsity floor: the share of its
    /// per-sample cost that did *not* shrink when it stepped at reduced
    /// ratios. Seeds from the nominal model's `sparsity_floor` and is
    /// EWMA-refined from sparse-step observations.
    pub sparsity_floor: f64,
}

impl DeviceEstimate {
    /// Predicted seconds for one step of a `bucket`-sized batch carrying
    /// `nnz` non-zeros, under this estimate of the device.
    pub fn step_secs(&self, nominal: &CostModel, bucket: usize, nnz: f64) -> f64 {
        self.step_secs_at(nominal, bucket, nnz, 1.0)
    }

    /// [`step_secs`](DeviceEstimate::step_secs) at an active-class
    /// sparsity ratio, using this device's *fitted* cost-vs-sparsity
    /// curve — the scaling plane inverts this to pick (batch, ratio)
    /// pairs.
    pub fn step_secs_at(&self, nominal: &CostModel, bucket: usize, nnz: f64, ratio: f64) -> f64 {
        let factor = if ratio >= 1.0 {
            1.0
        } else {
            self.sparsity_floor + (1.0 - self.sparsity_floor) * ratio.max(0.0)
        };
        self.t_fixed
            + self.slope * (nominal.t_per_nnz * nnz + nominal.t_per_sample * bucket as f64 * factor)
    }
}

/// The smoothed two-parameter fit (internal state).
#[derive(Clone, Copy, Debug)]
struct Fit {
    t_fixed: f64,
    slope: f64,
}

/// Online cost estimator for a single roster device.
#[derive(Clone, Debug)]
pub struct DeviceEstimator {
    cfg: EstimatorConfig,
    nominal: CostModel,
    /// FIFO observation window (len <= cfg.window).
    window: Vec<Observation>,
    smoothed: Option<Fit>,
    outlier_streak: usize,
    observations: u64,
    drift_events: u64,
    /// EWMA-fitted device sparsity floor (None until a sparse step has
    /// been observed; falls back to the nominal model's floor).
    sparsity_floor: Option<f64>,
}

impl DeviceEstimator {
    /// Estimator fitting against `nominal` (the cost model the engine
    /// charges time with — estimates are multipliers on *its* terms).
    pub fn new(cfg: EstimatorConfig, nominal: CostModel) -> DeviceEstimator {
        assert!(cfg.window >= 3, "estimator window must hold at least 3 observations");
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha must be in (0, 1]");
        assert!(cfg.step_threshold > 0.0, "step threshold must be positive");
        assert!(cfg.step_obs >= 1, "step_obs must be >= 1");
        DeviceEstimator {
            cfg,
            nominal,
            window: Vec::new(),
            smoothed: None,
            outlier_streak: 0,
            observations: 0,
            drift_events: 0,
            sparsity_floor: None,
        }
    }

    /// Feed one observation. Returns `true` when the step-drift detector
    /// fired on this observation (the estimate just fast re-seeded from
    /// the post-step window — consumers may want to re-plan immediately).
    pub fn observe(&mut self, obs: Observation) -> bool {
        self.observations += 1;

        // Outlier test against the *smoothed* prediction (not the raw
        // window fit): a step change makes consecutive observations land
        // far from where the slow path thinks the device is.
        if let Some(f) = self.smoothed {
            let y_hat = (f.t_fixed + f.slope * self.x(&obs)).max(1e-12);
            let rel = (obs.secs_per_batch - y_hat).abs() / y_hat;
            if rel > self.cfg.step_threshold {
                self.outlier_streak += 1;
            } else {
                self.outlier_streak = 0;
            }
        }

        // Sparse steps also refine the device's cost-vs-sparsity floor:
        // given the current fit, the observation implies an effective
        // per-sample factor; invert `factor = floor + (1 - floor)·ratio`
        // and EWMA the result.
        if obs.ratio < 1.0 {
            if let Some(f) = self.smoothed {
                let dense_var = self.nominal.t_per_sample * obs.bucket as f64;
                let gather = self.nominal.t_per_nnz * obs.nnz_per_batch;
                let denom = f.slope * dense_var;
                if denom > 1e-15 {
                    let factor = ((obs.secs_per_batch - f.t_fixed - f.slope * gather) / denom)
                        .clamp(0.0, 1.0);
                    let floor = ((factor - obs.ratio) / (1.0 - obs.ratio)).clamp(0.0, 1.0);
                    self.sparsity_floor = Some(match self.sparsity_floor {
                        None => floor,
                        Some(prev) => self.cfg.alpha * floor + (1.0 - self.cfg.alpha) * prev,
                    });
                }
            }
        }

        self.window.push(obs);
        if self.window.len() > self.cfg.window {
            self.window.remove(0);
        }

        if self.smoothed.is_some() && self.outlier_streak >= self.cfg.step_obs {
            // Step drift: the pre-step window is stale evidence. Keep only
            // the outlier run and re-seed the smoothed estimate from it —
            // the fast path.
            let keep = self.outlier_streak.min(self.window.len());
            self.window.drain(..self.window.len() - keep);
            self.smoothed = Some(self.fit_window());
            self.outlier_streak = 0;
            self.drift_events += 1;
            return true;
        }

        // Slow path: robust window fit, EWMA-blended for gradual drift.
        let fresh = self.fit_window();
        self.smoothed = Some(match self.smoothed {
            None => fresh,
            Some(prev) => Fit {
                t_fixed: self.cfg.alpha * fresh.t_fixed + (1.0 - self.cfg.alpha) * prev.t_fixed,
                slope: self.cfg.alpha * fresh.slope + (1.0 - self.cfg.alpha) * prev.slope,
            },
        });
        false
    }

    /// The current estimate (None until the first observation).
    pub fn estimate(&self) -> Option<DeviceEstimate> {
        let f = self.smoothed?;
        let x_mean = self.window.iter().map(|o| self.x(o)).sum::<f64>()
            / self.window.len().max(1) as f64;
        let speed = ((f.t_fixed + f.slope * x_mean) / (self.nominal.t_fixed + x_mean)).max(1e-6);
        let mut residuals: Vec<f64> = self
            .window
            .iter()
            .map(|o| {
                let y_hat = (f.t_fixed + f.slope * self.x(o)).max(1e-12);
                (o.secs_per_batch - y_hat).abs() / y_hat
            })
            .collect();
        Some(DeviceEstimate {
            speed,
            t_fixed: f.t_fixed,
            slope: f.slope,
            residual_rel: median(&mut residuals),
            observations: self.observations,
            drift_events: self.drift_events,
            sparsity_floor: self.effective_floor(),
        })
    }

    /// Observations consumed so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Step-drift re-estimates fired so far.
    pub fn drift_events(&self) -> u64 {
        self.drift_events
    }

    /// The device's cost-vs-sparsity floor: fitted when sparse steps have
    /// been observed, the nominal model's otherwise.
    fn effective_floor(&self) -> f64 {
        self.sparsity_floor.unwrap_or(self.nominal.sparsity_floor)
    }

    /// Variable cost of an observation's workload at its sparsity ratio
    /// (device-floor-aware, so sparse steps fit the same line as dense
    /// ones instead of reading as the device speeding up).
    fn x(&self, o: &Observation) -> f64 {
        let factor = if o.ratio >= 1.0 {
            1.0
        } else {
            let fl = self.effective_floor();
            fl + (1.0 - fl) * o.ratio.max(0.0)
        };
        self.nominal.t_per_nnz * o.nnz_per_batch
            + self.nominal.t_per_sample * o.bucket as f64 * factor
    }

    /// Theil–Sen fit of `y = t_fixed + slope·x` over the window. When the
    /// window has no workload spread (every batch the same size and nnz —
    /// the static-batch strategies), the two parameters are not separately
    /// identifiable, so the fit degrades gracefully to a pure
    /// multiplicative model: `median(y/nominal) × (t_fixed, 1)`.
    fn fit_window(&self) -> Fit {
        let n = self.nominal;
        let pts: Vec<(f64, f64)> =
            self.window.iter().map(|o| (self.x(o), o.secs_per_batch)).collect();
        debug_assert!(!pts.is_empty(), "fit_window requires observations");
        let x_lo = pts.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let x_hi = pts.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
        if pts.len() < 2 || x_hi - x_lo <= 1e-9 * x_hi.max(1e-12) {
            let mut ratios: Vec<f64> =
                pts.iter().map(|&(x, y)| y / (n.t_fixed + x).max(1e-12)).collect();
            let m = median(&mut ratios).max(0.0);
            return Fit { t_fixed: m * n.t_fixed, slope: m };
        }
        let mut slopes = Vec::with_capacity(pts.len() * (pts.len() - 1) / 2);
        for i in 0..pts.len() {
            for j in i + 1..pts.len() {
                let dx = pts[j].0 - pts[i].0;
                if dx.abs() > 1e-15 {
                    slopes.push((pts[j].1 - pts[i].1) / dx);
                }
            }
        }
        let slope = median(&mut slopes).max(0.0);
        let mut intercepts: Vec<f64> = pts.iter().map(|&(x, y)| y - slope * x).collect();
        let t_fixed = median(&mut intercepts).max(0.0);
        Fit { t_fixed, slope }
    }
}

/// Median of a non-empty slice (sorts in place; lower-of-two for even
/// lengths, matching the robust-statistics convention used elsewhere).
fn median(v: &mut [f64]) -> f64 {
    assert!(!v.is_empty(), "median of an empty slice");
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[(v.len() - 1) / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(bucket: usize, nnz: f64, secs: f64) -> Observation {
        Observation { bucket, nnz_per_batch: nnz, secs_per_batch: secs, ratio: 1.0 }
    }

    /// Feed `k` noiseless observations of a `speed ×` nominal device over
    /// a spread of workloads.
    fn feed_true(est: &mut DeviceEstimator, speed: f64, k: usize) {
        let n = CostModel::default();
        for i in 0..k {
            let b = 32 + 16 * (i % 4);
            let nnz = 12.0 * b as f64;
            let secs = speed * n.step_time_parts(b, nnz as usize);
            est.observe(obs(b, nnz, secs));
        }
    }

    #[test]
    fn converges_to_a_scripted_multiplier() {
        let mut est = DeviceEstimator::new(EstimatorConfig::default(), CostModel::default());
        assert!(est.estimate().is_none(), "no estimate before observations");
        feed_true(&mut est, 1.32, 12);
        let e = est.estimate().unwrap();
        assert!((e.speed - 1.32).abs() < 0.02, "speed {}", e.speed);
        assert!(e.residual_rel < 0.02, "noiseless fit must have tiny residuals");
        assert_eq!(e.drift_events, 0);
        assert_eq!(e.observations, 12);
    }

    #[test]
    fn recovers_fixed_and_slope_separately() {
        // A device with doubled fixed overhead but nominal variable cost:
        // the two-parameter fit separates them; a pure multiplier cannot.
        let n = CostModel::default();
        let mut est = DeviceEstimator::new(
            EstimatorConfig { alpha: 1.0, ..Default::default() },
            n,
        );
        for i in 0..8 {
            let b = 16 + 16 * (i % 4);
            let nnz = 12.0 * b as f64;
            let secs = 2.0 * n.t_fixed + n.t_per_nnz * nnz + n.t_per_sample * b as f64;
            est.observe(obs(b, nnz, secs));
        }
        let e = est.estimate().unwrap();
        assert!((e.t_fixed - 2.0 * n.t_fixed).abs() < 0.1 * n.t_fixed, "t_fixed {}", e.t_fixed);
        assert!((e.slope - 1.0).abs() < 0.05, "slope {}", e.slope);
    }

    #[test]
    fn constant_workload_falls_back_to_multiplicative() {
        let n = CostModel::default();
        let mut est = DeviceEstimator::new(EstimatorConfig::default(), n);
        for _ in 0..6 {
            let secs = 1.21 * n.step_time_parts(64, 768);
            est.observe(obs(64, 768.0, secs));
        }
        let e = est.estimate().unwrap();
        assert!((e.speed - 1.21).abs() < 0.02, "speed {}", e.speed);
        assert!((e.slope - 1.21).abs() < 0.02, "degenerate fit is the multiplier");
    }

    #[test]
    fn single_outlier_does_not_bend_the_estimate() {
        let mut est = DeviceEstimator::new(EstimatorConfig::default(), CostModel::default());
        feed_true(&mut est, 1.0, 8);
        let before = est.estimate().unwrap().speed;
        // One wild observation (a GC pause, a noisy neighbor blip).
        let n = CostModel::default();
        let fired = est.observe(obs(64, 768.0, 10.0 * n.step_time_parts(64, 768)));
        assert!(!fired, "one outlier must not trigger a step re-estimate");
        feed_true(&mut est, 1.0, 2);
        let after = est.estimate().unwrap().speed;
        assert!((after - before).abs() < 0.15 * before, "{before} -> {after}");
        assert_eq!(est.drift_events(), 0);
    }

    #[test]
    fn step_drift_detected_within_step_obs() {
        let cfg = EstimatorConfig { step_obs: 2, ..Default::default() };
        let mut est = DeviceEstimator::new(cfg, CostModel::default());
        feed_true(&mut est, 1.0, 8);
        // The device throttles 1.8x: the first post-step observation is an
        // outlier, the second completes the streak and re-seeds.
        let n = CostModel::default();
        let secs = 1.8 * n.step_time_parts(64, 768);
        assert!(!est.observe(obs(64, 768.0, secs)), "first outlier only starts the streak");
        assert!(est.observe(obs(64, 768.0, secs)), "second outlier fires the detector");
        assert_eq!(est.drift_events(), 1);
        // The fast re-estimate is already at the new speed.
        let e = est.estimate().unwrap();
        assert!((e.speed - 1.8).abs() < 0.05, "fast re-estimate {}", e.speed);
    }

    #[test]
    fn gradual_drift_tracks_without_step_events() {
        let n = CostModel::default();
        let mut est = DeviceEstimator::new(
            EstimatorConfig { alpha: 0.5, step_threshold: 0.5, ..Default::default() },
            n,
        );
        // Speed creeps 1.00 -> 1.20 in 2% increments: never an outlier.
        for i in 0..20 {
            let speed = 1.0 + 0.01 * i as f64;
            let secs = speed * n.step_time_parts(64, 768);
            assert!(!est.observe(obs(64, 768.0, secs)), "creep must not fire the detector");
        }
        let e = est.estimate().unwrap();
        assert_eq!(e.drift_events, 0);
        assert!(e.speed > 1.1, "EWMA tracked the creep: {}", e.speed);
    }

    #[test]
    fn deterministic_given_the_same_observations() {
        let run = || {
            let mut est =
                DeviceEstimator::new(EstimatorConfig::default(), CostModel::default());
            feed_true(&mut est, 1.1, 9);
            est.estimate().unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sparse_steps_fit_the_device_floor_not_a_speedup() {
        // A device whose true sparsity floor (0.3) is steeper than the
        // nominal model's (0.1): the estimator must learn the device
        // curve from sparse observations, keep the speed estimate at 1.0
        // (cheap approximate steps are not the device getting faster),
        // and predict sparse step times with the fitted curve.
        let n = CostModel::default();
        let true_floor = 0.3;
        let cfg = EstimatorConfig { alpha: 1.0, step_threshold: 0.6, ..Default::default() };
        let mut est = DeviceEstimator::new(cfg, n);
        feed_true(&mut est, 1.0, 6);
        assert_eq!(est.estimate().unwrap().sparsity_floor, n.sparsity_floor, "nominal until observed");
        let ratio = 0.25;
        let factor = true_floor + (1.0 - true_floor) * ratio;
        for _ in 0..4 {
            let secs =
                n.t_fixed + n.t_per_nnz * 768.0 + n.t_per_sample * 64.0 * factor;
            est.observe(Observation {
                bucket: 64,
                nnz_per_batch: 768.0,
                secs_per_batch: secs,
                ratio,
            });
        }
        let e = est.estimate().unwrap();
        assert!((e.sparsity_floor - true_floor).abs() < 0.05, "floor {}", e.sparsity_floor);
        assert!((e.speed - 1.0).abs() < 0.12, "sparse steps read as speedup: {}", e.speed);
        assert_eq!(e.drift_events, 0, "sparse steps must not fire the drift detector");
        // The fitted curve predicts the sparse step time.
        let pred = e.step_secs_at(&n, 64, 768.0, ratio);
        let truth = n.t_fixed + n.t_per_nnz * 768.0 + n.t_per_sample * 64.0 * factor;
        assert!((pred - truth).abs() / truth < 0.06, "pred {pred} vs {truth}");
        // And the dense prediction is untouched by the sparse evidence.
        let dense = e.step_secs(&n, 64, 768.0);
        assert!((dense - n.step_time_parts(64, 768)).abs() / dense < 0.12);
    }

    #[test]
    fn median_is_deterministic_and_lower_of_two() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&mut [7.0]), 7.0);
    }
}

//! The what-if planner — re-score a dispatch plan under any speed vector
//! without running a step.
//!
//! Given a [`DispatchPlan`] and roster-indexed speed multipliers (the
//! configured nominals, or a [`CostsView`](super::view::CostsView)'s
//! estimates), [`score_plan`] replays the plan's dispatch rule on
//! *predicted* per-batch times and reports the makespan and per-device
//! update counts it would produce. `experiment calibration` uses the
//! nominal-vs-estimated pair to show how far the static cost assumptions
//! have drifted from what the calibration plane measures; operators can
//! use the same comparison to sanity-check a plan before committing a
//! long run to it.
//!
//! # Invariants
//!
//! * Scoring is a pure function — no engine, no model state, no clock —
//!   and replays *calibrated* dispatch exactly: earliest predicted
//!   completion under the given speed vector
//!   ([`next_completion_device`]), ties toward the lower slot. With
//!   uniform per-slot costs this reduces to the earliest-free rule, so a
//!   score difference always traces to the speed vector, never to
//!   simulation skew.
//! * Predicted per-batch cost charges the full padded bucket (as the
//!   engines do) at the plan's expected nnz; partial tail batches are
//!   charged like full ones, a deliberate over-estimate of at most one
//!   batch per device.

use crate::coordinator::dispatch::next_completion_device;
use crate::coordinator::plan::{DispatchMode, DispatchPlan};
use crate::runtime::CostModel;

/// Predicted outcome of one mega-batch under a given speed vector.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanScore {
    /// Predicted makespan: when the slowest device reaches the barrier.
    pub wall: f64,
    /// Predicted per-slot update counts (parallel to `plan.device_ids`).
    pub updates: Vec<u64>,
    /// Predicted per-slot sample counts (parallel to `plan.device_ids`).
    pub samples: Vec<u64>,
    /// Update balance: max/min predicted per-device update count (1.0 is
    /// perfect; `INFINITY` when a device would get no work at all).
    pub balance: f64,
}

/// Replay `plan`'s dispatch rule on predicted per-batch times.
/// `speeds` is roster-indexed (the same order as `DevicePool::roster`);
/// only the plan's active devices are read.
pub fn score_plan(plan: &DispatchPlan, speeds: &[f64], cost: &CostModel) -> PlanScore {
    let g = plan.devices();
    assert!(g > 0, "cannot score a plan with no active devices");
    assert!(
        plan.device_ids.iter().all(|&d| d < speeds.len()),
        "plan device outside the speed vector"
    );
    // Predicted seconds for one full batch on each active slot.
    let secs: Vec<f64> = plan
        .device_ids
        .iter()
        .zip(&plan.batch_sizes)
        .map(|(&d, &b)| {
            speeds[d] * cost.step_time_parts(b, (plan.nnz_estimate * b as f64) as usize)
        })
        .collect();

    let mut free = vec![0.0f64; g];
    let mut updates = vec![0u64; g];
    let mut samples = vec![0u64; g];
    match plan.mode {
        DispatchMode::Dynamic => {
            let mut remaining = plan.sample_budget;
            while remaining > 0 {
                // The calibrated engine's rule, on these predicted costs.
                let slot = next_completion_device(&free, 0.0, &secs, |_| true)
                    .expect("plan has at least one active device");
                let valid = plan.batch_sizes[slot].min(remaining);
                remaining -= valid;
                free[slot] += secs[slot];
                updates[slot] += 1;
                samples[slot] += valid as u64;
            }
        }
        DispatchMode::StaticQuota { batches_per_device } => {
            for slot in 0..g {
                updates[slot] = batches_per_device as u64;
                samples[slot] = (batches_per_device * plan.batch_sizes[slot]) as u64;
                free[slot] = batches_per_device as f64 * secs[slot];
            }
        }
    }
    let wall = free.iter().copied().fold(0.0, f64::max);
    let hi = updates.iter().copied().max().unwrap_or(0);
    let lo = updates.iter().copied().min().unwrap_or(0);
    let balance = if hi == 0 {
        1.0
    } else if lo == 0 {
        f64::INFINITY
    } else {
        hi as f64 / lo as f64
    };
    PlanScore { wall, updates, samples, balance }
}

/// Score the same plan under the nominal and the estimated speed vectors
/// — the "how wrong were the static assumptions" comparison.
pub fn compare(
    plan: &DispatchPlan,
    nominal: &[f64],
    estimated: &[f64],
    cost: &CostModel,
) -> (PlanScore, PlanScore) {
    (score_plan(plan, nominal, cost), score_plan(plan, estimated, cost))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_dynamic(g: usize, b: usize, budget: usize) -> DispatchPlan {
        DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: (0..g).collect(),
            batch_sizes: vec![b; g],
            lrs: vec![0.05; g],
            sample_budget: budget,
            crossbow_rate: None,
            nnz_estimate: 12.0,
            predicted_step_secs: None,
        }
    }

    #[test]
    fn uniform_speeds_balance_perfectly() {
        let s = score_plan(&plan_dynamic(4, 32, 4 * 32 * 10), &[1.0; 4], &CostModel::default());
        assert_eq!(s.updates, vec![10, 10, 10, 10]);
        assert_eq!(s.balance, 1.0);
        assert_eq!(s.samples.iter().sum::<u64>(), 4 * 32 * 10);
        assert!(s.wall > 0.0);
    }

    #[test]
    fn heterogeneous_speeds_skew_updates_and_equal_batches_unbalance() {
        let speeds = [1.0, 1.0, 1.0, 2.0];
        let s = score_plan(&plan_dynamic(4, 32, 4 * 32 * 10), &speeds, &CostModel::default());
        assert!(s.updates[0] > s.updates[3], "{:?}", s.updates);
        assert!(s.balance > 1.3, "equal batches on a 2x-slow device unbalance: {}", s.balance);
        // Sample conservation holds regardless of the speed vector.
        assert_eq!(s.samples.iter().sum::<u64>(), 4 * 32 * 10);
    }

    #[test]
    fn speed_matched_batch_sizes_rebalance_the_score() {
        // Half the batch on the 2x-slow device ≈ equal per-batch time.
        let mut plan = plan_dynamic(4, 64, 4 * 64 * 8);
        plan.batch_sizes = vec![64, 64, 64, 32];
        let speeds = [1.0, 1.0, 1.0, 2.0];
        let balanced = score_plan(&plan, &speeds, &CostModel::default());
        let naive = score_plan(&plan_dynamic(4, 64, 4 * 64 * 8), &speeds, &CostModel::default());
        assert!(
            balanced.balance < naive.balance,
            "calibrated sizes must score closer to balance: {} vs {}",
            balanced.balance,
            naive.balance
        );
    }

    #[test]
    fn static_quota_wall_is_the_slowest_device() {
        let plan = DispatchPlan {
            mode: DispatchMode::StaticQuota { batches_per_device: 5 },
            device_ids: vec![0, 1],
            batch_sizes: vec![32, 32],
            lrs: vec![0.05; 2],
            sample_budget: 0,
            crossbow_rate: None,
            nnz_estimate: 12.0,
            predicted_step_secs: None,
        };
        let cost = CostModel::default();
        let s = score_plan(&plan, &[1.0, 2.0], &cost);
        assert_eq!(s.updates, vec![5, 5]);
        assert_eq!(s.balance, 1.0);
        let per_batch = cost.step_time_parts(32, (12.0 * 32.0) as usize);
        assert!((s.wall - 5.0 * 2.0 * per_batch).abs() < 1e-9);
    }

    #[test]
    fn compare_pairs_nominal_and_estimated() {
        let plan = plan_dynamic(2, 32, 2 * 32 * 6);
        let (a, b) = compare(&plan, &[1.0, 1.0], &[1.0, 3.0], &CostModel::default());
        assert_eq!(a.balance, 1.0);
        assert!(b.balance > a.balance);
        assert!(b.wall > a.wall, "a slower fleet predicts a longer mega-batch");
    }
}

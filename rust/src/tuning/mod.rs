//! The online cost-model calibration plane — measured per-device costs
//! replacing the static `speed_factor` config as the scheduling source of
//! truth.
//!
//! Every consumer of relative device speed in this repo — dynamic dispatch
//! ([`crate::coordinator::dispatch`]), batch-size scaling
//! ([`crate::coordinator::scaling`]), fleet fair share
//! ([`crate::fleet::tenant`]), serve routing ([`crate::serve::router`]) —
//! historically read the *configured* `devices.speed_factors`. Real
//! heterogeneous servers drift: thermal throttling and co-tenant
//! contention move a device's effective speed mid-run, exactly the regime
//! the paper's dynamic scheduling is supposed to absorb. This module
//! closes that loop:
//!
//! * [`estimator`] — [`DeviceEstimator`]: per-device online estimation of
//!   the [`CostModel`](crate::runtime::CostModel)-shaped step cost (fixed
//!   overhead + variable slope) from observed mega-batch timings, via
//!   windowed Theil–Sen robust regression with EWMA smoothing and a
//!   step-drift detector (step change → fast re-estimate; gradual drift →
//!   slow tracking).
//! * [`link`] — [`LinkEstimator`]: the same Theil–Sen machinery pointed
//!   at inter-server links (latency + bytes/bandwidth), feeding the
//!   cluster plane's adaptive sync cadence ([`crate::cluster`]).
//! * [`view`] — [`CalibratedCosts`]: the versioned, `Arc`-swapped shared
//!   view of every device's current estimate (the snapshot-registry
//!   pattern applied to costs), read lock-free-ish by dispatch, scaling,
//!   the fleet arbiter, and the serve router.
//! * [`whatif`] — [`score_plan`]: re-scores a dispatch plan under any
//!   speed vector (estimated vs nominal), predicting makespan and
//!   update balance without running a single step.
//! * [`drift`] — [`DriftEvent`]: scripted throttle/recover traces
//!   (`[calibration] events`) applied to [`SimDevice`]s at mega-batch
//!   boundaries, so drift scenarios are reproducible experiments rather
//!   than anecdotes.
//!
//! Everything behind the `[calibration]` config block: `events` describe
//! the *physical* drift scenario and always apply; `enabled` decides
//! whether the estimates (rather than config constants) drive scheduling.
//! With `enabled = false` the plane is fully inert and runs are
//! bit-identical to the pre-calibration behavior.
//!
//! [`SimDevice`]: crate::runtime::SimDevice

// New-subsystem bar: every public item here must be documented — with
// `RUSTDOCFLAGS="-D warnings"` in CI, a missing doc fails the build.
#![warn(missing_docs)]

pub mod drift;
pub mod estimator;
pub mod link;
pub mod view;
pub mod whatif;

pub use drift::{multiplier_at, parse_trace, DriftEvent};
pub use estimator::{DeviceEstimate, DeviceEstimator, EstimatorConfig, Observation};
pub use link::{LinkEstimate, LinkEstimator};
pub use view::{CalibratedCosts, CostsView};
pub use whatif::{compare, score_plan, PlanScore};

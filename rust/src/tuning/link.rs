//! Online inter-server link calibration — [`DeviceEstimator`]'s Theil–Sen
//! machinery pointed at the network instead of a GPU.
//!
//! A link's transfer cost has exactly the shape the device estimator
//! already fits: a fixed term (propagation latency) plus a variable term
//! linear in the workload (bytes / bandwidth). So rather than writing a
//! second robust regressor, [`LinkEstimator`] wraps a [`DeviceEstimator`]
//! around a *synthetic* nominal [`CostModel`] in which
//!
//! * `t_fixed` is the link's nominal latency (seconds per hop),
//! * `t_per_nnz` is the nominal seconds-per-byte (1 / bandwidth), and
//! * `t_per_sample` is zero (links carry no per-sample work).
//!
//! Each observed sync hop feeds one [`Observation`] with the bytes moved
//! in the `nnz_per_batch` slot; the fit then recovers the link's
//! effective latency and bandwidth multiplier, and the estimate's `speed`
//! is the link slowdown the cluster plane's adaptive sync cadence reads
//! (2.0 = the link is twice as slow as configured). All of the device
//! estimator's behavior — windowed robust fit, EWMA tracking, step-drift
//! fast path — carries over unchanged, so scripted link throttles are
//! detected exactly like scripted device throttles.

use crate::runtime::CostModel;

use super::estimator::{DeviceEstimator, EstimatorConfig, Observation};

/// The current calibrated estimate for one inter-server link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkEstimate {
    /// Effective slowdown multiplier vs the configured link (always > 0;
    /// 1.0 = nominal, 2.0 = half the configured speed).
    pub slowdown: f64,
    /// Estimated per-hop latency in seconds (>= 0).
    pub latency: f64,
    /// Estimated effective seconds per byte (>= 0).
    pub secs_per_byte: f64,
    /// Median relative residual of the fit window — the estimate's own
    /// quality signal (small = trustworthy).
    pub residual_rel: f64,
    /// Observations consumed so far.
    pub observations: u64,
    /// Step-drift re-estimates fired so far (a scripted throttle landing
    /// shows up here within `step_obs` syncs).
    pub drift_events: u64,
}

impl LinkEstimate {
    /// Predicted seconds for one hop moving `bytes` over this link.
    pub fn hop_secs(&self, bytes: f64) -> f64 {
        self.latency + self.secs_per_byte * bytes
    }
}

/// Online cost estimator for a single inter-server uplink.
#[derive(Clone, Debug)]
pub struct LinkEstimator {
    inner: DeviceEstimator,
    nominal_secs_per_byte: f64,
}

impl LinkEstimator {
    /// Estimator for a link with nominal per-hop `latency` (seconds) and
    /// `bytes_per_sec` bandwidth (> 0).
    pub fn new(cfg: EstimatorConfig, latency: f64, bytes_per_sec: f64) -> LinkEstimator {
        assert!(bytes_per_sec > 0.0, "link bandwidth must be positive");
        assert!(latency >= 0.0, "link latency cannot be negative");
        let secs_per_byte = 1.0 / bytes_per_sec;
        // The synthetic nominal: latency in the fixed slot, seconds-per-
        // byte in the per-nnz slot, nothing per sample. The remaining
        // fields are irrelevant to the fit but kept sane.
        let nominal = CostModel {
            t_fixed: latency.max(1e-12),
            t_per_nnz: secs_per_byte,
            t_per_sample: 0.0,
            ..CostModel::default()
        };
        LinkEstimator {
            inner: DeviceEstimator::new(cfg, nominal),
            nominal_secs_per_byte: secs_per_byte,
        }
    }

    /// Feed one measured hop: `bytes` moved in `secs` seconds. Returns
    /// `true` when the step-drift detector fired (the link's behavior just
    /// step-changed — consumers may want to re-plan the sync cadence
    /// immediately).
    pub fn observe(&mut self, bytes: f64, secs: f64) -> bool {
        self.inner.observe(Observation {
            bucket: 0,
            nnz_per_batch: bytes,
            secs_per_batch: secs,
            ratio: 1.0,
        })
    }

    /// The current estimate (None until the first observation).
    pub fn estimate(&self) -> Option<LinkEstimate> {
        let e = self.inner.estimate()?;
        Some(LinkEstimate {
            slowdown: e.speed,
            latency: e.t_fixed,
            secs_per_byte: e.slope * self.nominal_secs_per_byte,
            residual_rel: e.residual_rel,
            observations: e.observations,
            drift_events: e.drift_events,
        })
    }

    /// The link slowdown the cadence controller reads: the estimate's
    /// multiplier when one exists, 1.0 (nominal) before any observation.
    pub fn slowdown(&self) -> f64 {
        self.estimate().map(|e| e.slowdown).unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> LinkEstimator {
        // 1 ms latency, 1 GB/s.
        LinkEstimator::new(EstimatorConfig::default(), 1e-3, 1e9)
    }

    fn hop_secs(bytes: f64, factor: f64) -> f64 {
        factor * (1e-3 + bytes / 1e9)
    }

    #[test]
    fn recovers_a_nominal_link() {
        let mut e = est();
        for i in 0..8 {
            let bytes = 1e6 + 2e5 * i as f64; // spread, so the fit separates terms
            e.observe(bytes, hop_secs(bytes, 1.0));
        }
        let got = e.estimate().unwrap();
        assert!((got.slowdown - 1.0).abs() < 0.05, "slowdown {}", got.slowdown);
        assert!((got.hop_secs(2e6) - hop_secs(2e6, 1.0)).abs() / hop_secs(2e6, 1.0) < 0.05);
    }

    #[test]
    fn detects_a_throttled_link() {
        let mut e = est();
        for i in 0..8 {
            let bytes = 1e6 + 2e5 * i as f64;
            e.observe(bytes, hop_secs(bytes, 1.0));
        }
        // The link degrades to a third of its speed: the step detector
        // must fire within `step_obs` hops and the slowdown re-seed fast.
        let mut fired = false;
        for i in 0..6 {
            let bytes = 1e6 + 2e5 * i as f64;
            fired |= e.observe(bytes, hop_secs(bytes, 3.0));
        }
        assert!(fired, "step detector never fired");
        let got = e.estimate().unwrap();
        assert!((got.slowdown - 3.0).abs() < 0.3, "slowdown {}", got.slowdown);
        assert!(got.drift_events >= 1);
    }

    #[test]
    fn slowdown_defaults_to_nominal() {
        assert_eq!(est().slowdown(), 1.0);
    }

    #[test]
    #[should_panic(expected = "bandwidth")]
    fn zero_bandwidth_is_rejected() {
        LinkEstimator::new(EstimatorConfig::default(), 1e-3, 0.0);
    }
}

//! Scripted drift traces — reproducible throttle/recover scenarios for
//! the calibration experiments (`[calibration] events`).
//!
//! A drift event ramps one device's *effective* speed away from its
//! configured factor at mega-batch boundaries: `"at_mb=10 device=0
//! factor=1.8 ramp=4"` means device 0's drift multiplier moves linearly
//! from its previous value to 1.8 over the 4 mega-batches starting at 10
//! (reaching 1.8 at mega-batch 14); `ramp=0` (the default) is a step.
//! Traces describe the *physical* scenario — they apply whether or not
//! `[calibration] enabled` closes the scheduling loop, which is exactly
//! what lets `experiment calibration` compare static and calibrated
//! scheduling under identical hardware behavior.
//!
//! # Invariants
//!
//! * [`multiplier_at`] is a pure function of (trace, device, mega-batch):
//!   no state, no clocks — drift scenarios are bit-reproducible.
//! * Multipliers are validated positive; an absent trace yields 1.0
//!   everywhere (no drift).

use anyhow::bail;

use crate::Result;

/// One scripted drift ramp, parsed from
/// `"at_mb=N device=D factor=F [ramp=R]"`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftEvent {
    /// Mega-batch (window) at which the ramp starts.
    pub at_mb: usize,
    /// Roster device id the ramp applies to.
    pub device: usize,
    /// Target drift multiplier on the device's configured speed factor
    /// (> 0; 1.0 = back to nominal, 2.0 = half speed).
    pub factor: f64,
    /// Mega-batches the linear ramp takes to reach `factor` (0 = step).
    pub ramp: usize,
}

impl DriftEvent {
    /// Parse one event string. Every token is `key=value`; `at_mb`,
    /// `device`, and `factor` are required, `ramp` defaults to 0 (and is
    /// the one last-wins duplicate the grammar allows).
    ///
    /// Thin view over the unified scenario grammar
    /// ([`crate::scenario::parse_event`]) under the drift-family mask;
    /// the accepted language is the legacy one, unchanged.
    pub fn parse(s: &str) -> Result<DriftEvent> {
        match crate::scenario::parse_event(s, crate::scenario::Mask::DRIFT)? {
            crate::scenario::ScenarioEvent::Drift(ev) => Ok(ev),
            other => bail!("event '{s}' parsed as a non-drift event ({other:?})"),
        }
    }
}

/// Parse a whole drift trace, sorted by `at_mb` (stable for ties).
/// Errors name the offending array index and full line.
pub fn parse_trace(events: &[String]) -> Result<Vec<DriftEvent>> {
    let mut trace =
        crate::scenario::parse_trace_indexed("events", events, DriftEvent::parse)?;
    trace.sort_by_key(|e| e.at_mb);
    Ok(trace)
}

/// The drift multiplier in effect for `device` at mega-batch `mb`: 1.0
/// before any of the device's events, then each ramp interpolates
/// linearly from the value it started at to its `factor`. Events chain —
/// a recover ramp starts from wherever the throttle left the device, and
/// an event landing mid-ramp freezes the old ramp at its value at the
/// new event's start (so every segment is monotone toward its target,
/// even when ramps overlap).
pub fn multiplier_at(trace: &[DriftEvent], device: usize, mb: usize) -> f64 {
    // (active event, the multiplier it started ramping from).
    let mut active: Option<(&DriftEvent, f64)> = None;
    for e in trace.iter().filter(|e| e.device == device) {
        if mb < e.at_mb {
            break;
        }
        let start = match active {
            Some((prev, prev_start)) => ramp_value(prev, prev_start, e.at_mb),
            None => 1.0,
        };
        active = Some((e, start));
    }
    match active {
        Some((e, start)) => ramp_value(e, start, mb),
        None => 1.0,
    }
}

/// Value of one ramp at `mb` (>= its `at_mb`), starting from `start`.
fn ramp_value(e: &DriftEvent, start: f64, mb: usize) -> f64 {
    if e.ramp == 0 || mb >= e.at_mb + e.ramp {
        e.factor
    } else {
        start + (e.factor - start) * ((mb - e.at_mb) as f64 / e.ramp as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let e = DriftEvent::parse("at_mb=10 device=0 factor=1.8 ramp=4").unwrap();
        assert_eq!(e, DriftEvent { at_mb: 10, device: 0, factor: 1.8, ramp: 4 });
        let e = DriftEvent::parse("factor=2 device=3 at_mb=5").unwrap();
        assert_eq!(e.ramp, 0, "ramp defaults to a step");
        assert!(DriftEvent::parse("at_mb=1 device=0").is_err(), "missing factor");
        assert!(DriftEvent::parse("at_mb=1 factor=2").is_err(), "missing device");
        assert!(DriftEvent::parse("device=0 factor=2").is_err(), "missing at_mb");
        assert!(DriftEvent::parse("at_mb=1 device=0 factor=0").is_err(), "factor must be > 0");
        assert!(DriftEvent::parse("at_mb=1 device=0 factor=2 explode=1").is_err());
        assert!(DriftEvent::parse("at_mb=x device=0 factor=2").is_err());
    }

    #[test]
    fn step_events_switch_at_the_boundary() {
        let trace = parse_trace(&[
            "at_mb=5 device=0 factor=2.0".to_string(),
            "at_mb=9 device=0 factor=1.0".to_string(),
        ])
        .unwrap();
        assert_eq!(multiplier_at(&trace, 0, 0), 1.0);
        assert_eq!(multiplier_at(&trace, 0, 4), 1.0);
        assert_eq!(multiplier_at(&trace, 0, 5), 2.0);
        assert_eq!(multiplier_at(&trace, 0, 8), 2.0);
        assert_eq!(multiplier_at(&trace, 0, 9), 1.0, "recover steps back");
        assert_eq!(multiplier_at(&trace, 1, 7), 1.0, "other devices untouched");
    }

    #[test]
    fn ramps_interpolate_linearly_and_chain() {
        let trace = parse_trace(&["at_mb=4 device=2 factor=2.0 ramp=4".to_string()]).unwrap();
        assert_eq!(multiplier_at(&trace, 2, 4), 1.0, "ramp starts from the old value");
        assert!((multiplier_at(&trace, 2, 6) - 1.5).abs() < 1e-12, "halfway");
        assert_eq!(multiplier_at(&trace, 2, 8), 2.0, "ramp completes at at_mb + ramp");
        assert_eq!(multiplier_at(&trace, 2, 99), 2.0, "holds after completion");

        // A recover ramp starting mid-throttle chains from the current value.
        let trace = parse_trace(&[
            "at_mb=0 device=0 factor=3.0".to_string(),
            "at_mb=10 device=0 factor=1.0 ramp=2".to_string(),
        ])
        .unwrap();
        assert_eq!(multiplier_at(&trace, 0, 9), 3.0);
        assert!((multiplier_at(&trace, 0, 11) - 2.0).abs() < 1e-12);
        assert_eq!(multiplier_at(&trace, 0, 12), 1.0);
    }

    #[test]
    fn overlapping_ramps_stay_monotone_toward_the_new_target() {
        // A recovery ramp interrupting a throttle ramp freezes the old
        // ramp at its current value and descends from there — the
        // multiplier must never rise during a recovery.
        let trace = parse_trace(&[
            "at_mb=0 device=0 factor=3.0 ramp=10".to_string(),
            "at_mb=5 device=0 factor=1.0 ramp=10".to_string(),
        ])
        .unwrap();
        // At mb 5 the throttle ramp sits at 1 + (3-1)*0.5 = 2.0.
        assert!((multiplier_at(&trace, 0, 5) - 2.0).abs() < 1e-12);
        let mut prev = multiplier_at(&trace, 0, 5);
        for mb in 6..=15 {
            let v = multiplier_at(&trace, 0, mb);
            assert!(v <= prev + 1e-12, "recovery rose at mb {mb}: {prev} -> {v}");
            prev = v;
        }
        assert_eq!(multiplier_at(&trace, 0, 15), 1.0, "recovery completes at at_mb + ramp");
    }

    #[test]
    fn trace_sorts_by_mega_batch() {
        let trace = parse_trace(&[
            "at_mb=9 device=0 factor=1.0".to_string(),
            "at_mb=2 device=0 factor=2.0".to_string(),
        ])
        .unwrap();
        assert_eq!(trace[0].at_mb, 2);
        assert_eq!(multiplier_at(&trace, 0, 5), 2.0);
        assert_eq!(multiplier_at(&trace, 0, 9), 1.0);
        assert!(parse_trace(&["garbage".to_string()]).is_err());
    }
}

//! SLIDE CPU baseline (paper §5.1, Fig. 8) — "smart algorithms over
//! hardware acceleration".
//!
//! SLIDE trains the same sparse MLP on CPU only, replacing the dense output
//! layer with LSH-sampled *active classes*: per sample, only the classes
//! whose weight vectors hash near the hidden activation (plus the true
//! labels and a few random negatives) participate in the softmax and the
//! backward pass. Updates are Hogwild-style asynchronous across threads.
//!
//! Our implementation:
//! * [`lsh`] — SimHash tables over the output-layer weight columns.
//! * [`network`] — active-set forward/backward on an atomic parameter store
//!   (relaxed-ordering `AtomicU32` bit-cast floats: true lock-free hogwild
//!   without UB; lost updates are part of the algorithm's contract).
//! * [`SlideTrainer`] — multi-threaded driver with periodic table rebuilds.

pub mod kernel;
pub mod lsh;
pub mod network;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::ModelDims;
use crate::data::SparseDataset;
use crate::model::ModelState;
use crate::util::rng::Rng;
use crate::Result;

pub use kernel::SparseStepper;
pub use network::SlideModel;

/// Runtime knobs of the multi-threaded Hogwild trainer. Built from the
/// unified `[slide]` config block via [`SlideTrainerConfig::from_section`]
/// so the Fig. 8 baseline and the adaptive-sparsity compute path cannot
/// drift apart.
#[derive(Clone, Debug)]
pub struct SlideTrainerConfig {
    pub threads: usize,
    pub lr: f32,
    /// LSH tables and bits per table.
    pub tables: usize,
    pub bits: usize,
    /// Random negative classes added to every active set.
    pub random_negatives: usize,
    /// Rebuild the LSH tables every this many updates (per trainer).
    pub rebuild_every: u64,
    pub seed: u64,
}

impl Default for SlideTrainerConfig {
    fn default() -> Self {
        SlideTrainerConfig {
            threads: 4,
            lr: 0.05,
            tables: 8,
            bits: 9,
            random_negatives: 16,
            rebuild_every: 2_000,
            seed: 33,
        }
    }
}

impl SlideTrainerConfig {
    /// Resolve the `[slide]` config block into trainer knobs. `lr = 0`
    /// in the section means "derive from the SGD plane" — the historical
    /// Fig. 8 choice of `lr_bmax / 4`.
    pub fn from_section(sec: &crate::config::SlideConfig, lr_bmax: f32) -> SlideTrainerConfig {
        SlideTrainerConfig {
            threads: sec.threads,
            lr: if sec.lr > 0.0 { sec.lr as f32 } else { lr_bmax / 4.0 },
            tables: sec.tables,
            bits: sec.bits,
            random_negatives: sec.random_negatives,
            rebuild_every: sec.rebuild_every,
            seed: sec.seed,
        }
    }
}

/// Multi-threaded SLIDE trainer over a shared atomic model.
pub struct SlideTrainer {
    pub cfg: SlideTrainerConfig,
    pub model: Arc<SlideModel>,
    dims: ModelDims,
    updates: Arc<AtomicU64>,
}

impl SlideTrainer {
    pub fn new(dims: &ModelDims, init: &ModelState, cfg: SlideTrainerConfig) -> Self {
        SlideTrainer {
            model: Arc::new(SlideModel::from_state(init)),
            dims: dims.clone(),
            updates: Arc::new(AtomicU64::new(0)),
            cfg,
        }
    }

    /// Train for (roughly) `wall_budget` seconds or `max_samples`, whichever
    /// comes first. Returns (samples processed, updates, elapsed seconds).
    pub fn train(
        &self,
        data: &SparseDataset,
        wall_budget: f64,
        max_samples: u64,
    ) -> Result<(u64, u64, f64)> {
        let stop = Arc::new(AtomicBool::new(false));
        let processed = Arc::new(AtomicU64::new(0));
        let t0 = std::time::Instant::now();

        // Initial LSH tables over the output layer.
        let tables = Arc::new(std::sync::RwLock::new(lsh::LshTables::build(
            &*self.model,
            self.cfg.tables,
            self.cfg.bits,
            self.cfg.seed,
        )));

        std::thread::scope(|scope| {
            for t in 0..self.cfg.threads {
                let model = self.model.clone();
                let stop = stop.clone();
                let processed = processed.clone();
                let updates = self.updates.clone();
                let tables = tables.clone();
                let cfg = self.cfg.clone();
                let dims = self.dims.clone();
                scope.spawn(move || {
                    let mut rng = Rng::new(cfg.seed ^ (t as u64 + 1).wrapping_mul(0x9E37));
                    let mut since_rebuild = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let i = rng.range(0, data.len());
                        let sample = data.sample(i);
                        {
                            let guard = tables.read().unwrap();
                            network::train_sample(&model, &dims, &sample, &guard, &cfg, &mut rng);
                        }
                        let n = processed.fetch_add(1, Ordering::Relaxed) + 1;
                        updates.fetch_add(1, Ordering::Relaxed);
                        since_rebuild += 1;
                        if n >= max_samples || t0.elapsed().as_secs_f64() >= wall_budget {
                            stop.store(true, Ordering::Relaxed);
                        }
                        // Thread 0 owns table rebuilds (as in SLIDE's
                        // periodic re-hashing).
                        if t == 0 && since_rebuild >= cfg.rebuild_every {
                            since_rebuild = 0;
                            let rebuilt = lsh::LshTables::build(
                                &*model,
                                cfg.tables,
                                cfg.bits,
                                cfg.seed ^ n,
                            );
                            *tables.write().unwrap() = rebuilt;
                        }
                    }
                });
            }
        });

        Ok((
            processed.load(Ordering::Relaxed),
            self.updates.load(Ordering::Relaxed),
            t0.elapsed().as_secs_f64(),
        ))
    }

    /// Snapshot the atomic model into a plain `ModelState` for evaluation.
    pub fn snapshot(&self) -> ModelState {
        self.model.to_state(&self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;
    use crate::data::batcher::EvalBatches;
    use crate::data::synthetic::Generator;
    use crate::model::reference;

    #[test]
    fn slide_improves_p_at_1() {
        let dims = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        let dcfg = DataConfig { train_samples: 2000, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
        let gen = Generator::new(&dims, &dcfg);
        let train = gen.generate(2000, 1);
        let test = gen.generate(300, 2);
        let init = ModelState::init(&dims, 5);
        let trainer = SlideTrainer::new(
            &dims,
            &init,
            SlideTrainerConfig { threads: 2, lr: 0.25, ..Default::default() },
        );

        let eval = EvalBatches::new(&test, &dims, 64);
        let p1 = |m: &ModelState| {
            let mut hit = 0;
            let mut tot = 0;
            for b in &eval.batches {
                let preds = reference::eval_ref(m, b);
                for (r, &id) in b.sample_ids.iter().enumerate() {
                    tot += 1;
                    if test.sample(id as usize).labels.contains(&(preds[r] as u32)) {
                        hit += 1;
                    }
                }
            }
            hit as f64 / tot as f64
        };

        let before = p1(&trainer.snapshot());
        let (samples, updates, _) = trainer.train(&train, 20.0, 12_000).unwrap();
        assert!(samples >= 12_000 || updates > 0);
        let after = p1(&trainer.snapshot());
        assert!(after > before + 0.05, "SLIDE failed to learn: {before} -> {after}");
    }

    #[test]
    fn respects_sample_cap() {
        let dims = ModelDims { features: 64, hidden: 8, classes: 16, max_nnz: 6, max_labels: 2 };
        let dcfg = DataConfig { train_samples: 200, avg_nnz: 4.0, ..Default::default() };
        let train = Generator::new(&dims, &dcfg).generate(200, 1);
        let init = ModelState::init(&dims, 1);
        let trainer =
            SlideTrainer::new(&dims, &init, SlideTrainerConfig { threads: 3, ..Default::default() });
        let (samples, _, _) = trainer.train(&train, 30.0, 500).unwrap();
        // Threads may overshoot by at most ~threads samples.
        assert!(samples >= 500 && samples < 600, "samples={samples}");
    }
}

//! The adaptive-sparsity compute lever: batch-level LSH active-class
//! stepping on a plain [`ModelState`].
//!
//! [`SparseStepper`] wraps the reusable active-set kernels from
//! `model::reference` with everything a scheduled compute path needs:
//! per-device LSH tables rebuilt on a staleness budget, active-set
//! selection (labels ∪ LSH candidates ∪ random negatives) sized toward a
//! target **sparsity ratio**, and an approximate inference mode for the
//! serving plane. The ratio is the schedulable knob: `scaling.rs` lowers
//! it on slow or throttled devices so their per-step cost shrinks roughly
//! in proportion to the output-layer work skipped, instead of only
//! shrinking their batches.
//!
//! # Invariants
//!
//! * `ratio >= 1.0` delegates to the dense `sgd_step_scratch` /
//!   `eval_scratch` paths — bit-identical to `sgd_step_ref`, no RNG
//!   advance, no table builds. A stepper pinned at 1.0 is free.
//! * Every label with nonzero weight in the batch is in the active set.
//! * Staleness bound: the tables used by a sparse step were rebuilt at
//!   most `rebuild_every` sparse steps ago (`steps_since_rebuild()` never
//!   exceeds `rebuild_every` when a step runs).

use crate::config::SlideConfig;
use crate::data::PaddedBatch;
use crate::model::reference::{self, StepScratch};
use crate::model::ModelState;
use crate::util::rng::Rng;

use super::lsh::LshTables;

/// Per-device driver of the active-class kernels. Owns the LSH tables and
/// the selection buffers; callers own the model and the [`StepScratch`].
pub struct SparseStepper {
    /// Fraction of output classes participating (1.0 = exact dense path).
    ratio: f64,
    n_tables: usize,
    bits: usize,
    random_negatives: usize,
    rebuild_every: u64,
    seed: u64,
    tables: Option<LshTables>,
    steps_since_rebuild: u64,
    rebuilds: u64,
    rng: Rng,
    /// Selection state, reused across steps.
    active: Vec<u32>,
    candidates: Vec<u32>,
    mark: Vec<bool>,
}

impl SparseStepper {
    /// Build from the `[slide]` config block. `salt` decorrelates the
    /// random-negative streams of different devices sharing one config.
    pub fn new(sec: &SlideConfig, salt: u64) -> SparseStepper {
        SparseStepper {
            ratio: 1.0,
            n_tables: sec.tables,
            bits: sec.bits,
            random_negatives: sec.random_negatives,
            rebuild_every: sec.rebuild_every.max(1),
            seed: sec.seed ^ salt.wrapping_mul(0x9E37_79B9),
            tables: None,
            steps_since_rebuild: 0,
            rebuilds: 0,
            rng: Rng::new(sec.seed ^ salt.wrapping_mul(0x85EB_CA6B) ^ 0x5DE3),
            active: Vec::new(),
            candidates: Vec::new(),
            mark: Vec::new(),
        }
    }

    /// Current sparsity ratio.
    pub fn ratio(&self) -> f64 {
        self.ratio
    }

    /// Set the sparsity ratio (clamped to `[0.01, 1.0]`). Takes effect on
    /// the next step; existing tables are kept (they do not depend on the
    /// ratio).
    pub fn set_ratio(&mut self, ratio: f64) {
        self.ratio = ratio.clamp(0.01, 1.0);
    }

    /// Total LSH table rebuilds so far.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Sparse steps taken since the last rebuild.
    pub fn steps_since_rebuild(&self) -> u64 {
        self.steps_since_rebuild
    }

    /// The active set used by the most recent sparse step (sorted class
    /// ids; empty if the stepper has only run dense so far).
    pub fn active(&self) -> &[u32] {
        &self.active
    }

    fn maybe_rebuild(&mut self, m: &ModelState) {
        if self.tables.is_none() || self.steps_since_rebuild >= self.rebuild_every {
            let seed = self.seed ^ self.rebuilds.wrapping_mul(0xC2B2_AE35);
            self.tables = Some(LshTables::build(m, self.n_tables, self.bits, seed));
            self.steps_since_rebuild = 0;
            self.rebuilds += 1;
        }
    }

    /// Number of classes a ratio targets (at least 1).
    fn target(&self, classes: usize) -> usize {
        ((self.ratio * classes as f64).ceil() as usize).clamp(1, classes)
    }

    /// Query the tables with every valid row's hidden activation and merge
    /// the hits into `active` (stops once `goal` classes are collected).
    fn collect_lsh_hits(
        &mut self,
        batch: &PaddedBatch,
        scratch: &StepScratch,
        h_dim: usize,
        goal: usize,
    ) {
        self.candidates.clear();
        if let Some(t) = &self.tables {
            for r in 0..batch.bucket {
                if batch.smask[r] != 0.0 {
                    t.query_into(scratch.hidden_row(r, h_dim), &mut self.candidates);
                }
            }
        }
        for i in 0..self.candidates.len() {
            if self.active.len() >= goal {
                break;
            }
            let cand = self.candidates[i] as usize;
            if !self.mark[cand] {
                self.mark[cand] = true;
                self.active.push(cand as u32);
            }
        }
    }

    /// Training selection: labels ∪ LSH candidates ∪ random negatives,
    /// sized toward `ratio * classes` (labels always kept; at least
    /// `random_negatives` non-label classes so a lone label never gets
    /// softmax probability 1 and a zero gradient).
    fn select_train(&mut self, batch: &PaddedBatch, scratch: &StepScratch, h_dim: usize, c: usize, l: usize) {
        self.mark.clear();
        self.mark.resize(c, false);
        self.active.clear();
        for r in 0..batch.bucket {
            if batch.smask[r] == 0.0 {
                continue;
            }
            for j in 0..l {
                if batch.lab_w[r * l + j] != 0.0 {
                    let lab = batch.lab[r * l + j] as usize;
                    if !self.mark[lab] {
                        self.mark[lab] = true;
                        self.active.push(lab as u32);
                    }
                }
            }
        }
        let n_labels = self.active.len();
        let goal = self.target(c).max(n_labels + self.random_negatives).min(c);
        self.collect_lsh_hits(batch, scratch, h_dim, goal);
        let mut attempts = 0usize;
        while self.active.len() < goal && attempts < 16 * goal {
            let cand = self.rng.range(0, c);
            attempts += 1;
            if !self.mark[cand] {
                self.mark[cand] = true;
                self.active.push(cand as u32);
            }
        }
        // Rejection sampling can stall when goal ≈ classes; finish by scan.
        if self.active.len() < goal {
            for cand in 0..c {
                if self.active.len() >= goal {
                    break;
                }
                if !self.mark[cand] {
                    self.mark[cand] = true;
                    self.active.push(cand as u32);
                }
            }
        }
        self.active.sort_unstable();
    }

    /// Serving selection: no labels, no randomness — LSH candidates plus a
    /// deterministic evenly-spaced fill so repeated identical requests get
    /// identical predictions.
    fn select_eval(&mut self, batch: &PaddedBatch, scratch: &StepScratch, h_dim: usize, c: usize) {
        self.mark.clear();
        self.mark.resize(c, false);
        self.active.clear();
        let goal = self.target(c);
        self.collect_lsh_hits(batch, scratch, h_dim, goal);
        if self.active.len() < goal {
            let stride = (c / goal).max(1);
            for cand in (0..c).step_by(stride) {
                if self.active.len() >= goal {
                    break;
                }
                if !self.mark[cand] {
                    self.mark[cand] = true;
                    self.active.push(cand as u32);
                }
            }
        }
        if self.active.len() < goal {
            for cand in 0..c {
                if self.active.len() >= goal {
                    break;
                }
                if !self.mark[cand] {
                    self.mark[cand] = true;
                    self.active.push(cand as u32);
                }
            }
        }
        self.active.sort_unstable();
    }

    /// One SGD step at the current ratio. Returns `(loss, active classes)`
    /// — the dense path reports every class active.
    pub fn step(
        &mut self,
        m: &mut ModelState,
        batch: &PaddedBatch,
        lr: f32,
        scratch: &mut StepScratch,
    ) -> (f32, usize) {
        let c = m.dims.classes;
        if self.ratio >= 1.0 {
            return (reference::sgd_step_scratch(m, batch, lr, scratch), c);
        }
        self.maybe_rebuild(m);
        reference::forward_hidden(m, batch, scratch);
        let (h_dim, l) = (m.dims.hidden, m.dims.max_labels);
        self.select_train(batch, scratch, h_dim, c, l);
        let loss = reference::sgd_step_active_prepared(m, batch, lr, &self.active, scratch);
        self.steps_since_rebuild += 1;
        (loss, self.active.len())
    }

    /// Forward-only top-1 at the current ratio: exact dense argmax at 1.0,
    /// an argmax restricted to the LSH-selected active set otherwise.
    pub fn eval(
        &mut self,
        m: &ModelState,
        batch: &PaddedBatch,
        scratch: &mut StepScratch,
    ) -> Vec<i32> {
        if self.ratio >= 1.0 {
            return reference::eval_scratch(m, batch, scratch);
        }
        self.maybe_rebuild(m);
        reference::forward_hidden(m, batch, scratch);
        let (h_dim, c) = (m.dims.hidden, m.dims.classes);
        self.select_eval(batch, scratch, h_dim, c);
        self.steps_since_rebuild += 1;
        let mut preds = vec![0i32; batch.bucket];
        for (r, pred) in preds.iter_mut().enumerate() {
            let hrow = scratch.hidden_row(r, h_dim);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (j, &cls) in self.active.iter().enumerate() {
                let cls = cls as usize;
                let mut acc = m.b2[cls];
                for (hi, &hv) in hrow.iter().enumerate() {
                    if hv != 0.0 {
                        acc += hv * m.w2[hi * c + cls];
                    }
                }
                if acc > best_v {
                    best_v = acc;
                    best = j;
                }
            }
            *pred = self.active[best] as i32;
        }
        preds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::batcher::Batcher;
    use crate::data::synthetic::Generator;
    use crate::model::reference::sgd_step_ref;

    fn setup() -> (ModelDims, crate::data::SparseDataset) {
        let dims = ModelDims { features: 128, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        let cfg = DataConfig { train_samples: 400, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(400, 1);
        (dims, ds)
    }

    fn section() -> SlideConfig {
        SlideConfig::default()
    }

    #[test]
    fn ratio_one_is_bit_identical_to_dense_and_builds_nothing() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 2);
        let mut dense = ModelState::init(&dims, 7);
        let mut stepped = dense.clone();
        let mut stepper = SparseStepper::new(&section(), 0);
        let mut scratch = StepScratch::new();
        for _ in 0..5 {
            let b = batcher.next_batch(16, 16);
            let ld = sgd_step_ref(&mut dense, &b, 0.05);
            let (ls, act) = stepper.step(&mut stepped, &b, 0.05, &mut scratch);
            assert_eq!(ld.to_bits(), ls.to_bits());
            assert_eq!(act, dims.classes);
        }
        assert_eq!(dense, stepped, "ratio=1.0 must be the dense path exactly");
        assert_eq!(stepper.rebuilds(), 0, "the dense path must not build tables");
    }

    #[test]
    fn sparse_steps_hit_the_target_size_and_keep_labels() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 3);
        let mut m = ModelState::init(&dims, 8);
        let mut stepper = SparseStepper::new(&section(), 1);
        stepper.set_ratio(0.5);
        let mut scratch = StepScratch::new();
        for _ in 0..10 {
            let b = batcher.next_batch(8, 8);
            let (_, act) = stepper.step(&mut m, &b, 0.05, &mut scratch);
            assert!(act < dims.classes, "active set must actually be sparse");
            assert!(act >= (0.5 * dims.classes as f64) as usize);
            for r in 0..b.bucket {
                for j in 0..dims.max_labels {
                    if b.lab_w[r * dims.max_labels + j] != 0.0 {
                        let lab = b.lab[r * dims.max_labels + j];
                        assert!(
                            stepper.active().binary_search(&lab).is_ok(),
                            "label {lab} missing from the active set"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn rebuild_staleness_is_bounded() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 5);
        let mut m = ModelState::init(&dims, 9);
        let mut sec = section();
        sec.rebuild_every = 8;
        let mut stepper = SparseStepper::new(&sec, 2);
        stepper.set_ratio(0.25);
        let mut scratch = StepScratch::new();
        let n = 30u64;
        for _ in 0..n {
            let b = batcher.next_batch(8, 8);
            stepper.step(&mut m, &b, 0.05, &mut scratch);
            assert!(
                stepper.steps_since_rebuild() <= sec.rebuild_every,
                "staleness bound violated: {} > {}",
                stepper.steps_since_rebuild(),
                sec.rebuild_every
            );
        }
        // First step builds; thereafter one rebuild per rebuild_every steps.
        assert_eq!(stepper.rebuilds(), 1 + (n - 1) / sec.rebuild_every);
        assert_eq!(stepper.steps_since_rebuild(), (n - 1) % sec.rebuild_every + 1);
    }

    #[test]
    fn training_at_low_ratio_still_learns() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 11);
        let mut m = ModelState::init(&dims, 13);
        let mut sec = section();
        sec.rebuild_every = 50;
        let mut stepper = SparseStepper::new(&sec, 3);
        stepper.set_ratio(0.25);
        let mut scratch = StepScratch::new();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..120 {
            let b = batcher.next_batch(32, 32);
            let (loss, _) = stepper.step(&mut m, &b, 0.1, &mut scratch);
            last = loss;
            first.get_or_insert(loss);
        }
        assert!(last < first.unwrap(), "sparse loss {} -> {last}", first.unwrap());
    }

    #[test]
    fn eval_is_deterministic_and_restricted() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 17);
        let b = batcher.next_batch(16, 16);
        let m = ModelState::init(&dims, 19);
        let mut scratch = StepScratch::new();
        let mut s1 = SparseStepper::new(&section(), 4);
        s1.set_ratio(0.2);
        let p1 = s1.eval(&m, &b, &mut scratch);
        let mut s2 = SparseStepper::new(&section(), 4);
        s2.set_ratio(0.2);
        let p2 = s2.eval(&m, &b, &mut scratch);
        assert_eq!(p1, p2, "approximate eval must be deterministic");
        assert!(p1.iter().all(|&p| s1.active().binary_search(&(p as u32)).is_ok()));
        // Exact mode matches the reference.
        let mut sx = SparseStepper::new(&section(), 5);
        assert_eq!(sx.eval(&m, &b, &mut scratch), reference::eval_ref(&m, &b));
    }
}

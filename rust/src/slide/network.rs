//! SLIDE's active-set sparse MLP on an atomic parameter store.
//!
//! Parameters live in `AtomicU32` arrays (f32 bit-cast, relaxed ordering):
//! genuine lock-free Hogwild without undefined behaviour. Reads may observe
//! torn *sets* of parameters (not torn words) and updates may be lost under
//! contention — both are inherent to Hogwild-style SGD and harmless at our
//! learning rates.
//!
//! Per training sample:
//! 1. sparse input layer + ReLU (exact, every hidden unit),
//! 2. active-set selection: true labels ∪ LSH candidates ∪ random negatives,
//! 3. softmax restricted to the active set, cross-entropy on labels,
//! 4. backprop through active classes only; sparse W1 scatter update.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::config::ModelDims;
use crate::data::sparse::SampleView;
use crate::model::ModelState;
use crate::util::rng::Rng;

use super::lsh::LshTables;
use super::SlideTrainerConfig;

const ORD: Ordering = Ordering::Relaxed;

/// Atomic twin of `ModelState` (same layouts).
pub struct SlideModel {
    pub hidden: usize,
    pub classes: usize,
    pub features: usize,
    w1: Vec<AtomicU32>,
    b1: Vec<AtomicU32>,
    w2: Vec<AtomicU32>,
    b2: Vec<AtomicU32>,
}

fn to_atomic(xs: &[f32]) -> Vec<AtomicU32> {
    xs.iter().map(|&x| AtomicU32::new(x.to_bits())).collect()
}

impl SlideModel {
    pub fn from_state(m: &ModelState) -> SlideModel {
        SlideModel {
            hidden: m.dims.hidden,
            classes: m.dims.classes,
            features: m.dims.features,
            w1: to_atomic(&m.w1),
            b1: to_atomic(&m.b1),
            w2: to_atomic(&m.w2),
            b2: to_atomic(&m.b2),
        }
    }

    pub fn to_state(&self, dims: &ModelDims) -> ModelState {
        let read = |v: &Vec<AtomicU32>| -> Vec<f32> {
            v.iter().map(|a| f32::from_bits(a.load(ORD))).collect()
        };
        ModelState {
            dims: dims.clone(),
            w1: read(&self.w1),
            b1: read(&self.b1),
            w2: read(&self.w2),
            b2: read(&self.b2),
        }
    }

    #[inline]
    fn load(v: &[AtomicU32], i: usize) -> f32 {
        f32::from_bits(v[i].load(ORD))
    }

    #[inline]
    fn add(v: &[AtomicU32], i: usize, delta: f32) {
        // Racy read-modify-write: classic Hogwild (lost updates allowed).
        let cur = f32::from_bits(v[i].load(ORD));
        v[i].store((cur + delta).to_bits(), ORD);
    }

    /// Copy W2[:, class] into `out` (LSH rebuilds).
    pub fn read_w2_column(&self, class: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.hidden);
        for (i, o) in out.iter_mut().enumerate() {
            *o = Self::load(&self.w2, i * self.classes + class);
        }
    }
}

/// One SLIDE SGD update from one sample. Returns the sample loss over its
/// active set.
pub fn train_sample(
    model: &SlideModel,
    dims: &ModelDims,
    sample: &SampleView<'_>,
    tables: &LshTables,
    cfg: &SlideTrainerConfig,
    rng: &mut Rng,
) -> f32 {
    let h_dim = dims.hidden;
    let c_dim = dims.classes;

    // ---- hidden layer (exact) ---------------------------------------------
    let mut a = vec![0.0f32; h_dim];
    for i in 0..h_dim {
        a[i] = SlideModel::load(&model.b1, i);
    }
    for (&fi, &fv) in sample.indices.iter().zip(sample.values) {
        let base = fi as usize * h_dim;
        for i in 0..h_dim {
            a[i] += fv * SlideModel::load(&model.w1, base + i);
        }
    }
    let h: Vec<f32> = a.iter().map(|&x| x.max(0.0)).collect();

    // ---- active set ---------------------------------------------------------
    let mut active: Vec<u32> = sample.labels.to_vec();
    tables.query_into(&h, &mut active);
    for _ in 0..cfg.random_negatives {
        active.push(rng.range(0, c_dim) as u32);
    }
    active.sort_unstable();
    active.dedup();

    // ---- softmax over the active set ---------------------------------------
    let mut logits = vec![0.0f32; active.len()];
    for (j, &c) in active.iter().enumerate() {
        let c = c as usize;
        let mut acc = SlideModel::load(&model.b2, c);
        for i in 0..h_dim {
            if h[i] != 0.0 {
                acc += h[i] * SlideModel::load(&model.w2, i * c_dim + c);
            }
        }
        logits[j] = acc;
    }
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for l in &logits {
        sum += (l - mx).exp();
    }
    let lse = mx + sum.ln();

    let label_w = 1.0 / sample.labels.len() as f32;
    let mut loss = lse;
    for (j, &c) in active.iter().enumerate() {
        if sample.labels.contains(&c) {
            loss -= label_w * logits[j];
        }
    }

    // ---- backward over active classes ---------------------------------------
    let lr = cfg.lr;
    let mut dh = vec![0.0f32; h_dim];
    for (j, &c) in active.iter().enumerate() {
        let c = c as usize;
        let mut dl = (logits[j] - lse).exp(); // softmax prob within active set
        if sample.labels.contains(&(c as u32)) {
            dl -= label_w;
        }
        // Accumulate dh before mutating w2 (consistent within this thread).
        for i in 0..h_dim {
            if h[i] != 0.0 {
                dh[i] += dl * SlideModel::load(&model.w2, i * c_dim + c);
                SlideModel::add(&model.w2, i * c_dim + c, -lr * dl * h[i]);
            }
        }
        SlideModel::add(&model.b2, c, -lr * dl);
    }

    // ReLU gate + input layer scatter.
    for i in 0..h_dim {
        if a[i] <= 0.0 {
            dh[i] = 0.0;
        }
    }
    for i in 0..h_dim {
        if dh[i] != 0.0 {
            SlideModel::add(&model.b1, i, -lr * dh[i]);
        }
    }
    for (&fi, &fv) in sample.indices.iter().zip(sample.values) {
        let base = fi as usize * h_dim;
        for i in 0..h_dim {
            if dh[i] != 0.0 {
                SlideModel::add(&model.w1, base + i, -lr * fv * dh[i]);
            }
        }
    }
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;
    use crate::data::synthetic::Generator;

    #[test]
    fn atomic_round_trip_preserves_state() {
        let dims = ModelDims { features: 32, hidden: 8, classes: 16, max_nnz: 4, max_labels: 2 };
        let m = ModelState::init(&dims, 7);
        let atomic = SlideModel::from_state(&m);
        let back = atomic.to_state(&dims);
        assert_eq!(m, back);
    }

    #[test]
    fn single_thread_training_reduces_loss() {
        let dims = ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 4 };
        let dcfg = DataConfig { train_samples: 400, avg_nnz: 5.0, ..Default::default() };
        let ds = Generator::new(&dims, &dcfg).generate(400, 1);
        let model = SlideModel::from_state(&ModelState::init(&dims, 3));
        let cfg = SlideTrainerConfig { lr: 0.2, ..Default::default() };
        let tables = LshTables::build(&model, cfg.tables, cfg.bits, 1);
        let mut rng = Rng::new(9);
        let mut first_window = 0.0;
        let mut last_window = 0.0;
        let n = 2000;
        for step in 0..n {
            let s = ds.sample(rng.range(0, ds.len()));
            let loss = train_sample(&model, &dims, &s, &tables, &cfg, &mut rng);
            if step < 200 {
                first_window += loss;
            }
            if step >= n - 200 {
                last_window += loss;
            }
        }
        assert!(
            last_window < first_window,
            "active-set loss should fall: {first_window} -> {last_window}"
        );
    }

    #[test]
    fn active_set_always_contains_labels() {
        // Implicit in train_sample construction; verify the selection logic
        // via a direct probe of the same code path.
        let dims = ModelDims { features: 16, hidden: 4, classes: 8, max_nnz: 2, max_labels: 2 };
        let model = SlideModel::from_state(&ModelState::init(&dims, 1));
        // Enough random negatives that the active set is never just the
        // label itself (a lone label gets softmax prob 1 ⇒ zero gradient).
        let cfg = SlideTrainerConfig { random_negatives: 8, ..Default::default() };
        let tables = LshTables::build(&model, 2, 3, 2);
        let mut rng = Rng::new(5);
        let indices = [1u32, 3];
        let values = [1.0f32, -0.5];
        let labels = [6u32];
        let s = SampleView { indices: &indices, values: &values, labels: &labels };
        // Loss must be finite and positive — and if labels were excluded
        // from the active set the positive term would be missing, making
        // loss == lse of negatives only; train_sample would still return a
        // value, so instead check the update moved the label's bias up.
        let b6_before = f32::from_bits(model.b2[6].load(std::sync::atomic::Ordering::Relaxed));
        train_sample(&model, &dims, &s, &tables, &cfg, &mut rng);
        let b6_after = f32::from_bits(model.b2[6].load(std::sync::atomic::Ordering::Relaxed));
        assert!(b6_after > b6_before, "label bias should increase");
    }
}

//! SimHash LSH tables over the output-layer weight columns.
//!
//! Table `t` hashes a vector `v ∈ R^H` to the sign pattern of `bits` random
//! projections. Classes whose weight column hashes to the same bucket as the
//! hidden activation are retrieved as active-set candidates — SLIDE's core
//! trick for sampling the softmax.

use crate::model::ModelState;
use crate::slide::network::SlideModel;
use crate::util::rng::Rng;

use std::collections::HashMap;

/// Anything exposing output-layer weight columns — the atomic Hogwild store
/// and the plain coordinator `ModelState` both qualify, so one table
/// implementation serves the standalone baseline and the adaptive-sparsity
/// compute path.
pub trait W2Columns {
    fn hidden_dim(&self) -> usize;
    fn class_count(&self) -> usize;
    /// Copy W2[:, class] into `out` (`out.len() == hidden_dim()`).
    fn read_w2_column(&self, class: usize, out: &mut [f32]);
}

impl W2Columns for SlideModel {
    fn hidden_dim(&self) -> usize {
        self.hidden
    }
    fn class_count(&self) -> usize {
        self.classes
    }
    fn read_w2_column(&self, class: usize, out: &mut [f32]) {
        SlideModel::read_w2_column(self, class, out)
    }
}

impl W2Columns for ModelState {
    fn hidden_dim(&self) -> usize {
        self.dims.hidden
    }
    fn class_count(&self) -> usize {
        self.dims.classes
    }
    fn read_w2_column(&self, class: usize, out: &mut [f32]) {
        let c = self.dims.classes;
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.w2[i * c + class];
        }
    }
}

pub struct LshTables {
    /// `projections[t]` holds `bits` random H-dim hyperplanes.
    projections: Vec<Vec<Vec<f32>>>,
    /// `buckets[t][hash] -> class ids`.
    buckets: Vec<HashMap<u32, Vec<u32>>>,
    pub bits: usize,
}

impl LshTables {
    /// Hash every class's output-weight column into every table.
    pub fn build<M: W2Columns + ?Sized>(
        model: &M,
        tables: usize,
        bits: usize,
        seed: u64,
    ) -> LshTables {
        assert!(bits <= 31);
        let h = model.hidden_dim();
        let c = model.class_count();
        let mut rng = Rng::new(seed);
        let projections: Vec<Vec<Vec<f32>>> = (0..tables)
            .map(|_| {
                (0..bits)
                    .map(|_| (0..h).map(|_| rng.normal() as f32).collect())
                    .collect()
            })
            .collect();
        let mut buckets: Vec<HashMap<u32, Vec<u32>>> = vec![HashMap::new(); tables];
        let mut col = vec![0.0f32; h];
        for class in 0..c {
            model.read_w2_column(class, &mut col);
            for (t, proj) in projections.iter().enumerate() {
                let key = simhash(proj, &col);
                buckets[t].entry(key).or_default().push(class as u32);
            }
        }
        LshTables { projections, buckets, bits }
    }

    /// Candidate classes whose columns collide with `v` in any table.
    pub fn query_into(&self, v: &[f32], out: &mut Vec<u32>) {
        for (t, proj) in self.projections.iter().enumerate() {
            let key = simhash(proj, v);
            if let Some(ids) = self.buckets[t].get(&key) {
                out.extend_from_slice(ids);
            }
        }
    }

    pub fn tables(&self) -> usize {
        self.projections.len()
    }
}

fn simhash(projections: &[Vec<f32>], v: &[f32]) -> u32 {
    let mut key = 0u32;
    for (b, plane) in projections.iter().enumerate() {
        let dot: f32 = plane.iter().zip(v).map(|(&p, &x)| p * x).sum();
        if dot >= 0.0 {
            key |= 1 << b;
        }
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;
    use crate::model::ModelState;

    fn model_with_columns(cols: &[Vec<f32>]) -> (ModelDims, SlideModel) {
        let h = cols[0].len();
        let c = cols.len();
        let dims = ModelDims { features: 4, hidden: h, classes: c, max_nnz: 2, max_labels: 2 };
        let mut state = ModelState::zeros(&dims);
        for (class, col) in cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                state.w2[i * c + class] = v;
            }
        }
        (dims, SlideModel::from_state(&state))
    }

    #[test]
    fn identical_vector_always_collides() {
        let col = vec![0.3, -0.7, 0.2, 0.9];
        let (_, model) = model_with_columns(&[col.clone(), vec![0.0, 0.0, 0.0, 0.1]]);
        let tables = LshTables::build(&model, 6, 8, 1);
        let mut out = Vec::new();
        tables.query_into(&col, &mut out);
        // Class 0's column == query, so it must appear in every table.
        let count0 = out.iter().filter(|&&c| c == 0).count();
        assert_eq!(count0, 6);
    }

    #[test]
    fn similar_vectors_collide_more_than_dissimilar() {
        let mut rng = Rng::new(2);
        let base: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let similar: Vec<f32> = base.iter().map(|&x| x + 0.05 * rng.normal() as f32).collect();
        let opposite: Vec<f32> = base.iter().map(|&x| -x).collect();
        let (_, model) = model_with_columns(&[similar, opposite]);
        let tables = LshTables::build(&model, 12, 6, 3);
        let mut out = Vec::new();
        tables.query_into(&base, &mut out);
        let sim_hits = out.iter().filter(|&&c| c == 0).count();
        let opp_hits = out.iter().filter(|&&c| c == 1).count();
        assert!(sim_hits > opp_hits, "sim={sim_hits} opp={opp_hits}");
    }

    #[test]
    fn bucket_partition_covers_all_classes() {
        let mut rng = Rng::new(4);
        let cols: Vec<Vec<f32>> =
            (0..40).map(|_| (0..8).map(|_| rng.normal() as f32).collect()).collect();
        let (_, model) = model_with_columns(&cols);
        let tables = LshTables::build(&model, 3, 5, 5);
        for t in 0..3 {
            let total: usize = tables.buckets[t].values().map(|v| v.len()).sum();
            assert_eq!(total, 40, "table {t} lost classes");
        }
    }
}

//! Decision audit: read scheduler instants back as structured decision
//! records and reconstruct *why* each action was taken.
//!
//! Every adaptive move in the tree already announces itself as an
//! instant event — pool churn, drift re-targets, Algorithm-1 rescales,
//! serve-mode flips, lease grants/preempts, cadence changes,
//! demote/promote, rack churn. The emitters attach their inputs
//! (calibrated speeds, old/new grids, p95 vs SLO, fair-share targets),
//! so [`explain`] can render a one-line "why" per decision without the
//! RunLog, and [`explain_query`] filters the audit log by substring —
//! the `report --explain` CLI path.

use super::{Ev, EvKind};
use crate::obs::chrome::process_label;

/// Instant names that are decisions (as opposed to samples like
/// `train.eval` or markers like `serve.churn`'s request-drop cousins).
const DECISION_NAMES: &[&str] = &[
    "cluster.cadence",
    "cluster.demote",
    "cluster.promote",
    "cluster.rack_down",
    "cluster.rack_up",
    "fleet.lease",
    "serve.churn",
    "serve.mode",
    "train.pool",
    "train.retarget",
    "train.scale",
];

/// One scheduler decision, lifted from its instant event.
#[derive(Clone, Debug)]
pub struct DecisionRecord {
    /// Virtual time of the decision (seconds).
    pub at: f64,
    /// Process lane it applies to.
    pub pid: u32,
    /// Thread lane it was stamped on.
    pub tid: u32,
    /// Decision kind — the instant's event name.
    pub kind: String,
    /// The inputs and chosen action, as emitted.
    pub args: Vec<(String, super::AVal)>,
}

impl DecisionRecord {
    fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_str())
    }

    fn arg_num(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_num())
    }
}

/// Extract the decision records from an event stream, in `(at, pid,
/// kind)` order.
pub fn decisions(events: &[Ev]) -> Vec<DecisionRecord> {
    let mut out: Vec<DecisionRecord> = events
        .iter()
        .filter(|e| e.kind == EvKind::Instant && DECISION_NAMES.contains(&e.name.as_str()))
        .map(|e| DecisionRecord {
            at: e.ts,
            pid: e.pid,
            tid: e.tid,
            kind: e.name.clone(),
            args: e.args.clone(),
        })
        .collect();
    out.sort_by(|a, b| {
        a.at.total_cmp(&b.at).then(a.pid.cmp(&b.pid)).then(a.kind.cmp(&b.kind))
    });
    out
}

/// One-line reconstruction of why the decision was taken, from the
/// inputs its emitter attached. Falls back to the raw args when a
/// record predates the structured emitters.
pub fn explain(d: &DecisionRecord) -> String {
    // An explicit "why" from the emitter wins outright.
    if let Some(why) = d.arg_str("why") {
        return why.to_string();
    }
    let reason = d.arg_str("reason").unwrap_or("");
    match d.kind.as_str() {
        "train.retarget" => {
            let (from, to) = (d.arg_str("from").unwrap_or("?"), d.arg_str("to").unwrap_or("?"));
            format!("{reason}: re-seeded batch grid {from} -> {to}")
        }
        "train.scale" => format!(
            "Algorithm 1 rescaled the grid {} -> {} at mb {}",
            d.arg_str("from").unwrap_or("?"),
            d.arg_str("to").unwrap_or("?"),
            d.arg_num("mb").map_or("?".to_string(), |x| format!("{}", x as u64)),
        ),
        "train.pool" | "serve.churn" => format!(
            "device {} {}: {reason}",
            d.arg_num("device").map_or("?".to_string(), |x| format!("{}", x as i64)),
            d.arg_str("action").unwrap_or("?"),
        ),
        "serve.mode" => format!(
            "flipped to {} inference: windowed p95 {:.4}s vs SLO {:.4}s (ratio {:.2})",
            d.arg_str("action").unwrap_or("?"),
            d.arg_num("p95_s").unwrap_or(f64::NAN),
            d.arg_num("slo_s").unwrap_or(f64::NAN),
            d.arg_num("ratio").unwrap_or(f64::NAN),
        ),
        "fleet.lease" => format!(
            "tenant {} {} device {} (fair-share target {}): {reason}",
            d.arg_num("tenant").map_or("?".to_string(), |x| format!("{}", x as u64)),
            d.arg_str("action").unwrap_or("?"),
            d.arg_num("device").map_or("?".to_string(), |x| format!("{}", x as i64)),
            d.arg_num("target").map_or("?".to_string(), |x| format!("{}", x as u64)),
        ),
        "cluster.cadence" => format!(
            "sync cadence {} -> {}: sync cost {:.4}s vs {:.4}s/mb compute, comm target {:.2} \
             (bottleneck x{:.2})",
            d.arg_num("from").map_or("?".to_string(), |x| format!("{}", x as u64)),
            d.arg_num("to").map_or("?".to_string(), |x| format!("{}", x as u64)),
            d.arg_num("sync_secs").unwrap_or(f64::NAN),
            d.arg_num("per_mb").unwrap_or(f64::NAN),
            d.arg_num("comm_target").unwrap_or(f64::NAN),
            d.arg_num("bottleneck").unwrap_or(f64::NAN),
        ),
        "cluster.demote" => format!(
            "{} demoted to async catch-up: measured {:.3} mb/s under floor {:.3}",
            process_label(d.pid),
            d.arg_num("rate").unwrap_or(f64::NAN),
            d.arg_num("floor").unwrap_or(f64::NAN),
        ),
        "cluster.promote" => format!(
            "{} rejoins the barrier: measured {:.3} mb/s over floor {:.3}",
            process_label(d.pid),
            d.arg_num("rate").unwrap_or(f64::NAN),
            d.arg_num("floor").unwrap_or(f64::NAN),
        ),
        "cluster.rack_down" | "cluster.rack_up" => {
            let dir = if d.kind.ends_with("down") { "lost" } else { "recovered" };
            format!("{} {dir} at mega-batch {}", process_label(d.pid), {
                d.arg_num("mega_batch")
                    .or_else(|| d.arg_num("mb"))
                    .map_or("?".to_string(), |x| format!("{}", x as u64))
            })
        }
        _ => {
            let args: Vec<String> =
                d.args.iter().map(|(k, v)| format!("{k}={}", v.display())).collect();
            args.join(" ")
        }
    }
}

/// Filter the audit log: records whose kind or explanation contains
/// `pattern` (case-insensitive), rendered one per line as
/// `t=<at> <server>: <kind>: <why>`. Empty pattern matches everything.
pub fn explain_query(records: &[DecisionRecord], pattern: &str) -> Vec<String> {
    let needle = pattern.to_lowercase();
    records
        .iter()
        .filter_map(|d| {
            let why = explain(d);
            let hay = format!("{} {}", d.kind, why).to_lowercase();
            hay.contains(&needle).then(|| {
                format!("t={:.6} {}: {}: {}", d.at, process_label(d.pid), d.kind, why)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::analyze::AVal;

    fn rec(kind: &str, args: Vec<(&str, AVal)>) -> DecisionRecord {
        DecisionRecord {
            at: 1.5,
            pid: 0,
            tid: 0,
            kind: kind.to_string(),
            args: args.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        }
    }

    #[test]
    fn why_arg_wins_outright() {
        let d = rec(
            "train.retarget",
            vec![
                ("reason", AVal::Str("step-drift".into())),
                ("why", AVal::Str("device 2: b 128 -> 72".into())),
            ],
        );
        assert_eq!(explain(&d), "device 2: b 128 -> 72");
    }

    #[test]
    fn kind_specific_explanations() {
        let lease = rec(
            "fleet.lease",
            vec![
                ("tenant", AVal::Num(1.0)),
                ("device", AVal::Num(3.0)),
                ("target", AVal::Num(2.0)),
                ("action", AVal::Str("preempt".into())),
                ("reason", AVal::Str("p95 12.00ms > SLO 8.00ms for 3 windows".into())),
            ],
        );
        assert_eq!(
            explain(&lease),
            "tenant 1 preempt device 3 (fair-share target 2): p95 12.00ms > SLO 8.00ms for 3 \
             windows"
        );
        let mode = rec(
            "serve.mode",
            vec![
                ("action", AVal::Str("approx".into())),
                ("p95_s", AVal::Num(0.0095)),
                ("slo_s", AVal::Num(0.01)),
                ("ratio", AVal::Num(0.25)),
            ],
        );
        assert_eq!(
            explain(&mode),
            "flipped to approx inference: windowed p95 0.0095s vs SLO 0.0100s (ratio 0.25)"
        );
        let unknown = rec("train.pool", vec![]);
        assert_eq!(explain(&unknown), "device ? ?: ");
    }

    #[test]
    fn decisions_filter_and_sort() {
        use crate::obs::analyze::{Ev, EvKind};
        let instant = |name: &str, pid: u32, ts: f64| Ev {
            name: name.to_string(),
            cat: String::new(),
            pid,
            tid: 0,
            ts,
            dur: 0.0,
            kind: EvKind::Instant,
            args: Vec::new(),
        };
        let span = Ev { kind: EvKind::Span, dur: 1.0, ..instant("train.megabatch", 0, 0.0) };
        let events = vec![
            instant("train.eval", 0, 0.5), // sample, not a decision
            instant("train.scale", 1, 2.0),
            instant("train.pool", 0, 2.0),
            span,
        ];
        let recs = decisions(&events);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].kind, "train.pool", "(at, pid) order");
        assert_eq!(recs[1].kind, "train.scale");
    }

    #[test]
    fn explain_query_filters_case_insensitively() {
        let recs = vec![
            rec("serve.mode", vec![("action", AVal::Str("approx".into()))]),
            rec("cluster.demote", vec![("rate", AVal::Num(0.5)), ("floor", AVal::Num(0.8))]),
        ];
        let hits = explain_query(&recs, "DEMOTE");
        assert_eq!(hits.len(), 1);
        assert!(hits[0].starts_with("t=1.500000 server0: cluster.demote:"), "{}", hits[0]);
        assert_eq!(explain_query(&recs, "").len(), 2, "empty pattern matches all");
        assert!(explain_query(&recs, "zzz").is_empty());
    }
}

//! Post-hoc trace analysis: turn the PR 8 telemetry (span stream +
//! registry snapshot) into answers.
//!
//! Three analyses, surfaced through the `report` CLI subcommand:
//!
//! 1. **Per-lane time attribution** ([`attribution`]): each lane's run
//!    window decomposed into compute / merge-wait (barrier stall) /
//!    cluster-sync / serve / idle. The categories are carved out of one
//!    shared free-interval list, so by construction they *partition* the
//!    lane's window — the invariant the property tests pin.
//! 2. **Critical-path extraction** ([`critical`]): per mega-batch, the
//!    device lane whose last `engine.step` determined barrier time,
//!    aggregated into a top-K "who gated the run" table — the paper's
//!    straggler story, quantified from the trace alone.
//! 3. **Decision audit** ([`decision`]): scheduler instants (dispatch
//!    pool churn, batch/sparsity re-targets, cadence changes, lease
//!    preemptions, serve-mode flips) read back as structured decision
//!    records, with an `explain` query reconstructing *why* each action
//!    was taken from the inputs the emitters now attach.
//!
//! The engine consumes either a live [`ObsHandle`] (the `--trace` path)
//! or an exported Chrome-trace JSON file ([`TraceData::parse_chrome`]),
//! so `report` works post-hoc on any trace a previous run wrote. All
//! outputs are deterministic: events are re-sorted on stable keys and
//! every float renders with a fixed format.

pub mod attribution;
pub mod critical;
pub mod decision;
pub mod report;

pub use attribution::{attribute, LaneAttribution};
pub use critical::{critical_path, top_gaters, CritSegment, GateRow};
pub use decision::{decisions, explain, explain_query, DecisionRecord};
pub use report::{diff, render_diff, DiffThresholds, Regression, Report};

use crate::obs::sink::{ArgVal, EventKind, TraceEvent};
use crate::obs::ObsHandle;
use crate::util::json::Json;
use anyhow::bail;

/// Analysis-side argument value: an owned mirror of
/// [`ArgVal`] with all numeric variants collapsed to `f64` (Chrome-trace
/// JSON cannot distinguish them anyway).
#[derive(Clone, Debug, PartialEq)]
pub enum AVal {
    /// Any numeric argument (`U`/`I`/`F` on the emit side).
    Num(f64),
    /// Boolean argument.
    Bool(bool),
    /// String argument.
    Str(String),
}

impl AVal {
    /// Numeric value, if this argument is one.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            AVal::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value, if this argument is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            AVal::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Render for report tables: whole numbers without a fraction,
    /// everything else with six decimals (matches the report's fixed
    /// float format).
    pub fn display(&self) -> String {
        match self {
            AVal::Num(x) if x.fract() == 0.0 && x.abs() < 1e15 => format!("{}", *x as i64),
            AVal::Num(x) => format!("{x:.6}"),
            AVal::Bool(b) => b.to_string(),
            AVal::Str(s) => s.clone(),
        }
    }
}

fn aval_of(v: &ArgVal) -> AVal {
    match v {
        ArgVal::U(n) => AVal::Num(*n as f64),
        ArgVal::I(n) => AVal::Num(*n as f64),
        ArgVal::F(x) => AVal::Num(*x),
        ArgVal::B(b) => AVal::Bool(*b),
        ArgVal::S(s) => AVal::Str(s.clone()),
    }
}

/// Whether an event is a span or an instant (analysis-side mirror of
/// [`EventKind`], decoupled so parsed traces and live sinks share one
/// type).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvKind {
    /// Complete event (`ph: "X"`): has a duration.
    Span,
    /// Instant event (`ph: "i"`): a point in time.
    Instant,
}

/// One analysis-side event: owned strings so it can come from a live
/// sink or a parsed trace file alike. Times are in seconds.
#[derive(Clone, Debug)]
pub struct Ev {
    /// Event name (`train.megabatch`, `engine.step`, ...).
    pub name: String,
    /// Subsystem category (`train`, `engine`, `serve`, ...).
    pub cat: String,
    /// Process lane (server / tenant).
    pub pid: u32,
    /// Thread lane (0 = coordinator, `1 + d` = GPU d, `101 + d` = serve
    /// replica).
    pub tid: u32,
    /// Start time, seconds.
    pub ts: f64,
    /// Duration, seconds (0 for instants).
    pub dur: f64,
    /// Span or instant.
    pub kind: EvKind,
    /// Arguments, in emit order.
    pub args: Vec<(String, AVal)>,
}

impl Ev {
    /// End time (`ts + dur`).
    pub fn end(&self) -> f64 {
        self.ts + self.dur
    }

    /// Look up an argument by key.
    pub fn arg(&self, key: &str) -> Option<&AVal> {
        self.args.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Numeric argument by key.
    pub fn arg_num(&self, key: &str) -> Option<f64> {
        self.arg(key).and_then(|v| v.as_num())
    }

    /// String argument by key.
    pub fn arg_str(&self, key: &str) -> Option<&str> {
        self.arg(key).and_then(|v| v.as_str())
    }
}

fn ev_of(e: &TraceEvent) -> Ev {
    Ev {
        name: e.name.to_string(),
        cat: e.subsystem.name().to_string(),
        pid: e.pid,
        tid: e.tid,
        ts: e.ts,
        dur: e.dur,
        kind: match e.kind {
            EventKind::Span => EvKind::Span,
            EventKind::Instant => EvKind::Instant,
        },
        args: e.args.iter().map(|(k, v)| (k.to_string(), aval_of(v))).collect(),
    }
}

/// A full analysis input: the event stream plus the truncation and
/// registry context the analyses need to stay honest about what they
/// saw.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    /// Where this trace came from (file path or "live sink") — shown in
    /// report headers.
    pub label: String,
    /// Events sorted by `(ts, pid, tid, name)` for deterministic
    /// analysis regardless of emit interleaving.
    pub events: Vec<Ev>,
    /// Ring-buffer evictions: > 0 means the analyses below run over a
    /// truncated window.
    pub dropped: u64,
    /// `(opened, closed)` span balance, when known (live sinks only —
    /// exported traces don't carry it).
    pub balance: Option<(u64, u64)>,
    /// Registry counters/gauges at capture time, name-ordered.
    pub counters: Vec<(String, f64)>,
}

fn sort_events(events: &mut [Ev]) {
    events.sort_by(|a, b| {
        a.ts.total_cmp(&b.ts)
            .then(a.pid.cmp(&b.pid))
            .then(a.tid.cmp(&b.tid))
            .then(a.name.cmp(&b.name))
    });
}

impl TraceData {
    /// Capture a live handle: sink events + drop tally + span balance +
    /// counter/gauge registry rows (histogram expansions are series, not
    /// point samples — they stay in the RunLog metrics section).
    pub fn from_handle(label: &str, obs: &ObsHandle) -> TraceData {
        let mut events: Vec<Ev> = obs.sink().events().iter().map(ev_of).collect();
        sort_events(&mut events);
        let counters = obs
            .registry()
            .snapshot()
            .into_iter()
            .filter(|r| r.kind == "counter" || r.kind == "gauge")
            .map(|r| (r.name, r.value))
            .collect();
        TraceData {
            label: label.to_string(),
            events,
            dropped: obs.sink().dropped(),
            balance: Some(obs.sink().balance()),
            counters,
        }
    }

    /// Parse an exported Chrome-trace file (the output of `--trace` /
    /// [`crate::obs::chrome::render_events`]). `X` rows become spans,
    /// `i` rows instants, `C` rows counter samples (last sample per name
    /// wins), `M` metadata is skipped. Times convert back from
    /// microseconds to seconds.
    pub fn parse_chrome(label: &str, root: &Json) -> crate::Result<TraceData> {
        let rows = match root.get("traceEvents").as_arr() {
            Some(a) => a,
            None => bail!("trace missing top-level \"traceEvents\" array"),
        };
        let mut events = Vec::new();
        let mut counters: Vec<(String, f64)> = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            let ph = match row.get("ph").as_str() {
                Some(p) => p,
                None => bail!("event {i}: missing \"ph\""),
            };
            let name = row.get("name").as_str().unwrap_or("").to_string();
            let pid = row.get("pid").as_f64().unwrap_or(0.0) as u32;
            let tid = row.get("tid").as_f64().unwrap_or(0.0) as u32;
            let ts = row.get("ts").as_f64().unwrap_or(0.0) / 1e6;
            match ph {
                "X" | "i" => {
                    let mut args = Vec::new();
                    if let Some(obj) = row.get("args").as_obj() {
                        for (k, v) in obj {
                            let val = if let Some(x) = v.as_f64() {
                                AVal::Num(x)
                            } else if let Some(b) = v.as_bool() {
                                AVal::Bool(b)
                            } else if let Some(s) = v.as_str() {
                                AVal::Str(s.to_string())
                            } else {
                                continue;
                            };
                            args.push((k.clone(), val));
                        }
                    }
                    events.push(Ev {
                        name,
                        cat: row.get("cat").as_str().unwrap_or("").to_string(),
                        pid,
                        tid,
                        ts,
                        dur: if ph == "X" {
                            row.get("dur").as_f64().unwrap_or(0.0) / 1e6
                        } else {
                            0.0
                        },
                        kind: if ph == "X" { EvKind::Span } else { EvKind::Instant },
                        args,
                    });
                }
                "C" => {
                    let value = row
                        .get("args")
                        .as_obj()
                        .and_then(|o| o.values().find_map(|v| v.as_f64()))
                        .unwrap_or(0.0);
                    match counters.iter_mut().find(|(n, _)| *n == name) {
                        Some(slot) => slot.1 = value,
                        None => counters.push((name, value)),
                    }
                }
                "M" => {}
                other => bail!("event {i}: unsupported phase {other:?}"),
            }
        }
        sort_events(&mut events);
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(TraceData {
            label: label.to_string(),
            events,
            dropped: root.get("droppedEvents").as_f64().unwrap_or(0.0) as u64,
            balance: None,
            counters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::{Level, Subsystem, TraceSink};
    use crate::obs::Registry;
    use std::sync::Arc;

    fn handle_with_events() -> ObsHandle {
        let cfg = crate::config::ObsConfig { enabled: true, ..Default::default() };
        let h = ObsHandle::from_config(&cfg, false);
        h.sink().span_at(
            Subsystem::Engine,
            Level::Info,
            "engine.step",
            0,
            1,
            1.0,
            0.5,
            vec![("batch", ArgVal::U(64)), ("why", ArgVal::S("x".into()))],
        );
        h.sink().instant_at(
            Subsystem::Train,
            Level::Info,
            "train.retarget",
            0,
            0,
            1.5,
            vec![("reason", ArgVal::S("step-drift".into()))],
        );
        h.counter("train.updates").add(7);
        h
    }

    #[test]
    fn from_handle_captures_events_balance_and_counters() {
        let h = handle_with_events();
        let td = TraceData::from_handle("live", &h);
        assert_eq!(td.events.len(), 2);
        assert_eq!(td.events[0].name, "engine.step");
        assert_eq!(td.events[0].kind, EvKind::Span);
        assert_eq!(td.events[0].arg_num("batch"), Some(64.0));
        assert_eq!(td.events[1].kind, EvKind::Instant);
        assert_eq!(td.balance, Some((1, 1)));
        assert_eq!(td.counters, vec![("train.updates".to_string(), 7.0)]);
    }

    #[test]
    fn parse_chrome_round_trips_a_rendered_sink() {
        let h = handle_with_events();
        let counters = vec![("train.updates".to_string(), 7.0)];
        let text = crate::obs::chrome::render_events_with_counters(
            &h.sink().events(),
            h.sink().dropped(),
            &counters,
        );
        let root = Json::parse(&text).unwrap();
        let td = TraceData::parse_chrome("file", &root).unwrap();
        assert_eq!(td.events.len(), 2);
        let step = &td.events[0];
        assert_eq!(step.name, "engine.step");
        assert_eq!(step.cat, "engine");
        assert!((step.ts - 1.0).abs() < 1e-9, "µs→s round trip: {}", step.ts);
        assert!((step.dur - 0.5).abs() < 1e-9);
        assert_eq!(step.arg_str("why"), Some("x"));
        assert_eq!(td.counters, counters);
        assert_eq!(td.dropped, 0);
        assert_eq!(td.balance, None, "exported traces don't carry balance");
    }

    #[test]
    fn parse_chrome_rejects_garbage() {
        assert!(TraceData::parse_chrome("f", &Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(r#"{"traceEvents":[{"ph":"q"}]}"#).unwrap();
        assert!(TraceData::parse_chrome("f", &bad).is_err());
    }

    #[test]
    fn parse_chrome_reads_dropped_events() {
        let root =
            Json::parse(r#"{"traceEvents":[],"droppedEvents":12}"#).unwrap();
        let td = TraceData::parse_chrome("f", &root).unwrap();
        assert_eq!(td.dropped, 12);
    }

    #[test]
    fn events_sort_on_stable_keys() {
        let s = TraceSink::new(true, u16::MAX, Level::Info, 64);
        s.instant_at(Subsystem::Train, Level::Info, "b", 1, 0, 1.0, Vec::new());
        s.instant_at(Subsystem::Train, Level::Info, "a", 0, 0, 1.0, Vec::new());
        let obs = ObsHandle::from_parts_for_tests(Arc::new(s), Arc::new(Registry::new()));
        let td = TraceData::from_handle("live", &obs);
        assert_eq!(td.events[0].pid, 0, "ties on ts sort by pid");
        assert_eq!(td.events[1].pid, 1);
    }
}

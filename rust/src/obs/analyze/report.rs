//! Deterministic run reports and the `report --diff` regression gate.
//!
//! A [`Report`] condenses one run's trace (or RunLog JSON) into the
//! headline numbers the paper argues about — makespan, update balance,
//! serve p99 — plus the three analyses (attribution, critical path,
//! decision audit). [`Report::to_markdown`] renders it with fixed float
//! formats over pre-sorted data, so virtual-mode reports are
//! bit-deterministic; [`diff`] compares two reports against fixed
//! thresholds and returns the regressions, the CLI's non-zero-exit CI
//! gate.

use super::attribution::{attribute, LaneAttribution};
use super::critical::{critical_path, top_gaters, CritSegment};
use super::decision::{decisions, explain, DecisionRecord};
use super::TraceData;
use crate::obs::chrome::{process_label, SERVE_TID_BASE};
use crate::util::json::Json;
use anyhow::Context;
use std::collections::BTreeMap;

/// Decisions shown inline in the markdown audit table; the rest is
/// summarized (use `--explain` to filter the full log).
const MAX_DECISION_ROWS: usize = 40;

/// One run, analyzed.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Where the data came from (file path or "live sink").
    pub label: String,
    /// Events analyzed (0 for RunLog-sourced reports).
    pub events: usize,
    /// Ring evictions at capture time.
    pub dropped: u64,
    /// `(opened, closed)` span balance, when known.
    pub balance: Option<(u64, u64)>,
    /// `max end − min ts` over the trace (or the last row's clock).
    pub makespan: f64,
    /// Per-lane attribution, `(pid, tid)`-sorted.
    pub lanes: Vec<LaneAttribution>,
    /// Per-mega-batch critical-path segments.
    pub crit: Vec<CritSegment>,
    /// Decision audit log, time-ordered.
    pub decisions: Vec<DecisionRecord>,
    /// Registry counters/gauges at capture time, name-ordered.
    pub counters: Vec<(String, f64)>,
    /// `max/min` update count across device lanes that stepped.
    pub update_balance: Option<f64>,
    /// p99 request latency over `serve.batch` spans (queueing included).
    pub p99: Option<f64>,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 * p).ceil() as usize).max(1) - 1;
    sorted[idx.min(sorted.len() - 1)]
}

impl Report {
    /// Analyze a trace (live or parsed).
    pub fn from_trace(td: &TraceData) -> Report {
        let (mut t0, mut t1) = (f64::INFINITY, f64::NEG_INFINITY);
        let mut updates: BTreeMap<(u32, u32), u64> = BTreeMap::new();
        let mut latencies: Vec<f64> = Vec::new();
        for e in &td.events {
            t0 = t0.min(e.ts);
            t1 = t1.max(e.end());
            if e.name.starts_with("engine.")
                && e.tid >= 1
                && e.tid < SERVE_TID_BASE
                && e.kind == super::EvKind::Span
            {
                *updates.entry((e.pid, e.tid)).or_insert(0) += 1;
            }
            if e.name == "serve.batch" && e.kind == super::EvKind::Span {
                latencies.push(e.arg_num("queued_s").unwrap_or(0.0) + e.dur);
            }
        }
        let update_balance = (!updates.is_empty()).then(|| {
            let max = updates.values().copied().max().unwrap_or(1).max(1) as f64;
            let min = updates.values().copied().min().unwrap_or(1).max(1) as f64;
            max / min
        });
        latencies.sort_by(|a, b| a.total_cmp(b));
        Report {
            label: td.label.clone(),
            events: td.events.len(),
            dropped: td.dropped,
            balance: td.balance,
            makespan: if t1 > t0 { t1 - t0 } else { 0.0 },
            lanes: attribute(&td.events),
            crit: critical_path(&td.events),
            decisions: decisions(&td.events),
            counters: td.counters.clone(),
            update_balance,
            p99: (!latencies.is_empty()).then(|| percentile(&latencies, 0.99)),
        }
    }

    /// Reduced report from a RunLog JSON export (no spans → no
    /// attribution or critical path, but the headline numbers and the
    /// exported metrics still diff).
    pub fn from_run_json(label: &str, root: &Json) -> crate::Result<Report> {
        let rows = root
            .get("rows")
            .as_arr()
            .with_context(|| format!("{label}: not a RunLog export (no \"rows\")"))?;
        let makespan = rows.last().map(|r| r.get("clock").as_f64().unwrap_or(0.0)).unwrap_or(0.0);
        let mut per_device: Vec<u64> = Vec::new();
        for r in rows {
            if let Some(us) = r.get("updates").as_arr() {
                per_device.resize(per_device.len().max(us.len()), 0);
                for (d, u) in us.iter().enumerate() {
                    per_device[d] += u.as_f64().unwrap_or(0.0) as u64;
                }
            }
        }
        let stepped: Vec<u64> = per_device.into_iter().filter(|&u| u > 0).collect();
        let update_balance = (!stepped.is_empty()).then(|| {
            let max = *stepped.iter().max().unwrap() as f64;
            let min = *stepped.iter().min().unwrap() as f64;
            max / min
        });
        let mut counters: Vec<(String, f64)> = Vec::new();
        let mut dropped = 0u64;
        if let Some(metrics) = root.get("metrics").as_arr() {
            for m in metrics {
                let name = m.get("name").as_str().unwrap_or("").to_string();
                let value = m.get("value").as_f64().unwrap_or(0.0);
                if name == "obs.dropped_events" {
                    dropped = value as u64;
                }
                let kind = m.get("kind").as_str().unwrap_or("");
                if kind == "counter" || kind == "gauge" {
                    counters.push((name, value));
                }
            }
        }
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(Report {
            label: label.to_string(),
            makespan,
            dropped,
            counters,
            update_balance,
            ..Report::default()
        })
    }

    /// Truncation-honesty warnings: non-empty means the analyses above
    /// ran over an incomplete window (`report --strict` fails on these).
    pub fn warnings(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.dropped > 0 {
            out.push(format!(
                "trace ring dropped {} events — this report covers a truncated window \
                 (raise [obs] buffer_events)",
                self.dropped
            ));
        }
        if let Some((opened, closed)) = self.balance {
            if opened != closed {
                out.push(format!(
                    "span imbalance: {opened} opened vs {closed} closed — a span guard \
                     never closed"
                ));
            }
        }
        out
    }

    /// Render the deterministic markdown run report. `top_k` bounds the
    /// critical-path table.
    pub fn to_markdown(&self, top_k: usize) -> String {
        let mut s = String::new();
        let pct = |part: f64, total: f64| {
            if total > 0.0 {
                format!("{:.1}%", 100.0 * part / total)
            } else {
                "-".to_string()
            }
        };
        s.push_str(&format!("# heterosparse run report — {}\n\n", self.label));
        s.push_str(&format!("- events analyzed: {} (dropped: {})\n", self.events, self.dropped));
        if let Some((opened, closed)) = self.balance {
            s.push_str(&format!("- span balance: {opened} opened / {closed} closed\n"));
        }
        s.push_str(&format!("- makespan: {:.6} s\n", self.makespan));
        if let Some(b) = self.update_balance {
            s.push_str(&format!("- update balance (max/min per device lane): {b:.3}\n"));
        }
        if let Some(p) = self.p99 {
            s.push_str(&format!("- serve p99 latency: {p:.6} s\n"));
        }
        let warnings = self.warnings();
        if !warnings.is_empty() {
            s.push_str("\n## Warnings\n\n");
            for w in &warnings {
                s.push_str(&format!("- {w}\n"));
            }
        }
        if !self.lanes.is_empty() {
            s.push_str("\n## Lane time attribution\n\n");
            s.push_str("| lane | total s | compute | serve | merge-wait | cluster-sync | idle |\n");
            s.push_str("|---|---|---|---|---|---|---|\n");
            for l in &self.lanes {
                s.push_str(&format!(
                    "| {} | {:.6} | {} | {} | {} | {} | {} |\n",
                    l.label(),
                    l.total,
                    pct(l.compute, l.total),
                    pct(l.serve, l.total),
                    pct(l.merge_wait, l.total),
                    pct(l.cluster_sync, l.total),
                    pct(l.idle, l.total),
                ));
            }
        }
        s.push_str("\n## Critical path — who gated the run\n\n");
        let top = top_gaters(&self.crit, top_k);
        if top.is_empty() {
            s.push_str("(no mega-batch windows with device steps in this trace)\n");
        } else {
            s.push_str("| lane | windows gated | gating busy s | busy share of gated time |\n");
            s.push_str("|---|---|---|---|\n");
            for g in &top {
                s.push_str(&format!(
                    "| {} | {} | {:.6} | {} |\n",
                    g.label(),
                    g.gated,
                    g.busy,
                    pct(g.share, 1.0),
                ));
            }
        }
        s.push_str("\n## Decision audit\n\n");
        if self.decisions.is_empty() {
            s.push_str("(no decision instants in this trace)\n");
        } else {
            let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
            for d in &self.decisions {
                *counts.entry(d.kind.as_str()).or_insert(0) += 1;
            }
            let summary: Vec<String> =
                counts.iter().map(|(k, n)| format!("{k}={n}")).collect();
            s.push_str(&format!(
                "{} decisions: {}\n\n",
                self.decisions.len(),
                summary.join(" ")
            ));
            s.push_str("| t (s) | lane | kind | why |\n|---|---|---|---|\n");
            for d in self.decisions.iter().take(MAX_DECISION_ROWS) {
                s.push_str(&format!(
                    "| {:.6} | {} | {} | {} |\n",
                    d.at,
                    process_label(d.pid),
                    d.kind,
                    explain(d).replace('|', "\\|"),
                ));
            }
            if self.decisions.len() > MAX_DECISION_ROWS {
                s.push_str(&format!(
                    "\n… and {} more (filter with `report --explain PATTERN`)\n",
                    self.decisions.len() - MAX_DECISION_ROWS
                ));
            }
        }
        if !self.counters.is_empty() {
            s.push_str("\n## Counters\n\n| metric | value |\n|---|---|\n");
            for (name, value) in &self.counters {
                let v = if value.fract() == 0.0 && value.abs() < 1e15 {
                    format!("{}", *value as i64)
                } else {
                    format!("{value:.6}")
                };
                s.push_str(&format!("| {name} | {v} |\n"));
            }
        }
        s
    }
}

/// Regression thresholds for [`diff`]. Percentages are relative
/// increases; `attribution_pp` is an absolute percentage-point shift per
/// lane category.
#[derive(Clone, Copy, Debug)]
pub struct DiffThresholds {
    /// Makespan may grow this % before flagging.
    pub makespan_pct: f64,
    /// Update-balance ratio may grow this %.
    pub balance_pct: f64,
    /// Serve p99 may grow this %.
    pub p99_pct: f64,
    /// A lane's compute share may drop (or its stall+idle share rise) by
    /// this many percentage points.
    pub attribution_pp: f64,
}

impl Default for DiffThresholds {
    fn default() -> Self {
        DiffThresholds { makespan_pct: 5.0, balance_pct: 5.0, p99_pct: 10.0, attribution_pp: 5.0 }
    }
}

/// One flagged regression from [`diff`].
#[derive(Clone, Debug)]
pub struct Regression {
    /// What regressed (`makespan`, `serve p99`, `server0/gpu2 compute
    /// share`, ...).
    pub metric: String,
    /// Value in the baseline report.
    pub before: f64,
    /// Value in the candidate report.
    pub after: f64,
    /// The flagged delta, in `unit`.
    pub delta: f64,
    /// `%` for relative deltas, `pp` for share shifts.
    pub unit: &'static str,
}

fn rel_pct(before: f64, after: f64) -> f64 {
    if before > 0.0 {
        100.0 * (after - before) / before
    } else {
        0.0
    }
}

/// Compare `after` against the `before` baseline: makespan, update
/// balance, p99, and per-lane attribution shifts, each against its
/// threshold. Identical reports return no regressions.
pub fn diff(before: &Report, after: &Report, th: &DiffThresholds) -> Vec<Regression> {
    let mut out = Vec::new();
    let mut rel = |metric: &str, b: f64, a: f64, limit: f64| {
        let d = rel_pct(b, a);
        if d > limit {
            out.push(Regression {
                metric: metric.to_string(),
                before: b,
                after: a,
                delta: d,
                unit: "%",
            });
        }
    };
    rel("makespan", before.makespan, after.makespan, th.makespan_pct);
    if let (Some(b), Some(a)) = (before.update_balance, after.update_balance) {
        rel("update balance", b, a, th.balance_pct);
    }
    if let (Some(b), Some(a)) = (before.p99, after.p99) {
        rel("serve p99", b, a, th.p99_pct);
    }
    // Attribution shifts: matched lanes only (churn can legitimately
    // add/remove lanes between runs).
    for la in &after.lanes {
        let Some(lb) = before.lanes.iter().find(|l| l.pid == la.pid && l.tid == la.tid) else {
            continue;
        };
        if lb.total <= 0.0 || la.total <= 0.0 {
            continue;
        }
        let share = |x: f64, l: &LaneAttribution| 100.0 * x / l.total;
        let compute_drop = share(lb.compute, lb) - share(la.compute, la);
        if compute_drop > th.attribution_pp {
            out.push(Regression {
                metric: format!("{} compute share", la.label()),
                before: share(lb.compute, lb),
                after: share(la.compute, la),
                delta: -compute_drop,
                unit: "pp",
            });
        }
        let stall_b = share(lb.merge_wait + lb.idle, lb);
        let stall_a = share(la.merge_wait + la.idle, la);
        if stall_a - stall_b > th.attribution_pp {
            out.push(Regression {
                metric: format!("{} stall+idle share", la.label()),
                before: stall_b,
                after: stall_a,
                delta: stall_a - stall_b,
                unit: "pp",
            });
        }
    }
    out
}

/// Render the diff as deterministic markdown: the headline comparison,
/// then each flagged regression.
pub fn render_diff(
    before: &Report,
    after: &Report,
    regs: &[Regression],
    th: &DiffThresholds,
) -> String {
    let mut s = String::new();
    s.push_str(&format!("# report diff — {} -> {}\n\n", before.label, after.label));
    let opt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.6}"));
    s.push_str("| metric | before | after | delta |\n|---|---|---|---|\n");
    s.push_str(&format!(
        "| makespan (s) | {:.6} | {:.6} | {:+.2}% |\n",
        before.makespan,
        after.makespan,
        rel_pct(before.makespan, after.makespan)
    ));
    s.push_str(&format!(
        "| update balance | {} | {} | |\n",
        opt(before.update_balance),
        opt(after.update_balance)
    ));
    s.push_str(&format!("| serve p99 (s) | {} | {} | |\n", opt(before.p99), opt(after.p99)));
    s.push_str(&format!(
        "| lanes compared | {} | {} | |\n",
        before.lanes.len(),
        after.lanes.len()
    ));
    s.push('\n');
    if regs.is_empty() {
        s.push_str(&format!(
            "No regressions over thresholds (makespan +{:.0}%, balance +{:.0}%, p99 \
             +{:.0}%, attribution ±{:.0}pp).\n",
            th.makespan_pct, th.balance_pct, th.p99_pct, th.attribution_pp
        ));
    } else {
        s.push_str(&format!("## {} regression(s)\n\n", regs.len()));
        for r in regs {
            s.push_str(&format!(
                "- **{}**: {:.6} -> {:.6} ({:+.2}{})\n",
                r.metric, r.before, r.after, r.delta, r.unit
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::analyze::{AVal, Ev, EvKind};

    fn span(name: &str, pid: u32, tid: u32, ts: f64, dur: f64) -> Ev {
        Ev {
            name: name.to_string(),
            cat: String::new(),
            pid,
            tid,
            ts,
            dur,
            kind: EvKind::Span,
            args: Vec::new(),
        }
    }

    fn sample_trace() -> TraceData {
        let mut serve = span("serve.batch", 0, 101, 0.0, 0.004);
        serve.args.push(("queued_s".to_string(), AVal::Num(0.001)));
        TraceData {
            label: "test".to_string(),
            events: vec![
                span("train.megabatch", 0, 0, 0.0, 4.0),
                span("engine.step", 0, 1, 0.0, 2.0),
                span("engine.step", 0, 1, 2.0, 2.0),
                span("engine.step", 0, 2, 0.0, 3.0),
                span("train.merge", 0, 0, 3.8, 0.2),
                serve,
                Ev {
                    kind: EvKind::Instant,
                    args: vec![("reason".to_string(), AVal::Str("step-drift".into()))],
                    ..span("train.retarget", 0, 0, 4.0, 0.0)
                },
            ],
            dropped: 0,
            balance: Some((6, 6)),
            counters: vec![("train.updates".to_string(), 3.0)],
        }
    }

    #[test]
    fn report_computes_headline_numbers() {
        let r = Report::from_trace(&sample_trace());
        assert_eq!(r.events, 7);
        assert!((r.makespan - 4.0).abs() < 1e-12);
        assert_eq!(r.update_balance, Some(2.0), "2 steps vs 1 step");
        assert!((r.p99.unwrap() - 0.005).abs() < 1e-12, "queued + service");
        assert_eq!(r.decisions.len(), 1);
        assert_eq!(r.lanes.len(), 4);
        assert!(r.warnings().is_empty());
    }

    #[test]
    fn markdown_is_deterministic_and_complete() {
        let a = Report::from_trace(&sample_trace()).to_markdown(8);
        let b = Report::from_trace(&sample_trace()).to_markdown(8);
        assert_eq!(a, b);
        assert!(a.contains("## Lane time attribution"));
        assert!(a.contains("## Critical path"));
        assert!(a.contains("server0/gpu0"));
        assert!(a.contains("## Decision audit"));
        assert!(a.contains("train.retarget"));
        assert!(a.contains("| train.updates | 3 |"));
        assert!(!a.contains("## Warnings"));
    }

    #[test]
    fn truncated_traces_warn() {
        let mut td = sample_trace();
        td.dropped = 9;
        td.balance = Some((6, 5));
        let r = Report::from_trace(&td);
        let w = r.warnings();
        assert_eq!(w.len(), 2);
        assert!(w[0].contains("dropped 9 events"));
        assert!(w[1].contains("6 opened vs 5 closed"));
        assert!(r.to_markdown(8).contains("## Warnings"));
    }

    #[test]
    fn self_diff_is_clean() {
        let r = Report::from_trace(&sample_trace());
        let regs = diff(&r, &r, &DiffThresholds::default());
        assert!(regs.is_empty(), "{regs:?}");
        let text = render_diff(&r, &r, &regs, &DiffThresholds::default());
        assert!(text.contains("No regressions"));
    }

    #[test]
    fn diff_flags_makespan_and_attribution_shifts() {
        let base = Report::from_trace(&sample_trace());
        let mut slow = sample_trace();
        // Stretch the mega-batch window without more compute: makespan
        // grows and gpu0's compute share collapses.
        slow.events[0].dur = 8.0;
        slow.events[4].ts = 7.8;
        let after = Report::from_trace(&slow);
        let regs = diff(&base, &after, &DiffThresholds::default());
        assert!(regs.iter().any(|r| r.metric == "makespan"), "{regs:?}");
        assert!(
            regs.iter().any(|r| r.metric.contains("compute share")),
            "{regs:?}"
        );
        let text = render_diff(&base, &after, &regs, &DiffThresholds::default());
        assert!(text.contains("regression(s)"));
    }

    #[test]
    fn run_json_reports_diff_on_headline_numbers() {
        let json = Json::parse(
            r#"{"rows":[{"clock":1.5,"updates":[4,2]},{"clock":3.0,"updates":[4,2]}],
                "metrics":[{"name":"obs.dropped_events","kind":"counter","value":0},
                           {"name":"train.updates","kind":"counter","value":12}]}"#,
        )
        .unwrap();
        let r = Report::from_run_json("run.json", &json).unwrap();
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert_eq!(r.update_balance, Some(2.0));
        assert_eq!(r.dropped, 0);
        assert_eq!(r.counters.len(), 2);
        assert!(diff(&r, &r, &DiffThresholds::default()).is_empty());
        assert!(Report::from_run_json("x", &Json::parse("{}").unwrap()).is_err());
    }
}

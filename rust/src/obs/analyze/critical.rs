//! Critical-path extraction: per mega-batch, which device lane's
//! `engine.step` chain determined barrier time.
//!
//! Each `train.megabatch` span on a coordinator lane is a barrier
//! window: every device's update chain must finish inside it, and the
//! window closes (after the coordinator's merge) when the *last* chain
//! does. The gating lane is therefore the device lane whose final
//! in-window step ends latest — ties break toward the lower tid, the
//! same direction dispatch breaks them. Aggregating gate counts over
//! the run yields the top-K "who gated the run" table: the paper's
//! straggler story, measured instead of asserted.

use std::collections::BTreeMap;

use super::{Ev, EvKind};
use crate::obs::chrome::{process_label, thread_label, SERVE_TID_BASE};

/// Slack for window-membership comparisons (float timestamps round-trip
/// through microsecond JSON).
const EPS: f64 = 1e-7;

/// One mega-batch barrier window and the chain that closed it.
#[derive(Clone, Debug)]
pub struct CritSegment {
    /// Process lane the window belongs to.
    pub pid: u32,
    /// Mega-batch index (`mb` arg), when the span carried one.
    pub mb: Option<u64>,
    /// Window start (seconds).
    pub start: f64,
    /// Window length (seconds).
    pub dur: f64,
    /// Gating device lane (tid), when any step landed in the window.
    pub gate_tid: Option<u32>,
    /// Sum of the gating lane's step durations inside the window.
    pub gate_busy: f64,
    /// When the gating lane's last step ended (absolute seconds).
    pub gate_end: f64,
    /// Coordinator merge time inside the window.
    pub merge: f64,
    /// Tier-2 sync charged to this window (a `cluster.sync` span
    /// starting at the window's end).
    pub sync: f64,
}

/// One row of the aggregated "who gated the run" table.
#[derive(Clone, Debug, PartialEq)]
pub struct GateRow {
    /// Process lane.
    pub pid: u32,
    /// Device lane.
    pub tid: u32,
    /// Windows this lane gated.
    pub gated: usize,
    /// Step time the lane burned inside the windows it gated.
    pub busy: f64,
    /// `busy` as a share of the total windowed time it gated (1.0 =
    /// the lane computed wall-to-wall; lower means even the gater
    /// stalled).
    pub share: f64,
}

impl GateRow {
    /// `server0/gpu2`-style label.
    pub fn label(&self) -> String {
        format!("{}/{}", process_label(self.pid), thread_label(self.tid))
    }
}

/// Extract one [`CritSegment`] per `train.megabatch` window, in
/// `(pid, start)` order.
pub fn critical_path(events: &[Ev]) -> Vec<CritSegment> {
    let mut segs = Vec::new();
    for w in events
        .iter()
        .filter(|e| e.kind == EvKind::Span && e.tid == 0 && e.name == "train.megabatch")
    {
        let (ws, we) = (w.ts, w.end());
        // Per device lane: (last step end, busy sum) inside the window.
        let mut chains: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
        let mut merge = 0.0;
        let mut sync = 0.0;
        for e in events.iter().filter(|e| e.kind == EvKind::Span && e.pid == w.pid) {
            if e.tid == 0 {
                if e.name == "train.merge" && e.ts >= ws - EPS && e.end() <= we + EPS {
                    merge += e.dur;
                } else if e.name == "cluster.sync" && (e.ts - we).abs() < EPS {
                    sync += e.dur;
                }
                continue;
            }
            if e.tid >= SERVE_TID_BASE || !e.name.starts_with("engine.") {
                continue;
            }
            if e.ts >= ws - EPS && e.end() <= we + EPS {
                let c = chains.entry(e.tid).or_insert((f64::NEG_INFINITY, 0.0));
                c.0 = c.0.max(e.end());
                c.1 += e.dur;
            }
        }
        // Latest last-step end gates; ties toward the lower tid (BTreeMap
        // iteration order makes `>` keep the first/lowest).
        let mut gate: Option<(u32, f64, f64)> = None;
        for (&tid, &(last_end, busy)) in &chains {
            let better = match gate {
                None => true,
                Some((_, end, _)) => last_end > end + EPS,
            };
            if better {
                gate = Some((tid, last_end, busy));
            }
        }
        segs.push(CritSegment {
            pid: w.pid,
            mb: w.arg_num("mb").map(|x| x as u64),
            start: ws,
            dur: w.dur,
            gate_tid: gate.map(|(tid, _, _)| tid),
            gate_busy: gate.map_or(0.0, |(_, _, busy)| busy),
            gate_end: gate.map_or(ws, |(_, end, _)| end),
            merge,
            sync,
        });
    }
    segs.sort_by(|a, b| a.pid.cmp(&b.pid).then(a.start.total_cmp(&b.start)));
    segs
}

/// Aggregate segments into the top-K gaters table: lanes ranked by
/// windows gated (then gated-window busy time), `share` = busy / gated
/// windowed time.
pub fn top_gaters(segs: &[CritSegment], k: usize) -> Vec<GateRow> {
    let mut agg: BTreeMap<(u32, u32), (usize, f64, f64)> = BTreeMap::new();
    for s in segs {
        if let Some(tid) = s.gate_tid {
            let e = agg.entry((s.pid, tid)).or_insert((0, 0.0, 0.0));
            e.0 += 1;
            e.1 += s.gate_busy;
            e.2 += s.dur;
        }
    }
    let mut rows: Vec<GateRow> = agg
        .into_iter()
        .map(|((pid, tid), (gated, busy, windowed))| GateRow {
            pid,
            tid,
            gated,
            busy,
            share: if windowed > 0.0 { busy / windowed } else { 0.0 },
        })
        .collect();
    rows.sort_by(|a, b| {
        b.gated
            .cmp(&a.gated)
            .then(b.busy.total_cmp(&a.busy))
            .then(a.pid.cmp(&b.pid))
            .then(a.tid.cmp(&b.tid))
    });
    rows.truncate(k);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::analyze::AVal;

    fn span(name: &str, pid: u32, tid: u32, ts: f64, dur: f64) -> Ev {
        Ev {
            name: name.to_string(),
            cat: String::new(),
            pid,
            tid,
            ts,
            dur,
            kind: EvKind::Span,
            args: Vec::new(),
        }
    }

    #[test]
    fn slowest_chain_gates_the_window() {
        let mut mb = span("train.megabatch", 0, 0, 0.0, 5.0);
        mb.args.push(("mb".to_string(), AVal::Num(3.0)));
        let events = vec![
            mb,
            // Device 0 (tid 1): done at 2.0.
            span("engine.step", 0, 1, 0.0, 2.0),
            // Device 2 (tid 3): done at 4.5 — the gater.
            span("engine.step", 0, 3, 0.0, 2.5),
            span("engine.step", 0, 3, 2.5, 2.0),
            span("train.merge", 0, 0, 4.5, 0.5),
            span("cluster.sync", 0, 0, 5.0, 0.25),
        ];
        let segs = critical_path(&events);
        assert_eq!(segs.len(), 1);
        let s = &segs[0];
        assert_eq!(s.mb, Some(3));
        assert_eq!(s.gate_tid, Some(3));
        assert!((s.gate_busy - 4.5).abs() < 1e-12);
        assert!((s.gate_end - 4.5).abs() < 1e-12);
        assert!((s.merge - 0.5).abs() < 1e-12);
        assert!((s.sync - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ties_break_toward_the_lower_tid() {
        let events = vec![
            span("train.megabatch", 0, 0, 0.0, 2.0),
            span("engine.step", 0, 2, 0.0, 2.0),
            span("engine.step", 0, 1, 0.0, 2.0),
        ];
        let segs = critical_path(&events);
        assert_eq!(segs[0].gate_tid, Some(1));
    }

    #[test]
    fn top_gaters_ranks_by_windows_then_busy() {
        let events = vec![
            span("train.megabatch", 0, 0, 0.0, 2.0),
            span("engine.step", 0, 1, 0.0, 2.0),
            span("train.megabatch", 0, 0, 2.0, 3.0),
            span("engine.step", 0, 2, 2.0, 3.0),
            span("train.megabatch", 0, 0, 5.0, 3.0),
            span("engine.step", 0, 2, 5.0, 3.0),
        ];
        let rows = top_gaters(&critical_path(&events), 8);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].tid, rows[0].gated), (2, 2));
        assert_eq!((rows[1].tid, rows[1].gated), (1, 1));
        assert!((rows[0].share - 1.0).abs() < 1e-12, "wall-to-wall gater");
        let truncated = top_gaters(&critical_path(&events), 1);
        assert_eq!(truncated.len(), 1);
    }

    #[test]
    fn serve_lanes_and_other_processes_never_gate() {
        let events = vec![
            span("train.megabatch", 0, 0, 0.0, 2.0),
            span("serve.batch", 0, 101, 0.0, 5.0),
            span("engine.step", 1, 1, 0.0, 2.0),
        ];
        let segs = critical_path(&events);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].gate_tid, None, "no in-window device steps");
        assert!(top_gaters(&segs, 4).is_empty());
    }
}

//! Per-lane time attribution: carve each lane's run window into
//! compute / serve / merge-wait / cluster-sync / idle.
//!
//! The partition invariant is structural, not arithmetic: every lane
//! starts from one free-interval list spanning its process's run window
//! `[min ts, max end]`, and each category *subtracts* its intervals from
//! whatever is still free, in a fixed priority order:
//!
//! 1. own `engine.*` spans → **compute**
//! 2. own `serve.*` spans → **serve**
//! 3. own `train.merge` spans → **merge-wait** (the coordinator's merge
//!    work is part of the barrier every device waits on)
//! 4. `cluster.sync` windows (own lane, plus the process coordinator's
//!    for device lanes) → **cluster-sync**
//! 5. the process's `train.megabatch` windows → **merge-wait** on device
//!    lanes (inside a mega-batch window, a device that isn't stepping is
//!    stalled on the barrier, not idle)
//! 6. whatever remains → **idle**
//!
//! Because each second of the window is claimed exactly once, the five
//! categories sum to the window length to float precision — the property
//! test random-churn scenarios pin this. `train.megabatch` on the
//! coordinator's *own* lane is structural (it brackets the window), so
//! step 5 applies only to device lanes; the coordinator's in-window
//! remainder counts as idle (it is bookkeeping, not busy time).

use std::collections::BTreeMap;

use super::{Ev, EvKind};
use crate::obs::chrome::{process_label, thread_label, SERVE_TID_BASE};

/// One lane's attributed time, all in seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneAttribution {
    /// Process lane (server / tenant).
    pub pid: u32,
    /// Thread lane within the process.
    pub tid: u32,
    /// Length of the process's run window (shared by all its lanes).
    pub total: f64,
    /// Time inside own `engine.*` spans.
    pub compute: f64,
    /// Time inside own `serve.*` spans.
    pub serve: f64,
    /// Barrier stall: own merge spans plus mega-batch window time this
    /// lane spent neither computing nor syncing.
    pub merge_wait: f64,
    /// Tier-2 fabric synchronization windows.
    pub cluster_sync: f64,
    /// Window time outside every category above.
    pub idle: f64,
    /// Number of spans observed on this lane.
    pub spans: usize,
}

impl LaneAttribution {
    /// `server0/gpu2`-style label.
    pub fn label(&self) -> String {
        format!("{}/{}", process_label(self.pid), thread_label(self.tid))
    }

    /// Sum of the five categories — equals `total` up to float error
    /// (the partition invariant).
    pub fn category_sum(&self) -> f64 {
        self.compute + self.serve + self.merge_wait + self.cluster_sync + self.idle
    }
}

/// Sort, clamp to positive length, and merge overlapping or touching
/// intervals.
fn normalize(mut v: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    v.retain(|(s, e)| e > s);
    v.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(v.len());
    for (s, e) in v {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Subtract `cuts` (normalized) from the free list in place; returns the
/// total length removed. Both lists stay sorted and disjoint.
fn subtract(free: &mut Vec<(f64, f64)>, cuts: &[(f64, f64)]) -> f64 {
    if cuts.is_empty() || free.is_empty() {
        return 0.0;
    }
    let mut removed = 0.0;
    let mut next: Vec<(f64, f64)> = Vec::with_capacity(free.len() + cuts.len());
    for &(fs, fe) in free.iter() {
        let mut cursor = fs;
        for &(cs, ce) in cuts {
            if ce <= cursor {
                continue;
            }
            if cs >= fe {
                break;
            }
            let lo = cs.max(cursor);
            let hi = ce.min(fe);
            if hi > lo {
                removed += hi - lo;
                if lo > cursor {
                    next.push((cursor, lo));
                }
                cursor = hi;
            }
        }
        if cursor < fe {
            next.push((cursor, fe));
        }
    }
    *free = next;
    removed
}

fn free_len(free: &[(f64, f64)]) -> f64 {
    free.iter().map(|(s, e)| e - s).sum()
}

/// Attribute every lane in the event stream. Lanes are grouped per
/// process: all lanes of a `pid` share the window `[min ts, max end]`
/// over that process's events, so their totals are comparable
/// denominators. Returns lanes sorted by `(pid, tid)`.
pub fn attribute(events: &[Ev]) -> Vec<LaneAttribution> {
    // Per-process windows and structural span sets.
    let mut window: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
    let mut mb_windows: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
    let mut sync_windows: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
    let mut lanes: BTreeMap<(u32, u32), Vec<&Ev>> = BTreeMap::new();
    for e in events {
        let w = window.entry(e.pid).or_insert((f64::INFINITY, f64::NEG_INFINITY));
        w.0 = w.0.min(e.ts);
        w.1 = w.1.max(e.end());
        lanes.entry((e.pid, e.tid)).or_default().push(e);
        if e.kind == EvKind::Span && e.tid == 0 {
            if e.name == "train.megabatch" {
                mb_windows.entry(e.pid).or_default().push((e.ts, e.end()));
            } else if e.name == "cluster.sync" {
                sync_windows.entry(e.pid).or_default().push((e.ts, e.end()));
            }
        }
    }

    let mut out = Vec::with_capacity(lanes.len());
    for ((pid, tid), evs) in &lanes {
        let (t0, t1) = window[pid];
        if t1 <= t0 {
            continue;
        }
        let spans: Vec<&&Ev> = evs.iter().filter(|e| e.kind == EvKind::Span).collect();
        let mut free = vec![(t0, t1)];
        let own = |prefix: &str| -> Vec<(f64, f64)> {
            normalize(
                spans
                    .iter()
                    .filter(|e| e.name.starts_with(prefix))
                    .map(|e| (e.ts, e.end()))
                    .collect(),
            )
        };
        let compute = subtract(&mut free, &own("engine."));
        let serve = subtract(&mut free, &own("serve."));
        let mut merge_wait = subtract(&mut free, &own("train.merge"));
        // Sync windows cover the whole process: devices hold at the
        // barrier while their coordinator runs the tier-2 exchange.
        let mut syncs = sync_windows.get(pid).cloned().unwrap_or_default();
        if *tid != 0 {
            syncs.extend(
                spans
                    .iter()
                    .filter(|e| e.name == "cluster.sync")
                    .map(|e| (e.ts, e.end())),
            );
        }
        let cluster_sync = subtract(&mut free, &normalize(syncs));
        if *tid != 0 && *tid < SERVE_TID_BASE {
            // Device lane inside a mega-batch window but not stepping:
            // stalled on the barrier.
            let mbs = normalize(mb_windows.get(pid).cloned().unwrap_or_default());
            merge_wait += subtract(&mut free, &mbs);
        }
        out.push(LaneAttribution {
            pid: *pid,
            tid: *tid,
            total: t1 - t0,
            compute,
            serve,
            merge_wait,
            cluster_sync,
            idle: free_len(&free),
            spans: spans.len(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, pid: u32, tid: u32, ts: f64, dur: f64) -> Ev {
        Ev {
            name: name.to_string(),
            cat: String::new(),
            pid,
            tid,
            ts,
            dur,
            kind: EvKind::Span,
            args: Vec::new(),
        }
    }

    fn instant(name: &str, pid: u32, tid: u32, ts: f64) -> Ev {
        Ev { kind: EvKind::Instant, ..span(name, pid, tid, ts, 0.0) }
    }

    #[test]
    fn interval_subtraction_is_exact() {
        let mut free = vec![(0.0, 10.0)];
        let removed = subtract(&mut free, &normalize(vec![(2.0, 4.0), (3.0, 5.0), (8.0, 12.0)]));
        assert!((removed - 5.0).abs() < 1e-12, "removed {removed}");
        assert_eq!(free, vec![(0.0, 2.0), (5.0, 8.0)]);
        // Subtracting the same cuts again removes nothing.
        let again = subtract(&mut free, &normalize(vec![(2.0, 5.0)]));
        assert_eq!(again, 0.0);
    }

    #[test]
    fn device_lane_partitions_into_compute_stall_sync_idle() {
        // Coordinator: one mega-batch window [0,6], a sync [6,7].
        // Device (tid 1): two steps [0,2] and [3,5] inside the window.
        let events = vec![
            span("train.megabatch", 0, 0, 0.0, 6.0),
            span("cluster.sync", 0, 0, 6.0, 1.0),
            span("engine.step", 0, 1, 0.0, 2.0),
            span("engine.step", 0, 1, 3.0, 2.0),
            instant("train.pool", 0, 0, 0.0),
        ];
        let lanes = attribute(&events);
        assert_eq!(lanes.len(), 2);
        let dev = lanes.iter().find(|l| l.tid == 1).unwrap();
        assert!((dev.total - 7.0).abs() < 1e-12);
        assert!((dev.compute - 4.0).abs() < 1e-12);
        // Gaps [2,3] and [5,6] sit inside the mega-batch window → stall.
        assert!((dev.merge_wait - 2.0).abs() < 1e-12, "stall {}", dev.merge_wait);
        assert!((dev.cluster_sync - 1.0).abs() < 1e-12);
        assert_eq!(dev.idle, 0.0);
        assert!((dev.category_sum() - dev.total).abs() < 1e-9);
        // Coordinator: megabatch on its own lane is structural → idle,
        // sync span is cluster-sync.
        let coord = lanes.iter().find(|l| l.tid == 0).unwrap();
        assert!((coord.cluster_sync - 1.0).abs() < 1e-12);
        assert!((coord.idle - 6.0).abs() < 1e-12);
        assert!((coord.category_sum() - coord.total).abs() < 1e-9);
    }

    #[test]
    fn serve_lane_and_overlapping_spans() {
        // Overlapping serve spans must not double-count.
        let events = vec![
            span("serve.batch", 0, 101, 0.0, 2.0),
            span("serve.batch", 0, 101, 1.0, 2.0),
            span("engine.step", 0, 1, 0.0, 4.0),
        ];
        let lanes = attribute(&events);
        let srv = lanes.iter().find(|l| l.tid == 101).unwrap();
        assert!((srv.serve - 3.0).abs() < 1e-12, "merged overlap: {}", srv.serve);
        assert!((srv.idle - 1.0).abs() < 1e-12);
        assert!((srv.category_sum() - srv.total).abs() < 1e-9);
    }

    #[test]
    fn processes_get_independent_windows() {
        let events = vec![
            span("engine.step", 0, 1, 0.0, 1.0),
            span("engine.step", 3, 1, 10.0, 2.0),
        ];
        let lanes = attribute(&events);
        assert_eq!(lanes.len(), 2);
        assert!((lanes[0].total - 1.0).abs() < 1e-12);
        assert!((lanes[1].total - 2.0).abs() < 1e-12);
        assert_eq!(lanes[1].pid, 3);
    }
}

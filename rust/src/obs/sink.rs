//! The structured trace sink: a bounded ring buffer of spans and instant
//! events with subsystem + level filtering.
//!
//! The sink is built once, from the `[obs]` section (or armed by the CLI's
//! `--trace` flag), and never mutates its filter state afterwards — the
//! hot-path check is one immutable bool and a bitmask test, so a disabled
//! sink costs a branch per call site (benched in `perf_hotpath`).
//!
//! Timestamps are plain `f64` seconds on whatever clock the emitter runs:
//! the discrete-event engines stamp virtual time (making traces
//! bit-deterministic), the threaded engine stamps wall seconds since the
//! sink's epoch via [`TraceSink::now`] / the [`SpanGuard`] scoped API.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which plane of the system an event came from. Used for filtering
/// (`[obs] subsystems`) and as the Chrome-trace category.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Subsystem {
    /// Coordinator / `TrainerSession`: plan, mega-batch, merge, eval,
    /// scaling and calibration decisions.
    Train,
    /// Execution engines: per-device step phases.
    Engine,
    /// Data plane: pipeline and buffer-pool counters.
    Data,
    /// Serving plane: admit → route → eval → respond lifecycle.
    Serve,
    /// Fleet arbiter: lease decisions with their reason.
    Fleet,
    /// Cluster plane: tier-2 syncs, cadence moves, rack churn.
    Cluster,
}

impl Subsystem {
    /// Stable lowercase name (config grammar + trace category).
    pub fn name(&self) -> &'static str {
        match self {
            Subsystem::Train => "train",
            Subsystem::Engine => "engine",
            Subsystem::Data => "data",
            Subsystem::Serve => "serve",
            Subsystem::Fleet => "fleet",
            Subsystem::Cluster => "cluster",
        }
    }

    /// Every subsystem, in bitmask order.
    pub fn all() -> [Subsystem; 6] {
        [
            Subsystem::Train,
            Subsystem::Engine,
            Subsystem::Data,
            Subsystem::Serve,
            Subsystem::Fleet,
            Subsystem::Cluster,
        ]
    }

    /// Parse a `[obs] subsystems` entry.
    pub fn parse(s: &str) -> Option<Subsystem> {
        Subsystem::all().into_iter().find(|sub| sub.name() == s)
    }

    fn bit(&self) -> u16 {
        match self {
            Subsystem::Train => 1 << 0,
            Subsystem::Engine => 1 << 1,
            Subsystem::Data => 1 << 2,
            Subsystem::Serve => 1 << 3,
            Subsystem::Fleet => 1 << 4,
            Subsystem::Cluster => 1 << 5,
        }
    }
}

/// Event verbosity. `Info` is the decision-level timeline (the default);
/// `Debug` adds high-volume per-request detail.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Decision-level spans and instants.
    Info,
    /// High-volume detail (per-admission queue depths and the like).
    Debug,
}

impl Level {
    /// Parse a `[obs] level` value.
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// A typed event argument (rendered into the Chrome trace's `args` object).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgVal {
    /// Unsigned counter-like value.
    U(u64),
    /// Signed integer.
    I(i64),
    /// Floating-point value (seconds, ratios, …).
    F(f64),
    /// Boolean flag.
    B(bool),
    /// Free-form string (decision reasons).
    S(String),
}

impl From<u64> for ArgVal {
    fn from(v: u64) -> Self {
        ArgVal::U(v)
    }
}
impl From<usize> for ArgVal {
    fn from(v: usize) -> Self {
        ArgVal::U(v as u64)
    }
}
impl From<i64> for ArgVal {
    fn from(v: i64) -> Self {
        ArgVal::I(v)
    }
}
impl From<f64> for ArgVal {
    fn from(v: f64) -> Self {
        ArgVal::F(v)
    }
}
impl From<bool> for ArgVal {
    fn from(v: bool) -> Self {
        ArgVal::B(v)
    }
}
impl From<String> for ArgVal {
    fn from(v: String) -> Self {
        ArgVal::S(v)
    }
}
impl From<&str> for ArgVal {
    fn from(v: &str) -> Self {
        ArgVal::S(v.to_string())
    }
}

/// Whether an event is a duration span or a point-in-time instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Complete span (`ph: "X"` in the Chrome trace).
    Span,
    /// Instant event (`ph: "i"`).
    Instant,
}

/// One recorded event. `pid`/`tid` select the trace lane (process = server
/// or tenant, thread = device / coordinator / serve replica).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Emission order (the ring buffer's monotone sequence number).
    pub seq: u64,
    /// Originating plane (trace category).
    pub subsystem: Subsystem,
    /// Span taxonomy name (`train.megabatch`, `cluster.sync`, …).
    pub name: &'static str,
    /// Process lane: server index (cluster) or tenant index (fleet).
    pub pid: u32,
    /// Thread lane: 0 = coordinator, `1 + d` = device `d`,
    /// `101 + d` = serve replica on device `d`.
    pub tid: u32,
    /// Start time, seconds (virtual or wall, per emitter).
    pub ts: f64,
    /// Duration, seconds (0 for instants).
    pub dur: f64,
    /// Span vs instant.
    pub kind: EventKind,
    /// Typed arguments (decision reasons ride here).
    pub args: Vec<(&'static str, ArgVal)>,
}

struct SinkState {
    events: VecDeque<TraceEvent>,
    seq: u64,
    dropped: u64,
    opened: u64,
    closed: u64,
}

/// Bounded ring buffer of [`TraceEvent`]s with subsystem/level filtering.
///
/// Disabled sinks (the default) drop every event on an immutable-bool
/// check; enabled sinks keep at most `cap` events, discarding the oldest
/// (the `dropped` tally is exported as trace metadata so truncation is
/// never silent).
pub struct TraceSink {
    enabled: bool,
    mask: u16,
    level: Level,
    cap: usize,
    epoch: Instant,
    /// Virtual-clock base (f64 bits) the engines add their window-local
    /// offsets to — set by the trainer before each mega-batch dispatch.
    base_bits: AtomicU64,
    state: Mutex<SinkState>,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("enabled", &self.enabled)
            .field("len", &self.len())
            .finish()
    }
}

/// An open wall-clock span from [`TraceSink::begin`]. Close it with
/// [`TraceSink::end`]; an unclosed guard shows up as an open/close
/// imbalance in [`TraceSink::balance`] (which the property tests assert
/// against).
#[derive(Debug)]
#[must_use = "close the span with TraceSink::end"]
pub struct SpanGuard {
    subsystem: Subsystem,
    name: &'static str,
    pid: u32,
    tid: u32,
    start: f64,
}

impl TraceSink {
    /// A sink that drops everything (the ambient default).
    pub fn disabled() -> TraceSink {
        TraceSink::new(false, u16::MAX, Level::Info, 1)
    }

    /// Build a sink. `mask` is the subsystem bitmask (see
    /// [`TraceSink::mask_of`]), `cap` the ring capacity in events.
    pub fn new(enabled: bool, mask: u16, level: Level, cap: usize) -> TraceSink {
        TraceSink {
            enabled,
            mask,
            level,
            cap: cap.max(1),
            epoch: Instant::now(),
            base_bits: AtomicU64::new(0),
            state: Mutex::new(SinkState {
                events: VecDeque::new(),
                seq: 0,
                dropped: 0,
                opened: 0,
                closed: 0,
            }),
        }
    }

    /// Bitmask selecting `subsystems` (empty = all).
    pub fn mask_of(subsystems: &[Subsystem]) -> u16 {
        if subsystems.is_empty() {
            u16::MAX
        } else {
            subsystems.iter().fold(0, |m, s| m | s.bit())
        }
    }

    /// Whether the sink records anything at all.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The hot-path filter: records from `sub` at `level`?
    #[inline]
    pub fn on(&self, sub: Subsystem, level: Level) -> bool {
        self.enabled && level <= self.level && self.mask & sub.bit() != 0
    }

    /// Wall seconds since the sink's epoch (the threaded engine's clock).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Set the virtual-clock base the engines stamp their window-local
    /// step offsets onto (called by the trainer before each dispatch).
    pub fn set_time_base(&self, base: f64) {
        self.base_bits.store(base.to_bits(), Ordering::Relaxed);
    }

    /// The current virtual-clock base (see [`TraceSink::set_time_base`]).
    pub fn time_base(&self) -> f64 {
        f64::from_bits(self.base_bits.load(Ordering::Relaxed))
    }

    /// Record a complete span at an explicit timestamp (virtual-clock
    /// emitters). No-op when filtered out.
    #[allow(clippy::too_many_arguments)]
    pub fn span_at(
        &self,
        sub: Subsystem,
        level: Level,
        name: &'static str,
        pid: u32,
        tid: u32,
        ts: f64,
        dur: f64,
        args: Vec<(&'static str, ArgVal)>,
    ) {
        if !self.on(sub, level) {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.opened += 1;
        st.closed += 1;
        push(&mut st, self.cap, sub, name, pid, tid, ts, dur, EventKind::Span, args);
    }

    /// Record an instant event at an explicit timestamp. No-op when
    /// filtered out.
    pub fn instant_at(
        &self,
        sub: Subsystem,
        level: Level,
        name: &'static str,
        pid: u32,
        tid: u32,
        ts: f64,
        args: Vec<(&'static str, ArgVal)>,
    ) {
        if !self.on(sub, level) {
            return;
        }
        let mut st = self.state.lock().unwrap();
        push(&mut st, self.cap, sub, name, pid, tid, ts, 0.0, EventKind::Instant, args);
    }

    /// Open a wall-clock scoped span (threaded-engine emitters). Returns
    /// `None` when filtered out so the fast path stays branch-only.
    pub fn begin(
        &self,
        sub: Subsystem,
        level: Level,
        name: &'static str,
        pid: u32,
        tid: u32,
    ) -> Option<SpanGuard> {
        if !self.on(sub, level) {
            return None;
        }
        self.state.lock().unwrap().opened += 1;
        Some(SpanGuard { subsystem: sub, name, pid, tid, start: self.now() })
    }

    /// Close a span from [`TraceSink::begin`], stamping its wall duration.
    pub fn end(&self, guard: SpanGuard, args: Vec<(&'static str, ArgVal)>) {
        let dur = self.now() - guard.start;
        let mut st = self.state.lock().unwrap();
        st.closed += 1;
        push(
            &mut st,
            self.cap,
            guard.subsystem,
            guard.name,
            guard.pid,
            guard.tid,
            guard.start,
            dur,
            EventKind::Span,
            args,
        );
    }

    /// Snapshot of the recorded events, in emission order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.state.lock().unwrap().events.iter().cloned().collect()
    }

    /// `(opened, closed)` span tallies — equal after a clean run (the
    /// open/close balance property).
    pub fn balance(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.opened, st.closed)
    }

    /// Events evicted by the ring cap (exported as trace metadata).
    pub fn dropped(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    /// Total events recorded so far (after eviction).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().events.len()
    }

    /// No events recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[allow(clippy::too_many_arguments)]
fn push(
    st: &mut SinkState,
    cap: usize,
    sub: Subsystem,
    name: &'static str,
    pid: u32,
    tid: u32,
    ts: f64,
    dur: f64,
    kind: EventKind,
    args: Vec<(&'static str, ArgVal)>,
) {
    if st.events.len() >= cap {
        st.events.pop_front();
        st.dropped += 1;
    }
    let seq = st.seq;
    st.seq += 1;
    st.events.push_back(TraceEvent { seq, subsystem: sub, name, pid, tid, ts, dur, kind, args });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_sink(cap: usize) -> TraceSink {
        TraceSink::new(true, u16::MAX, Level::Info, cap)
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let s = TraceSink::disabled();
        s.span_at(Subsystem::Train, Level::Info, "x", 0, 0, 0.0, 1.0, Vec::new());
        s.instant_at(Subsystem::Train, Level::Info, "y", 0, 0, 0.0, Vec::new());
        assert!(s.begin(Subsystem::Train, Level::Info, "z", 0, 0).is_none());
        assert!(s.is_empty());
        assert_eq!(s.balance(), (0, 0));
    }

    #[test]
    fn level_and_subsystem_filters_apply() {
        let s = TraceSink::new(
            true,
            TraceSink::mask_of(&[Subsystem::Serve]),
            Level::Info,
            64,
        );
        s.span_at(Subsystem::Train, Level::Info, "t", 0, 0, 0.0, 1.0, Vec::new());
        s.span_at(Subsystem::Serve, Level::Debug, "d", 0, 0, 0.0, 1.0, Vec::new());
        s.span_at(Subsystem::Serve, Level::Info, "s", 0, 0, 0.0, 1.0, Vec::new());
        let evs = s.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "s");
    }

    #[test]
    fn ring_drops_oldest_and_counts_them() {
        let s = enabled_sink(3);
        for i in 0..5u64 {
            s.instant_at(Subsystem::Train, Level::Info, "i", 0, 0, i as f64, Vec::new());
        }
        let evs = s.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(s.dropped(), 2);
        assert_eq!(evs[0].seq, 2, "oldest two evicted");
        assert_eq!(evs[2].seq, 4);
    }

    #[test]
    fn guard_spans_balance_and_measure_wall_time() {
        let s = enabled_sink(16);
        let g = s.begin(Subsystem::Engine, Level::Info, "step", 0, 1).unwrap();
        assert_eq!(s.balance(), (1, 0), "open until ended");
        s.end(g, vec![("dev", ArgVal::U(0))]);
        assert_eq!(s.balance(), (1, 1));
        let evs = s.events();
        assert_eq!(evs.len(), 1);
        assert!(evs[0].dur >= 0.0);
        assert_eq!(evs[0].kind, EventKind::Span);
    }

    #[test]
    fn subsystem_names_round_trip() {
        for sub in Subsystem::all() {
            assert_eq!(Subsystem::parse(sub.name()), Some(sub));
        }
        assert_eq!(Subsystem::parse("nope"), None);
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("trace"), None);
    }
}

//! Chrome-trace (Catapult / Perfetto `trace_event`) export and a minimal
//! schema checker for CI.
//!
//! Spans render as complete events (`ph: "X"`, microsecond `ts`/`dur`),
//! instants as `ph: "i"`. Lane layout: one Perfetto *process* per
//! server/tenant (`pid`), one *thread* per device lane (`tid` 0 =
//! coordinator, `1 + d` = GPU `d`, `101 + d` = serve replica on device
//! `d`) — metadata events carry the human-readable lane names. Rendering
//! uses the in-tree [`crate::util::json::Json`] writer (BTreeMap objects),
//! so identical event streams serialize to identical bytes: in virtual
//! mode the exported file is bit-deterministic.

use std::collections::BTreeSet;

use crate::obs::sink::{ArgVal, EventKind, TraceEvent, TraceSink};
use crate::obs::Registry;
use crate::util::json::Json;
use anyhow::{bail, Context};

/// Offset of serve-replica thread lanes (`tid = SERVE_TID_BASE + device`).
pub const SERVE_TID_BASE: u32 = 101;

/// Human-readable name of a thread lane.
pub fn thread_label(tid: u32) -> String {
    if tid == 0 {
        "coordinator".to_string()
    } else if tid < SERVE_TID_BASE {
        format!("gpu{}", tid - 1)
    } else {
        format!("serve-gpu{}", tid - SERVE_TID_BASE)
    }
}

/// Human-readable name of a process lane (server in cluster runs, tenant
/// in fleet runs, `server0` for single-node runs).
pub fn process_label(pid: u32) -> String {
    format!("server{pid}")
}

fn arg_json(v: &ArgVal) -> Json {
    match v {
        ArgVal::U(n) => Json::num(*n as f64),
        ArgVal::I(n) => Json::int(*n),
        ArgVal::F(x) => Json::num(*x),
        ArgVal::B(b) => Json::Bool(*b),
        ArgVal::S(s) => Json::str(s.clone()),
    }
}

fn event_json(e: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.subsystem.name())),
        ("pid", Json::num(e.pid as f64)),
        ("tid", Json::num(e.tid as f64)),
        ("ts", Json::num(e.ts * 1e6)),
    ];
    match e.kind {
        EventKind::Span => {
            pairs.push(("ph", Json::str("X")));
            pairs.push(("dur", Json::num(e.dur * 1e6)));
        }
        EventKind::Instant => {
            pairs.push(("ph", Json::str("i")));
            pairs.push(("s", Json::str("t")));
        }
    }
    if !e.args.is_empty() {
        pairs.push(("args", Json::obj(e.args.iter().map(|(k, v)| (*k, arg_json(v))).collect())));
    }
    Json::obj(pairs)
}

fn metadata_json(pid: u32, name: &str, label: &str, tid: u32) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(label))])),
    ])
}

/// Perfetto counter event (`ph: "C"`): one sample of a registry
/// counter/gauge, rendered as a counter track on process 0.
fn counter_json(name: &str, ts_us: f64, value: f64) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ph", Json::str("C")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(0.0)),
        ("ts", Json::num(ts_us)),
        ("args", Json::obj(vec![("value", Json::num(value))])),
    ])
}

/// Render an event stream to trace_event JSON. Metadata (lane names) is
/// derived from the `(pid, tid)` pairs actually seen, in sorted order;
/// the ring's eviction tally is surfaced as a top-level `droppedEvents`
/// key so truncation is never silent.
pub fn render_events(events: &[TraceEvent], dropped: u64) -> String {
    render_events_with_counters(events, dropped, &[])
}

/// [`render_events`] plus registry counter/gauge samples as Perfetto
/// counter ("C") tracks, stamped at the end of the trace (they are
/// end-of-run totals, not time series).
pub fn render_events_with_counters(
    events: &[TraceEvent],
    dropped: u64,
    counters: &[(String, f64)],
) -> String {
    let pids: BTreeSet<u32> = events.iter().map(|e| e.pid).collect();
    let lanes: BTreeSet<(u32, u32)> = events.iter().map(|e| (e.pid, e.tid)).collect();
    let mut out = Vec::new();
    for &pid in &pids {
        out.push(metadata_json(pid, "process_name", &process_label(pid), 0));
    }
    for &(pid, tid) in &lanes {
        out.push(metadata_json(pid, "thread_name", &thread_label(tid), tid));
    }
    out.extend(events.iter().map(event_json));
    let end_us = events.iter().map(|e| (e.ts + e.dur) * 1e6).fold(0.0, f64::max);
    out.extend(counters.iter().map(|(name, value)| counter_json(name, end_us, *value)));
    let root = Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
        ("droppedEvents", Json::num(dropped as f64)),
    ]);
    root.to_string()
}

/// Render a sink's current contents (see [`render_events`]).
pub fn render(sink: &TraceSink) -> String {
    render_events(&sink.events(), sink.dropped())
}

/// Render a sink plus its registry's counters/gauges (histogram
/// expansions are series, not point samples — they stay in the RunLog).
pub fn render_with_registry(sink: &TraceSink, registry: &Registry) -> String {
    let counters: Vec<(String, f64)> = registry
        .snapshot()
        .into_iter()
        .filter(|r| r.kind == "counter" || r.kind == "gauge")
        .map(|r| (r.name, r.value))
        .collect();
    render_events_with_counters(&sink.events(), sink.dropped(), &counters)
}

/// Render a sink's contents to `path`.
pub fn write_trace(sink: &TraceSink, path: &str) -> crate::Result<()> {
    std::fs::write(path, render(sink)).with_context(|| format!("writing trace to {path}"))
}

/// Render a sink plus registry counters to `path` (the `--trace` CLI
/// path).
pub fn write_trace_with_registry(
    sink: &TraceSink,
    registry: &Registry,
    path: &str,
) -> crate::Result<()> {
    std::fs::write(path, render_with_registry(sink, registry))
        .with_context(|| format!("writing trace to {path}"))
}

/// Minimal trace_event schema checker (used by the `trace-check` CLI
/// subcommand in CI). Validates the top-level shape and the per-event
/// required fields for the phases we emit (`X`, `i`, `M`, `C`); returns
/// the number of events checked.
pub fn validate(text: &str) -> crate::Result<usize> {
    let root = Json::parse(text).map_err(|e| anyhow::anyhow!("trace is not valid JSON: {e}"))?;
    let events = match root.get("traceEvents").as_arr() {
        Some(a) => a,
        None => bail!("trace missing top-level \"traceEvents\" array"),
    };
    for (i, ev) in events.iter().enumerate() {
        let obj = ev.as_obj().with_context(|| format!("event {i}: not an object"))?;
        let ph = obj
            .get("ph")
            .and_then(|p| p.as_str())
            .with_context(|| format!("event {i}: missing \"ph\""))?;
        let need_num = |key: &str| -> crate::Result<()> {
            match obj.get(key).and_then(|v| v.as_f64()) {
                Some(_) => Ok(()),
                None => bail!("event {i} (ph {ph:?}): missing numeric \"{key}\""),
            }
        };
        let need_str = |key: &str| -> crate::Result<()> {
            match obj.get(key).and_then(|v| v.as_str()) {
                Some(_) => Ok(()),
                None => bail!("event {i} (ph {ph:?}): missing string \"{key}\""),
            }
        };
        match ph {
            "X" => {
                need_str("name")?;
                need_num("pid")?;
                need_num("tid")?;
                need_num("ts")?;
                need_num("dur")?;
            }
            "i" => {
                need_str("name")?;
                need_num("pid")?;
                need_num("tid")?;
                need_num("ts")?;
                need_str("s")?;
            }
            "M" => {
                need_str("name")?;
                need_num("pid")?;
                if obj.get("args").and_then(|a| a.as_obj()).is_none() {
                    bail!("event {i}: metadata event missing \"args\" object");
                }
            }
            "C" => {
                need_str("name")?;
                need_num("pid")?;
                need_num("ts")?;
                let has_series = obj
                    .get("args")
                    .and_then(|a| a.as_obj())
                    .is_some_and(|o| o.values().any(|v| v.as_f64().is_some()));
                if !has_series {
                    bail!("event {i}: counter event needs an args object with a numeric series");
                }
            }
            other => bail!("event {i}: unsupported phase {other:?}"),
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::{Level, Subsystem};

    fn sink_with_events() -> TraceSink {
        let s = TraceSink::new(true, u16::MAX, Level::Info, 64);
        s.span_at(
            Subsystem::Train,
            Level::Info,
            "train.megabatch",
            0,
            0,
            0.5,
            0.25,
            vec![("updates", ArgVal::U(8)), ("reason", ArgVal::S("drift".into()))],
        );
        s.span_at(Subsystem::Engine, Level::Info, "engine.step", 0, 1, 0.5, 0.1, Vec::new());
        s.instant_at(
            Subsystem::Cluster,
            Level::Info,
            "cluster.rack_down",
            1,
            0,
            0.75,
            vec![("rack", ArgVal::U(1))],
        );
        s
    }

    #[test]
    fn render_passes_validation_and_counts_events() {
        let s = sink_with_events();
        let text = render(&s);
        // 3 events + process metadata (pids 0, 1) + thread metadata (3 lanes).
        let n = validate(&text).unwrap();
        assert_eq!(n, 3 + 2 + 3);
    }

    #[test]
    fn render_is_deterministic_for_equal_streams() {
        let a = render(&sink_with_events());
        let b = render(&sink_with_events());
        assert_eq!(a, b);
    }

    #[test]
    fn timestamps_are_microseconds() {
        let text = render(&sink_with_events());
        let root = Json::parse(&text).unwrap();
        let evs = root.get("traceEvents").as_arr().unwrap();
        let span = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("train.megabatch"))
            .unwrap();
        assert_eq!(span.get("ts").as_f64(), Some(500000.0));
        assert_eq!(span.get("dur").as_f64(), Some(250000.0));
        assert_eq!(span.get("args").get("reason").as_str(), Some("drift"));
    }

    #[test]
    fn lane_labels() {
        assert_eq!(thread_label(0), "coordinator");
        assert_eq!(thread_label(3), "gpu2");
        assert_eq!(thread_label(SERVE_TID_BASE + 2), "serve-gpu2");
        assert_eq!(process_label(4), "server4");
    }

    #[test]
    fn counters_render_as_validated_counter_tracks() {
        let s = sink_with_events();
        let registry = Registry::new();
        registry.counter("train.updates").add(5);
        registry.gauge("serve.depth").set(3.0);
        registry.histogram("serve.latency_s").observe(0.01);
        let text = render_with_registry(&s, &registry);
        // Histograms don't become counter tracks; counter + gauge do.
        let n = validate(&text).unwrap();
        assert_eq!(n, 3 + 2 + 3 + 2);
        let root = Json::parse(&text).unwrap();
        let evs = root.get("traceEvents").as_arr().unwrap();
        let c = evs.iter().find(|e| e.get("ph").as_str() == Some("C")).unwrap();
        assert_eq!(c.get("name").as_str(), Some("serve.depth"));
        assert_eq!(c.get("args").get("value").as_f64(), Some(3.0));
        // Stamped at the end of the trace (0.75 s → 750000 µs).
        assert_eq!(c.get("ts").as_f64(), Some(750000.0));
        // Deterministic like everything else the writer emits.
        assert_eq!(text, render_with_registry(&sink_with_events(), &registry));
    }

    #[test]
    fn validate_rejects_malformed_traces() {
        assert!(validate(
            r#"{"traceEvents":[{"ph":"C","name":"c","pid":0,"ts":1,"args":{"value":2}}]}"#
        )
        .is_ok());
        assert!(validate(r#"{"traceEvents":[{"ph":"C","name":"c","pid":0,"ts":1}]}"#).is_err());
        assert!(validate("not json").is_err());
        assert!(validate("{}").is_err());
        assert!(validate(r#"{"traceEvents":[{"ph":"X","name":"a"}]}"#).is_err());
        assert!(validate(r#"{"traceEvents":[{"ph":"q","name":"a"}]}"#).is_err());
        assert!(
            validate(r#"{"traceEvents":[{"ph":"i","name":"a","pid":0,"tid":0,"ts":1,"s":"t"}]}"#)
                .is_ok()
        );
    }
}

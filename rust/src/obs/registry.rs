//! The counter registry: typed monotonic counters, gauges, and
//! fixed-log-bucket histograms behind stable dotted names.
//!
//! Unlike the trace sink, the registry is *always on*: handles are plain
//! `Arc<AtomicU64>` increments, cheap enough that the subsystems that
//! migrated their ad-hoc tallies here (pipeline starvation/flush,
//! serve truncation, cluster link stats) keep their RunLog values
//! bit-for-bit whether or not `[obs]` is enabled. Only trace collection
//! and the RunLog `metrics` export section are gated.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log₂ buckets per histogram; bucket `i` counts observations
/// in `[2^(i - BUCKET_BIAS), 2^(i + 1 - BUCKET_BIAS))`.
pub const HIST_BUCKETS: usize = 32;
/// Bias applied to the log₂ exponent so sub-unit values (milliseconds
/// expressed in seconds) land in distinct buckets: bucket 0 holds
/// everything below `2^-BUCKET_BIAS`.
pub const BUCKET_BIAS: i32 = 20;

/// A monotonic counter. Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct CounterHandle(Arc<AtomicU64>);

impl CounterHandle {
    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A floating-point gauge (also used as an accumulating float tally,
/// e.g. bytes or seconds per cluster link). Cloning shares the cell;
/// the value is stored as `f64` bits in an atomic.
#[derive(Clone, Debug, Default)]
pub struct GaugeHandle(Arc<AtomicU64>);

impl GaugeHandle {
    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Accumulate `v` (CAS loop over the f64 bit pattern).
    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct Histo {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

/// A fixed-log-bucket histogram (32 log₂ buckets). Used for serve batch
/// latencies; exports `count`, `sum`, and each non-empty bucket.
#[derive(Clone, Debug)]
pub struct HistogramHandle(Arc<Histo>);

impl Default for HistogramHandle {
    fn default() -> Self {
        HistogramHandle(Arc::new(Histo {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
        }))
    }
}

impl HistogramHandle {
    /// Bucket index for a value: floored log₂ plus [`BUCKET_BIAS`],
    /// clamped to the fixed range. Non-positive values land in bucket 0.
    pub fn bucket_of(v: f64) -> usize {
        if v <= 0.0 || !v.is_finite() {
            return 0;
        }
        let idx = v.log2().floor() as i64 + BUCKET_BIAS as i64;
        idx.clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    /// Upper bound (exclusive) of bucket `i`.
    pub fn bucket_bound(i: usize) -> f64 {
        2f64.powi(i as i32 + 1 - BUCKET_BIAS)
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        self.0.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        // Accumulate the sum via the same CAS-over-bits scheme as GaugeHandle.
        let cell = &self.0.sum_bits;
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of observed values.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// `(bucket_index, count)` for every non-empty bucket.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.0
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect()
    }
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(CounterHandle),
    Gauge(GaugeHandle),
    Histogram(HistogramHandle),
}

/// One row of a registry snapshot, as exported into RunLog CSV/JSON.
/// Histograms expand into `<name>.count`, `<name>.sum`, and one
/// `<name>.le_<bound>` row per non-empty bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricRow {
    /// Stable dotted metric name.
    pub name: String,
    /// `counter`, `gauge`, or `histogram`.
    pub kind: &'static str,
    /// Current value (counters cast to `f64`; counts are small enough
    /// that the cast is exact).
    pub value: f64,
}

/// The metric registry: dotted names → typed handles. Get-or-register
/// semantics; snapshots iterate in name order (BTreeMap) so exports are
/// deterministic.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    /// Fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or register the counter called `name`. Panics if `name` is
    /// already registered with a different kind (a naming bug).
    pub fn counter(&self, name: &str) -> CounterHandle {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(CounterHandle::default()))
        {
            Metric::Counter(h) => h.clone(),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get or register the gauge called `name`. Panics on kind mismatch.
    pub fn gauge(&self, name: &str) -> GaugeHandle {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(GaugeHandle::default()))
        {
            Metric::Gauge(h) => h.clone(),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get or register the histogram called `name`. Panics on kind
    /// mismatch.
    pub fn histogram(&self, name: &str) -> HistogramHandle {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(HistogramHandle::default()))
        {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Deterministic snapshot: one [`MetricRow`] per counter/gauge, and
    /// an expansion per histogram, in name order.
    pub fn snapshot(&self) -> Vec<MetricRow> {
        let m = self.metrics.lock().unwrap();
        let mut rows = Vec::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(h) => rows.push(MetricRow {
                    name: name.clone(),
                    kind: "counter",
                    value: h.get() as f64,
                }),
                Metric::Gauge(h) => rows.push(MetricRow {
                    name: name.clone(),
                    kind: "gauge",
                    value: h.get(),
                }),
                Metric::Histogram(h) => {
                    rows.push(MetricRow {
                        name: format!("{name}.count"),
                        kind: "histogram",
                        value: h.count() as f64,
                    });
                    rows.push(MetricRow {
                        name: format!("{name}.sum"),
                        kind: "histogram",
                        value: h.sum(),
                    });
                    for (i, n) in h.nonzero_buckets() {
                        rows.push(MetricRow {
                            name: format!("{name}.le_{:e}", HistogramHandle::bucket_bound(i)),
                            kind: "histogram",
                            value: n as f64,
                        });
                    }
                }
            }
        }
        rows
    }
}

/// Per-name difference `after - before` of two snapshots, keeping only
/// names whose value changed (names present only in `after` count from
/// zero). Used to attribute counter deltas to a window of work.
pub fn diff(before: &[MetricRow], after: &[MetricRow]) -> Vec<MetricRow> {
    let base: BTreeMap<&str, f64> = before.iter().map(|r| (r.name.as_str(), r.value)).collect();
    after
        .iter()
        .filter_map(|r| {
            let d = r.value - base.get(r.name.as_str()).copied().unwrap_or(0.0);
            (d != 0.0).then(|| MetricRow { name: r.name.clone(), kind: r.kind, value: d })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_one_cell() {
        let reg = Registry::new();
        let a = reg.counter("x.hits");
        let b = reg.counter("x.hits");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5);
        assert_eq!(b.get(), 5);
    }

    #[test]
    fn gauge_add_accumulates() {
        let reg = Registry::new();
        let g = reg.gauge("x.bytes");
        g.add(1.5);
        g.add(2.5);
        assert_eq!(g.get(), 4.0);
        g.set(7.0);
        assert_eq!(g.get(), 7.0);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        let _ = reg.counter("x");
        let _ = reg.gauge("x");
    }

    #[test]
    fn histogram_buckets_cover_range() {
        assert_eq!(HistogramHandle::bucket_of(0.0), 0);
        assert_eq!(HistogramHandle::bucket_of(-1.0), 0);
        assert_eq!(HistogramHandle::bucket_of(f64::NAN), 0);
        // 1e-3 s ≈ 2^-9.97 → exponent -10 → bucket 10 with bias 20.
        assert_eq!(HistogramHandle::bucket_of(1e-3), 10);
        // Huge values clamp to the top bucket.
        assert_eq!(HistogramHandle::bucket_of(1e30), HIST_BUCKETS - 1);
        // Bounds are exclusive upper edges: a value just below the bound
        // stays in its bucket.
        let b = HistogramHandle::bucket_of(1e-3);
        assert!(1e-3 < HistogramHandle::bucket_bound(b));
    }

    #[test]
    fn histogram_snapshot_expands_nonzero_buckets() {
        let reg = Registry::new();
        let h = reg.histogram("serve.batch_secs");
        h.observe(1e-3);
        h.observe(1e-3);
        h.observe(2.0);
        let rows = reg.snapshot();
        assert_eq!(rows[0].name, "serve.batch_secs.count");
        assert_eq!(rows[0].value, 3.0);
        assert_eq!(rows[1].name, "serve.batch_secs.sum");
        assert!((rows[1].value - 2.002).abs() < 1e-12);
        // Two non-empty buckets follow.
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn snapshot_is_name_ordered_and_diff_filters_unchanged() {
        let reg = Registry::new();
        let b = reg.counter("b.n");
        let a = reg.counter("a.n");
        a.inc();
        let s1 = reg.snapshot();
        assert_eq!(s1[0].name, "a.n");
        assert_eq!(s1[1].name, "b.n");
        b.add(3);
        let s2 = reg.snapshot();
        let d = diff(&s1, &s2);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].name, "b.n");
        assert_eq!(d[0].value, 3.0);
    }
}

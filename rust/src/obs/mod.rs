//! Unified observability plane: structured spans, Chrome-trace export,
//! and one counter registry across train/serve/fleet/cluster.
//!
//! Every scheduling decision in Adaptive SGD is driven by measured time;
//! this module is where that time becomes visible. Three layers:
//!
//! 1. **Spans/events** ([`sink`]): a bounded ring buffer of
//!    subsystem-tagged spans and instants, stamped on the virtual clock
//!    by the discrete-event paths and on the wall clock by the threaded
//!    engine. Zero-cost no-op when `[obs]` is disabled.
//! 2. **Chrome-trace export** ([`chrome`]): Catapult/Perfetto
//!    `trace_event` JSON — one lane per device/server/serve-replica —
//!    written by the `--trace out.json` CLI flag. Bit-deterministic in
//!    virtual mode.
//! 3. **Counter registry** ([`registry`]): typed monotonic counters,
//!    gauges and log-bucket histograms behind stable dotted names,
//!    always on (the migrated subsystem tallies live here), snapshot
//!    into the RunLog `metrics` section when `[obs]` is enabled.
//!
//! The plane is threaded through the tree as an [`ObsHandle`] — a cheap
//! cloneable bundle of `(sink, registry, pid)`. The CLI installs the
//! configured handle as the process-wide *ambient* handle
//! ([`install_ambient`]); `TrainerOptions::default()` and the
//! experiment entry points pick it up from there, so library callers
//! that never mention obs keep byte-identical behavior. Tests inject
//! explicit handles through the `*_with` entry-point variants instead.

pub mod analyze;
pub mod chrome;
pub mod registry;
pub mod sink;

pub use registry::{diff, CounterHandle, GaugeHandle, HistogramHandle, MetricRow, Registry};
pub use sink::{ArgVal, EventKind, Level, SpanGuard, Subsystem, TraceEvent, TraceSink};

use std::sync::{Arc, Mutex, OnceLock};

use crate::config::ObsConfig;

/// A cheap, cloneable handle onto the observability plane: the trace
/// sink, the metric registry, and the process lane (`pid`) this clone
/// stamps on its events. All clones share the same sink and registry;
/// [`ObsHandle::for_pid`] re-lanes a clone for a cluster server or fleet
/// tenant.
#[derive(Clone, Debug)]
pub struct ObsHandle {
    sink: Arc<TraceSink>,
    registry: Arc<Registry>,
    pid: u32,
}

impl Default for ObsHandle {
    /// The ambient handle (disabled unless the CLI installed one).
    fn default() -> Self {
        ambient()
    }
}

impl ObsHandle {
    /// A handle whose sink drops everything (the registry still works —
    /// it is always on).
    pub fn disabled() -> ObsHandle {
        ObsHandle {
            sink: Arc::new(TraceSink::disabled()),
            registry: Arc::new(Registry::new()),
            pid: 0,
        }
    }

    /// Build a handle from the `[obs]` config section. `force_trace`
    /// arms the sink even when `enabled = false` (the `--trace` flag
    /// implies collection). The config is assumed validated: unknown
    /// level/subsystem strings fall back to `info` / all.
    pub fn from_config(cfg: &ObsConfig, force_trace: bool) -> ObsHandle {
        let enabled = cfg.enabled || force_trace;
        let level = Level::parse(&cfg.level).unwrap_or(Level::Info);
        let subs: Vec<Subsystem> =
            cfg.subsystems.iter().filter_map(|s| Subsystem::parse(s)).collect();
        ObsHandle {
            sink: Arc::new(TraceSink::new(
                enabled,
                TraceSink::mask_of(&subs),
                level,
                cfg.buffer_events,
            )),
            registry: Arc::new(Registry::new()),
            pid: 0,
        }
    }

    /// Assemble a handle from explicit parts (test-only seam for the
    /// analyze module).
    #[cfg(test)]
    pub(crate) fn from_parts_for_tests(
        sink: Arc<TraceSink>,
        registry: Arc<Registry>,
    ) -> ObsHandle {
        ObsHandle { sink, registry, pid: 0 }
    }

    /// A clone stamping `pid` as its process lane (shares sink and
    /// registry with `self`).
    pub fn for_pid(&self, pid: u32) -> ObsHandle {
        ObsHandle { sink: self.sink.clone(), registry: self.registry.clone(), pid }
    }

    /// This handle's process lane.
    pub fn pid(&self) -> u32 {
        self.pid
    }

    /// Whether the sink records anything (the registry is always on).
    pub fn enabled(&self) -> bool {
        self.sink.enabled()
    }

    /// The shared trace sink.
    pub fn sink(&self) -> &TraceSink {
        &self.sink
    }

    /// The shared metric registry.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Registry snapshot if the plane is enabled, else empty (keeps the
    /// RunLog `metrics` section absent for disabled runs). The sink's
    /// own truncation state rides along as `obs.dropped_events` /
    /// `obs.spans_opened` / `obs.spans_closed` rows, so a truncated ring
    /// is visible even when no trace file was exported.
    pub fn metrics_rows(&self) -> Vec<MetricRow> {
        if !self.enabled() {
            return Vec::new();
        }
        let mut rows = self.registry.snapshot();
        let (opened, closed) = self.sink.balance();
        rows.push(MetricRow {
            name: "obs.dropped_events".to_string(),
            kind: "counter",
            value: self.sink.dropped() as f64,
        });
        rows.push(MetricRow {
            name: "obs.spans_closed".to_string(),
            kind: "counter",
            value: closed as f64,
        });
        rows.push(MetricRow {
            name: "obs.spans_opened".to_string(),
            kind: "counter",
            value: opened as f64,
        });
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    // -- emission helpers ---------------------------------------------------

    /// Record an info-level span at an explicit timestamp (virtual-clock
    /// emitters).
    #[inline]
    pub fn span(
        &self,
        sub: Subsystem,
        name: &'static str,
        tid: u32,
        ts: f64,
        dur: f64,
        args: Vec<(&'static str, ArgVal)>,
    ) {
        self.sink.span_at(sub, Level::Info, name, self.pid, tid, ts, dur, args);
    }

    /// Record an info-level instant event at an explicit timestamp.
    #[inline]
    pub fn instant(
        &self,
        sub: Subsystem,
        name: &'static str,
        tid: u32,
        ts: f64,
        args: Vec<(&'static str, ArgVal)>,
    ) {
        self.sink.instant_at(sub, Level::Info, name, self.pid, tid, ts, args);
    }

    /// Record a debug-level instant event (high-volume detail).
    #[inline]
    pub fn instant_debug(
        &self,
        sub: Subsystem,
        name: &'static str,
        tid: u32,
        ts: f64,
        args: Vec<(&'static str, ArgVal)>,
    ) {
        self.sink.instant_at(sub, Level::Debug, name, self.pid, tid, ts, args);
    }

    /// Open an info-level wall-clock span (threaded-engine emitters).
    #[inline]
    pub fn begin(&self, sub: Subsystem, name: &'static str, tid: u32) -> Option<SpanGuard> {
        self.sink.begin(sub, Level::Info, name, self.pid, tid)
    }

    /// Close a span from [`ObsHandle::begin`].
    #[inline]
    pub fn end(&self, guard: SpanGuard, args: Vec<(&'static str, ArgVal)>) {
        self.sink.end(guard, args);
    }

    /// Wall seconds since the sink's epoch.
    pub fn now(&self) -> f64 {
        self.sink.now()
    }

    /// Set the virtual-clock base for engine-emitted spans (called by
    /// the trainer before each mega-batch dispatch).
    pub fn set_time_base(&self, base: f64) {
        self.sink.set_time_base(base);
    }

    /// The current virtual-clock base.
    pub fn time_base(&self) -> f64 {
        self.sink.time_base()
    }

    // -- registry shorthands ------------------------------------------------

    /// Get or register a counter (see [`Registry::counter`]).
    pub fn counter(&self, name: &str) -> CounterHandle {
        self.registry.counter(name)
    }

    /// Get or register a gauge (see [`Registry::gauge`]).
    pub fn gauge(&self, name: &str) -> GaugeHandle {
        self.registry.gauge(name)
    }

    /// Get or register a histogram (see [`Registry::histogram`]).
    pub fn histogram(&self, name: &str) -> HistogramHandle {
        self.registry.histogram(name)
    }
}

static AMBIENT: OnceLock<Mutex<ObsHandle>> = OnceLock::new();

fn ambient_cell() -> &'static Mutex<ObsHandle> {
    AMBIENT.get_or_init(|| Mutex::new(ObsHandle::disabled()))
}

/// The process-wide ambient handle (disabled unless [`install_ambient`]
/// was called). `TrainerOptions::default()` and the experiment wrappers
/// read this, so obs reaches every subsystem with zero signature churn.
pub fn ambient() -> ObsHandle {
    ambient_cell().lock().unwrap().clone()
}

/// Install `handle` as the process-wide ambient handle. Called once by
/// the CLI after parsing config + flags; tests prefer passing explicit
/// handles through the `*_with` entry points instead of mutating
/// process-global state.
pub fn install_ambient(handle: ObsHandle) {
    *ambient_cell().lock().unwrap() = handle;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert_but_registry_counts() {
        let h = ObsHandle::disabled();
        assert!(!h.enabled());
        h.span(Subsystem::Train, "x", 0, 0.0, 1.0, Vec::new());
        assert!(h.sink().is_empty());
        let c = h.counter("n");
        c.inc();
        assert_eq!(c.get(), 1);
        assert!(h.metrics_rows().is_empty(), "metrics export gated on enabled");
    }

    #[test]
    fn for_pid_shares_sink_and_registry() {
        let cfg = ObsConfig { enabled: true, ..ObsConfig::default() };
        let h = ObsHandle::from_config(&cfg, false);
        let h1 = h.for_pid(3);
        h1.instant(Subsystem::Cluster, "sync", 0, 1.0, Vec::new());
        let evs = h.sink().events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].pid, 3);
        h1.counter("c").inc();
        assert_eq!(h.counter("c").get(), 1);
    }

    #[test]
    fn force_trace_arms_a_disabled_config() {
        let cfg = ObsConfig::default();
        assert!(!ObsHandle::from_config(&cfg, false).enabled());
        assert!(ObsHandle::from_config(&cfg, true).enabled());
    }

    #[test]
    fn subsystem_filter_from_config() {
        let cfg = ObsConfig {
            enabled: true,
            subsystems: vec!["serve".to_string()],
            ..ObsConfig::default()
        };
        let h = ObsHandle::from_config(&cfg, false);
        h.instant(Subsystem::Train, "t", 0, 0.0, Vec::new());
        h.instant(Subsystem::Serve, "s", 0, 0.0, Vec::new());
        let evs = h.sink().events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "s");
    }
}

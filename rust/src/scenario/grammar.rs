//! The unified scenario grammar: one tokenizer/parser behind every
//! scripted event list in the config (`[elastic]`, `[calibration]`,
//! `[serve]`, `[fleet]`, `[cluster]`, and the cross-subsystem
//! `[scenario]` block).
//!
//! Every event line is whitespace-separated `key=value` tokens (plus the
//! bare `up` / `down` rack-state words) anchored by `at_mb=N`:
//!
//! ```text
//! event   := token+                         (one subsystem verb per event)
//! token   := "at_mb=" int | verb | "up" | "down"
//! verb    := pool | drift | link | rack
//! pool    := ("remove"|"add"|"remove_id"|"add_id") "=" int
//! drift   := "device=" int | "factor=" float | "ramp=" int
//! link    := "link="   int | "factor=" float | "ramp=" int
//! rack    := "server=" int                  (with a bare "up"/"down")
//! ```
//!
//! A [`Mask`] selects which families a call site accepts, which is how the
//! legacy per-subsystem parsers ([`ElasticEvent::parse`],
//! [`DriftEvent::parse`](crate::tuning::DriftEvent::parse),
//! [`ClusterEvent::parse`](crate::cluster::ClusterEvent::parse)) became
//! thin views over this one tokenizer: each passes its family mask and the
//! accepted language — including every rejection quirk the tests pin
//! (duplicate keys, mixed verbs, `remove=0` no-ops, last-wins `ramp`) — is
//! unchanged.
//!
//! Compound lines (`[scenario] events` only) chain clauses with `;`; later
//! clauses inherit `at_mb` from the previous clause and may carry an
//! explicit `target:` prefix. See [`route_line`].

use std::fmt;

use anyhow::{bail, Context};

use crate::config::{ElasticEvent, ElasticOp};
use crate::tuning::DriftEvent;
use crate::Result;

/// The four event families the grammar knows about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Pool churn: `remove= | add= | remove_id= | add_id=`.
    Pool,
    /// Per-device cost drift: `device= factor= [ramp=]`.
    Drift,
    /// Inter-server link throttle: `link= factor= [ramp=]`.
    Link,
    /// Whole-server outage / recovery: `server=` + bare `down` / `up`.
    Rack,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Pool => "pool",
            Family::Drift => "drift",
            Family::Link => "link",
            Family::Rack => "rack",
        }
    }
}

/// Bitmask of event families a call site accepts. Gates which verbs the
/// tokenizer recognises, so unknown-key errors list exactly the accepting
/// subsystem's vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mask(u8);

impl Mask {
    pub const POOL: Mask = Mask(1);
    pub const DRIFT: Mask = Mask(2);
    pub const LINK: Mask = Mask(4);
    pub const RACK: Mask = Mask(8);
    /// `[cluster] events`: link throttles and rack outages.
    pub const CLUSTER: Mask = Mask(4 | 8);
    /// The `[scenario]` block: every family.
    pub const ALL: Mask = Mask(15);

    pub fn union(self, other: Mask) -> Mask {
        Mask(self.0 | other.0)
    }

    pub fn allows(self, family: Family) -> bool {
        match family {
            Family::Pool => self.0 & 1 != 0,
            Family::Drift => self.0 & 2 != 0,
            Family::Link => self.0 & 4 != 0,
            Family::Rack => self.0 & 8 != 0,
        }
    }

    /// The `key=` vocabulary this mask accepts, for error messages.
    fn vocabulary(self) -> String {
        let mut keys = vec!["at_mb"];
        if self.allows(Family::Pool) {
            keys.extend(["remove", "add", "remove_id", "add_id"]);
        }
        if self.allows(Family::Drift) {
            keys.push("device");
        }
        if self.allows(Family::Link) {
            keys.push("link");
        }
        if self.allows(Family::Drift) || self.allows(Family::Link) {
            keys.extend(["factor", "ramp"]);
        }
        if self.allows(Family::Rack) {
            keys.extend(["server", "down", "up"]);
        }
        keys.join("|")
    }

    /// What a line with no subsystem verb was missing, per family.
    fn wanted(self) -> String {
        let mut parts = Vec::new();
        if self.allows(Family::Pool) {
            parts.push("an operation (remove|add|remove_id|add_id)");
        }
        if self.allows(Family::Drift) {
            parts.push("device=D");
        }
        if self.allows(Family::Link) && self.allows(Family::Rack) {
            parts.push("link=L or server=S");
        } else if self.allows(Family::Link) {
            parts.push("link=L");
        } else if self.allows(Family::Rack) {
            parts.push("server=S");
        }
        parts.join(" or ")
    }
}

/// One parsed scenario event, any family. `Pool` and `Drift`/`Link` wrap
/// the legacy structs directly so the per-subsystem views are zero-cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScenarioEvent {
    /// Pool churn (elastic / serve / fleet event lists).
    Pool(ElasticEvent),
    /// Per-device cost drift (`[calibration] events`).
    Drift(DriftEvent),
    /// Link throttle (`[cluster] events`; the `device` slot holds the
    /// link id).
    Link(DriftEvent),
    /// Server outage / recovery (`[cluster] events`).
    Rack { at_mb: usize, server: usize, up: bool },
}

impl ScenarioEvent {
    pub fn at_mb(&self) -> usize {
        match self {
            ScenarioEvent::Pool(e) => e.at_mb,
            ScenarioEvent::Drift(d) | ScenarioEvent::Link(d) => d.at_mb,
            ScenarioEvent::Rack { at_mb, .. } => *at_mb,
        }
    }

    pub fn family(&self) -> Family {
        match self {
            ScenarioEvent::Pool(_) => Family::Pool,
            ScenarioEvent::Drift(_) => Family::Drift,
            ScenarioEvent::Link(_) => Family::Link,
            ScenarioEvent::Rack { .. } => Family::Rack,
        }
    }
}

impl fmt::Display for ScenarioEvent {
    /// Canonical form: `at_mb` first, `ramp=` omitted when 0, rack state
    /// last. Parsing the output reproduces the event exactly (the
    /// round-trip property in `integration_scenario.rs`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "at_mb={}", self.at_mb())?;
        match self {
            ScenarioEvent::Pool(e) => match e.op {
                ElasticOp::Remove(n) => write!(f, " remove={n}"),
                ElasticOp::Add(n) => write!(f, " add={n}"),
                ElasticOp::RemoveId(d) => write!(f, " remove_id={d}"),
                ElasticOp::AddId(d) => write!(f, " add_id={d}"),
            },
            ScenarioEvent::Drift(d) => {
                write!(f, " device={} factor={}", d.device, d.factor)?;
                if d.ramp > 0 {
                    write!(f, " ramp={}", d.ramp)?;
                }
                Ok(())
            }
            ScenarioEvent::Link(d) => {
                write!(f, " link={} factor={}", d.device, d.factor)?;
                if d.ramp > 0 {
                    write!(f, " ramp={}", d.ramp)?;
                }
                Ok(())
            }
            ScenarioEvent::Rack { server, up, .. } => {
                write!(f, " server={} {}", server, if *up { "up" } else { "down" })
            }
        }
    }
}

/// Raw fields scanned off one clause, before family classification.
#[derive(Default)]
struct Fields {
    at_mb: Option<usize>,
    op: Option<ElasticOp>,
    device: Option<usize>,
    link: Option<usize>,
    server: Option<usize>,
    factor: Option<f64>,
    ramp: usize,
    state: Option<bool>,
}

/// Tokenize one clause under `mask`. Duplicate-key and unknown-key
/// rejection happens here; `ramp=` is deliberately last-wins (the one
/// duplicate the legacy drift grammar allowed, pinned by its tests).
fn scan(s: &str, mask: Mask) -> Result<Fields> {
    let mut f = Fields::default();
    for tok in s.split_whitespace() {
        if mask.allows(Family::Rack) && (tok == "down" || tok == "up") {
            if f.state.replace(tok == "up").is_some() {
                bail!("scenario event '{s}' has more than one up/down");
            }
            continue;
        }
        let (key, value) = tok
            .split_once('=')
            .with_context(|| format!("scenario event token '{tok}' is not key=value"))?;
        match key {
            "at_mb" => {
                let n: usize = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
                if f.at_mb.replace(n).is_some() {
                    bail!("scenario event '{s}' has more than one at_mb");
                }
            }
            "remove" | "add" | "remove_id" | "add_id" if mask.allows(Family::Pool) => {
                let n: usize = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
                let op = match key {
                    "remove" => ElasticOp::Remove(n),
                    "add" => ElasticOp::Add(n),
                    "remove_id" => ElasticOp::RemoveId(n),
                    _ => ElasticOp::AddId(n),
                };
                if f.op.replace(op).is_some() {
                    bail!(
                        "scenario event '{s}' has more than one operation; \
                         use one event string per operation"
                    );
                }
            }
            "device" if mask.allows(Family::Drift) => {
                let n: usize = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
                if f.device.replace(n).is_some() {
                    bail!("scenario event '{s}' has more than one device");
                }
            }
            "link" if mask.allows(Family::Link) => {
                let n: usize = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
                if f.link.replace(n).is_some() {
                    bail!("scenario event '{s}' has more than one link");
                }
            }
            "server" if mask.allows(Family::Rack) => {
                let n: usize = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
                if f.server.replace(n).is_some() {
                    bail!("scenario event '{s}' has more than one server");
                }
            }
            "factor" if mask.allows(Family::Drift) || mask.allows(Family::Link) => {
                let x: f64 = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not a number"))?;
                if f.factor.replace(x).is_some() {
                    bail!("scenario event '{s}' has more than one factor");
                }
            }
            "ramp" if mask.allows(Family::Drift) || mask.allows(Family::Link) => {
                // Last-wins, matching the legacy drift grammar.
                f.ramp = value
                    .parse()
                    .with_context(|| format!("scenario event value '{value}' is not an integer"))?;
            }
            other => {
                bail!("unknown scenario event key '{other}' ({})", mask.vocabulary())
            }
        }
    }
    Ok(f)
}

/// Classify scanned fields into exactly one family, enforcing the
/// cross-family exclusions the legacy parsers had (`up`/`down` only with
/// `server=`, `factor`/`ramp` never with `server=`, one verb per event).
fn classify(s: &str, mask: Mask, f: Fields, inherit_at: Option<usize>) -> Result<ScenarioEvent> {
    let at_mb = match f.at_mb.or(inherit_at) {
        Some(n) => n,
        None => bail!("scenario event '{s}' missing at_mb=N"),
    };
    let mut families = Vec::new();
    if f.op.is_some() {
        families.push(Family::Pool);
    }
    if f.device.is_some() {
        families.push(Family::Drift);
    }
    if f.link.is_some() {
        families.push(Family::Link);
    }
    if f.server.is_some() {
        families.push(Family::Rack);
    }
    if families.len() > 1 {
        bail!(
            "scenario event '{s}' mixes {} and {} verbs (one subsystem per clause; \
             separate clauses with ';')",
            families[0].name(),
            families[1].name()
        );
    }
    match families.first() {
        Some(Family::Pool) => {
            if f.factor.is_some() || f.ramp > 0 {
                bail!("scenario event '{s}': factor/ramp apply to device= or link=, not pool ops");
            }
            if f.state.is_some() {
                bail!("scenario event '{s}': up/down applies to server=, not pool ops");
            }
            let op = f.op.expect("classified as pool");
            if let ElasticOp::Remove(0) | ElasticOp::Add(0) = op {
                bail!("scenario event '{s}' is a no-op (count 0)");
            }
            Ok(ScenarioEvent::Pool(ElasticEvent { at_mb, op }))
        }
        Some(Family::Drift) | Some(Family::Link) => {
            if f.state.is_some() {
                bail!("scenario event '{s}': up/down applies to server=, not device=/link=");
            }
            let factor = f
                .factor
                .with_context(|| format!("scenario event '{s}' missing factor=F"))?;
            if factor <= 0.0 {
                bail!("scenario event '{s}': factor must be positive");
            }
            let drift = DriftEvent {
                at_mb,
                device: f.device.or(f.link).expect("classified as drift/link"),
                factor,
                ramp: f.ramp,
            };
            if f.device.is_some() {
                Ok(ScenarioEvent::Drift(drift))
            } else {
                Ok(ScenarioEvent::Link(drift))
            }
        }
        Some(Family::Rack) => {
            if f.factor.is_some() || f.ramp > 0 {
                bail!("scenario event '{s}': factor/ramp apply to link= or device=, not server=");
            }
            let up = f
                .state
                .with_context(|| format!("scenario event '{s}' missing down or up"))?;
            Ok(ScenarioEvent::Rack { at_mb, server: f.server.expect("classified as rack"), up })
        }
        None => bail!("scenario event '{s}' missing {}", mask.wanted()),
    }
}

/// Parse one single-clause event under `mask`. This is the function the
/// legacy per-subsystem parsers delegate to.
pub fn parse_event(s: &str, mask: Mask) -> Result<ScenarioEvent> {
    classify(s, mask, scan(s, mask)?, None)
}

/// Parse a compound line: `;`-separated clauses under one mask. Later
/// clauses inherit `at_mb` from the previous clause when they omit it.
pub fn parse_line(line: &str, mask: Mask) -> Result<Vec<ScenarioEvent>> {
    let mut out = Vec::new();
    let mut inherit = None;
    for clause in line.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            bail!("scenario line '{line}' has an empty clause");
        }
        let ev = classify(clause, mask, scan(clause, mask)?, inherit)?;
        inherit = Some(ev.at_mb());
        out.push(ev);
    }
    if out.is_empty() {
        bail!("scenario line '{line}' is empty");
    }
    Ok(out)
}

/// Which per-subsystem event list a routed clause lands in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    Elastic,
    Calibration,
    Serve,
    Fleet,
    Cluster,
}

impl Target {
    pub fn name(self) -> &'static str {
        match self {
            Target::Elastic => "elastic",
            Target::Calibration => "calibration",
            Target::Serve => "serve",
            Target::Fleet => "fleet",
            Target::Cluster => "cluster",
        }
    }

    fn parse(s: &str) -> Option<Target> {
        match s {
            "elastic" => Some(Target::Elastic),
            "calibration" => Some(Target::Calibration),
            "serve" => Some(Target::Serve),
            "fleet" => Some(Target::Fleet),
            "cluster" => Some(Target::Cluster),
            _ => None,
        }
    }

    /// The families a target's event list accepts.
    pub fn mask(self) -> Mask {
        match self {
            Target::Elastic | Target::Serve | Target::Fleet => Mask::POOL,
            Target::Calibration => Mask::DRIFT,
            Target::Cluster => Mask::CLUSTER,
        }
    }

    /// Default routing for an untagged clause, by family. `Pool` has three
    /// possible homes; untagged pool clauses go to the training pool
    /// (`[elastic]`) — tag `serve:` / `fleet:` to route elsewhere.
    fn for_family(family: Family) -> Target {
        match family {
            Family::Pool => Target::Elastic,
            Family::Drift => Target::Calibration,
            Family::Link | Family::Rack => Target::Cluster,
        }
    }
}

/// Parse one `[scenario] events` line: `;`-separated clauses, each
/// optionally prefixed with `target:` (`serve: at_mb=3 add=1`). Untagged
/// clauses route by family ([`Target::for_family`]); tagged clauses are
/// parsed under the target's own mask so e.g. `cluster: remove=1` is
/// rejected with that subsystem's vocabulary. Later clauses inherit
/// `at_mb` from the previous clause:
///
/// ```text
/// "at_mb=4 server=1 down; link=0 factor=6.0 ramp=2; serve: add=1"
/// ```
///
/// downs server 1, throttles link 0, and grows the serving pool — all at
/// window 4.
pub fn route_line(line: &str) -> Result<Vec<(Target, ScenarioEvent)>> {
    let mut out = Vec::new();
    let mut inherit = None;
    for clause in line.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            bail!("scenario line '{line}' has an empty clause");
        }
        let (tag, body) = match clause.split_once(':') {
            Some((head, rest)) => match Target::parse(head.trim()) {
                Some(t) => (Some(t), rest.trim()),
                None => bail!(
                    "scenario clause '{clause}': unknown target '{}' \
                     (elastic|calibration|serve|fleet|cluster)",
                    head.trim()
                ),
            },
            None => (None, clause),
        };
        let mask = tag.map(Target::mask).unwrap_or(Mask::ALL);
        let ev = classify(body, mask, scan(body, mask)?, inherit)?;
        inherit = Some(ev.at_mb());
        out.push((tag.unwrap_or_else(|| Target::for_family(ev.family())), ev));
    }
    if out.is_empty() {
        bail!("scenario line '{line}' is empty");
    }
    Ok(out)
}

/// Parse a whole event list, wrapping any error with the offending array
/// index and the full line — `section[i]: '<line>': <cause>`. Every
/// `parsed_events()` goes through here (the ISSUE-10 error-reporting fix).
pub fn parse_trace_indexed<T>(
    section: &str,
    events: &[String],
    parse: impl Fn(&str) -> Result<T>,
) -> Result<Vec<T>> {
    events
        .iter()
        .enumerate()
        .map(|(i, s)| parse(s).with_context(|| format!("{section}[{i}]: '{s}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(s: &str) -> Result<ScenarioEvent> {
        parse_event(s, Mask::POOL)
    }

    #[test]
    fn pool_events_parse_and_reject_like_legacy() {
        assert_eq!(
            pool("at_mb=20 remove=2").unwrap(),
            ScenarioEvent::Pool(ElasticEvent { at_mb: 20, op: ElasticOp::Remove(2) })
        );
        assert_eq!(
            pool("add_id=3 at_mb=5").unwrap(),
            ScenarioEvent::Pool(ElasticEvent { at_mb: 5, op: ElasticOp::AddId(3) })
        );
        // Rejection quirks pinned by the legacy tests.
        assert!(pool("at_mb=1").is_err(), "missing op");
        assert!(pool("remove=1").is_err(), "missing at_mb");
        assert!(pool("at_mb=1 remove=0").is_err(), "no-op count");
        assert!(pool("at_mb=1 remove=1 add=1").is_err(), "two ops");
        assert!(pool("at_mb=1 at_mb=2 add=1").is_err(), "two at_mb");
        assert!(pool("at_mb=x add=1").is_err(), "non-integer");
        assert!(pool("at_mb=1 explode=1").is_err(), "unknown key");
        // remove_id=0 / add_id=0 name device 0 — not no-ops.
        assert!(pool("at_mb=1 remove_id=0").is_ok());
        // Other families' verbs are unknown keys under the pool mask.
        assert!(pool("at_mb=1 device=0 factor=2.0").is_err());
        assert!(pool("at_mb=1 server=0 down").is_err());
    }

    #[test]
    fn drift_events_parse_and_reject_like_legacy() {
        let ev = parse_event("at_mb=10 device=1 factor=1.8 ramp=2", Mask::DRIFT).unwrap();
        assert_eq!(
            ev,
            ScenarioEvent::Drift(DriftEvent { at_mb: 10, device: 1, factor: 1.8, ramp: 2 })
        );
        // ramp defaults to 0 and is the one last-wins duplicate.
        let ev = parse_event("at_mb=1 device=0 factor=2.0 ramp=1 ramp=3", Mask::DRIFT).unwrap();
        assert_eq!(
            ev,
            ScenarioEvent::Drift(DriftEvent { at_mb: 1, device: 0, factor: 2.0, ramp: 3 })
        );
        assert!(parse_event("at_mb=1 device=0", Mask::DRIFT).is_err(), "missing factor");
        assert!(parse_event("at_mb=1 factor=2.0", Mask::DRIFT).is_err(), "missing device");
        assert!(parse_event("device=0 factor=2.0", Mask::DRIFT).is_err(), "missing at_mb");
        assert!(parse_event("at_mb=1 device=0 factor=0.0", Mask::DRIFT).is_err(), "factor<=0");
        assert!(
            parse_event("at_mb=1 device=0 device=1 factor=2.0", Mask::DRIFT).is_err(),
            "dup device"
        );
        assert!(parse_event("at_mb=1 device=0 factor=2.0 up", Mask::DRIFT).is_err(), "bare word");
    }

    #[test]
    fn cluster_events_parse_and_reject_like_legacy() {
        assert_eq!(
            parse_event("at_mb=8 link=1 factor=6.0 ramp=2", Mask::CLUSTER).unwrap(),
            ScenarioEvent::Link(DriftEvent { at_mb: 8, device: 1, factor: 6.0, ramp: 2 })
        );
        assert_eq!(
            parse_event("at_mb=12 server=2 down", Mask::CLUSTER).unwrap(),
            ScenarioEvent::Rack { at_mb: 12, server: 2, up: false }
        );
        assert_eq!(
            parse_event("at_mb=20 up server=2", Mask::CLUSTER).unwrap(),
            ScenarioEvent::Rack { at_mb: 20, server: 2, up: true }
        );
        assert!(parse_event("at_mb=1 link=0 server=1 down", Mask::CLUSTER).is_err(), "both");
        assert!(parse_event("at_mb=1 down", Mask::CLUSTER).is_err(), "neither");
        assert!(parse_event("at_mb=1 server=1", Mask::CLUSTER).is_err(), "missing state");
        assert!(parse_event("at_mb=1 server=1 down up", Mask::CLUSTER).is_err(), "dup state");
        assert!(parse_event("at_mb=1 link=0 factor=2.0 down", Mask::CLUSTER).is_err());
        assert!(parse_event("at_mb=1 server=1 factor=2.0 down", Mask::CLUSTER).is_err());
        assert!(parse_event("at_mb=1 link=0 factor=0.0", Mask::CLUSTER).is_err(), "factor<=0");
    }

    #[test]
    fn unknown_key_errors_list_the_masks_vocabulary() {
        let e = format!("{:#}", pool("at_mb=1 zap=1").unwrap_err());
        assert!(e.contains("at_mb|remove|add|remove_id|add_id"), "{e}");
        let e = format!("{:#}", parse_event("at_mb=1 zap=1", Mask::DRIFT).unwrap_err());
        assert!(e.contains("at_mb|device|factor|ramp"), "{e}");
        let e = format!("{:#}", parse_event("at_mb=1 zap=1", Mask::CLUSTER).unwrap_err());
        assert!(e.contains("link|factor|ramp|server|down|up"), "{e}");
    }

    #[test]
    fn compound_lines_inherit_at_mb() {
        let evs = parse_line("at_mb=4 server=1 down; link=0 factor=6.0; at_mb=9 server=1 up", Mask::CLUSTER)
            .unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].at_mb(), 4);
        assert_eq!(evs[1].at_mb(), 4, "inherits from the previous clause");
        assert_eq!(evs[2].at_mb(), 9);
        assert!(parse_line("at_mb=1 server=0 down;", Mask::CLUSTER).is_err(), "empty clause");
        assert!(parse_line("server=0 down", Mask::CLUSTER).is_err(), "first clause needs at_mb");
    }

    #[test]
    fn route_line_routes_by_family_and_honors_tags() {
        let routed =
            route_line("at_mb=4 server=1 down; link=0 factor=6.0 ramp=2; serve: add=1; device=0 factor=2.0")
                .unwrap();
        let targets: Vec<Target> = routed.iter().map(|(t, _)| *t).collect();
        assert_eq!(
            targets,
            vec![Target::Cluster, Target::Cluster, Target::Serve, Target::Calibration]
        );
        assert!(routed.iter().all(|(_, e)| e.at_mb() == 4));
        // Untagged pool churn goes to the training pool.
        let routed = route_line("at_mb=3 remove=1").unwrap();
        assert_eq!(routed, vec![(
            Target::Elastic,
            ScenarioEvent::Pool(ElasticEvent { at_mb: 3, op: ElasticOp::Remove(1) })
        )]);
        // A tag restricts the clause to that subsystem's vocabulary.
        assert!(route_line("cluster: at_mb=1 remove=1").is_err());
        assert!(route_line("turbo: at_mb=1 remove=1").is_err(), "unknown target");
    }

    #[test]
    fn display_is_canonical_and_reparses() {
        for s in [
            "at_mb=20 remove=2",
            "at_mb=5 add_id=3",
            "at_mb=10 device=1 factor=1.8 ramp=2",
            "at_mb=8 link=1 factor=6.0",
            "at_mb=12 server=2 down",
        ] {
            let ev = parse_event(s, Mask::ALL).unwrap();
            let printed = ev.to_string();
            assert_eq!(parse_event(&printed, Mask::ALL).unwrap(), ev, "{s} -> {printed}");
        }
        // Canonical form normalises key order and drops ramp=0.
        let ev = parse_event("remove=2 at_mb=20", Mask::ALL).unwrap();
        assert_eq!(ev.to_string(), "at_mb=20 remove=2");
        let ev = parse_event("at_mb=3 device=0 factor=2.5 ramp=0", Mask::ALL).unwrap();
        assert_eq!(ev.to_string(), "at_mb=3 device=0 factor=2.5");
    }

    #[test]
    fn indexed_trace_errors_name_index_and_line() {
        let events = vec!["at_mb=1 remove=1".to_string(), "at_mb=2 explode=9".to_string()];
        let err = parse_trace_indexed("elastic.events", &events, |s| parse_event(s, Mask::POOL))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("elastic.events[1]: 'at_mb=2 explode=9'"), "{msg}");
    }
}

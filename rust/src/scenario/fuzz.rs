//! Seeded cross-subsystem scenario fuzzer (`experiment fuzz`).
//!
//! Generates random-but-valid scenario timelines over a subsystem mask,
//! drives the virtual-mode sims, and property-checks the global invariants
//! after every run:
//!
//! * **epoch-exact emission** — over two full epochs the data pipeline
//!   serves every sample exactly twice, through arbitrary chunk sizes and
//!   `unget` flushes;
//! * **merge-weight sum-to-1** — every recorded mega-batch's merge weights
//!   sum to 1 with inactive roster slots at exactly 0, under scripted pool
//!   churn and cost drift;
//! * **attribution partition** — per-lane span categories
//!   (compute/serve/merge-wait/cluster-sync/idle) partition the lane's
//!   wall-clock exactly;
//! * **request conservation** — every admitted serving request is answered
//!   exactly once (dense unique ids), through serving-pool churn;
//! * **lease conservation** — `co_schedule` completes with its every-tick
//!   ledger audit clean under fleet churn + preemption;
//! * **bit-determinism** — replaying the same case seed reproduces losses,
//!   clocks, active sets, and latency percentiles bit-exactly.
//!
//! Cases are valid by construction (`gen_case` bounds every id by the
//! roster / server count it also generates), so a failure is a real
//! invariant violation, not a config error. Failures shrink greedily —
//! drop event lists, drop trailing events, shorten the horizon — until no
//! smaller case still fails, in the style of
//! [`util::prop`](crate::util::prop).

use std::sync::Arc;

use crate::config::{Config, DataConfig, DeviceConfig, ModelDims, SgdConfig};
use crate::coordinator::backend::RefBackend;
use crate::coordinator::trainer::TrainerOptions;
use crate::data::pipeline::{SampleStream, ShardedDataset};
use crate::data::synthetic::Generator;
use crate::fleet::{co_schedule, TenantJob};
use crate::harness::{run_single, Backend};
use crate::model::ModelState;
use crate::obs::analyze::{attribute, TraceData};
use crate::obs::ObsHandle;
use crate::serve::{replay, ReplayOptions, SnapshotRegistry};
use crate::util::rng::Rng;
use crate::Result;

use super::ScenarioEvent;

/// Which invariant groups a fuzz run drives (`--subsystems`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Subsystems {
    /// Training runs: merge weights, attribution partition, determinism.
    pub train: bool,
    /// Data pipeline: epoch-exact emission through random chunking/unget.
    pub data: bool,
    /// Serving replay: request conservation + latency determinism.
    pub serve: bool,
    /// Fleet co-scheduling: lease conservation audits.
    pub fleet: bool,
    /// Cluster scale-out: hierarchical-merge determinism.
    pub cluster: bool,
}

impl Subsystems {
    pub fn all() -> Subsystems {
        Subsystems { train: true, data: true, serve: true, fleet: true, cluster: true }
    }

    /// Parse a comma list: `train,serve`, `cluster`, `all`. The event
    /// aliases `elastic`, `calibration`, and `slide` map to `train` (their
    /// invariants are checked on the training run).
    pub fn parse(s: &str) -> Result<Subsystems> {
        let mut subs =
            Subsystems { train: false, data: false, serve: false, fleet: false, cluster: false };
        for tok in s.split(',') {
            match tok.trim() {
                "all" => subs = Subsystems::all(),
                "train" | "elastic" | "calibration" | "slide" => subs.train = true,
                "data" => subs.data = true,
                "serve" => subs.serve = true,
                "fleet" => subs.fleet = true,
                "cluster" => subs.cluster = true,
                other => anyhow::bail!(
                    "unknown subsystem '{other}' (train|data|serve|fleet|cluster|all)"
                ),
            }
        }
        if subs == (Subsystems { train: false, data: false, serve: false, fleet: false, cluster: false })
        {
            anyhow::bail!("--subsystems selected nothing (train|data|serve|fleet|cluster|all)");
        }
        Ok(subs)
    }

    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.train {
            parts.push("train");
        }
        if self.data {
            parts.push("data");
        }
        if self.serve {
            parts.push("serve");
        }
        if self.fleet {
            parts.push("fleet");
        }
        if self.cluster {
            parts.push("cluster");
        }
        parts.join(",")
    }
}

/// One generated scenario: topology knobs plus a canonical event timeline
/// per subsystem. Regenerable from `seed` alone (see [`gen_case`]);
/// shrinking produces smaller cases that are no longer seed-derivable,
/// which is why counterexample reports carry the full case.
#[derive(Clone, Debug)]
pub struct FuzzCase {
    pub seed: u64,
    pub devices: usize,
    pub spares: usize,
    pub servers: usize,
    pub mega_batches: usize,
    pub elastic: Vec<ScenarioEvent>,
    pub calibration: Vec<ScenarioEvent>,
    pub serve: Vec<ScenarioEvent>,
    pub fleet: Vec<ScenarioEvent>,
    pub cluster: Vec<ScenarioEvent>,
}

impl FuzzCase {
    /// One-line rendering of the whole timeline for counterexample reports.
    pub fn describe(&self) -> String {
        let fmt = |name: &str, evs: &[ScenarioEvent]| -> Option<String> {
            if evs.is_empty() {
                return None;
            }
            let lines: Vec<String> = evs.iter().map(|e| format!("\"{e}\"")).collect();
            Some(format!("{name}=[{}]", lines.join(", ")))
        };
        let mut parts = vec![format!(
            "devices={} spares={} servers={} mega_batches={}",
            self.devices, self.spares, self.servers, self.mega_batches
        )];
        parts.extend(fmt("elastic", &self.elastic));
        parts.extend(fmt("calibration", &self.calibration));
        parts.extend(fmt("serve", &self.serve));
        parts.extend(fmt("fleet", &self.fleet));
        parts.extend(fmt("cluster", &self.cluster));
        parts.join(" ")
    }

    /// Materialize the case as a tiny virtual-mode [`Config`]: micro model,
    /// zero jitter (determinism checks compare bits), event lists in
    /// canonical grammar form. Valid by construction — `validate()` is
    /// still called and a failure here is itself a fuzzer bug.
    pub fn config(&self) -> Result<Config> {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 2 };
        cfg.sgd = SgdConfig {
            b_min: 8,
            b_max: 16,
            beta: 8,
            lr_bmax: 0.4,
            mega_batches: 6,
            num_mega_batches: self.mega_batches,
            initial_batch: 16,
            warmup_mega_batches: 0,
            seed: self.seed ^ 0x5EED,
            ..Default::default()
        };
        cfg.devices = DeviceConfig {
            count: self.devices,
            speed_factors: (0..self.devices).map(|i| 1.0 + 0.1 * i as f64).collect(),
            jitter: 0.0,
            nnz_sensitivity: 1.0,
            seed: 17,
        };
        cfg.data = DataConfig {
            train_samples: 600,
            test_samples: 120,
            avg_nnz: 4.0,
            seed: self.seed | 1,
            ..Default::default()
        };
        cfg.elastic.spare_devices = (0..self.spares).map(|i| 0.9 + 0.05 * i as f64).collect();
        cfg.elastic.events = self.elastic.iter().map(|e| e.to_string()).collect();
        cfg.calibration.events = self.calibration.iter().map(|e| e.to_string()).collect();
        cfg.serve.events = self.serve.iter().map(|e| e.to_string()).collect();
        cfg.serve.rate = 1_500.0;
        cfg.serve.duration = 0.5;
        cfg.serve.window = 0.1;
        cfg.serve.max_delay = 0.002;
        cfg.serve.max_batch = 16;
        cfg.serve.seed = self.seed ^ 0x7A11;
        cfg.fleet.events = self.fleet.iter().map(|e| e.to_string()).collect();
        cfg.fleet.decision_window = 0.02;
        cfg.fleet.grace = 0.1;
        cfg.fleet.train_weights = vec![1.0, 1.0];
        cfg.cluster.servers = self.servers;
        cfg.cluster.sync_every = 2;
        cfg.cluster.events = self.cluster.iter().map(|e| e.to_string()).collect();
        cfg.validate()
            .map_err(|e| anyhow::anyhow!("fuzz case {:#x} built an invalid config: {e:#}", self.seed))?;
        Ok(cfg)
    }
}

/// SplitMix64-style mix of (run seed, case index) → per-case seed, so
/// adjacent cases decorrelate. Index 0 is the identity: that is what
/// makes the reported `--seed <case_seed> --runs 1` replay regenerate
/// the failing case exactly rather than case 0 of a fresh sweep.
pub fn case_seed(seed: u64, index: usize) -> u64 {
    if index == 0 {
        return seed;
    }
    let mut z = seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn gen_pool(rng: &mut Rng, horizon: usize, roster: usize) -> ScenarioEvent {
    use crate::config::{ElasticEvent, ElasticOp};
    let at_mb = 1 + rng.below(horizon.max(2) as u64 - 1) as usize;
    let op = match rng.below(4) {
        0 => ElasticOp::Remove(1 + rng.below(2) as usize),
        1 => ElasticOp::Add(1 + rng.below(2) as usize),
        2 => ElasticOp::RemoveId(rng.below(roster as u64) as usize),
        _ => ElasticOp::AddId(rng.below(roster as u64) as usize),
    };
    ScenarioEvent::Pool(ElasticEvent { at_mb, op })
}

fn gen_drift(rng: &mut Rng, horizon: usize, roster: usize) -> ScenarioEvent {
    ScenarioEvent::Drift(crate::tuning::DriftEvent {
        at_mb: 1 + rng.below(horizon.max(2) as u64 - 1) as usize,
        device: rng.below(roster as u64) as usize,
        factor: 0.5 + rng.f64() * 3.5,
        ramp: rng.below(3) as usize,
    })
}

fn gen_cluster(rng: &mut Rng, horizon: usize, servers: usize) -> Vec<ScenarioEvent> {
    if rng.below(2) == 0 {
        vec![ScenarioEvent::Link(crate::tuning::DriftEvent {
            at_mb: 1 + rng.below(horizon.max(2) as u64 - 1) as usize,
            device: rng.below(servers as u64) as usize,
            factor: 1.0 + rng.f64() * 7.0,
            ramp: rng.below(3) as usize,
        })]
    } else {
        // Rack outage + recovery; server 0 always stays up so the cluster
        // is never fully dark.
        let server = 1 + rng.below(servers as u64 - 1) as usize;
        let down_at = 1 + rng.below(horizon.max(2) as u64 - 1) as usize;
        let up_at = down_at + 1 + rng.below(3) as usize;
        vec![
            ScenarioEvent::Rack { at_mb: down_at, server, up: false },
            ScenarioEvent::Rack { at_mb: up_at, server, up: true },
        ]
    }
}

/// Generate one random-but-valid case from its seed. Draw order is fixed
/// and independent of the subsystem mask so a counterexample seed replays
/// identically whatever `--subsystems` selected.
pub fn gen_case(case_seed: u64) -> FuzzCase {
    let mut rng = Rng::new(case_seed);
    let devices = 2 + rng.below(3) as usize;
    let spares = rng.below(3) as usize;
    let servers = 2 + rng.below(2) as usize;
    let mega_batches = 3 + rng.below(4) as usize;
    let roster = devices + spares;
    let mut case = FuzzCase {
        seed: case_seed,
        devices,
        spares,
        servers,
        mega_batches,
        elastic: Vec::new(),
        calibration: Vec::new(),
        serve: Vec::new(),
        fleet: Vec::new(),
        cluster: Vec::new(),
    };
    for _ in 0..rng.below(3) {
        case.elastic.push(gen_pool(&mut rng, mega_batches, roster));
    }
    for _ in 0..rng.below(3) {
        case.calibration.push(gen_drift(&mut rng, mega_batches, roster));
    }
    // Serve events index telemetry windows (duration 0.5 / window 0.1 → 5),
    // fleet events index decision windows (a longer horizon).
    for _ in 0..rng.below(3) {
        case.serve.push(gen_pool(&mut rng, 5, roster));
    }
    for _ in 0..rng.below(3) {
        case.fleet.push(gen_pool(&mut rng, 10, roster));
    }
    for _ in 0..rng.below(3) {
        case.cluster.extend(gen_cluster(&mut rng, mega_batches, servers));
    }
    case
}

fn corpus(cfg: &Config, seed: u64) -> Arc<ShardedDataset> {
    let gen = Generator::new(&cfg.model, &cfg.data);
    let train = gen.generate(cfg.data.train_samples, seed);
    Arc::new(ShardedDataset::from_dataset(&train, 128))
}

/// Epoch-exact emission: stream two full epochs in random-sized chunks
/// (occasionally flushing a chunk back through `unget`) and require every
/// sample served exactly twice.
fn check_data(case: &FuzzCase, cfg: &Config) -> std::result::Result<(), String> {
    for policy in crate::config::CompositionPolicy::all() {
        let data = corpus(cfg, case.seed ^ 0xDA7A);
        let len = data.len();
        let mut stream = SampleStream::new(data, policy, case.seed ^ 0x57EE);
        let mut rng = Rng::new(case.seed ^ 0xC4A7);
        let mut counts = vec![0u64; len];
        let target = 2 * len as u64;
        let mut served = 0u64;
        let (mut ids, mut runs) = (Vec::new(), Vec::new());
        let (mut ids2, mut runs2) = (Vec::new(), Vec::new());
        while served < target {
            let want = (1 + rng.below(48) as usize).min((target - served) as usize);
            stream.next_ids(want, &mut ids, &mut runs);
            if ids.len() != want {
                return Err(format!("{policy:?}: stream returned {} of {want} ids", ids.len()));
            }
            // Exercise the flush path: a single-run (current-epoch) draw
            // pushed back must re-emit the same multiset.
            if runs.len() == 1 && rng.below(8) == 0 {
                stream.unget(&ids, &runs);
                stream.next_ids(want, &mut ids2, &mut runs2);
                let mut before = ids.clone();
                let mut after = ids2.clone();
                before.sort_unstable();
                after.sort_unstable();
                if before != after {
                    return Err(format!("{policy:?}: unget changed the emitted multiset"));
                }
                std::mem::swap(&mut ids, &mut ids2);
            }
            for &id in &ids {
                counts[id as usize] += 1;
            }
            served += want as u64;
        }
        if stream.samples_served() != target {
            return Err(format!(
                "{policy:?}: samples_served {} != {target}",
                stream.samples_served()
            ));
        }
        if let Some(id) = counts.iter().position(|&c| c != 2) {
            return Err(format!(
                "{policy:?}: sample {id} served {} times in 2 epochs",
                counts[id]
            ));
        }
    }
    Ok(())
}

/// Training invariants: merge-weight sum-to-1 with inactive slots at 0,
/// per-lane attribution partition, and bit-determinism across a replay.
fn check_train(cfg: &Config) -> std::result::Result<(), String> {
    let run = || -> std::result::Result<(crate::metrics::RunLog, ObsHandle), String> {
        let obs = ObsHandle::from_config(
            &crate::config::ObsConfig { enabled: true, ..Default::default() },
            false,
        );
        let opts = TrainerOptions { obs: obs.clone(), ..Default::default() };
        let log = run_single(cfg, Backend::Reference, opts)
            .map_err(|e| format!("train run failed: {e:#}"))?;
        Ok((log, obs))
    };
    let (a, obs) = run()?;
    if a.rows.len() != cfg.sgd.num_mega_batches {
        return Err(format!(
            "train run recorded {} of {} mega-batches",
            a.rows.len(),
            cfg.sgd.num_mega_batches
        ));
    }
    let mut weighted_rows = 0usize;
    for (i, row) in a.rows.iter().enumerate() {
        if row.merge_weights.is_empty() {
            continue;
        }
        weighted_rows += 1;
        let sum: f64 = row.merge_weights.iter().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("mega-batch {i}: merge weights sum to {sum}, not 1"));
        }
        for (d, &w) in row.merge_weights.iter().enumerate() {
            if w < -1e-12 {
                return Err(format!("mega-batch {i}: device {d} has negative weight {w}"));
            }
            if !row.active_devices.contains(&d) && w != 0.0 {
                return Err(format!(
                    "mega-batch {i}: inactive device {d} carries weight {w}"
                ));
            }
        }
    }
    if weighted_rows == 0 {
        return Err("no mega-batch recorded merge weights".to_string());
    }
    // Attribution partition: per lane, the category times partition the
    // lane total exactly (idle is defined as the remainder, so a violation
    // means overlapping spans were double-counted).
    let trace = TraceData::from_handle("fuzz", &obs);
    for lane in attribute(&trace.events) {
        let err = (lane.category_sum() - lane.total).abs();
        if err > 1e-6 * lane.total.max(1.0) {
            return Err(format!(
                "lane pid={} tid={}: categories sum to {} but lane total is {}",
                lane.pid,
                lane.tid,
                lane.category_sum(),
                lane.total
            ));
        }
    }
    let (b, _) = run()?;
    for (i, (ra, rb)) in a.rows.iter().zip(&b.rows).enumerate() {
        if ra.loss.to_bits() != rb.loss.to_bits()
            || ra.clock.to_bits() != rb.clock.to_bits()
            || ra.active_devices != rb.active_devices
        {
            return Err(format!("train replay diverged at mega-batch {i}"));
        }
    }
    Ok(())
}

/// Request conservation + determinism on a steady-state serving replay.
fn check_serve(cfg: &Config) -> std::result::Result<(), String> {
    let data = corpus(cfg, cfg.serve.seed ^ 0x5E4E);
    let run = || -> std::result::Result<crate::serve::ServeLog, String> {
        let registry = SnapshotRegistry::new();
        registry.publish(ModelState::init(&cfg.model, 5), Some(0), 0.0);
        let opts = ReplayOptions {
            pattern: cfg.serve.pattern,
            duration: cfg.serve.duration,
            follow_clock: false,
            train_log: None,
            name: "fuzz-serve".to_string(),
            obs: ObsHandle::disabled(),
        };
        replay(cfg, data.clone(), &registry, &RefBackend, &opts)
            .map_err(|e| format!("serve replay failed: {e:#}"))
    };
    let a = run()?;
    if a.requests.is_empty() {
        return Err("serve replay answered no requests".to_string());
    }
    let mut ids: Vec<u64> = a.requests.iter().map(|r| r.id).collect();
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    if ids.len() != n {
        return Err(format!("{} requests answered more than once", n - ids.len()));
    }
    if ids.last().map(|&i| i as usize + 1) != Some(n) {
        return Err(format!(
            "request ids not dense: {} answered, max id {}",
            n,
            ids.last().unwrap()
        ));
    }
    let b = run()?;
    if a.requests.len() != b.requests.len()
        || a.latency_percentile_ms(95.0).to_bits() != b.latency_percentile_ms(95.0).to_bits()
    {
        return Err("serve replay diverged across identical seeds".to_string());
    }
    Ok(())
}

/// Lease conservation: `co_schedule` audits the ledger every tick and
/// errors on violation, so a clean completion with audits recorded IS the
/// property; request conservation on the co-served lane rides along.
fn check_fleet(cfg: &Config) -> std::result::Result<(), String> {
    let jobs: Vec<TenantJob> = (0..2)
        .map(|i| {
            let mut tenant_cfg = cfg.clone();
            tenant_cfg.sgd.seed = cfg.sgd.seed + i as u64;
            tenant_cfg.data.seed = cfg.data.seed + 7 * i as u64;
            let gen = Generator::new(&tenant_cfg.model, &tenant_cfg.data);
            let train = gen.generate(tenant_cfg.data.train_samples, 1 + i as u64);
            let test = gen.generate(tenant_cfg.data.test_samples, 91 + i as u64);
            TenantJob {
                name: format!("tenant-{i}"),
                weight: 1.0,
                train: Arc::new(ShardedDataset::from_dataset(&train, 128)),
                test: Arc::new(test),
                cfg: tenant_cfg,
            }
        })
        .collect();
    let corpus = jobs[0].train.clone();
    let out = co_schedule(cfg, &jobs, Some(corpus), Arc::new(SnapshotRegistry::new()), "fuzz-fleet")
        .map_err(|e| format!("lease conservation violated (co_schedule failed): {e:#}"))?;
    if out.conservation_checks == 0 {
        return Err("co_schedule ran no conservation audits".to_string());
    }
    for (name, log) in &out.tenant_logs {
        if log.rows.len() != cfg.sgd.num_mega_batches {
            return Err(format!(
                "{name} finished {} of {} mega-batches",
                log.rows.len(),
                cfg.sgd.num_mega_batches
            ));
        }
    }
    if let Some(serve) = &out.serve {
        let mut ids: Vec<u64> = serve.requests.iter().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != n || ids.last().map(|&i| i as usize + 1) != Some(n) {
            return Err("co-served request ids not dense/unique".to_string());
        }
    }
    Ok(())
}

/// Cluster scale-out: the hierarchical run completes under link throttles
/// and rack outages, converges to a finite accuracy, and is bit-
/// deterministic across a replay.
fn check_cluster(cfg: &Config) -> std::result::Result<(), String> {
    let run = || {
        crate::cluster::run_cluster(
            cfg,
            crate::cluster::ClusterPolicy { flat: false, adaptive: true },
            "fuzz-cluster",
        )
        .map_err(|e| format!("cluster run failed: {e:#}"))
    };
    let a = run()?;
    let acc = a.mean_final_accuracy();
    if !acc.is_finite() {
        return Err(format!("cluster mean final accuracy is {acc}"));
    }
    let b = run()?;
    if acc.to_bits() != b.mean_final_accuracy().to_bits() || a.syncs != b.syncs {
        return Err("cluster replay diverged across identical seeds".to_string());
    }
    Ok(())
}

/// Run every enabled invariant group against one case.
pub fn check_case(case: &FuzzCase, subs: &Subsystems) -> std::result::Result<(), String> {
    let cfg = case.config().map_err(|e| format!("{e:#}"))?;
    if subs.data {
        check_data(case, &cfg)?;
    }
    if subs.train {
        check_train(&cfg)?;
    }
    if subs.serve {
        check_serve(&cfg)?;
    }
    if subs.fleet {
        check_fleet(&cfg)?;
    }
    if subs.cluster {
        check_cluster(&cfg)?;
    }
    Ok(())
}

/// Replay one case seed under a subsystem mask — the regression-corpus
/// entry point (`rust/tests/fuzz_corpus.rs`).
pub fn replay_seed(case_seed: u64, subs: &Subsystems) -> std::result::Result<(), String> {
    check_case(&gen_case(case_seed), subs)
}

/// Shrink candidates, largest reduction first: empty a whole event list,
/// drop a trailing event, shorten the horizon. All candidates stay valid
/// (events past the horizon are legal; ids are untouched).
pub fn shrink(case: &FuzzCase) -> Vec<FuzzCase> {
    let mut out = Vec::new();
    let lists: [fn(&mut FuzzCase) -> &mut Vec<ScenarioEvent>; 5] = [
        |c| &mut c.elastic,
        |c| &mut c.calibration,
        |c| &mut c.serve,
        |c| &mut c.fleet,
        |c| &mut c.cluster,
    ];
    for get in lists {
        let mut cleared = case.clone();
        if get(&mut cleared).is_empty() {
            continue;
        }
        get(&mut cleared).clear();
        out.push(cleared);
        let mut popped = case.clone();
        get(&mut popped).pop();
        out.push(popped);
    }
    if case.mega_batches > 3 {
        let mut shorter = case.clone();
        shorter.mega_batches -= 1;
        out.push(shorter);
    }
    out
}

#[derive(Clone, Debug)]
pub struct FuzzOptions {
    pub seed: u64,
    pub runs: usize,
    pub subsystems: Subsystems,
    pub verbose: bool,
}

/// A shrunk failing case. `case_seed` replays the original (unshrunk)
/// failure via `--seed <case_seed> --runs 1`; `case` is the greedy-shrink
/// minimum with `message` its invariant violation.
#[derive(Clone, Debug)]
pub struct Counterexample {
    pub case_index: usize,
    pub case_seed: u64,
    pub message: String,
    pub case: FuzzCase,
}

#[derive(Clone, Debug)]
pub struct FuzzReport {
    pub seed: u64,
    pub runs: usize,
    pub subsystems: Subsystems,
    pub failures: Vec<Counterexample>,
    /// Total invariant checks executed, shrink re-runs included.
    pub cases_checked: usize,
}

/// The fuzz loop: generate → check → (on failure) greedy-shrink, exactly
/// the `util::prop::check` discipline but over scenario space.
pub fn run(opts: &FuzzOptions) -> FuzzReport {
    let mut failures = Vec::new();
    let mut cases_checked = 0usize;
    for index in 0..opts.runs {
        let cs = case_seed(opts.seed, index);
        let case = gen_case(cs);
        cases_checked += 1;
        if opts.verbose {
            println!("  case {index} (seed {cs:#x}): {}", case.describe());
        }
        let Err(mut message) = check_case(&case, &opts.subsystems) else {
            continue;
        };
        let mut best = case;
        'shrinking: loop {
            for candidate in shrink(&best) {
                cases_checked += 1;
                if let Err(m) = check_case(&candidate, &opts.subsystems) {
                    best = candidate;
                    message = m;
                    continue 'shrinking;
                }
            }
            break;
        }
        failures.push(Counterexample { case_index: index, case_seed: cs, message, case: best });
    }
    FuzzReport {
        seed: opts.seed,
        runs: opts.runs,
        subsystems: opts.subsystems,
        failures,
        cases_checked,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_seed_mix_decorrelates_and_is_stable() {
        // Pinned values: the regression corpus stores case seeds, so the
        // mix function must never change.
        assert_eq!(case_seed(7, 0), 7, "index 0 is the identity — the replay contract");
        assert_ne!(case_seed(7, 0), case_seed(7, 1));
        assert_ne!(case_seed(7, 0), case_seed(8, 0));
        // Replaying a reported case seed alone regenerates that case.
        let cs = case_seed(0xABCD, 5);
        assert_eq!(case_seed(cs, 0), cs);
    }

    #[test]
    fn generated_cases_build_valid_configs() {
        for i in 0..50 {
            let case = gen_case(case_seed(0xF00D, i));
            let cfg = case.config().expect("fuzz cases are valid by construction");
            assert_eq!(cfg.devices.count, case.devices);
            assert_eq!(cfg.cluster.servers, case.servers);
            assert_eq!(cfg.elastic.events.len(), case.elastic.len());
            // Canonical strings re-parse through the per-subsystem views.
            cfg.elastic.parsed_events().unwrap();
            cfg.calibration.parsed_events().unwrap();
            cfg.cluster.parsed_events().unwrap();
        }
    }

    #[test]
    fn subsystem_masks_parse() {
        assert_eq!(Subsystems::parse("all").unwrap(), Subsystems::all());
        let s = Subsystems::parse("train,serve").unwrap();
        assert!(s.train && s.serve && !s.fleet && !s.cluster && !s.data);
        assert!(Subsystems::parse("elastic").unwrap().train, "alias");
        assert!(Subsystems::parse("warp").is_err());
        assert_eq!(Subsystems::all().label(), "train,data,serve,fleet,cluster");
    }

    #[test]
    fn shrink_candidates_stay_valid_and_smaller() {
        let case = gen_case(case_seed(7, 3));
        for cand in shrink(&case) {
            cand.config().expect("shrunk cases stay valid");
            let size = |c: &FuzzCase| {
                c.elastic.len()
                    + c.calibration.len()
                    + c.serve.len()
                    + c.fleet.len()
                    + c.cluster.len()
                    + c.mega_batches
            };
            assert!(size(&cand) < size(&case));
        }
    }

    #[test]
    fn one_full_case_passes_every_invariant() {
        // A smoke of the real check path (the 200-run sweep lives in the
        // CI `experiment fuzz` smoke, not the unit suite).
        let case = gen_case(case_seed(7, 0));
        if let Err(msg) = check_case(&case, &Subsystems::all()) {
            panic!("case 0 of the default seed violated an invariant: {msg}");
        }
    }
}

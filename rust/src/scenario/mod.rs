//! The scenario plane: one grammar for every scripted event list, the
//! `[scenario]` cross-subsystem block, and a seeded fuzzer that explores
//! the timeline space the hand-written configs never will.
//!
//! * [`grammar`] — the unified tokenizer/parser ([`parse_event`],
//!   [`route_line`]) behind `[elastic]`, `[calibration]`, `[serve]`,
//!   `[fleet]`, `[cluster]`, and `[scenario]`; the legacy per-subsystem
//!   parsers are thin views over it.
//! * [`fuzz`] — random-but-valid scenario generation + the global
//!   invariant checks (`experiment fuzz`), with greedy
//!   minimal-counterexample shrinking in the style of
//!   [`util::prop`](crate::util::prop).
//!
//! DESIGN.md §14 documents the grammar (BNF, verb table) and the fuzzer's
//! invariant list.

pub mod fuzz;
pub mod grammar;

pub use grammar::{
    parse_event, parse_line, parse_trace_indexed, route_line, Family, Mask, ScenarioEvent, Target,
};

//! The training session: strategy dispatch, model merging, batch scaling,
//! evaluation, and metrics — the outer loop of Figure 4.
//!
//! One `Trainer` drives one run of one strategy:
//!
//! * **Adaptive** — dynamic dispatch over a sample-budget mega-batch, then
//!   Algorithm 2 merging (normalized weights + perturbation + momentum) and
//!   Algorithm 1 batch-size scaling.
//! * **Elastic** — static equal batches, plain average merge with the same
//!   momentum update rule (the paper implements both in HeteroGPU with the
//!   same update rule; Fig. 6 note).
//! * **SyncGradAgg** — the TensorFlow-mirrored analog: per-device batch
//!   `b_max/G`, merge after *every* round; a configurable framework-overhead
//!   multiplier models TF's slower epoch execution.
//! * **Crossbow** — dynamic dispatch with per-batch replica correction
//!   toward the fleet average, plain average merge at mega-batch ends.
//!
//! The training clock *excludes* evaluation time (paper §5.1 methodology).

use crate::allreduce::{self, Algo};
use crate::config::{Config, Strategy};
use crate::data::batcher::{Batcher, EvalBatches};
use crate::data::SparseDataset;
use crate::metrics::{MegaBatchRow, RunLog};
use crate::model::ModelState;
use crate::Result;

use super::backend::StepBackend;
use super::engine_sim::SimEngine;
use super::engine_threaded::ThreadedEngine;
use super::plan::{DispatchMode, DispatchPlan, MegaBatchReport};
use super::{merge, scaling};

/// Either engine, unified behind one dispatch call.
pub enum Engine<'b> {
    Sim(SimEngine<'b>),
    Threaded(ThreadedEngine),
}

impl<'b> Engine<'b> {
    fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        batcher: &mut Batcher<'_>,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport> {
        match self {
            Engine::Sim(e) => e.run_mega_batch(replicas, batcher, plan),
            Engine::Threaded(e) => e.run_mega_batch(replicas, batcher, plan),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// Stop once the training clock exceeds this many seconds.
    pub time_budget: Option<f64>,
    /// Evaluate every k mega-batches (1 = the paper's cadence).
    pub eval_every: usize,
    /// All-reduce variant used for merging.
    pub allreduce: Algo,
    /// Evaluation batch bucket. With a PJRT eval backend this MUST equal the
    /// manifest's `eval_batch`; `None` picks a reference-backend-friendly
    /// default.
    pub eval_bucket: Option<usize>,
    /// Resume from this model instead of a fresh initialization.
    pub init_model: Option<crate::model::ModelState>,
    /// Save the merged global model here after every mega-batch (atomic).
    pub checkpoint: Option<std::path::PathBuf>,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            time_budget: None,
            eval_every: 1,
            allreduce: Algo::Ring,
            eval_bucket: None,
            init_model: None,
            checkpoint: None,
            verbose: false,
        }
    }
}

pub struct Trainer<'b> {
    pub cfg: Config,
    pub engine: Engine<'b>,
    pub eval_backend: &'b dyn StepBackend,
    pub opts: TrainerOptions,
}

impl<'b> Trainer<'b> {
    pub fn new(
        cfg: Config,
        engine: Engine<'b>,
        eval_backend: &'b dyn StepBackend,
        opts: TrainerOptions,
    ) -> Self {
        Trainer { cfg, engine, eval_backend, opts }
    }

    /// Train on `train`, evaluating P@1 on `test` after every merge window.
    pub fn run(&mut self, train: &SparseDataset, test: &SparseDataset) -> Result<RunLog> {
        let cfg = self.cfg.clone();
        let g = cfg.devices.count;
        let dims = cfg.model.clone();
        let strategy = cfg.strategy.kind;

        let mut log = RunLog::new(format!("{}-{}gpu", strategy.name(), g));
        let mut batcher = Batcher::new(train, &dims, cfg.sgd.seed);
        let eval_bucket = self.eval_bucket();
        let eval_batches = EvalBatches::new(test, &dims, eval_bucket);

        // Global model + momentum history + per-device replicas.
        let mut global = match self.opts.init_model.take() {
            Some(m) => {
                anyhow::ensure!(m.dims == dims, "resume model dims mismatch");
                m
            }
            None => ModelState::init(&dims, cfg.sgd.seed),
        };
        let mut global_prev = global.clone();
        let mut replicas: Vec<ModelState> = vec![global.clone(); g];

        // Per-device adaptive state.
        let mut batch_sizes = vec![cfg.sgd.initial_batch; g];
        let mut lrs = vec![cfg.lr_for_batch(cfg.sgd.initial_batch); g];
        let mut scaling_state = scaling::ScalingState::default();

        let mut clock = 0.0f64;
        let mut samples = 0u64;

        for mb in 0..cfg.sgd.num_mega_batches {
            if let Some(budget) = self.opts.time_budget {
                if clock >= budget {
                    break;
                }
            }
            // Goyal-style linear warmup on every device's learning rate.
            let warmup = warmup_factor(mb, cfg.sgd.warmup_mega_batches);

            let (report, merge_secs, perturbed) = match strategy {
                Strategy::Adaptive | Strategy::Elastic | Strategy::Crossbow => {
                    let mut plan = self.plan_for(strategy, &batch_sizes, &lrs);
                    for lr in plan.lrs.iter_mut() {
                        *lr *= warmup;
                    }
                    let report = self.engine.run_mega_batch(&mut replicas, &mut batcher, &plan)?;
                    clock += report.wall;

                    // ---- merge (Algorithm 2 for Adaptive) -----------------
                    let updates = report.updates();
                    let outcome = match strategy {
                        Strategy::Adaptive => {
                            let l2s: Vec<f64> =
                                replicas.iter().map(|r| r.l2_per_param()).collect();
                            merge::compute_weights(&updates, &batch_sizes, &l2s, &cfg.merge)
                        }
                        _ => merge::MergeOutcome {
                            weights: vec![1.0 / g as f64; g],
                            perturbed: false,
                            by_updates: false,
                        },
                    };
                    let mut merged = ModelState::zeros(&dims);
                    let refs: Vec<&ModelState> = replicas.iter().collect();
                    let stats = allreduce::allreduce_merge(
                        &mut merged,
                        &refs,
                        &outcome.weights,
                        self.opts.allreduce,
                        g,
                        &self.cost(),
                    );
                    // Momentum global update for the HeteroGPU strategies.
                    let momentum = match strategy {
                        Strategy::Adaptive | Strategy::Elastic => cfg.merge.momentum,
                        _ => 0.0,
                    };
                    merge::momentum_update(&mut global, &mut global_prev, &merged, momentum);
                    clock += stats.seconds;

                    // ---- Algorithm 1 (Adaptive only), gated by the
                    // stability/oscillation controller -----------------------
                    scaling_state.observe(&batch_sizes);
                    if strategy == Strategy::Adaptive
                        && cfg.strategy.batch_scaling
                        && scaling_state.should_scale()
                    {
                        scaling::rescale(&mut batch_sizes, &mut lrs, &updates, &cfg.sgd);
                    }
                    (report, stats.seconds, outcome.perturbed)
                }
                Strategy::SyncGradAgg => {
                    // One "mega-batch" worth of synchronous rounds, merging
                    // after every round (gradient aggregation ≡ averaging
                    // one-step replicas).
                    let b_tf = scaling::round_to_grid(
                        (cfg.sgd.b_max as f64 / g as f64).max(cfg.sgd.b_min as f64),
                        &cfg.sgd,
                    );
                    let rounds =
                        (cfg.sgd.mega_batch_samples() / (g * b_tf)).max(1);
                    let mut agg: Option<MegaBatchReport> = None;
                    let mut merge_total = 0.0;
                    for _ in 0..rounds {
                        let plan = DispatchPlan {
                            mode: DispatchMode::StaticQuota { batches_per_device: 1 },
                            batch_sizes: vec![b_tf; g],
                            lrs: vec![cfg.lr_for_batch(b_tf) * warmup; g],
                            sample_budget: 0,
                            crossbow_rate: None,
                        };
                        let report =
                            self.engine.run_mega_batch(&mut replicas, &mut batcher, &plan)?;
                        clock += report.wall * cfg.strategy.sync_overhead;

                        let mut merged = ModelState::zeros(&dims);
                        let refs: Vec<&ModelState> = replicas.iter().collect();
                        let stats = allreduce::allreduce_merge(
                            &mut merged,
                            &refs,
                            &vec![1.0 / g as f64; g],
                            self.opts.allreduce,
                            g,
                            &self.cost(),
                        );
                        clock += stats.seconds * cfg.strategy.sync_overhead;
                        merge_total += stats.seconds;
                        global_prev = global.clone();
                        global = merged;
                        for r in replicas.iter_mut() {
                            *r = global.clone();
                        }
                        agg = Some(match agg.take() {
                            None => report,
                            Some(mut acc) => {
                                for (a, b) in acc.per_device.iter_mut().zip(report.per_device) {
                                    a.updates += b.updates;
                                    a.samples += b.samples;
                                    a.busy += b.busy;
                                    a.loss_sum += b.loss_sum;
                                    a.nnz += b.nnz;
                                }
                                acc.wall += report.wall;
                                acc
                            }
                        });
                    }
                    (agg.unwrap(), merge_total, false)
                }
            };

            // Reset replicas to the merged global model for the next window.
            if strategy != Strategy::SyncGradAgg {
                for r in replicas.iter_mut() {
                    *r = global.clone();
                }
            }

            samples += report.total_samples();

            // ---- evaluate (excluded from the training clock) --------------
            let accuracy = if (mb + 1) % self.opts.eval_every == 0 {
                crate::eval::p_at_1(self.eval_backend, &global, &eval_batches, test)?
            } else {
                log.rows.last().map(|r| r.accuracy).unwrap_or(0.0)
            };

            // Hardware efficiency: fraction of the barrier window each
            // device spent busy (1.0 = no straggler idling).
            let utilization: Vec<f64> = report
                .per_device
                .iter()
                .map(|d| if report.wall > 0.0 { (d.busy / report.wall).min(1.0) } else { 1.0 })
                .collect();

            let row = MegaBatchRow {
                mega_batch: mb,
                clock,
                samples,
                loss: report.mean_loss(),
                accuracy,
                batch_sizes: batch_sizes.clone(),
                updates: report.updates(),
                perturbed,
                merge_time: merge_secs,
                l2_per_param: global.l2_per_param(),
                utilization,
            };
            if let Some(path) = &self.opts.checkpoint {
                crate::model::checkpoint::save(&global, path)?;
            }
            if self.opts.verbose {
                println!(
                    "[{}] mb={:<3} clock={:>8.3}s loss={:<8.4} P@1={:<6.4} b={:?} u={:?}{}",
                    log.name,
                    mb,
                    clock,
                    row.loss,
                    accuracy,
                    row.batch_sizes,
                    row.updates,
                    if perturbed { " pert" } else { "" }
                );
            }
            log.push(row);
        }
        Ok(log)
    }

    fn plan_for(&self, strategy: Strategy, batch_sizes: &[usize], lrs: &[f32]) -> DispatchPlan {
        let cfg = &self.cfg;
        let g = cfg.devices.count;
        match strategy {
            Strategy::Adaptive => DispatchPlan {
                mode: DispatchMode::Dynamic,
                batch_sizes: batch_sizes.to_vec(),
                lrs: lrs.to_vec(),
                sample_budget: cfg.sgd.mega_batch_samples(),
                crossbow_rate: None,
            },
            Strategy::Elastic => {
                let b = cfg.sgd.b_max;
                DispatchPlan {
                    mode: DispatchMode::StaticQuota {
                        batches_per_device: (cfg.sgd.mega_batch_samples() / (g * b)).max(1),
                    },
                    batch_sizes: vec![b; g],
                    lrs: vec![cfg.lr_for_batch(b); g],
                    sample_budget: 0,
                    crossbow_rate: None,
                }
            }
            Strategy::Crossbow => DispatchPlan {
                mode: DispatchMode::Dynamic,
                batch_sizes: vec![cfg.sgd.b_max; g],
                lrs: vec![cfg.lr_for_batch(cfg.sgd.b_max); g],
                sample_budget: cfg.sgd.mega_batch_samples(),
                crossbow_rate: Some(cfg.strategy.crossbow_rate),
            },
            Strategy::SyncGradAgg => unreachable!("sync handled inline"),
        }
    }

    fn eval_bucket(&self) -> usize {
        self.opts
            .eval_bucket
            .unwrap_or_else(|| 256.min(self.cfg.data.test_samples.max(1)).max(1))
    }

    fn cost(&self) -> crate::runtime::CostModel {
        match &self.engine {
            Engine::Sim(e) => e.cost,
            Engine::Threaded(_) => crate::runtime::CostModel::default(),
        }
    }
}

/// Linear warmup multiplier for mega-batch `mb` (1.0 once warmup is over or
/// disabled).
fn warmup_factor(mb: usize, warmup_mega_batches: usize) -> f32 {
    if warmup_mega_batches == 0 {
        1.0
    } else {
        (((mb + 1) as f32) / warmup_mega_batches as f32).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
    use crate::coordinator::backend::RefBackend;
    use crate::data::synthetic::Generator;
    use crate::runtime::{CostModel, SimDevice};

    fn test_config(strategy: Strategy, g: usize) -> Config {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        cfg.sgd = SgdConfig {
            b_min: 8,
            b_max: 32,
            beta: 4,
            lr_bmax: 0.4,
            mega_batches: 24,
            num_mega_batches: 6,
            initial_batch: 32,
            warmup_mega_batches: 0,
            seed: 7,
        };
        cfg.devices = DeviceConfig {
            count: g,
            speed_factors: (0..g).map(|i| 1.0 + 0.32 * i as f64 / (g.max(2) - 1) as f64).collect(),
            jitter: 0.0,
            nnz_sensitivity: 1.0,
            seed: 17,
        };
        cfg.data = DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
        cfg.strategy.kind = strategy;
        cfg.validate().unwrap();
        cfg
    }

    fn run_strategy(strategy: Strategy, g: usize) -> RunLog {
        let cfg = test_config(strategy, g);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = Engine::Sim(SimEngine::new(
            &backend,
            SimDevice::fleet(&cfg.devices),
            CostModel::default(),
        ));
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        trainer.run(&train, &test).unwrap()
    }

    #[test]
    fn adaptive_trains_and_improves() {
        let log = run_strategy(Strategy::Adaptive, 4);
        assert_eq!(log.rows.len(), 6);
        assert!(log.rows[5].loss < log.rows[0].loss, "loss should fall");
        assert!(log.best_accuracy() > 0.15, "acc {}", log.best_accuracy());
        // Clock advances monotonically.
        assert!(log.rows.windows(2).all(|w| w[1].clock > w[0].clock));
    }

    #[test]
    fn all_strategies_complete_and_learn() {
        for strategy in Strategy::all() {
            let log = run_strategy(strategy, 2);
            assert!(!log.rows.is_empty(), "{strategy:?}");
            assert!(
                log.rows.last().unwrap().loss < log.rows[0].loss + 0.1,
                "{strategy:?} loss went up: {} -> {}",
                log.rows[0].loss,
                log.rows.last().unwrap().loss
            );
        }
    }

    #[test]
    fn adaptive_batch_sizes_differentiate_under_heterogeneity() {
        let log = run_strategy(Strategy::Adaptive, 4);
        let last = log.rows.last().unwrap();
        // The slowest device should have drifted below the fastest.
        assert!(
            last.batch_sizes[0] > last.batch_sizes[3]
                || last.batch_sizes.iter().any(|&b| b != last.batch_sizes[0]),
            "batch sizes never adapted: {:?}",
            last.batch_sizes
        );
    }

    #[test]
    fn elastic_keeps_static_batches() {
        let log = run_strategy(Strategy::Elastic, 4);
        for row in &log.rows {
            assert!(row.batch_sizes.iter().all(|&b| b == 32));
            // Equal updates by construction.
            assert!(row.updates.iter().all(|&u| u == row.updates[0]));
        }
    }

    #[test]
    fn time_budget_stops_early() {
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(500, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = Engine::Sim(SimEngine::new(
            &backend,
            SimDevice::fleet(&cfg.devices),
            CostModel::default(),
        ));
        let opts = TrainerOptions { time_budget: Some(1e-9), ..Default::default() };
        let mut trainer = Trainer::new(cfg, engine, &backend, opts);
        let log = trainer.run(&train, &test).unwrap();
        assert!(log.rows.len() <= 1);
    }

    #[test]
    fn warmup_factor_ramps_linearly() {
        assert_eq!(warmup_factor(0, 0), 1.0);
        assert_eq!(warmup_factor(0, 4), 0.25);
        assert_eq!(warmup_factor(1, 4), 0.5);
        assert_eq!(warmup_factor(3, 4), 1.0);
        assert_eq!(warmup_factor(100, 4), 1.0);
    }

    #[test]
    fn warmup_slows_early_updates() {
        // With warmup the first mega-batch moves the model strictly less.
        let mut cfg = test_config(Strategy::Adaptive, 2);
        cfg.sgd.num_mega_batches = 1;
        let run = |cfg: &Config| {
            let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
            let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
            let backend = RefBackend;
            let engine = Engine::Sim(SimEngine::new(
                &backend,
                SimDevice::fleet(&cfg.devices),
                CostModel::default(),
            ));
            let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
            let log = trainer.run(&train, &test).unwrap();
            log.rows[0].l2_per_param
        };
        let no_warmup = run(&cfg);
        cfg.sgd.warmup_mega_batches = 10;
        let with_warmup = run(&cfg);
        // Warmup shrinks the first-step learning rates 10x, so the merged
        // model stays closer to the (zero-bias) init -> smaller L2 drift
        // relative to the aggressive run is not guaranteed in general, but
        // the two must at least differ, proving warmup reached the plan.
        assert_ne!(no_warmup, with_warmup);
    }

    #[test]
    fn checkpoint_and_resume_round_trip() {
        let dir = std::env::temp_dir().join("hs-trainer-ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("global.ckpt");

        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = Engine::Sim(SimEngine::new(
            &backend,
            SimDevice::fleet(&cfg.devices),
            CostModel::default(),
        ));
        let opts = TrainerOptions { checkpoint: Some(path.clone()), ..Default::default() };
        let mut trainer = Trainer::new(cfg.clone(), engine, &backend, opts);
        trainer.run(&train, &test).unwrap();
        assert!(path.exists());

        // Resume from the checkpoint: first-row loss must be well below a
        // fresh run's first-row loss.
        let saved = crate::model::checkpoint::load(&path).unwrap();
        let engine2 = Engine::Sim(SimEngine::new(
            &backend,
            SimDevice::fleet(&cfg.devices),
            CostModel::default(),
        ));
        let opts2 = TrainerOptions { init_model: Some(saved), ..Default::default() };
        let mut resumed = Trainer::new(cfg.clone(), engine2, &backend, opts2);
        let log2 = resumed.run(&train, &test).unwrap();

        let engine3 = Engine::Sim(SimEngine::new(
            &backend,
            SimDevice::fleet(&cfg.devices),
            CostModel::default(),
        ));
        let mut fresh = Trainer::new(cfg, engine3, &backend, TrainerOptions::default());
        let fresh_log = fresh.run(&train, &test).unwrap();
        assert!(
            log2.rows[0].loss < fresh_log.rows[0].loss,
            "resumed run should start ahead: {} vs {}",
            log2.rows[0].loss,
            fresh_log.rows[0].loss
        );
    }

    #[test]
    fn deterministic_runs_with_zero_jitter() {
        let a = run_strategy(Strategy::Adaptive, 3);
        let b = run_strategy(Strategy::Adaptive, 3);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.clock, y.clock);
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.batch_sizes, y.batch_sizes);
        }
    }
}

//! The training session: pool membership, strategy dispatch, model merging,
//! batch scaling, evaluation, and metrics — the outer loop of Figure 4.
//!
//! One `Trainer` drives one run of one strategy:
//!
//! * **Adaptive** — dynamic dispatch over a sample-budget mega-batch, then
//!   Algorithm 2 merging (normalized weights + perturbation + momentum) and
//!   Algorithm 1 batch-size scaling.
//! * **Elastic** — static equal batches, plain average merge with the same
//!   momentum update rule (the paper implements both in HeteroGPU with the
//!   same update rule; Fig. 6 note).
//! * **SyncGradAgg** — the TensorFlow-mirrored analog: per-device batch
//!   `b_max/G`, merge after *every* round; a configurable framework-overhead
//!   multiplier models TF's slower epoch execution.
//! * **Crossbow** — dynamic dispatch with per-batch replica correction
//!   toward the fleet average, plain average merge at mega-batch ends.
//!
//! Every strategy now runs on an elastic [`DevicePool`]: membership changes
//! (scripted trace or straggler policy) land at mega-batch boundaries, the
//! dispatch plan covers only the active subset, and Algorithm 2's merge
//! weights renormalize over that subset. Per-device state — replicas, batch
//! sizes, learning rates — is roster-indexed, and the momentum history
//! lives on the global model, so both survive membership churn.
//!
//! The training clock *excludes* evaluation time (paper §5.1 methodology).

use std::sync::Arc;

use crate::allreduce::{self, Algo};
use crate::config::{Config, ExecMode, Strategy};
use crate::data::batcher::EvalBatches;
use crate::data::pipeline::{DataPlane, PipelineStats, ShardedDataset};
use crate::data::SparseDataset;
use crate::metrics::{MegaBatchRow, PipelineStatsRow, PoolEventRow, RunLog};
use crate::model::ModelState;
use crate::Result;

use super::backend::StepBackend;
use super::plan::{plan_for_strategy, DispatchPlan, ExecutionEngine, MegaBatchReport};
use super::pool::{DevicePool, PoolAction, PoolEvent};
use super::{merge, scaling};

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// Stop once the training clock exceeds this many seconds.
    pub time_budget: Option<f64>,
    /// Evaluate every k mega-batches (1 = the paper's cadence).
    pub eval_every: usize,
    /// All-reduce variant used for merging.
    pub allreduce: Algo,
    /// Evaluation batch bucket. With a PJRT eval backend this MUST equal the
    /// manifest's `eval_batch`; `None` picks a reference-backend-friendly
    /// default.
    pub eval_bucket: Option<usize>,
    /// Resume from this model instead of a fresh initialization.
    pub init_model: Option<crate::model::ModelState>,
    /// Save the merged global model here after every mega-batch (atomic).
    pub checkpoint: Option<std::path::PathBuf>,
    /// Publish merged global models into this snapshot registry: the
    /// initial model before training starts (serving warm-starts on it)
    /// and then every `[serve] publish_every` mega-batches — the
    /// train→serve hook the serving plane reads from.
    pub publish: Option<Arc<crate::serve::SnapshotRegistry>>,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            time_budget: None,
            eval_every: 1,
            allreduce: Algo::Ring,
            eval_bucket: None,
            init_model: None,
            checkpoint: None,
            publish: None,
            verbose: false,
        }
    }
}

pub struct Trainer<'b> {
    pub cfg: Config,
    pub engine: Box<dyn ExecutionEngine + 'b>,
    pub eval_backend: &'b dyn StepBackend,
    pub opts: TrainerOptions,
}

impl<'b> Trainer<'b> {
    pub fn new(
        cfg: Config,
        engine: Box<dyn ExecutionEngine + 'b>,
        eval_backend: &'b dyn StepBackend,
        opts: TrainerOptions,
    ) -> Self {
        Trainer { cfg, engine, eval_backend, opts }
    }

    /// Train on `train`, evaluating P@1 on `test` after every merge window.
    ///
    /// Reshards the borrowed corpus (one copy) — callers that already hold
    /// a sharded corpus (e.g. from `ShardedDataset::from_libsvm`) should
    /// use [`run_sharded`](Trainer::run_sharded) and pay no copy at all.
    pub fn run(&mut self, train: &SparseDataset, test: &SparseDataset) -> Result<RunLog> {
        let shard_samples = self.cfg.data.pipeline.shard_samples;
        let sharded = Arc::new(ShardedDataset::from_dataset(train, shard_samples));
        self.run_sharded(sharded, test)
    }

    /// Train from an already-sharded corpus — the zero-extra-copy path the
    /// data plane is built around.
    pub fn run_sharded(
        &mut self,
        train: Arc<ShardedDataset>,
        test: &SparseDataset,
    ) -> Result<RunLog> {
        let cfg = self.cfg.clone();
        let dims = cfg.model.clone();
        let strategy = cfg.strategy.kind;

        let mut pool = DevicePool::new(&cfg)?;
        let roster = pool.roster_len();
        anyhow::ensure!(
            roster == self.engine.roster_len(),
            "engine roster ({}) disagrees with the device pool ({roster}); build the engine \
             from DevicePool::roster(&cfg)",
            self.engine.roster_len()
        );

        let mut log =
            RunLog::new(format!("{}-{}gpu", strategy.name(), cfg.devices.count));

        // The data plane: sharded corpus + composition policy + (for the
        // threaded engine) async prefetch. Virtual-time runs force
        // synchronous assembly so the sample→device routing — and with it
        // the whole run — stays deterministic.
        let producer_threads = match cfg.runtime.mode {
            ExecMode::Virtual => 0,
            ExecMode::Real => cfg.data.pipeline.producer_threads,
        };
        let plane =
            DataPlane::new(train, &dims, &cfg.data.pipeline, producer_threads, cfg.sgd.seed);
        let nnz_estimate = plane.nnz_estimate();

        let eval_bucket = self.eval_bucket();
        let eval_batches = EvalBatches::new(test, &dims, eval_bucket);

        // Global model + momentum history + roster-indexed replicas.
        let mut global = match self.opts.init_model.take() {
            Some(m) => {
                anyhow::ensure!(m.dims == dims, "resume model dims mismatch");
                m
            }
            None => ModelState::init(&dims, cfg.sgd.seed),
        };
        let mut global_prev = global.clone();
        let mut replicas: Vec<ModelState> = vec![global.clone(); roster];

        // Serving warm-start: the init (or resumed) model is servable before
        // the first merge lands.
        if let Some(reg) = &self.opts.publish {
            reg.publish(global.clone(), None, 0.0);
        }

        // Roster-indexed adaptive state (survives membership churn).
        let mut batch_sizes = vec![cfg.sgd.initial_batch; roster];
        let mut lrs = vec![cfg.lr_for_batch(cfg.sgd.initial_batch); roster];
        let mut scaling_state = scaling::ScalingState::default();

        let mut clock = 0.0f64;
        let mut samples = 0u64;

        for mb in 0..cfg.sgd.num_mega_batches {
            if let Some(budget) = self.opts.time_budget {
                if clock >= budget {
                    break;
                }
            }

            // ---- pool membership for this mega-batch ----------------------
            let events = pool.begin_mega_batch(mb);
            let active = pool.active_ids();
            // A device (re-)joining the pool resumes from the current global
            // model; the momentum history lives on the global model and is
            // unaffected by churn. (Inactive replicas are left stale rather
            // than kept in sync — one clone per join, not per mega-batch.)
            for ev in &events {
                if matches!(ev.action, PoolAction::Add | PoolAction::Readmit) {
                    replicas[ev.device] = global.clone();
                }
            }
            if self.opts.verbose {
                for ev in &events {
                    println!(
                        "[{}] mb={:<3} pool: {} device {} ({})",
                        log.name,
                        mb,
                        ev.action.name(),
                        ev.device,
                        ev.reason
                    );
                }
            }

            // Goyal-style linear warmup on every device's learning rate.
            let warmup = warmup_factor(mb, cfg.sgd.warmup_mega_batches);

            let (report, merge_secs, merge_weights, perturbed) = match strategy {
                Strategy::Adaptive | Strategy::Elastic | Strategy::Crossbow => {
                    let mut plan = plan_for_strategy(
                        &cfg, strategy, &active, &batch_sizes, &lrs, nnz_estimate,
                    );
                    for lr in plan.lrs.iter_mut() {
                        *lr *= warmup;
                    }
                    let report = self.engine.run_mega_batch(&mut replicas, &plane, &plan)?;
                    clock += report.wall;

                    // ---- merge (Algorithm 2 for Adaptive), weights
                    // renormalized over the active subset -------------------
                    let active_updates: Vec<u64> =
                        active.iter().map(|&d| report.per_device[d].updates).collect();
                    let active_batches: Vec<usize> =
                        active.iter().map(|&d| batch_sizes[d]).collect();
                    let outcome = match strategy {
                        Strategy::Adaptive => {
                            let l2s: Vec<f64> =
                                active.iter().map(|&d| replicas[d].l2_per_param()).collect();
                            merge::compute_weights(&active_updates, &active_batches, &l2s, &cfg.merge)
                        }
                        _ => merge::MergeOutcome {
                            weights: vec![1.0 / active.len() as f64; active.len()],
                            perturbed: false,
                            by_updates: false,
                        },
                    };
                    let (merged, merge_secs) =
                        self.merge_active(&replicas, &active, &outcome.weights, &dims);
                    // Momentum global update for the HeteroGPU strategies.
                    let momentum = match strategy {
                        Strategy::Adaptive | Strategy::Elastic => cfg.merge.momentum,
                        _ => 0.0,
                    };
                    merge::momentum_update(&mut global, &mut global_prev, &merged, momentum);
                    clock += merge_secs;

                    // ---- Algorithm 1 (Adaptive only) over the active
                    // subset, gated by the stability/oscillation controller --
                    scaling_state.observe(&batch_sizes);
                    if strategy == Strategy::Adaptive
                        && cfg.strategy.batch_scaling
                        && scaling_state.should_scale()
                    {
                        let mut b_act: Vec<usize> =
                            active.iter().map(|&d| batch_sizes[d]).collect();
                        let mut lr_act: Vec<f32> = active.iter().map(|&d| lrs[d]).collect();
                        scaling::rescale(&mut b_act, &mut lr_act, &active_updates, &cfg.sgd);
                        for (i, &d) in active.iter().enumerate() {
                            batch_sizes[d] = b_act[i];
                            lrs[d] = lr_act[i];
                        }
                    }
                    let weights = scatter_weights(&outcome.weights, &active, roster);
                    (report, merge_secs, weights, outcome.perturbed)
                }
                Strategy::SyncGradAgg => {
                    // One "mega-batch" worth of synchronous rounds, merging
                    // after every round (gradient aggregation ≡ averaging
                    // one-step replicas).
                    let plan: DispatchPlan = plan_for_strategy(
                        &cfg, strategy, &active, &batch_sizes, &lrs, nnz_estimate,
                    );
                    let b_tf = plan.batch_sizes[0];
                    let rounds =
                        (cfg.sgd.mega_batch_samples() / (active.len() * b_tf)).max(1);
                    let mut agg: Option<MegaBatchReport> = None;
                    let mut merge_total = 0.0;
                    let uniform = vec![1.0 / active.len() as f64; active.len()];
                    for _ in 0..rounds {
                        let mut plan = plan.clone();
                        for lr in plan.lrs.iter_mut() {
                            *lr *= warmup;
                        }
                        let report =
                            self.engine.run_mega_batch(&mut replicas, &plane, &plan)?;
                        clock += report.wall * cfg.strategy.sync_overhead;

                        let (merged, merge_secs) =
                            self.merge_active(&replicas, &active, &uniform, &dims);
                        clock += merge_secs * cfg.strategy.sync_overhead;
                        merge_total += merge_secs;
                        global_prev = global.clone();
                        global = merged;
                        for &d in &active {
                            replicas[d] = global.clone();
                        }
                        agg = Some(match agg.take() {
                            None => report,
                            Some(mut acc) => {
                                for (a, b) in acc.per_device.iter_mut().zip(report.per_device) {
                                    a.updates += b.updates;
                                    a.samples += b.samples;
                                    a.busy += b.busy;
                                    a.loss_sum += b.loss_sum;
                                    a.nnz += b.nnz;
                                }
                                acc.wall += report.wall;
                                acc.batch_nnz.extend(report.batch_nnz);
                                acc
                            }
                        });
                    }
                    let weights = scatter_weights(&uniform, &active, roster);
                    (agg.unwrap(), merge_total, weights, false)
                }
            };

            // Reset the active replicas to the merged global model for the
            // next window. Inactive slots are synced lazily when their
            // device re-joins (see the pool-event handling above).
            for &d in &active {
                replicas[d] = global.clone();
            }

            samples += report.total_samples();
            pool.observe(&report);

            // ---- evaluate (excluded from the training clock) --------------
            let accuracy = if (mb + 1) % self.opts.eval_every == 0 {
                crate::eval::p_at_1(self.eval_backend, &global, &eval_batches, test)?
            } else {
                log.rows.last().map(|r| r.accuracy).unwrap_or(0.0)
            };

            // Hardware efficiency: fraction of the barrier window each
            // active device spent busy (1.0 = no straggler idling; inactive
            // devices report 0).
            let utilization: Vec<f64> = report
                .per_device
                .iter()
                .map(|d| {
                    if d.updates > 0 && report.wall > 0.0 {
                        (d.busy / report.wall).min(1.0)
                    } else {
                        0.0
                    }
                })
                .collect();

            // Per-batch nnz dispersion (the cost variance the composition
            // policy controls) plus cumulative data-plane counters.
            let (nnz_mean, nnz_cv) = report.nnz_dispersion();
            let row = MegaBatchRow {
                mega_batch: mb,
                clock,
                samples,
                loss: report.mean_loss(),
                accuracy,
                batch_sizes: batch_sizes.clone(),
                updates: report.updates(),
                perturbed,
                merge_time: merge_secs,
                l2_per_param: global.l2_per_param(),
                utilization,
                active_devices: active.clone(),
                merge_weights,
                pool_events: events.iter().map(pool_event_row).collect(),
                nnz_mean,
                nnz_cv,
                pipeline: pipeline_row(&plane.stats()),
            };
            for ev in events {
                log.pool_events.push(pool_event_row(&ev));
            }
            if let Some(path) = &self.opts.checkpoint {
                crate::model::checkpoint::save(&global, path)?;
            }
            // Publish into the serving registry at the configured cadence
            // (the clock stamp excludes eval time, like the training clock).
            if let Some(reg) = &self.opts.publish {
                if (mb + 1) % cfg.serve.publish_every == 0 {
                    reg.publish(global.clone(), Some(mb), clock);
                }
            }
            if self.opts.verbose {
                println!(
                    "[{}] mb={:<3} clock={:>8.3}s loss={:<8.4} P@1={:<6.4} g={} b={:?} u={:?}{}",
                    log.name,
                    mb,
                    clock,
                    row.loss,
                    accuracy,
                    row.active_devices.len(),
                    row.batch_sizes,
                    row.updates,
                    if perturbed { " pert" } else { "" }
                );
            }
            log.push(row);
        }
        Ok(log)
    }

    /// Weighted all-reduce over the active replicas; returns the merged
    /// model and the simulated transfer seconds.
    fn merge_active(
        &self,
        replicas: &[ModelState],
        active: &[usize],
        weights: &[f64],
        dims: &crate::config::ModelDims,
    ) -> (ModelState, f64) {
        let mut merged = ModelState::zeros(dims);
        let refs: Vec<&ModelState> = active.iter().map(|&d| &replicas[d]).collect();
        let stats = allreduce::allreduce_merge(
            &mut merged,
            &refs,
            weights,
            self.opts.allreduce,
            active.len(),
            &self.engine.cost_model(),
        );
        (merged, stats.seconds)
    }

    fn eval_bucket(&self) -> usize {
        self.opts
            .eval_bucket
            .unwrap_or_else(|| 256.min(self.cfg.data.test_samples.max(1)).max(1))
    }
}

/// Spread active-subset merge weights back onto the roster (inactive = 0),
/// for the per-row telemetry.
fn scatter_weights(weights: &[f64], active: &[usize], roster: usize) -> Vec<f64> {
    let mut out = vec![0.0; roster];
    for (w, &d) in weights.iter().zip(active) {
        out[d] = *w;
    }
    out
}

fn pool_event_row(ev: &PoolEvent) -> PoolEventRow {
    PoolEventRow {
        mega_batch: ev.mega_batch,
        device: ev.device,
        action: ev.action.name().to_string(),
        reason: ev.reason.clone(),
    }
}

fn pipeline_row(s: &PipelineStats) -> PipelineStatsRow {
    PipelineStatsRow {
        prefetched: s.prefetched,
        synchronous: s.synchronous,
        starved: s.starved,
        flushed: s.flushed,
        truncated_features: s.truncated_features,
        pool_hits: s.pool.hits,
        pool_misses: s.pool.misses,
    }
}

/// Linear warmup multiplier for mega-batch `mb` (1.0 once warmup is over or
/// disabled).
fn warmup_factor(mb: usize, warmup_mega_batches: usize) -> f32 {
    if warmup_mega_batches == 0 {
        1.0
    } else {
        (((mb + 1) as f32) / warmup_mega_batches as f32).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
    use crate::coordinator::backend::RefBackend;
    use crate::coordinator::engine_sim::SimEngine;
    use crate::data::synthetic::Generator;
    use crate::runtime::CostModel;

    fn test_config(strategy: Strategy, g: usize) -> Config {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        cfg.sgd = SgdConfig {
            b_min: 8,
            b_max: 32,
            beta: 4,
            lr_bmax: 0.4,
            mega_batches: 24,
            num_mega_batches: 6,
            initial_batch: 32,
            warmup_mega_batches: 0,
            seed: 7,
        };
        cfg.devices = DeviceConfig {
            count: g,
            speed_factors: (0..g).map(|i| 1.0 + 0.32 * i as f64 / (g.max(2) - 1) as f64).collect(),
            jitter: 0.0,
            nnz_sensitivity: 1.0,
            seed: 17,
        };
        cfg.data = DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
        cfg.strategy.kind = strategy;
        cfg.validate().unwrap();
        cfg
    }

    fn sim_engine<'b>(cfg: &Config, backend: &'b RefBackend) -> Box<dyn ExecutionEngine + 'b> {
        Box::new(SimEngine::new(backend, DevicePool::roster(cfg), CostModel::default()))
    }

    fn run_strategy(strategy: Strategy, g: usize) -> RunLog {
        let cfg = test_config(strategy, g);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        trainer.run(&train, &test).unwrap()
    }

    #[test]
    fn adaptive_trains_and_improves() {
        let log = run_strategy(Strategy::Adaptive, 4);
        assert_eq!(log.rows.len(), 6);
        assert!(log.rows[5].loss < log.rows[0].loss, "loss should fall");
        assert!(log.best_accuracy() > 0.15, "acc {}", log.best_accuracy());
        // Clock advances monotonically.
        assert!(log.rows.windows(2).all(|w| w[1].clock > w[0].clock));
        // Static pool: every row covers the whole fleet, no events.
        assert!(log.rows.iter().all(|r| r.active_devices == vec![0, 1, 2, 3]));
        assert!(log.pool_events.is_empty());
    }

    #[test]
    fn all_strategies_complete_and_learn() {
        for strategy in Strategy::all() {
            let log = run_strategy(strategy, 2);
            assert!(!log.rows.is_empty(), "{strategy:?}");
            assert!(
                log.rows.last().unwrap().loss < log.rows[0].loss + 0.1,
                "{strategy:?} loss went up: {} -> {}",
                log.rows[0].loss,
                log.rows.last().unwrap().loss
            );
        }
    }

    #[test]
    fn adaptive_batch_sizes_differentiate_under_heterogeneity() {
        let log = run_strategy(Strategy::Adaptive, 4);
        let last = log.rows.last().unwrap();
        // The slowest device should have drifted below the fastest.
        assert!(
            last.batch_sizes[0] > last.batch_sizes[3]
                || last.batch_sizes.iter().any(|&b| b != last.batch_sizes[0]),
            "batch sizes never adapted: {:?}",
            last.batch_sizes
        );
    }

    #[test]
    fn elastic_keeps_static_batches() {
        let log = run_strategy(Strategy::Elastic, 4);
        for row in &log.rows {
            assert!(row.batch_sizes.iter().all(|&b| b == 32));
            // Equal updates by construction.
            assert!(row.updates.iter().all(|&u| u == row.updates[0]));
        }
    }

    #[test]
    fn scripted_pool_events_flow_into_the_log() {
        let mut cfg = test_config(Strategy::Adaptive, 4);
        cfg.elastic.events =
            vec!["at_mb=2 remove_id=0".to_string(), "at_mb=4 add_id=0".to_string()];
        cfg.validate().unwrap();
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let log = trainer.run(&train, &test).unwrap();

        let counts: Vec<usize> = log.rows.iter().map(|r| r.active_devices.len()).collect();
        assert_eq!(counts, vec![4, 4, 3, 3, 4, 4]);
        assert_eq!(log.pool_events.len(), 2);
        assert_eq!(log.pool_events[0].action, "remove");
        assert_eq!(log.pool_events[0].device, 0);
        assert_eq!(log.pool_events[1].action, "add");
        // While device 0 is out it does no updates and carries no weight.
        for r in &log.rows[2..4] {
            assert_eq!(r.updates[0], 0);
            assert_eq!(r.merge_weights[0], 0.0);
            assert!(!r.active_devices.contains(&0));
        }
        // Merge weights renormalize over the active subset at every merge
        // (perturbation may denormalize by at most ±delta).
        for r in &log.rows {
            let sum: f64 = r.merge_weights.iter().sum();
            assert!((sum - 1.0).abs() < 0.1 + 1e-9, "weight sum {sum} at mb {}", r.mega_batch);
        }
    }

    #[test]
    fn time_budget_stops_early() {
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(500, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let opts = TrainerOptions { time_budget: Some(1e-9), ..Default::default() };
        let mut trainer = Trainer::new(cfg, engine, &backend, opts);
        let log = trainer.run(&train, &test).unwrap();
        assert!(log.rows.len() <= 1);
    }

    #[test]
    fn warmup_factor_ramps_linearly() {
        assert_eq!(warmup_factor(0, 0), 1.0);
        assert_eq!(warmup_factor(0, 4), 0.25);
        assert_eq!(warmup_factor(1, 4), 0.5);
        assert_eq!(warmup_factor(3, 4), 1.0);
        assert_eq!(warmup_factor(100, 4), 1.0);
    }

    #[test]
    fn warmup_slows_early_updates() {
        // With warmup the first mega-batch moves the model strictly less.
        let mut cfg = test_config(Strategy::Adaptive, 2);
        cfg.sgd.num_mega_batches = 1;
        let run = |cfg: &Config| {
            let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
            let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
            let backend = RefBackend;
            let engine = sim_engine(cfg, &backend);
            let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
            let log = trainer.run(&train, &test).unwrap();
            log.rows[0].l2_per_param
        };
        let no_warmup = run(&cfg);
        cfg.sgd.warmup_mega_batches = 10;
        let with_warmup = run(&cfg);
        // Warmup shrinks the first-step learning rates 10x, so the merged
        // model stays closer to the (zero-bias) init -> smaller L2 drift
        // relative to the aggressive run is not guaranteed in general, but
        // the two must at least differ, proving warmup reached the plan.
        assert_ne!(no_warmup, with_warmup);
    }

    #[test]
    fn checkpoint_and_resume_round_trip() {
        let dir = std::env::temp_dir().join("hs-trainer-ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("global.ckpt");

        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let opts = TrainerOptions { checkpoint: Some(path.clone()), ..Default::default() };
        let mut trainer = Trainer::new(cfg.clone(), engine, &backend, opts);
        trainer.run(&train, &test).unwrap();
        assert!(path.exists());

        // Resume from the checkpoint: first-row loss must be well below a
        // fresh run's first-row loss.
        let saved = crate::model::checkpoint::load(&path).unwrap();
        let engine2 = sim_engine(&cfg, &backend);
        let opts2 = TrainerOptions { init_model: Some(saved), ..Default::default() };
        let mut resumed = Trainer::new(cfg.clone(), engine2, &backend, opts2);
        let log2 = resumed.run(&train, &test).unwrap();

        let engine3 = sim_engine(&cfg, &backend);
        let mut fresh = Trainer::new(cfg, engine3, &backend, TrainerOptions::default());
        let fresh_log = fresh.run(&train, &test).unwrap();
        assert!(
            log2.rows[0].loss < fresh_log.rows[0].loss,
            "resumed run should start ahead: {} vs {}",
            log2.rows[0].loss,
            fresh_log.rows[0].loss
        );
    }

    #[test]
    fn publish_hook_feeds_the_snapshot_registry() {
        let mut cfg = test_config(Strategy::Adaptive, 2); // 6 mega-batches
        cfg.serve.publish_every = 2;
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let reg = std::sync::Arc::new(crate::serve::SnapshotRegistry::new());
        let opts = TrainerOptions { publish: Some(reg.clone()), ..Default::default() };
        let mut trainer = Trainer::new(cfg, engine, &backend, opts);
        let log = trainer.run(&train, &test).unwrap();

        let h = reg.history();
        // Init publish + mega-batches 1, 3, 5.
        assert_eq!(h.len(), 4);
        assert_eq!(h[0].mega_batch, None, "warm-start snapshot first");
        assert_eq!(
            h[1..].iter().map(|s| s.mega_batch).collect::<Vec<_>>(),
            vec![Some(1), Some(3), Some(5)]
        );
        // Publish clocks are the training clock at those merges.
        assert_eq!(h[1].published_clock, log.rows[1].clock);
        assert_eq!(h[3].published_clock, log.rows[5].clock);
        assert!(h.windows(2).all(|w| w[0].published_clock < w[1].published_clock));
        assert_eq!(reg.current().unwrap().version, 4);
    }

    #[test]
    fn rows_carry_nnz_dispersion_and_pipeline_counters() {
        let mut cfg = test_config(Strategy::Adaptive, 2);
        cfg.data.nnz_sigma = 1.0; // heavier tail -> nonzero dispersion
        cfg.validate().unwrap();
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let log = trainer.run(&train, &test).unwrap();
        for r in &log.rows {
            assert!(r.nnz_mean > 0.0, "mb {} nnz_mean", r.mega_batch);
            assert!(r.nnz_cv > 0.0, "shuffled heavy-tailed batches must disperse");
        }
        let last = &log.rows.last().unwrap().pipeline;
        assert!(last.synchronous > 0, "virtual mode assembles synchronously");
        assert_eq!(last.starved, 0, "sync mode never starves");
        assert!(last.pool_hits > 0, "engine recycling must produce pool hits");
    }

    #[test]
    fn balanced_policy_cuts_batch_cost_dispersion() {
        // The acceptance check at trainer level: same heavy-tailed corpus,
        // same strategy, only the composition policy differs.
        // Elastic keeps every batch at b_max, so the CV is purely
        // compositional (no batch-size variation mixed in).
        let mean_cv = |policy| {
            let mut cfg = test_config(Strategy::Elastic, 2);
            cfg.model.max_nnz = 24;
            cfg.data.avg_nnz = 8.0;
            cfg.data.nnz_sigma = 1.2;
            cfg.data.pipeline.policy = policy;
            cfg.validate().unwrap();
            let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
            let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
            let backend = RefBackend;
            let engine = sim_engine(&cfg, &backend);
            let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
            let log = trainer.run(&train, &test).unwrap();
            log.rows.iter().map(|r| r.nnz_cv).sum::<f64>() / log.rows.len() as f64
        };
        let shuffled = mean_cv(crate::config::CompositionPolicy::Shuffled);
        let balanced = mean_cv(crate::config::CompositionPolicy::NnzBalanced);
        assert!(
            balanced < shuffled * 0.6,
            "NnzBalanced must cut per-batch nnz CV: {balanced:.4} vs shuffled {shuffled:.4}"
        );
    }

    #[test]
    fn run_sharded_matches_run() {
        // run() is a resharding wrapper over run_sharded(); with the same
        // corpus and seeds the two must be trajectory-identical.
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;

        let engine = sim_engine(&cfg, &backend);
        let mut t1 = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
        let via_run = t1.run(&train, &test).unwrap();

        let sharded = std::sync::Arc::new(
            crate::data::pipeline::ShardedDataset::from_dataset(
                &train,
                cfg.data.pipeline.shard_samples,
            ),
        );
        let engine = sim_engine(&cfg, &backend);
        let mut t2 = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let via_sharded = t2.run_sharded(sharded, &test).unwrap();

        assert_eq!(via_run.rows.len(), via_sharded.rows.len());
        for (a, b) in via_run.rows.iter().zip(&via_sharded.rows) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.clock, b.clock);
        }
    }

    #[test]
    fn deterministic_runs_with_zero_jitter() {
        let a = run_strategy(Strategy::Adaptive, 3);
        let b = run_strategy(Strategy::Adaptive, 3);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.clock, y.clock);
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.batch_sizes, y.batch_sizes);
        }
    }
}

//! The training session: pool membership, strategy dispatch, model merging,
//! batch scaling, evaluation, and metrics — the outer loop of Figure 4.
//!
//! Two layers live here:
//!
//! * [`TrainerSession`] — the resumable per-mega-batch core. One call to
//!   [`TrainerSession::step`] runs one mega-batch over an *externally
//!   imposed* active device subset: dispatch plan, merge (Algorithm 2
//!   weights renormalized over that subset), batch scaling (Algorithm 1),
//!   evaluation, and the metrics row. Because the roster arrives per step,
//!   a session can pause (no step while it holds no devices) and resume by
//!   re-planning through the existing elastic path — this is what the
//!   fleet scheduler ([`crate::fleet`]) drives when an arbiter grants and
//!   revokes device leases mid-run.
//! * [`Trainer`] — the classic single-job loop: owns a [`DevicePool`]
//!   (scripted traces + straggler policy) and feeds its active set into
//!   the session, one mega-batch per pool window.
//!
//! Strategies:
//!
//! * **Adaptive** — dynamic dispatch over a sample-budget mega-batch, then
//!   Algorithm 2 merging (normalized weights + perturbation + momentum) and
//!   Algorithm 1 batch-size scaling.
//! * **Elastic** — static equal batches, plain average merge with the same
//!   momentum update rule (the paper implements both in HeteroGPU with the
//!   same update rule; Fig. 6 note).
//! * **SyncGradAgg** — the TensorFlow-mirrored analog: per-device batch
//!   `b_max/G`, merge after *every* round; a configurable framework-overhead
//!   multiplier models TF's slower epoch execution.
//! * **Crossbow** — dynamic dispatch with per-batch replica correction
//!   toward the fleet average, plain average merge at mega-batch ends.
//!
//! Per-device state — replicas, batch sizes, learning rates — is
//! roster-indexed, and the momentum history lives on the global model, so
//! both survive membership churn. The training clock *excludes* evaluation
//! time (paper §5.1 methodology).

use std::sync::Arc;

use crate::allreduce::{self, Algo};
use crate::config::{Config, ExecMode, Strategy};
use crate::data::batcher::EvalBatches;
use crate::data::pipeline::{DataPlane, PipelineStats, ShardedDataset};
use crate::data::SparseDataset;
use crate::metrics::{MegaBatchRow, PipelineStatsRow, PoolEventRow, RunLog};
use crate::model::ModelState;
use crate::tuning::{
    self, CalibratedCosts, DeviceEstimator, DriftEvent, EstimatorConfig, Observation,
};
use crate::Result;

use super::backend::StepBackend;
use super::plan::{plan_for_strategy, DispatchPlan, ExecutionEngine, MegaBatchReport};
use super::pool::{DevicePool, PoolEvent};
use super::{merge, scaling};

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// Stop once the training clock exceeds this many seconds.
    pub time_budget: Option<f64>,
    /// Evaluate every k mega-batches (1 = the paper's cadence).
    pub eval_every: usize,
    /// All-reduce variant used for merging.
    pub allreduce: Algo,
    /// Evaluation batch bucket. With a PJRT eval backend this MUST equal the
    /// manifest's `eval_batch`; `None` picks a reference-backend-friendly
    /// default.
    pub eval_bucket: Option<usize>,
    /// Resume from this model instead of a fresh initialization.
    pub init_model: Option<crate::model::ModelState>,
    /// Save the merged global model here after every mega-batch (atomic).
    pub checkpoint: Option<std::path::PathBuf>,
    /// Publish merged global models into this snapshot registry: the
    /// initial model before training starts (serving warm-starts on it)
    /// and then every `[serve] publish_every` mega-batches — the
    /// train→serve hook the serving plane reads from.
    pub publish: Option<Arc<crate::serve::SnapshotRegistry>>,
    /// Share this calibrated-costs view instead of creating a private one
    /// — the fleet co-scheduler hands every tenant (and the serve router)
    /// the same view, so all observers of a device pool their estimates.
    /// Ignored when `[calibration]` is disabled.
    pub costs: Option<Arc<CalibratedCosts>>,
    /// Print progress lines.
    pub verbose: bool,
    /// Observability handle: trace sink + metric registry + process lane.
    /// Defaults to the process-wide ambient handle (installed by the CLI
    /// from `[obs]` / `--trace`; disabled otherwise), so library callers
    /// that never mention obs keep byte-identical behavior. The fleet and
    /// cluster planes re-lane this per tenant/server via
    /// [`crate::obs::ObsHandle::for_pid`].
    pub obs: crate::obs::ObsHandle,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            time_budget: None,
            eval_every: 1,
            allreduce: Algo::Ring,
            eval_bucket: None,
            init_model: None,
            checkpoint: None,
            publish: None,
            costs: None,
            verbose: false,
            obs: crate::obs::ambient(),
        }
    }
}

/// A resumable training session stepped one mega-batch at a time.
///
/// The caller supplies the active device subset at every step — the
/// trainer's own [`DevicePool`] in single-job runs, the fleet arbiter's
/// lease set under multi-tenant co-scheduling. A step with a different
/// subset than the last one re-plans through the elastic path: joining
/// devices resync to the global model, merge weights renormalize over the
/// new subset, and Algorithm 1 state stays roster-indexed so it survives
/// the churn.
pub struct TrainerSession<'b> {
    cfg: Config,
    engine: Box<dyn ExecutionEngine + 'b>,
    eval_backend: &'b dyn StepBackend,
    opts: TrainerOptions,
    plane: DataPlane,
    eval_batches: EvalBatches,
    test: Arc<SparseDataset>,
    nnz_estimate: f64,
    roster: usize,
    global: ModelState,
    global_prev: ModelState,
    replicas: Vec<ModelState>,
    batch_sizes: Vec<usize>,
    lrs: Vec<f32>,
    /// Roster-indexed active-class sparsity ratios (`[slide] adaptive`;
    /// all 1.0 = dense, the default). The joint re-targeting path moves
    /// these together with `batch_sizes` when a drift fires.
    sparsity_ratios: Vec<f64>,
    scaling_state: scaling::ScalingState,
    /// Per-roster-device cost estimators (`[calibration] enabled`; empty
    /// when the plane is off).
    estimators: Vec<DeviceEstimator>,
    /// Shared calibrated-costs view the estimators publish into (None =
    /// calibration off; every consumer then reads config constants).
    costs: Option<Arc<CalibratedCosts>>,
    /// Scripted drift trace (`[calibration] events`), re-applied to the
    /// engine's devices at every mega-batch boundary. Applies whether or
    /// not `enabled` closes the scheduling loop — it is the physical
    /// scenario, not the policy.
    drift_trace: Vec<DriftEvent>,
    /// Active set of the previous step (resync detection). Starts as the
    /// full roster: every replica begins as a clone of the global model.
    prev_active: Vec<usize>,
    clock: f64,
    samples: u64,
    mb: usize,
    last_report: Option<MegaBatchReport>,
    log: RunLog,
}

impl<'b> TrainerSession<'b> {
    /// Build a session over an already-sharded corpus. `name` labels the
    /// run log (tenant name under the fleet scheduler).
    pub fn new(
        cfg: Config,
        mut engine: Box<dyn ExecutionEngine + 'b>,
        eval_backend: &'b dyn StepBackend,
        mut opts: TrainerOptions,
        train: Arc<ShardedDataset>,
        test: Arc<SparseDataset>,
        name: impl Into<String>,
    ) -> Result<TrainerSession<'b>> {
        let dims = cfg.model.clone();
        let roster = engine.roster_len();
        // The engine emits per-device step spans onto the same sink/lane.
        engine.set_obs(opts.obs.clone());

        // The data plane: sharded corpus + composition policy + (for the
        // threaded engine) async prefetch. Virtual-time runs force
        // synchronous assembly so the sample→device routing — and with it
        // the whole run — stays deterministic.
        let producer_threads = match cfg.runtime.mode {
            ExecMode::Virtual => 0,
            ExecMode::Real => cfg.data.pipeline.producer_threads,
        };
        let plane = DataPlane::new_obs(
            train,
            &dims,
            &cfg.data.pipeline,
            producer_threads,
            cfg.sgd.seed,
            &opts.obs,
        );
        let nnz_estimate = plane.nnz_estimate();

        let eval_bucket = opts
            .eval_bucket
            .unwrap_or_else(|| 256.min(cfg.data.test_samples.max(1)).max(1));
        let eval_batches = EvalBatches::new(&test, &dims, eval_bucket);

        // Global model + momentum history + roster-indexed replicas.
        let global = match opts.init_model.take() {
            Some(m) => {
                anyhow::ensure!(m.dims == dims, "resume model dims mismatch");
                m
            }
            None => ModelState::init(&dims, cfg.sgd.seed),
        };
        let global_prev = global.clone();
        let replicas: Vec<ModelState> = vec![global.clone(); roster];

        // Serving warm-start: the init (or resumed) model is servable before
        // the first merge lands.
        if let Some(reg) = &opts.publish {
            reg.publish(global.clone(), None, 0.0);
        }

        let batch_sizes = vec![cfg.sgd.initial_batch; roster];
        let lrs = vec![cfg.lr_for_batch(cfg.sgd.initial_batch); roster];
        let scaling_state = scaling::ScalingState::from_config(&cfg.sgd);

        // ---- calibration plane -------------------------------------------
        // The drift trace is the physical scenario: parsed unconditionally.
        // Estimators and the shared view only exist when `enabled` closes
        // the scheduling loop on them.
        let drift_trace = cfg.calibration.parsed_events()?;
        let (estimators, costs) = if cfg.calibration.enabled {
            let ecfg = EstimatorConfig {
                window: cfg.calibration.window,
                alpha: cfg.calibration.alpha,
                step_threshold: cfg.calibration.step_threshold,
                step_obs: cfg.calibration.step_obs,
            };
            let nominal_cost = engine.cost_model();
            let estimators: Vec<DeviceEstimator> =
                (0..roster).map(|_| DeviceEstimator::new(ecfg, nominal_cost)).collect();
            let costs = match opts.costs.clone() {
                Some(shared) => {
                    anyhow::ensure!(
                        shared.current().roster_len() == roster,
                        "shared calibrated-costs view covers {} devices, roster has {roster}",
                        shared.current().roster_len()
                    );
                    shared
                }
                None => {
                    let mut nominal = cfg.devices.speed_factors.clone();
                    nominal.extend(cfg.elastic.spare_devices.iter().copied());
                    Arc::new(CalibratedCosts::new(nominal))
                }
            };
            (estimators, Some(costs))
        } else {
            (Vec::new(), None)
        };

        Ok(TrainerSession {
            log: RunLog::new(name),
            plane,
            eval_batches,
            test,
            nnz_estimate,
            roster,
            global,
            global_prev,
            replicas,
            batch_sizes,
            lrs,
            sparsity_ratios: vec![1.0; roster],
            scaling_state,
            estimators,
            costs,
            drift_trace,
            prev_active: (0..roster).collect(),
            clock: 0.0,
            samples: 0,
            mb: 0,
            last_report: None,
            cfg,
            engine,
            eval_backend,
            opts,
        })
    }

    /// All configured mega-batches have run.
    pub fn done(&self) -> bool {
        self.mb >= self.cfg.sgd.num_mega_batches
    }

    /// Training clock in virtual/wall seconds (excludes evaluation time).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Mega-batches completed so far.
    pub fn completed_mega_batches(&self) -> usize {
        self.mb
    }

    pub fn log(&self) -> &RunLog {
        &self.log
    }

    pub fn into_log(mut self) -> RunLog {
        self.log.metrics = self.opts.obs.metrics_rows();
        self.log
    }

    /// Tear the session down, returning the run log and the engine it
    /// borrowed (so a [`Trainer`] can reclaim it). With `[obs]` enabled
    /// the registry snapshot rides out in the log's `metrics` section.
    pub fn finish(mut self) -> (RunLog, Box<dyn ExecutionEngine + 'b>) {
        self.log.metrics = self.opts.obs.metrics_rows();
        (self.log, self.engine)
    }

    /// Report of the most recent mega-batch (straggler-policy food).
    pub fn last_report(&self) -> Option<&MegaBatchReport> {
        self.last_report.as_ref()
    }

    /// The calibrated-costs view this session publishes into (None when
    /// `[calibration]` is disabled). The fleet arbiter and serve router
    /// read the same view for capacity weighting and routing.
    pub fn calibrated_costs(&self) -> Option<&Arc<CalibratedCosts>> {
        self.costs.as_ref()
    }

    /// The current intra-server consensus model (Algorithm 2's merged
    /// state). The cluster plane reads this between steps as the server's
    /// contribution to the inter-server merge.
    pub fn global_model(&self) -> &ModelState {
        &self.global
    }

    /// Replace the consensus model with an externally merged one (the
    /// cluster plane's inter-server sync writing the tier-2 average back).
    ///
    /// Two invariants are preserved:
    ///
    /// * **Momentum velocity.** `global_prev` is shifted by the same delta
    ///   as `global`, so the next merge's momentum term
    ///   `momentum * (global - global_prev)` still measures local progress,
    ///   not the cross-server correction we just applied.
    /// * **Replica coherence.** [`Self::step`] leaves every previously
    ///   active device's replica equal to the old consensus; those replicas
    ///   are refreshed so the next mega-batch starts from the installed
    ///   model (devices rejoining later are resynced by `step` itself).
    pub fn install_global(&mut self, model: ModelState) {
        // global_prev += (model - global): velocity (global - global_prev)
        // is unchanged by the installation.
        let old = std::mem::replace(&mut self.global, model);
        self.global_prev.add_scaled_diff(&self.global, &old, 1.0);
        for &d in &self.prev_active {
            self.replicas[d] = self.global.clone();
        }
    }

    /// Calibrated per-slot step predictions for a plan's active slots
    /// (None when calibration is off): the device's current estimate when
    /// one exists, its nominal speed factor otherwise.
    fn predicted_secs(&self, device_ids: &[usize], batch_sizes: &[usize]) -> Option<Vec<f64>> {
        let view = self.costs.as_ref()?.current();
        let cost = self.engine.cost_model();
        let adaptive = self.cfg.slide.adaptive;
        Some(
            device_ids
                .iter()
                .zip(batch_sizes)
                .map(|(&d, &b)| {
                    let nnz = self.nnz_estimate * b as f64;
                    // Price the sparsity knob into dispatch predictions
                    // (ratio 1.0 is bit-identical to the dense formula).
                    let ratio = if adaptive { self.sparsity_ratios[d] } else { 1.0 };
                    match view.estimate(d) {
                        Some(e) => e.step_secs_at(&cost, b, nnz, ratio),
                        None => view.nominal[d] * cost.step_time_parts_at(b, nnz as usize, ratio),
                    }
                })
                .collect(),
        )
    }

    /// Run one mega-batch over `active` starting no earlier than `now`
    /// (the clock jumps forward to `now` first — a paused tenant resuming
    /// under the fleet scheduler lands on the shared fleet clock).
    /// `events` are the membership/lease changes that produced this
    /// roster; they are recorded into the row and the run-wide event log.
    /// Returns the completed row (its `clock` is the post-step time).
    pub fn step(
        &mut self,
        active: &[usize],
        now: f64,
        events: Vec<PoolEventRow>,
    ) -> Result<&MegaBatchRow> {
        anyhow::ensure!(!self.done(), "session already ran all mega-batches");
        anyhow::ensure!(!active.is_empty(), "step needs at least one active device");
        anyhow::ensure!(
            active.iter().all(|&d| d < self.roster),
            "active device outside the roster"
        );
        // One config clone per mega-batch keeps the borrow graph trivial
        // across the strategy match below; it is a few small Vecs next to
        // thousands of model steps, not a hot-path cost.
        let cfg = self.cfg.clone();
        let dims = cfg.model.clone();
        let strategy = cfg.strategy.kind;
        let mb = self.mb;
        self.clock = self.clock.max(now);
        let t_step_start = self.clock;
        let sizes_before = self.batch_sizes.clone();
        let obs = self.opts.obs.clone();
        if obs.enabled() {
            for ev in &events {
                obs.instant(
                    crate::obs::Subsystem::Train,
                    "train.pool",
                    0,
                    t_step_start,
                    vec![
                        ("device", crate::obs::ArgVal::U(ev.device as u64)),
                        ("action", crate::obs::ArgVal::S(ev.action.clone())),
                        ("reason", crate::obs::ArgVal::S(ev.reason.clone())),
                    ],
                );
            }
        }

        // A device (re-)joining resumes from the current global model; the
        // momentum history lives on the global model and is unaffected by
        // churn. (Inactive replicas are left stale rather than kept in
        // sync — one clone per join, not per mega-batch.)
        for &d in active {
            if !self.prev_active.contains(&d) {
                self.replicas[d] = self.global.clone();
            }
        }
        if self.opts.verbose {
            for ev in &events {
                println!(
                    "[{}] mb={:<3} pool: {} device {} ({})",
                    self.log.name, mb, ev.action, ev.device, ev.reason
                );
            }
        }

        // Goyal-style linear warmup on every device's learning rate.
        let warmup = warmup_factor(mb, cfg.sgd.warmup_mega_batches);

        // Scripted drift lands at mega-batch boundaries — the physical
        // throttle/recover scenario, applied whether or not calibration
        // closes the loop on it.
        if !self.drift_trace.is_empty() {
            for d in 0..self.roster {
                self.engine.set_drift(d, tuning::multiplier_at(&self.drift_trace, d, mb));
            }
        }

        // Roster-indexed batch sizes / sparsity ratios each device actually
        // ran this mega-batch (captured per plan below — calibration
        // observations must describe the work that ran, not post-rescale
        // state).
        let mut sizes_used = vec![0usize; self.roster];
        let mut ratios_used = vec![1.0f64; self.roster];

        let (report, merge_secs, merge_weights, perturbed) = match strategy {
            Strategy::Adaptive | Strategy::Elastic | Strategy::Crossbow => {
                let mut plan = plan_for_strategy(
                    &cfg,
                    strategy,
                    active,
                    &self.batch_sizes,
                    &self.lrs,
                    self.nnz_estimate,
                );
                for lr in plan.lrs.iter_mut() {
                    *lr *= warmup;
                }
                if cfg.slide.adaptive {
                    let ratios: Vec<f64> =
                        plan.device_ids.iter().map(|&d| self.sparsity_ratios[d]).collect();
                    plan = plan.with_sparsity_ratios(ratios);
                }
                if let Some(secs) = self.predicted_secs(&plan.device_ids, &plan.batch_sizes) {
                    plan = plan.with_predicted_step_secs(secs);
                }
                for (i, &d) in plan.device_ids.iter().enumerate() {
                    sizes_used[d] = plan.batch_sizes[i];
                    ratios_used[d] = plan.sparsity_ratio(i);
                }
                // Park the virtual clock in the sink so the engine's step
                // spans land at absolute run time, not window offsets.
                obs.set_time_base(self.clock);
                let report = self.engine.run_mega_batch(&mut self.replicas, &self.plane, &plan)?;
                self.clock += report.wall;

                // ---- merge (Algorithm 2 for Adaptive), weights
                // renormalized over the active subset -----------------------
                let active_updates: Vec<u64> =
                    active.iter().map(|&d| report.per_device[d].updates).collect();
                let active_batches: Vec<usize> =
                    active.iter().map(|&d| self.batch_sizes[d]).collect();
                let mut outcome = match strategy {
                    Strategy::Adaptive => {
                        let l2s: Vec<f64> =
                            active.iter().map(|&d| self.replicas[d].l2_per_param()).collect();
                        merge::compute_weights(&active_updates, &active_batches, &l2s, &cfg.merge)
                    }
                    _ => merge::MergeOutcome {
                        weights: vec![1.0 / active.len() as f64; active.len()],
                        perturbed: false,
                        by_updates: false,
                    },
                };
                // Gradient-quality discount: a replica trained on a
                // truncated class set carries proportionally less weight
                // into the merge (`ratio^discount`, renormalized). Only
                // touched when some active device actually ran sparse, so
                // dense runs keep the historical weights bit-for-bit.
                if cfg.slide.adaptive
                    && cfg.slide.quality_discount > 0.0
                    && active.iter().any(|&d| ratios_used[d] < 1.0)
                {
                    for (w, &d) in outcome.weights.iter_mut().zip(active) {
                        *w *= ratios_used[d].powf(cfg.slide.quality_discount);
                    }
                    let sum: f64 = outcome.weights.iter().sum();
                    if sum > 0.0 {
                        for w in outcome.weights.iter_mut() {
                            *w /= sum;
                        }
                    }
                }
                let (merged, merge_secs) = self.merge_active(active, &outcome.weights, &dims);
                // Momentum global update for the HeteroGPU strategies.
                let momentum = match strategy {
                    Strategy::Adaptive | Strategy::Elastic => cfg.merge.momentum,
                    _ => 0.0,
                };
                merge::momentum_update(
                    &mut self.global,
                    &mut self.global_prev,
                    &merged,
                    momentum,
                );
                self.clock += merge_secs;

                // ---- Algorithm 1 (Adaptive only) over the active subset,
                // gated by the stability/oscillation controller --------------
                self.scaling_state.observe(&self.batch_sizes);
                if strategy == Strategy::Adaptive
                    && cfg.strategy.batch_scaling
                    && self.scaling_state.should_scale()
                {
                    let mut b_act: Vec<usize> =
                        active.iter().map(|&d| self.batch_sizes[d]).collect();
                    let mut lr_act: Vec<f32> = active.iter().map(|&d| self.lrs[d]).collect();
                    scaling::rescale(&mut b_act, &mut lr_act, &active_updates, &cfg.sgd);
                    for (i, &d) in active.iter().enumerate() {
                        self.batch_sizes[d] = b_act[i];
                        self.lrs[d] = lr_act[i];
                    }
                }
                let weights = scatter_weights(&outcome.weights, active, self.roster);
                (report, merge_secs, weights, outcome.perturbed)
            }
            Strategy::SyncGradAgg => {
                // One "mega-batch" worth of synchronous rounds, merging
                // after every round (gradient aggregation ≡ averaging
                // one-step replicas).
                let mut plan: DispatchPlan = plan_for_strategy(
                    &cfg,
                    strategy,
                    active,
                    &self.batch_sizes,
                    &self.lrs,
                    self.nnz_estimate,
                );
                if let Some(secs) = self.predicted_secs(&plan.device_ids, &plan.batch_sizes) {
                    plan = plan.with_predicted_step_secs(secs);
                }
                for (i, &d) in plan.device_ids.iter().enumerate() {
                    sizes_used[d] = plan.batch_sizes[i];
                }
                let b_tf = plan.batch_sizes[0];
                let rounds = (cfg.sgd.mega_batch_samples() / (active.len() * b_tf)).max(1);
                let mut agg: Option<MegaBatchReport> = None;
                let mut merge_total = 0.0;
                let uniform = vec![1.0 / active.len() as f64; active.len()];
                for _ in 0..rounds {
                    let mut plan = plan.clone();
                    for lr in plan.lrs.iter_mut() {
                        *lr *= warmup;
                    }
                    obs.set_time_base(self.clock);
                    let report =
                        self.engine.run_mega_batch(&mut self.replicas, &self.plane, &plan)?;
                    self.clock += report.wall * cfg.strategy.sync_overhead;

                    let (merged, merge_secs) = self.merge_active(active, &uniform, &dims);
                    self.clock += merge_secs * cfg.strategy.sync_overhead;
                    merge_total += merge_secs;
                    self.global_prev = self.global.clone();
                    self.global = merged;
                    for &d in active {
                        self.replicas[d] = self.global.clone();
                    }
                    agg = Some(match agg.take() {
                        None => report,
                        Some(mut acc) => {
                            for (a, b) in acc.per_device.iter_mut().zip(report.per_device) {
                                a.updates += b.updates;
                                a.samples += b.samples;
                                a.busy += b.busy;
                                a.loss_sum += b.loss_sum;
                                a.nnz += b.nnz;
                            }
                            acc.wall += report.wall;
                            acc.batch_nnz.extend(report.batch_nnz);
                            acc
                        }
                    });
                }
                let weights = scatter_weights(&uniform, active, self.roster);
                (agg.unwrap(), merge_total, weights, false)
            }
        };

        if obs.enabled() {
            // The mega-batch window (dispatch + merge) on the coordinator
            // lane, and the merge tail as its own span with the decision
            // detail (perturbation fired or not).
            obs.span(
                crate::obs::Subsystem::Train,
                "train.megabatch",
                0,
                t_step_start,
                self.clock - t_step_start,
                vec![
                    ("mb", crate::obs::ArgVal::U(mb as u64)),
                    ("strategy", crate::obs::ArgVal::S(format!("{strategy:?}"))),
                    ("devices", crate::obs::ArgVal::U(active.len() as u64)),
                    ("updates", crate::obs::ArgVal::U(report.total_updates())),
                    ("samples", crate::obs::ArgVal::U(report.total_samples())),
                ],
            );
            obs.span(
                crate::obs::Subsystem::Train,
                "train.merge",
                0,
                self.clock - merge_secs,
                merge_secs,
                vec![("perturbed", crate::obs::ArgVal::B(perturbed))],
            );
        }

        // ---- calibration plane: observe, publish, fast re-target ----------
        // Every active device's mean per-batch time feeds its estimator;
        // fresh estimates publish into the shared view (Arc-swap). When the
        // step-drift detector fires, batch sizes re-seed immediately from
        // the estimated speeds — Algorithm 1 would need several merge
        // windows (and a paused stability controller re-arm) to catch up.
        if let Some(costs) = &self.costs {
            let nominal_cost = self.engine.cost_model();
            let mut fresh: Vec<(usize, tuning::DeviceEstimate)> = Vec::new();
            let mut drifted = false;
            for &d in active {
                let s = &report.per_device[d];
                if s.updates == 0 {
                    continue;
                }
                let obs = Observation {
                    bucket: sizes_used[d],
                    nnz_per_batch: s.nnz as f64 / s.updates as f64,
                    secs_per_batch: s.busy / s.updates as f64,
                    ratio: ratios_used[d],
                };
                if self.estimators[d].observe(obs) {
                    drifted = true;
                }
                if let Some(e) = self.estimators[d].estimate() {
                    fresh.push((d, e));
                }
            }
            if !fresh.is_empty() {
                costs.update_devices(&fresh, self.clock);
            }
            if drifted
                && strategy == Strategy::Adaptive
                && (cfg.strategy.batch_scaling || cfg.slide.adaptive)
            {
                let view = costs.current();
                let speeds: Vec<f64> = active.iter().map(|&d| view.speed(d)).collect();
                // Two-knob re-targeting when the sparsity lever is armed;
                // ratio-only when batch scaling is ablated away with the
                // lever still on; the historical batch-only path otherwise.
                let (targets, ratios) = if cfg.slide.adaptive && !cfg.strategy.batch_scaling {
                    let held: Vec<usize> = active.iter().map(|&d| self.batch_sizes[d]).collect();
                    let r = scaling::sparsity_targets(
                        &speeds,
                        &held,
                        self.nnz_estimate,
                        &nominal_cost,
                        &cfg.slide,
                    );
                    (held, r)
                } else if cfg.slide.adaptive {
                    scaling::joint_targets(
                        &speeds,
                        self.nnz_estimate,
                        &nominal_cost,
                        &cfg.sgd,
                        &cfg.slide,
                    )
                } else {
                    let t = scaling::calibrated_targets(
                        &speeds,
                        self.nnz_estimate,
                        &nominal_cost,
                        &cfg.sgd,
                    );
                    let ones = vec![1.0; t.len()];
                    (t, ones)
                };
                if obs.enabled() {
                    // Decision record: the inputs (calibrated speeds, old
                    // grid) and the chosen action (new grid + ratios), so
                    // `report --explain` can reconstruct the why post-hoc.
                    let from: Vec<usize> =
                        active.iter().map(|&d| self.batch_sizes[d]).collect();
                    obs.instant(
                        crate::obs::Subsystem::Train,
                        "train.retarget",
                        0,
                        self.clock,
                        vec![
                            ("reason", crate::obs::ArgVal::S("step-drift".to_string())),
                            ("devices", crate::obs::ArgVal::U(active.len() as u64)),
                            ("mb", crate::obs::ArgVal::U(mb as u64)),
                            ("speeds", scaling::fmt_speeds(&speeds).into()),
                            ("from", scaling::fmt_grid(&from).into()),
                            ("to", scaling::fmt_grid(&targets).into()),
                            ("ratios", scaling::fmt_speeds(&ratios).into()),
                            (
                                "why",
                                scaling::describe_retarget(active, &speeds, &from, &targets)
                                    .into(),
                            ),
                        ],
                    );
                }
                if self.opts.verbose {
                    println!(
                        "[{}] mb={:<3} calibration: step drift detected; re-seeding batch \
                         grid {:?} -> {:?} (ratios {:?}) on {:?}",
                        self.log.name,
                        mb,
                        active.iter().map(|&d| self.batch_sizes[d]).collect::<Vec<_>>(),
                        targets,
                        ratios,
                        active
                    );
                }
                for (i, &d) in active.iter().enumerate() {
                    if targets[i] != self.batch_sizes[d] {
                        self.lrs[d] *= targets[i] as f32 / self.batch_sizes[d] as f32;
                        self.batch_sizes[d] = targets[i];
                    }
                    if cfg.slide.adaptive {
                        self.sparsity_ratios[d] = ratios[i];
                    }
                }
            }
        }

        // Reset the active replicas to the merged global model for the
        // next window. Inactive slots are synced lazily when their device
        // re-joins (the prev_active diff above).
        for &d in active {
            self.replicas[d] = self.global.clone();
        }

        self.samples += report.total_samples();

        if obs.enabled() && self.batch_sizes != sizes_before {
            // Either Algorithm 1 rescaled or the drift re-target re-seeded;
            // one instant marks the new grid landing.
            obs.instant(
                crate::obs::Subsystem::Train,
                "train.scale",
                0,
                self.clock,
                vec![
                    ("mb", crate::obs::ArgVal::U(mb as u64)),
                    ("from", scaling::fmt_grid(&sizes_before).into()),
                    ("to", scaling::fmt_grid(&self.batch_sizes).into()),
                ],
            );
        }

        // ---- evaluate (excluded from the training clock) ------------------
        let accuracy = if (mb + 1) % self.opts.eval_every == 0 {
            let acc = crate::eval::p_at_1(
                self.eval_backend,
                &self.global,
                &self.eval_batches,
                &self.test,
            )?;
            if obs.enabled() {
                obs.instant(
                    crate::obs::Subsystem::Train,
                    "train.eval",
                    0,
                    self.clock,
                    vec![("p_at_1", crate::obs::ArgVal::F(acc))],
                );
            }
            acc
        } else {
            self.log.rows.last().map(|r| r.accuracy).unwrap_or(0.0)
        };

        // Hardware efficiency: fraction of the barrier window each active
        // device spent busy (1.0 = no straggler idling; inactive devices
        // report 0).
        let utilization: Vec<f64> = report
            .per_device
            .iter()
            .map(|d| {
                if d.updates > 0 && report.wall > 0.0 {
                    (d.busy / report.wall).min(1.0)
                } else {
                    0.0
                }
            })
            .collect();

        // Per-batch nnz dispersion (the cost variance the composition
        // policy controls) plus cumulative data-plane counters.
        let (nnz_mean, nnz_cv) = report.nnz_dispersion();

        // Calibration telemetry: the current estimate (and its residual)
        // per roster device; zeros mean "no estimate" / plane off.
        let (cost_speed, cost_residual) = match &self.costs {
            Some(costs) => {
                let view = costs.current();
                let speed: Vec<f64> = (0..self.roster)
                    .map(|d| view.estimate(d).map(|e| e.speed).unwrap_or(0.0))
                    .collect();
                let residual: Vec<f64> = (0..self.roster)
                    .map(|d| view.estimate(d).map(|e| e.residual_rel).unwrap_or(0.0))
                    .collect();
                (speed, residual)
            }
            None => (vec![0.0; self.roster], vec![0.0; self.roster]),
        };
        // Sparsity telemetry: the ratio each device ran and its mean
        // active-set size per step (classes for dense rows).
        let active_classes: Vec<f64> = report
            .per_device
            .iter()
            .map(|d| if d.updates > 0 { d.active_classes as f64 / d.updates as f64 } else { 0.0 })
            .collect();
        let row = MegaBatchRow {
            mega_batch: mb,
            clock: self.clock,
            samples: self.samples,
            loss: report.mean_loss(),
            accuracy,
            batch_sizes: self.batch_sizes.clone(),
            updates: report.updates(),
            perturbed,
            merge_time: merge_secs,
            l2_per_param: self.global.l2_per_param(),
            utilization,
            active_devices: active.to_vec(),
            merge_weights,
            pool_events: events.clone(),
            nnz_mean,
            nnz_cv,
            pipeline: pipeline_row(&self.plane.stats()),
            cost_speed,
            cost_residual,
            sparsity_ratio: ratios_used,
            active_classes,
        };
        self.log.pool_events.extend(events);
        if let Some(path) = &self.opts.checkpoint {
            crate::model::checkpoint::save(&self.global, path)?;
        }
        // Publish into the serving registry at the configured cadence
        // (the clock stamp excludes eval time, like the training clock).
        if let Some(reg) = &self.opts.publish {
            if (mb + 1) % cfg.serve.publish_every == 0 {
                reg.publish(self.global.clone(), Some(mb), self.clock);
            }
        }
        if self.opts.verbose {
            println!(
                "[{}] mb={:<3} clock={:>8.3}s loss={:<8.4} P@1={:<6.4} g={} b={:?} u={:?}{}",
                self.log.name,
                mb,
                self.clock,
                row.loss,
                accuracy,
                row.active_devices.len(),
                row.batch_sizes,
                row.updates,
                if perturbed { " pert" } else { "" }
            );
        }
        self.log.push(row);
        self.prev_active = active.to_vec();
        self.last_report = Some(report);
        self.mb += 1;
        Ok(self.log.rows.last().expect("row just pushed"))
    }

    /// Weighted all-reduce over the active replicas; returns the merged
    /// model and the simulated transfer seconds.
    fn merge_active(
        &self,
        active: &[usize],
        weights: &[f64],
        dims: &crate::config::ModelDims,
    ) -> (ModelState, f64) {
        let mut merged = ModelState::zeros(dims);
        let refs: Vec<&ModelState> = active.iter().map(|&d| &self.replicas[d]).collect();
        let stats = allreduce::allreduce_merge(
            &mut merged,
            &refs,
            weights,
            self.opts.allreduce,
            active.len(),
            &self.engine.cost_model(),
        );
        (merged, stats.seconds)
    }
}

pub struct Trainer<'b> {
    pub cfg: Config,
    pub engine: Box<dyn ExecutionEngine + 'b>,
    pub eval_backend: &'b dyn StepBackend,
    pub opts: TrainerOptions,
}

impl<'b> Trainer<'b> {
    pub fn new(
        cfg: Config,
        engine: Box<dyn ExecutionEngine + 'b>,
        eval_backend: &'b dyn StepBackend,
        opts: TrainerOptions,
    ) -> Self {
        Trainer { cfg, engine, eval_backend, opts }
    }

    /// Train on `train`, evaluating P@1 on `test` after every merge window.
    ///
    /// Reshards the borrowed corpus (one copy) — callers that already hold
    /// a sharded corpus (e.g. from `ShardedDataset::from_libsvm`) should
    /// use [`run_sharded`](Trainer::run_sharded) and pay no copy at all.
    pub fn run(&mut self, train: &SparseDataset, test: &SparseDataset) -> Result<RunLog> {
        let shard_samples = self.cfg.data.pipeline.shard_samples;
        let sharded = Arc::new(ShardedDataset::from_dataset(train, shard_samples));
        self.run_sharded(sharded, test)
    }

    /// Train from an already-sharded corpus — the zero-extra-copy path the
    /// data plane is built around (the *test* split is still cloned once
    /// into the session's `Arc`; callers that train many times over one
    /// corpus should hold a `TrainerSession` with a shared
    /// `Arc<SparseDataset>` instead). Owns the classic single-job loop: the
    /// [`DevicePool`] decides membership at every mega-batch boundary and a
    /// [`TrainerSession`] does the rest.
    pub fn run_sharded(
        &mut self,
        train: Arc<ShardedDataset>,
        test: &SparseDataset,
    ) -> Result<RunLog> {
        let cfg = self.cfg.clone();
        let mut pool = DevicePool::new(&cfg)?;
        let roster = pool.roster_len();
        anyhow::ensure!(
            roster == self.engine.roster_len(),
            "engine roster ({}) disagrees with the device pool ({roster}); build the engine \
             from DevicePool::roster(&cfg)",
            self.engine.roster_len()
        );
        // Fail fallible session inputs *before* handing over the engine, so
        // an invalid resume model leaves this Trainer usable (the session
        // constructor cannot give the engine back on error).
        if let Some(m) = &self.opts.init_model {
            anyhow::ensure!(m.dims == cfg.model, "resume model dims mismatch");
        }

        // Hand the engine to the session for the duration of the run; a
        // placeholder engine takes its slot so Trainer stays usable after.
        let engine = std::mem::replace(&mut self.engine, Box::new(NullEngine { roster }));
        // Move (not clone) any resume model into the session's options —
        // it can be a full paper-scale ModelState — and never resume twice.
        let init_model = self.opts.init_model.take();
        let mut opts = self.opts.clone();
        opts.init_model = init_model;
        let name = format!("{}-{}gpu", cfg.strategy.kind.name(), cfg.devices.count);
        let test = Arc::new(test.clone());
        let mut session =
            TrainerSession::new(cfg.clone(), engine, self.eval_backend, opts, train, test, name)?;

        let mut step_err: Option<anyhow::Error> = None;
        while !session.done() {
            if let Some(budget) = self.opts.time_budget {
                if session.clock() >= budget {
                    break;
                }
            }
            // ---- pool membership for this mega-batch ----------------------
            let mb = session.completed_mega_batches();
            let events = pool.begin_mega_batch(mb);
            let active = pool.active_ids();
            let rows = events.iter().map(pool_event_row).collect();
            match session.step(&active, session.clock(), rows) {
                Ok(_) => pool.observe(session.last_report().expect("step just ran")),
                Err(e) => {
                    step_err = Some(e);
                    break;
                }
            }
        }
        // Reclaim the engine so this Trainer stays usable for another run.
        let (log, engine) = session.finish();
        self.engine = engine;
        match step_err {
            Some(e) => Err(e),
            None => Ok(log),
        }
    }
}

/// Placeholder engine occupying `Trainer::engine` while a session borrows
/// the real one; any attempt to run through it is a programming error.
struct NullEngine {
    roster: usize,
}

impl ExecutionEngine for NullEngine {
    fn run_mega_batch(
        &mut self,
        _replicas: &mut [ModelState],
        _plane: &DataPlane,
        _plan: &DispatchPlan,
    ) -> Result<MegaBatchReport> {
        anyhow::bail!("trainer engine is owned by an active session")
    }

    fn roster_len(&self) -> usize {
        self.roster
    }

    fn name(&self) -> &'static str {
        "null"
    }
}

/// Spread active-subset merge weights back onto the roster (inactive = 0),
/// for the per-row telemetry.
fn scatter_weights(weights: &[f64], active: &[usize], roster: usize) -> Vec<f64> {
    let mut out = vec![0.0; roster];
    for (w, &d) in weights.iter().zip(active) {
        out[d] = *w;
    }
    out
}

pub(crate) fn pool_event_row(ev: &PoolEvent) -> PoolEventRow {
    PoolEventRow {
        mega_batch: ev.mega_batch,
        device: ev.device,
        action: ev.action.name().to_string(),
        reason: ev.reason.clone(),
    }
}

fn pipeline_row(s: &PipelineStats) -> PipelineStatsRow {
    PipelineStatsRow {
        prefetched: s.prefetched,
        synchronous: s.synchronous,
        starved: s.starved,
        flushed: s.flushed,
        truncated_features: s.truncated_features,
        pool_hits: s.pool.hits,
        pool_misses: s.pool.misses,
    }
}

/// Linear warmup multiplier for mega-batch `mb` (1.0 once warmup is over or
/// disabled).
fn warmup_factor(mb: usize, warmup_mega_batches: usize) -> f32 {
    if warmup_mega_batches == 0 {
        1.0
    } else {
        (((mb + 1) as f32) / warmup_mega_batches as f32).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
    use crate::coordinator::backend::RefBackend;
    use crate::coordinator::engine_sim::SimEngine;
    use crate::data::synthetic::Generator;
    use crate::runtime::CostModel;

    fn test_config(strategy: Strategy, g: usize) -> Config {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        cfg.sgd = SgdConfig {
            b_min: 8,
            b_max: 32,
            beta: 4,
            lr_bmax: 0.4,
            mega_batches: 24,
            num_mega_batches: 6,
            initial_batch: 32,
            warmup_mega_batches: 0,
            seed: 7,
            ..Default::default()
        };
        cfg.devices = DeviceConfig {
            count: g,
            speed_factors: (0..g).map(|i| 1.0 + 0.32 * i as f64 / (g.max(2) - 1) as f64).collect(),
            jitter: 0.0,
            nnz_sensitivity: 1.0,
            seed: 17,
        };
        cfg.data = DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
        cfg.strategy.kind = strategy;
        cfg.validate().unwrap();
        cfg
    }

    fn sim_engine<'b>(cfg: &Config, backend: &'b RefBackend) -> Box<dyn ExecutionEngine + 'b> {
        Box::new(SimEngine::new(backend, DevicePool::roster(cfg), CostModel::default()))
    }

    fn run_strategy(strategy: Strategy, g: usize) -> RunLog {
        let cfg = test_config(strategy, g);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        trainer.run(&train, &test).unwrap()
    }

    #[test]
    fn adaptive_trains_and_improves() {
        let log = run_strategy(Strategy::Adaptive, 4);
        assert_eq!(log.rows.len(), 6);
        assert!(log.rows[5].loss < log.rows[0].loss, "loss should fall");
        assert!(log.best_accuracy() > 0.15, "acc {}", log.best_accuracy());
        // Clock advances monotonically.
        assert!(log.rows.windows(2).all(|w| w[1].clock > w[0].clock));
        // Static pool: every row covers the whole fleet, no events.
        assert!(log.rows.iter().all(|r| r.active_devices == vec![0, 1, 2, 3]));
        assert!(log.pool_events.is_empty());
    }

    #[test]
    fn all_strategies_complete_and_learn() {
        for strategy in Strategy::all() {
            let log = run_strategy(strategy, 2);
            assert!(!log.rows.is_empty(), "{strategy:?}");
            assert!(
                log.rows.last().unwrap().loss < log.rows[0].loss + 0.1,
                "{strategy:?} loss went up: {} -> {}",
                log.rows[0].loss,
                log.rows.last().unwrap().loss
            );
        }
    }

    #[test]
    fn adaptive_batch_sizes_differentiate_under_heterogeneity() {
        let log = run_strategy(Strategy::Adaptive, 4);
        let last = log.rows.last().unwrap();
        // The slowest device should have drifted below the fastest.
        assert!(
            last.batch_sizes[0] > last.batch_sizes[3]
                || last.batch_sizes.iter().any(|&b| b != last.batch_sizes[0]),
            "batch sizes never adapted: {:?}",
            last.batch_sizes
        );
    }

    #[test]
    fn elastic_keeps_static_batches() {
        let log = run_strategy(Strategy::Elastic, 4);
        for row in &log.rows {
            assert!(row.batch_sizes.iter().all(|&b| b == 32));
            // Equal updates by construction.
            assert!(row.updates.iter().all(|&u| u == row.updates[0]));
        }
    }

    #[test]
    fn scripted_pool_events_flow_into_the_log() {
        let mut cfg = test_config(Strategy::Adaptive, 4);
        cfg.elastic.events =
            vec!["at_mb=2 remove_id=0".to_string(), "at_mb=4 add_id=0".to_string()];
        cfg.validate().unwrap();
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let log = trainer.run(&train, &test).unwrap();

        let counts: Vec<usize> = log.rows.iter().map(|r| r.active_devices.len()).collect();
        assert_eq!(counts, vec![4, 4, 3, 3, 4, 4]);
        assert_eq!(log.pool_events.len(), 2);
        assert_eq!(log.pool_events[0].action, "remove");
        assert_eq!(log.pool_events[0].device, 0);
        assert_eq!(log.pool_events[1].action, "add");
        // While device 0 is out it does no updates and carries no weight.
        for r in &log.rows[2..4] {
            assert_eq!(r.updates[0], 0);
            assert_eq!(r.merge_weights[0], 0.0);
            assert!(!r.active_devices.contains(&0));
        }
        // Merge weights renormalize over the active subset at every merge
        // (perturbation may denormalize by at most ±delta).
        for r in &log.rows {
            let sum: f64 = r.merge_weights.iter().sum();
            assert!((sum - 1.0).abs() < 0.1 + 1e-9, "weight sum {sum} at mb {}", r.mega_batch);
        }
    }

    #[test]
    fn time_budget_stops_early() {
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(500, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let opts = TrainerOptions { time_budget: Some(1e-9), ..Default::default() };
        let mut trainer = Trainer::new(cfg, engine, &backend, opts);
        let log = trainer.run(&train, &test).unwrap();
        assert!(log.rows.len() <= 1);
    }

    #[test]
    fn warmup_factor_ramps_linearly() {
        assert_eq!(warmup_factor(0, 0), 1.0);
        assert_eq!(warmup_factor(0, 4), 0.25);
        assert_eq!(warmup_factor(1, 4), 0.5);
        assert_eq!(warmup_factor(3, 4), 1.0);
        assert_eq!(warmup_factor(100, 4), 1.0);
    }

    #[test]
    fn warmup_slows_early_updates() {
        // With warmup the first mega-batch moves the model strictly less.
        let mut cfg = test_config(Strategy::Adaptive, 2);
        cfg.sgd.num_mega_batches = 1;
        let run = |cfg: &Config| {
            let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
            let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
            let backend = RefBackend;
            let engine = sim_engine(cfg, &backend);
            let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
            let log = trainer.run(&train, &test).unwrap();
            log.rows[0].l2_per_param
        };
        let no_warmup = run(&cfg);
        cfg.sgd.warmup_mega_batches = 10;
        let with_warmup = run(&cfg);
        // Warmup shrinks the first-step learning rates 10x, so the merged
        // model stays closer to the (zero-bias) init -> smaller L2 drift
        // relative to the aggressive run is not guaranteed in general, but
        // the two must at least differ, proving warmup reached the plan.
        assert_ne!(no_warmup, with_warmup);
    }

    #[test]
    fn checkpoint_and_resume_round_trip() {
        let dir = std::env::temp_dir().join("hs-trainer-ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("global.ckpt");

        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(800, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let opts = TrainerOptions { checkpoint: Some(path.clone()), ..Default::default() };
        let mut trainer = Trainer::new(cfg.clone(), engine, &backend, opts);
        trainer.run(&train, &test).unwrap();
        assert!(path.exists());

        // Resume from the checkpoint: first-row loss must be well below a
        // fresh run's first-row loss.
        let saved = crate::model::checkpoint::load(&path).unwrap();
        let engine2 = sim_engine(&cfg, &backend);
        let opts2 = TrainerOptions { init_model: Some(saved), ..Default::default() };
        let mut resumed = Trainer::new(cfg.clone(), engine2, &backend, opts2);
        let log2 = resumed.run(&train, &test).unwrap();

        let engine3 = sim_engine(&cfg, &backend);
        let mut fresh = Trainer::new(cfg, engine3, &backend, TrainerOptions::default());
        let fresh_log = fresh.run(&train, &test).unwrap();
        assert!(
            log2.rows[0].loss < fresh_log.rows[0].loss,
            "resumed run should start ahead: {} vs {}",
            log2.rows[0].loss,
            fresh_log.rows[0].loss
        );
    }

    #[test]
    fn publish_hook_feeds_the_snapshot_registry() {
        let mut cfg = test_config(Strategy::Adaptive, 2); // 6 mega-batches
        cfg.serve.publish_every = 2;
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let reg = std::sync::Arc::new(crate::serve::SnapshotRegistry::new());
        let opts = TrainerOptions { publish: Some(reg.clone()), ..Default::default() };
        let mut trainer = Trainer::new(cfg, engine, &backend, opts);
        let log = trainer.run(&train, &test).unwrap();

        let h = reg.history();
        // Init publish + mega-batches 1, 3, 5.
        assert_eq!(h.len(), 4);
        assert_eq!(h[0].mega_batch, None, "warm-start snapshot first");
        assert_eq!(
            h[1..].iter().map(|s| s.mega_batch).collect::<Vec<_>>(),
            vec![Some(1), Some(3), Some(5)]
        );
        // Publish clocks are the training clock at those merges.
        assert_eq!(h[1].published_clock, log.rows[1].clock);
        assert_eq!(h[3].published_clock, log.rows[5].clock);
        assert!(h.windows(2).all(|w| w[0].published_clock < w[1].published_clock));
        assert_eq!(reg.current().unwrap().version, 4);
    }

    #[test]
    fn rows_carry_nnz_dispersion_and_pipeline_counters() {
        let mut cfg = test_config(Strategy::Adaptive, 2);
        cfg.data.nnz_sigma = 1.0; // heavier tail -> nonzero dispersion
        cfg.validate().unwrap();
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let log = trainer.run(&train, &test).unwrap();
        for r in &log.rows {
            assert!(r.nnz_mean > 0.0, "mb {} nnz_mean", r.mega_batch);
            assert!(r.nnz_cv > 0.0, "shuffled heavy-tailed batches must disperse");
        }
        let last = &log.rows.last().unwrap().pipeline;
        assert!(last.synchronous > 0, "virtual mode assembles synchronously");
        assert_eq!(last.starved, 0, "sync mode never starves");
        assert!(last.pool_hits > 0, "engine recycling must produce pool hits");
    }

    #[test]
    fn balanced_policy_cuts_batch_cost_dispersion() {
        // The acceptance check at trainer level: same heavy-tailed corpus,
        // same strategy, only the composition policy differs.
        // Elastic keeps every batch at b_max, so the CV is purely
        // compositional (no batch-size variation mixed in).
        let mean_cv = |policy| {
            let mut cfg = test_config(Strategy::Elastic, 2);
            cfg.model.max_nnz = 24;
            cfg.data.avg_nnz = 8.0;
            cfg.data.nnz_sigma = 1.2;
            cfg.data.pipeline.policy = policy;
            cfg.validate().unwrap();
            let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
            let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
            let backend = RefBackend;
            let engine = sim_engine(&cfg, &backend);
            let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
            let log = trainer.run(&train, &test).unwrap();
            log.rows.iter().map(|r| r.nnz_cv).sum::<f64>() / log.rows.len() as f64
        };
        let shuffled = mean_cv(crate::config::CompositionPolicy::Shuffled);
        let balanced = mean_cv(crate::config::CompositionPolicy::NnzBalanced);
        assert!(
            balanced < shuffled * 0.6,
            "NnzBalanced must cut per-batch nnz CV: {balanced:.4} vs shuffled {shuffled:.4}"
        );
    }

    #[test]
    fn run_sharded_matches_run() {
        // run() is a resharding wrapper over run_sharded(); with the same
        // corpus and seeds the two must be trajectory-identical.
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;

        let engine = sim_engine(&cfg, &backend);
        let mut t1 = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
        let via_run = t1.run(&train, &test).unwrap();

        let sharded = std::sync::Arc::new(
            crate::data::pipeline::ShardedDataset::from_dataset(
                &train,
                cfg.data.pipeline.shard_samples,
            ),
        );
        let engine = sim_engine(&cfg, &backend);
        let mut t2 = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let via_sharded = t2.run_sharded(sharded, &test).unwrap();

        assert_eq!(via_run.rows.len(), via_sharded.rows.len());
        for (a, b) in via_run.rows.iter().zip(&via_sharded.rows) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.clock, b.clock);
        }
    }

    #[test]
    fn deterministic_runs_with_zero_jitter() {
        let a = run_strategy(Strategy::Adaptive, 3);
        let b = run_strategy(Strategy::Adaptive, 3);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.clock, y.clock);
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.batch_sizes, y.batch_sizes);
        }
    }

    #[test]
    fn session_pauses_and_resumes_on_an_imposed_roster() {
        // Drive a session directly with externally-imposed rosters — the
        // fleet scheduler's contract: shrink to one device, pause (no
        // step), then resume on a different subset at a later shared clock.
        let cfg = test_config(Strategy::Adaptive, 4);
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let sharded = std::sync::Arc::new(
            crate::data::pipeline::ShardedDataset::from_dataset(
                &train,
                cfg.data.pipeline.shard_samples,
            ),
        );
        let mut session = TrainerSession::new(
            cfg,
            engine,
            &backend,
            TrainerOptions::default(),
            sharded,
            std::sync::Arc::new(test),
            "tenant-a",
        )
        .unwrap();

        session.step(&[0, 1, 2, 3], 0.0, Vec::new()).unwrap();
        let t1 = session.clock();
        // Lease shrinks to a single device.
        let row = session.step(&[2], t1, Vec::new()).unwrap();
        assert_eq!(row.active_devices, vec![2]);
        assert_eq!(row.merge_weights[2], 1.0, "single-device merge weight is 1");
        assert!(row.updates.iter().enumerate().all(|(d, &u)| (u > 0) == (d == 2)));
        // Paused for 5 virtual seconds, then resumed on a disjoint subset:
        // the clock lands on the shared fleet time, not the private one.
        let resume_at = session.clock() + 5.0;
        let row = session.step(&[0, 3], resume_at, Vec::new()).unwrap();
        assert!(row.clock > resume_at, "resume starts at the shared clock");
        assert_eq!(row.active_devices, vec![0, 3]);
        let w: f64 = row.merge_weights.iter().sum();
        assert!((w - 1.0).abs() < 0.1 + 1e-9, "weights renormalize over the lease: {w}");
        // Loss keeps improving across the churn.
        let log = session.log();
        assert!(log.rows[2].loss < log.rows[0].loss + 0.5);
        assert_eq!(log.rows.len(), 3);
    }

    #[test]
    fn session_rejects_bad_rosters_and_trainer_reclaims_engine() {
        let cfg = test_config(Strategy::Adaptive, 2);
        let train = Generator::new(&cfg.model, &cfg.data).generate(600, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(100, 2);
        let backend = RefBackend;
        let engine = sim_engine(&cfg, &backend);
        let sharded = std::sync::Arc::new(
            crate::data::pipeline::ShardedDataset::from_dataset(&train, 4096),
        );
        let mut session = TrainerSession::new(
            cfg.clone(),
            engine,
            &backend,
            TrainerOptions::default(),
            sharded,
            std::sync::Arc::new(test.clone()),
            "t",
        )
        .unwrap();
        assert!(session.step(&[], 0.0, Vec::new()).is_err(), "empty roster");
        assert!(session.step(&[9], 0.0, Vec::new()).is_err(), "outside roster");
        assert!(!session.done());

        // Trainer::run reclaims its engine: a second run on the same
        // instance works.
        let engine = sim_engine(&cfg, &backend);
        let mut trainer = Trainer::new(cfg, engine, &backend, TrainerOptions::default());
        let a = trainer.run(&train, &test).unwrap();
        let b = trainer.run(&train, &test).unwrap();
        assert_eq!(a.rows.len(), b.rows.len(), "the engine survives run()");
    }
}

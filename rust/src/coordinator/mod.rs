//! The Layer-3 coordinator — the paper's system contribution.
//!
//! * [`backend`] — where step numerics come from (PJRT artifacts or the
//!   pure-Rust reference).
//! * [`pool`] — the elastic device pool: runtime membership (straggler
//!   quarantine, scripted remove/add traces, hot-add spares) applied at
//!   mega-batch boundaries.
//! * [`dispatch`] — the earliest-virtual-free-time routing rule shared by
//!   the dynamic scheduler and the serving router.
//! * [`scaling`] — **Algorithm 1**: adaptive batch size scaling.
//! * [`merge`] — **Algorithm 2**: normalized model merging with
//!   perturbation and momentum, renormalized over the active device subset.
//! * [`plan`] — dispatch plans, per-mega-batch reports, and the
//!   [`plan::ExecutionEngine`] trait both engines implement.
//! * [`engine_sim`] — deterministic discrete-event engine on a virtual
//!   clock (figure benches).
//! * [`engine_threaded`] — std::thread GPU-manager workers with real PJRT
//!   execution and injected heterogeneity (e2e runs); workers spawn lazily
//!   when their device first joins the pool and park when it leaves.
//! * [`trainer`] — the full training session: pool membership, strategy
//!   dispatch, merging, scaling, evaluation, metrics.

pub mod backend;
pub mod dispatch;
pub mod engine_sim;
pub mod engine_threaded;
pub mod merge;
pub mod plan;
pub mod pool;
pub mod scaling;
pub mod trainer;

pub use plan::{
    plan_for_strategy, DevStats, DispatchMode, DispatchPlan, ExecutionEngine, MegaBatchReport,
};
pub use pool::{DevicePool, DeviceSlot, PoolAction, PoolEvent, SlotState};
pub use trainer::{Trainer, TrainerOptions, TrainerSession};

//! The Layer-3 coordinator — the paper's system contribution.
//!
//! * [`backend`] — where step numerics come from (PJRT artifacts or the
//!   pure-Rust reference).
//! * [`scaling`] — **Algorithm 1**: adaptive batch size scaling.
//! * [`merge`] — **Algorithm 2**: normalized model merging with
//!   perturbation and momentum.
//! * [`plan`] — dispatch plans and per-mega-batch reports shared by both
//!   engines.
//! * [`engine_sim`] — deterministic discrete-event engine on a virtual
//!   clock (figure benches).
//! * [`engine_threaded`] — std::thread GPU-manager workers with real PJRT
//!   execution and injected heterogeneity (e2e runs).
//! * [`trainer`] — the full training session: strategy dispatch, merging,
//!   scaling, evaluation, metrics.

pub mod backend;
pub mod engine_sim;
pub mod engine_threaded;
pub mod merge;
pub mod plan;
pub mod scaling;
pub mod trainer;

pub use plan::{DevStats, DispatchMode, DispatchPlan, MegaBatchReport};
pub use trainer::{Trainer, TrainerOptions};

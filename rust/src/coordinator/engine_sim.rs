//! Discrete-event virtual-time engine.
//!
//! Numerics still run for real (through whatever [`StepBackend`] is
//! supplied), but *time* advances on a virtual clock driven by the
//! heterogeneity model + cost model. Dispatch order is exactly the dynamic
//! scheduler's: the next batch goes to the active device with the earliest
//! virtual free-time (ties broken by device id), so the schedule is
//! deterministic given the seeds — which is what the figure benches need.
//!
//! The engine holds the full device *roster*; each plan's `device_ids`
//! selects the active subset, so pool membership can change between
//! mega-batches without touching engine state.

use crate::data::pipeline::DataPlane;
use crate::model::reference::StepScratch;
use crate::model::ModelState;
use crate::obs::{ArgVal, ObsHandle, Subsystem};
use crate::runtime::{CostModel, SimDevice};
use crate::slide::SparseStepper;
use crate::Result;

use super::backend::StepBackend;
use super::dispatch::{next_completion_device, next_free_device};
use super::plan::{DevStats, DispatchMode, DispatchPlan, ExecutionEngine, MegaBatchReport};

pub struct SimEngine<'b> {
    backend: &'b dyn StepBackend,
    pub devices: Vec<SimDevice>,
    pub cost: CostModel,
    /// `[slide]` section driving the sparse kernels (defaults are inert:
    /// plans carry no ratios unless `[slide] adaptive` is on).
    slide: crate::config::SlideConfig,
    /// Lazily-built per-roster-device LSH steppers (sparse slots only; a
    /// device that always runs dense never builds tables).
    steppers: Vec<Option<SparseStepper>>,
    /// One pooled step scratch shared across every step this engine runs
    /// (the engine is single-threaded; numerics are bit-identical to fresh
    /// buffers — pinned by `model::reference` tests).
    scratch: StepScratch,
    /// Trace sink for per-device `engine.step` spans, stamped on the
    /// virtual clock (sink time base + this window's free-time offset).
    obs: ObsHandle,
}

impl<'b> SimEngine<'b> {
    pub fn new(backend: &'b dyn StepBackend, devices: Vec<SimDevice>, cost: CostModel) -> Self {
        assert!(!devices.is_empty());
        let n = devices.len();
        SimEngine {
            backend,
            devices,
            cost,
            slide: crate::config::SlideConfig::default(),
            steppers: (0..n).map(|_| None).collect(),
            scratch: StepScratch::new(),
            obs: ObsHandle::disabled(),
        }
    }

    /// Use this `[slide]` section for the sparse active-class kernels
    /// (table/bit counts, negatives, rebuild cadence, seed). Without it a
    /// sparse plan still runs, on default SLIDE hyperparameters.
    pub fn with_slide(mut self, sec: &crate::config::SlideConfig) -> Self {
        self.slide = sec.clone();
        self
    }

    #[allow(clippy::too_many_arguments)]
    fn one_step(
        &mut self,
        replicas: &mut [ModelState],
        plane: &DataPlane,
        plan: &DispatchPlan,
        slot: usize,
        bucket: usize,
        valid: usize,
        stats: &mut [DevStats],
        free_time: &mut [f64],
        batch_nnz: &mut Vec<u64>,
    ) -> Result<()> {
        let dev = plan.device_ids[slot];
        let batch = plane.next_batch_for(slot, bucket, valid);
        let ratio = plan.sparsity_ratio(slot);
        let (loss, active_classes) = if ratio >= 1.0 {
            // Dense path: the backend's exact kernel, through the pooled
            // scratch (bit-identical to per-step allocation).
            let (loss, _real) =
                self.backend.step_scratch(&mut replicas[dev], &batch, plan.lrs[slot], &mut self.scratch)?;
            (loss, replicas[dev].dims.classes)
        } else {
            // Sparse path: the LSH active-class kernel on the reference
            // numerics (the CPU compute lever; PJRT artifacts stay dense).
            let stepper = self.steppers[dev]
                .get_or_insert_with(|| SparseStepper::new(&self.slide, dev as u64));
            stepper.set_ratio(ratio);
            stepper.step(&mut replicas[dev], &batch, plan.lrs[slot], &mut self.scratch)
        };
        let dur = self.devices[dev].step_duration_at(&self.cost, &batch, ratio);
        if self.obs.enabled() {
            // Virtual-clock stamp: the trainer parked its clock in the sink
            // before dispatch; this window's offset is the slot's free-time.
            self.obs.span(
                Subsystem::Engine,
                "engine.step",
                1 + dev as u32,
                self.obs.time_base() + free_time[slot],
                dur,
                vec![
                    ("batch", ArgVal::U(valid as u64)),
                    ("nnz", ArgVal::U(batch.nnz as u64)),
                    ("ratio", ArgVal::F(ratio)),
                ],
            );
        }
        free_time[slot] += dur;
        let s = &mut stats[dev];
        s.updates += 1;
        s.samples += valid as u64;
        s.loss_sum += loss as f64;
        s.nnz += batch.nnz as u64;
        s.active_classes += active_classes as u64;
        batch_nnz.push(batch.nnz as u64);
        plane.recycle(batch);

        // CROSSBOW-style correction: pull this replica toward the current
        // average of the *active* replicas after every batch.
        if let Some(rate) = plan.crossbow_rate {
            correct_toward_average(replicas, &plan.device_ids, dev, rate);
        }
        Ok(())
    }
}

impl<'b> ExecutionEngine for SimEngine<'b> {
    /// Run one mega-batch over the plan's active devices, pulling batches
    /// from the data plane. `replicas` covers the whole roster. The plane
    /// runs synchronously under this engine (the trainer passes zero
    /// producer threads in virtual mode), so the sample→device routing is
    /// deterministic.
    fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        plane: &DataPlane,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport> {
        let roster = self.devices.len();
        let g = plan.devices();
        assert_eq!(replicas.len(), roster);
        assert_eq!(plan.batch_sizes.len(), g);
        assert!(g > 0, "plan has no active devices");
        assert!(plan.device_ids.iter().all(|&d| d < roster), "plan device outside roster");

        plane.begin_window(&plan.batch_sizes);
        let mut stats = vec![DevStats::default(); roster];
        let mut batch_nnz = Vec::new();
        // Virtual free-times, parallel to the plan's active slots.
        let mut free_time = vec![0.0f64; g];

        match plan.mode {
            DispatchMode::Dynamic => {
                let mut remaining = plan.sample_budget;
                while remaining > 0 {
                    // Earliest-free device wins the next batch (dynamic
                    // scheduling); ties break toward the lower slot — the
                    // same rule the serving router uses (dispatch.rs). A
                    // calibrated plan upgrades to earliest-predicted-
                    // completion, so per-device batch sizes and drifted
                    // speeds are priced in at dispatch time.
                    let slot = match &plan.predicted_step_secs {
                        Some(secs) => next_completion_device(&free_time, 0.0, secs, |_| true),
                        None => next_free_device(&free_time, 0.0, |_| true),
                    }
                    .expect("plan has at least one active device");
                    let bucket = plan.batch_sizes[slot];
                    let valid = bucket.min(remaining);
                    remaining -= valid;
                    self.one_step(
                        replicas, plane, plan, slot, bucket, valid, &mut stats, &mut free_time,
                        &mut batch_nnz,
                    )?;
                }
            }
            DispatchMode::StaticQuota { batches_per_device } => {
                let mut quota = vec![batches_per_device; g];
                while quota.iter().any(|&q| q > 0) {
                    let slot = match &plan.predicted_step_secs {
                        Some(secs) => {
                            next_completion_device(&free_time, 0.0, secs, |i| quota[i] > 0)
                        }
                        None => next_free_device(&free_time, 0.0, |i| quota[i] > 0),
                    }
                    .expect("some quota remains");
                    quota[slot] -= 1;
                    let bucket = plan.batch_sizes[slot];
                    self.one_step(
                        replicas, plane, plan, slot, bucket, bucket, &mut stats, &mut free_time,
                        &mut batch_nnz,
                    )?;
                }
            }
        }

        for (slot, &t) in free_time.iter().enumerate() {
            stats[plan.device_ids[slot]].busy = t;
        }
        let wall = free_time.iter().copied().fold(0.0, f64::max);
        Ok(MegaBatchReport { per_device: stats, wall, batch_nnz })
    }

    fn roster_len(&self) -> usize {
        self.devices.len()
    }

    fn cost_model(&self) -> CostModel {
        self.cost
    }

    /// Scripted drift lands directly on the simulated device's clock
    /// model — the virtual-time analog of a real GPU throttling.
    fn set_drift(&mut self, device: usize, multiplier: f64) {
        if let Some(d) = self.devices.get_mut(device) {
            d.set_drift(multiplier);
        }
    }

    fn set_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

/// `replica[dev] += rate * (mean(active replicas) − replica[dev])`.
pub fn correct_toward_average(
    replicas: &mut [ModelState],
    active: &[usize],
    dev: usize,
    rate: f64,
) {
    let g = active.len() as f32;
    let r = rate as f32;
    for seg in 0..4 {
        let len = replicas[0].segments()[seg].len();
        for p in 0..len {
            let mut mean = 0.0f32;
            for &a in active {
                mean += replicas[a].segments()[seg][p];
            }
            mean /= g;
            let dst = match seg {
                0 => &mut replicas[dev].w1,
                1 => &mut replicas[dev].b1,
                2 => &mut replicas[dev].w2,
                _ => &mut replicas[dev].b2,
            };
            dst[p] += r * (mean - dst[p]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompositionPolicy, Config, DataConfig, ModelDims};
    use crate::coordinator::backend::RefBackend;
    use crate::data::pipeline::ShardedDataset;
    use crate::data::synthetic::Generator;
    use std::sync::Arc;

    fn setup() -> (Config, Arc<ShardedDataset>) {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 4 };
        cfg.sgd.b_min = 8;
        cfg.sgd.b_max = 32;
        cfg.sgd.beta = 4;
        cfg.sgd.initial_batch = 32;
        cfg.devices.jitter = 0.0;
        let data_cfg = DataConfig { train_samples: 500, avg_nnz: 5.0, ..Default::default() };
        let ds = Generator::new(&cfg.model, &data_cfg).generate(500, 1);
        (cfg, Arc::new(ShardedDataset::from_dataset(&ds, 128)))
    }

    fn sync_plane(cfg: &Config, data: &Arc<ShardedDataset>, seed: u64) -> DataPlane {
        DataPlane::new_sync(data.clone(), &cfg.model, CompositionPolicy::Shuffled, seed)
    }

    fn plan_dynamic(g: usize, b: usize, budget: usize) -> DispatchPlan {
        DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: (0..g).collect(),
            batch_sizes: vec![b; g],
            lrs: vec![0.05; g],
            sample_budget: budget,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        }
    }

    #[test]
    fn dynamic_budget_is_conserved_exactly() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        // Budget not divisible by the batch size: last dispatch is partial.
        let report = engine
            .run_mega_batch(&mut replicas, &plane, &plan_dynamic(4, 32, 330))
            .unwrap();
        assert_eq!(report.total_samples(), 330);
        // Every dispatched batch reported its nnz.
        assert_eq!(report.batch_nnz.len() as u64, report.total_updates());
        let total_nnz: u64 = report.per_device.iter().map(|d| d.nnz).sum();
        assert_eq!(report.batch_nnz.iter().sum::<u64>(), total_nnz);
    }

    #[test]
    fn faster_devices_get_more_batches() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let report = engine
            .run_mega_batch(&mut replicas, &plane, &plan_dynamic(4, 16, 3200))
            .unwrap();
        let u = report.updates();
        // Device 0 is fastest (factor 1.0), device 3 slowest (1.32).
        assert!(u[0] > u[3], "updates {u:?}");
        assert_eq!(report.total_updates(), 200);
    }

    #[test]
    fn active_subset_leaves_inactive_replicas_untouched() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let plane = sync_plane(&cfg, &ds, 1);
        let init = ModelState::init(&cfg.model, 2);
        let mut replicas = vec![init.clone(); 4];
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: vec![0, 2], // device 1 and 3 out of the pool
            batch_sizes: vec![16, 16],
            lrs: vec![0.05; 2],
            sample_budget: 320,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(report.total_samples(), 320);
        let u = report.updates();
        assert_eq!(u[1], 0);
        assert_eq!(u[3], 0);
        assert!(u[0] > 0 && u[2] > 0);
        assert_eq!(report.per_device[1].busy, 0.0);
        // Inactive replicas are bit-identical to their initial state.
        assert_eq!(replicas[1].max_abs_diff(&init), 0.0);
        assert_eq!(replicas[3].max_abs_diff(&init), 0.0);
        assert!(replicas[0].max_abs_diff(&init) > 0.0);
    }

    #[test]
    fn static_quota_gives_equal_updates_but_idle_time() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let plan = DispatchPlan {
            mode: DispatchMode::StaticQuota { batches_per_device: 10 },
            device_ids: vec![0, 1, 2, 3],
            batch_sizes: vec![32; 4],
            lrs: vec![0.05; 4],
            sample_budget: 0,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert!(report.updates().iter().all(|&u| u == 10));
        // The straggler forces idle time on the fast device (the paper's
        // elastic-SGD pathology).
        assert!(report.max_idle() > 0.0);
    }

    #[test]
    fn deterministic_given_zero_jitter() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let run = || {
            let mut engine =
                SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
            let plane = sync_plane(&cfg, &ds, 7);
            let mut replicas = vec![ModelState::init(&cfg.model, 3); 4];
            let r = engine
                .run_mega_batch(&mut replicas, &plane, &plan_dynamic(4, 16, 640))
                .unwrap();
            (r.updates(), r.wall, replicas[0].w1[10], r.batch_nnz.clone())
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
        assert_eq!(a.3, b.3, "per-batch nnz sequence is deterministic in sync mode");
    }

    #[test]
    fn calibrated_dispatch_conserves_the_budget_and_shifts_work() {
        // Device 3 is 1.32x slow; with calibrated per-slot predictions the
        // completion-keyed dispatcher hands it strictly less work, and the
        // sample budget still lands exactly.
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let cost = CostModel::default();
        let mut engine = SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), cost);
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let secs: Vec<f64> = cfg
            .devices
            .speed_factors
            .iter()
            .map(|sf| sf * cost.step_time_parts(16, 16 * 5))
            .collect();
        let plan = plan_dynamic(4, 16, 3200).with_predicted_step_secs(secs);
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(report.total_samples(), 3200, "budget conserved under calibration");
        let u = report.updates();
        assert!(u[0] > u[3], "calibrated dispatch still favors the fast device: {u:?}");
    }

    #[test]
    fn sparse_plan_cuts_virtual_step_cost_and_tracks_active_classes() {
        let (cfg, ds) = setup(); // classes = 32, jitter = 0
        let backend = RefBackend;
        let mut engine = SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default())
            .with_slide(&cfg.slide);
        let classes = cfg.model.classes as u64;

        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let dense = engine
            .run_mega_batch(&mut replicas, &plane, &plan_dynamic(4, 16, 640))
            .unwrap();
        for d in dense.per_device.iter().filter(|d| d.updates > 0) {
            assert_eq!(d.active_classes, d.updates * classes, "dense rows count every class");
        }

        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let plan = plan_dynamic(4, 16, 640).with_sparsity_ratios(vec![0.25; 4]);
        let sparse = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(sparse.total_samples(), 640, "budget conserved under sparsity");
        assert!(
            sparse.wall < dense.wall,
            "active-class truncation must cut virtual time: {} vs {}",
            sparse.wall,
            dense.wall
        );
        for d in sparse.per_device.iter().filter(|d| d.updates > 0) {
            assert!(
                d.active_classes < d.updates * classes,
                "sparse rows must truncate the class set"
            );
            assert!(d.active_classes > 0);
        }
        // The sparse mega-batch still trains (loss is finite and sane).
        assert!(sparse.mean_loss().is_finite() && sparse.mean_loss() > 0.0);
    }

    #[test]
    fn ratio_one_plan_matches_a_dense_plan_bitwise() {
        // A plan carrying all-1.0 ratios must leave models and virtual
        // time exactly where the ratio-free plan does.
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let run = |ratios: Option<Vec<f64>>| {
            let mut engine =
                SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default())
                    .with_slide(&cfg.slide);
            let plane = sync_plane(&cfg, &ds, 7);
            let mut replicas = vec![ModelState::init(&cfg.model, 3); 4];
            let mut plan = plan_dynamic(4, 16, 640);
            if let Some(r) = ratios {
                plan = plan.with_sparsity_ratios(r);
            }
            let rep = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
            (rep.wall, rep.updates(), replicas)
        };
        let (wall_a, updates_a, reps_a) = run(None);
        let (wall_b, updates_b, reps_b) = run(Some(vec![1.0; 4]));
        assert_eq!(wall_a, wall_b);
        assert_eq!(updates_a, updates_b);
        for (a, b) in reps_a.iter().zip(&reps_b) {
            assert_eq!(a.max_abs_diff(b), 0.0, "ratio 1.0 must be the dense kernel bit-for-bit");
        }
    }

    #[test]
    fn set_drift_slows_a_device_live() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let plan = plan_dynamic(4, 16, 1600);
        let before = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        engine.set_drift(0, 4.0); // the fastest device throttles hard
        let after = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert!(
            after.updates()[0] < before.updates()[0],
            "throttled device wins fewer batches: {:?} -> {:?}",
            before.updates(),
            after.updates()
        );
        engine.set_drift(99, 2.0); // out-of-roster drift is ignored, not a panic
    }

    #[test]
    fn engine_step_spans_land_on_device_lanes() {
        let (cfg, ds) = setup();
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&cfg.devices), CostModel::default());
        let obs = ObsHandle::from_config(
            &crate::config::ObsConfig { enabled: true, ..Default::default() },
            false,
        );
        engine.set_obs(obs.clone());
        obs.set_time_base(5.0);
        let plane = sync_plane(&cfg, &ds, 1);
        let mut replicas = vec![ModelState::init(&cfg.model, 2); 4];
        let report = engine
            .run_mega_batch(&mut replicas, &plane, &plan_dynamic(4, 32, 320))
            .unwrap();
        let evs = obs.sink().events();
        assert_eq!(evs.len() as u64, report.total_updates(), "one span per step");
        assert!(evs.iter().all(|e| e.name == "engine.step"));
        assert!(evs.iter().all(|e| e.tid >= 1), "device lanes start at tid 1");
        assert!(evs.iter().all(|e| e.ts >= 5.0 && e.dur > 0.0), "base + offset stamps");
        assert_eq!(obs.sink().balance(), (evs.len() as u64, evs.len() as u64));
    }

    #[test]
    fn crossbow_correction_contracts_replicas() {
        let dims = ModelDims { features: 32, hidden: 4, classes: 8, max_nnz: 4, max_labels: 2 };
        let mut replicas: Vec<ModelState> =
            (0..3).map(|i| ModelState::init(&dims, i as u64)).collect();
        let spread_before: f32 = replicas[0].max_abs_diff(&replicas[1]);
        let active = [0usize, 1, 2];
        correct_toward_average(&mut replicas, &active, 0, 0.5);
        correct_toward_average(&mut replicas, &active, 1, 0.5);
        let spread_after = replicas[0].max_abs_diff(&replicas[1]);
        assert!(spread_after < spread_before);
    }
}

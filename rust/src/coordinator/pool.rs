//! Elastic device pool — runtime membership for the coordinator.
//!
//! The paper's headline is *adaptive elastic training*, and this module is
//! where the elasticity lives: a [`DevicePool`] owns the full device
//! *roster* (the configured fleet plus any hot-add spares) and tracks which
//! devices are currently *active*. Membership changes happen only at
//! mega-batch boundaries — the merge barrier is the natural consistency
//! point — and come from two sources:
//!
//! * a **scripted trace** (`[elastic] events`, e.g. `"at_mb=20 remove=2"`),
//!   the reproducible way to study failover and resource limbo
//!   (ABS-SGD / Dynamic Mini-batch SGD scenarios);
//! * the **straggler policy**: a device whose windowed mean step time
//!   exceeds `straggler_factor ×` the active fleet's median is quarantined
//!   and auto-readmitted after `quarantine_mega_batches` (transient slowness
//!   — clock throttling, a noisy neighbor — usually passes).
//!
//! The trainer consumes the resulting [`PoolEvent`]s: dispatch plans, merge
//! weights and Algorithm 1 scaling all operate on the active subset, while
//! per-device state (replicas, batch sizes, momentum history) stays
//! roster-indexed so it survives churn — a re-admitted device resumes from
//! the current global model at its last batch size.

use crate::config::{Config, ElasticEvent, ElasticOp};
use crate::runtime::SimDevice;
use crate::Result;

use super::plan::MegaBatchReport;

/// Membership state of one roster slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    /// Participating in dispatch and merging.
    Active,
    /// Temporarily out (straggler policy); auto-readmitted later.
    Quarantined,
    /// Out of the pool (scripted removal, or a spare never yet added).
    Removed,
}

/// One device slot in the roster.
#[derive(Clone, Debug)]
pub struct DeviceSlot {
    pub id: usize,
    pub speed_factor: f64,
    pub state: SlotState,
    /// Mega-batch at which the slot last left the active set.
    left_at: Option<usize>,
    /// Sliding window of observed mean step times (seconds per update).
    window: Vec<f64>,
}

impl DeviceSlot {
    fn windowed_mean(&self) -> Option<f64> {
        if self.window.is_empty() {
            None
        } else {
            Some(self.window.iter().sum::<f64>() / self.window.len() as f64)
        }
    }
}

/// What happened to pool membership, and why.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolAction {
    /// Scripted ejection.
    Remove,
    /// Scripted re-admission / hot-add.
    Add,
    /// Straggler policy took the device out.
    Quarantine,
    /// Quarantine elapsed; the device re-joined.
    Readmit,
}

impl PoolAction {
    pub fn name(&self) -> &'static str {
        match self {
            PoolAction::Remove => "remove",
            PoolAction::Add => "add",
            PoolAction::Quarantine => "quarantine",
            PoolAction::Readmit => "readmit",
        }
    }
}

/// One membership change, recorded into the run log.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolEvent {
    pub mega_batch: usize,
    pub device: usize,
    pub action: PoolAction,
    pub reason: String,
}

/// The elastic device pool.
pub struct DevicePool {
    slots: Vec<DeviceSlot>,
    trace: Vec<ElasticEvent>,
    straggler_factor: f64,
    straggler_window: usize,
    quarantine_mega_batches: usize,
    min_devices: usize,
}

impl DevicePool {
    /// Build the pool from config. The initial fleet starts active; spares
    /// start outside the pool until an `add` event pulls them in.
    pub fn new(cfg: &Config) -> Result<DevicePool> {
        let trace = cfg.elastic.parsed_events()?;
        let mut slots = Vec::new();
        for (id, &sf) in cfg.devices.speed_factors.iter().enumerate() {
            slots.push(DeviceSlot {
                id,
                speed_factor: sf,
                state: SlotState::Active,
                left_at: None,
                window: Vec::new(),
            });
        }
        for (i, &sf) in cfg.elastic.spare_devices.iter().enumerate() {
            slots.push(DeviceSlot {
                id: cfg.devices.count + i,
                speed_factor: sf,
                state: SlotState::Removed,
                left_at: None,
                window: Vec::new(),
            });
        }
        Ok(DevicePool {
            slots,
            trace,
            straggler_factor: cfg.elastic.straggler_factor,
            straggler_window: cfg.elastic.straggler_window.max(1),
            quarantine_mega_batches: cfg.elastic.quarantine_mega_batches,
            min_devices: cfg.elastic.min_devices.max(1),
        })
    }

    /// A pool driven by an explicit scripted trace instead of
    /// `[elastic] events` — the serving plane reuses the membership
    /// machinery with window-indexed `[serve] events` while training keeps
    /// its own mega-batch-indexed trace.
    pub fn with_trace(cfg: &Config, events: &[String]) -> Result<DevicePool> {
        let mut pool = DevicePool::new(cfg)?;
        let mut trace = events
            .iter()
            .map(|s| crate::config::ElasticEvent::parse(s))
            .collect::<Result<Vec<_>>>()?;
        trace.sort_by_key(|e| e.at_mb);
        pool.trace = trace;
        Ok(pool)
    }

    /// The full simulated roster — configured fleet plus hot-add spares.
    /// Engines are sized to this; the pool activates subsets of it.
    pub fn roster(cfg: &Config) -> Vec<SimDevice> {
        let mut devices = SimDevice::fleet(&cfg.devices);
        for (i, &sf) in cfg.elastic.spare_devices.iter().enumerate() {
            devices.push(SimDevice::with_speed(cfg.devices.count + i, sf, &cfg.devices));
        }
        devices
    }

    pub fn roster_len(&self) -> usize {
        self.slots.len()
    }

    pub fn slots(&self) -> &[DeviceSlot] {
        &self.slots
    }

    /// Ids of the devices currently in the pool, ascending.
    pub fn active_ids(&self) -> Vec<usize> {
        self.slots
            .iter()
            .filter(|s| s.state == SlotState::Active)
            .map(|s| s.id)
            .collect()
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.state == SlotState::Active).count()
    }

    /// Lease-aware view of the pool: the active devices for which
    /// `is_taken` is false — what a fleet arbiter may still grant. The
    /// pool stays the source of truth for *physical* membership (churn,
    /// quarantine); the lease book overlays *ownership* on top of it.
    pub fn available_ids(&self, is_taken: impl Fn(usize) -> bool) -> Vec<usize> {
        self.slots
            .iter()
            .filter(|s| s.state == SlotState::Active && !is_taken(s.id))
            .map(|s| s.id)
            .collect()
    }

    /// Apply scripted trace events and policy decisions for the mega-batch
    /// about to run. Returns the membership changes, in application order.
    pub fn begin_mega_batch(&mut self, mb: usize) -> Vec<PoolEvent> {
        let mut events = Vec::new();

        // Scripted trace first — explicit intent beats policy.
        let due: Vec<ElasticEvent> =
            self.trace.iter().filter(|e| e.at_mb == mb).copied().collect();
        for ev in due {
            match ev.op {
                ElasticOp::Remove(k) => {
                    for _ in 0..k {
                        match self.slowest_active() {
                            Some(id) if self.active_count() > self.min_devices => {
                                self.set_state(id, SlotState::Removed, mb);
                                events.push(PoolEvent {
                                    mega_batch: mb,
                                    device: id,
                                    action: PoolAction::Remove,
                                    reason: "scripted".to_string(),
                                });
                            }
                            _ => break, // at the floor — trace op truncated
                        }
                    }
                }
                ElasticOp::RemoveId(id) => {
                    // Explicit intent beats policy: removing a *quarantined*
                    // device is allowed too (it cancels the pending
                    // auto-readmission); only removing an Active device is
                    // subject to the min_devices floor.
                    let state = self.state_of(id);
                    let removable = match state {
                        Some(SlotState::Active) => self.active_count() > self.min_devices,
                        Some(SlotState::Quarantined) => true,
                        _ => false,
                    };
                    if removable {
                        self.set_state(id, SlotState::Removed, mb);
                        events.push(PoolEvent {
                            mega_batch: mb,
                            device: id,
                            action: PoolAction::Remove,
                            reason: "scripted".to_string(),
                        });
                    }
                }
                ElasticOp::Add(k) => {
                    for _ in 0..k {
                        match self.first_inactive() {
                            Some(id) => {
                                self.set_state(id, SlotState::Active, mb);
                                events.push(PoolEvent {
                                    mega_batch: mb,
                                    device: id,
                                    action: PoolAction::Add,
                                    reason: "scripted".to_string(),
                                });
                            }
                            None => break, // nothing left to add
                        }
                    }
                }
                ElasticOp::AddId(id) => {
                    if matches!(
                        self.state_of(id),
                        Some(SlotState::Removed) | Some(SlotState::Quarantined)
                    ) {
                        self.set_state(id, SlotState::Active, mb);
                        events.push(PoolEvent {
                            mega_batch: mb,
                            device: id,
                            action: PoolAction::Add,
                            reason: "scripted".to_string(),
                        });
                    }
                }
            }
        }

        // Quarantine sentences served → readmit.
        let due_back: Vec<usize> = self
            .slots
            .iter()
            .filter(|s| {
                s.state == SlotState::Quarantined
                    && s.left_at.is_some_and(|t| mb.saturating_sub(t) >= self.quarantine_mega_batches)
            })
            .map(|s| s.id)
            .collect();
        for id in due_back {
            self.set_state(id, SlotState::Active, mb);
            events.push(PoolEvent {
                mega_batch: mb,
                device: id,
                action: PoolAction::Readmit,
                reason: format!("{}-mega-batch quarantine elapsed", self.quarantine_mega_batches),
            });
        }

        // Straggler policy over the observation windows.
        if self.straggler_factor > 0.0 {
            events.extend(self.quarantine_stragglers(mb));
        }
        events
    }

    /// Record per-device mean step times from the last mega-batch report
    /// (`per_device` is roster-indexed; devices with zero updates are
    /// skipped so idle pool members don't pollute their windows).
    pub fn observe(&mut self, report: &MegaBatchReport) {
        let window = self.straggler_window;
        for slot in &mut self.slots {
            if slot.state != SlotState::Active {
                continue;
            }
            if let Some(d) = report.per_device.get(slot.id) {
                if d.updates > 0 {
                    slot.window.push(d.busy / d.updates as f64);
                    if slot.window.len() > window {
                        slot.window.remove(0);
                    }
                }
            }
        }
    }

    fn quarantine_stragglers(&mut self, mb: usize) -> Vec<PoolEvent> {
        let mut events = Vec::new();
        // Only judge devices with a full window; the median is taken over
        // those same devices so the comparison is apples-to-apples.
        let means: Vec<(usize, f64)> = self
            .slots
            .iter()
            .filter(|s| s.state == SlotState::Active && s.window.len() >= self.straggler_window)
            .filter_map(|s| s.windowed_mean().map(|m| (s.id, m)))
            .collect();
        if means.len() < 2 {
            return events;
        }
        let mut sorted: Vec<f64> = means.iter().map(|&(_, m)| m).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        // Worst offenders first, so the floor cuts off the least-bad.
        let mut offenders: Vec<(usize, f64)> = means
            .into_iter()
            .filter(|&(_, m)| m > self.straggler_factor * median)
            .collect();
        offenders.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (id, m) in offenders {
            if self.active_count() <= self.min_devices {
                break;
            }
            self.set_state(id, SlotState::Quarantined, mb);
            events.push(PoolEvent {
                mega_batch: mb,
                device: id,
                action: PoolAction::Quarantine,
                reason: format!(
                    "step time {:.1}x fleet median (threshold {:.1}x)",
                    m / median,
                    self.straggler_factor
                ),
            });
        }
        events
    }

    /// The active device with the worst observed (or, lacking observations,
    /// configured) slowness — the scripted `remove=k` victim. Observed step
    /// times are only used when *every* active device has some, so seconds
    /// never get compared against configured speed ratios.
    fn slowest_active(&self) -> Option<usize> {
        let all_observed = self
            .slots
            .iter()
            .filter(|s| s.state == SlotState::Active)
            .all(|s| !s.window.is_empty());
        self.slots
            .iter()
            .filter(|s| s.state == SlotState::Active)
            .max_by(|a, b| {
                let key = |s: &DeviceSlot| {
                    if all_observed {
                        s.windowed_mean().unwrap_or(s.speed_factor)
                    } else {
                        s.speed_factor
                    }
                };
                key(a).partial_cmp(&key(b)).unwrap().then(a.id.cmp(&b.id))
            })
            .map(|s| s.id)
    }

    /// Next `add=k` candidate: healthy Removed slots (scripted ejections and
    /// never-used spares) before mid-quarantine stragglers — a scripted add
    /// should bring clean capacity online, not cut a quarantine short.
    fn first_inactive(&self) -> Option<usize> {
        self.slots
            .iter()
            .find(|s| s.state == SlotState::Removed)
            .or_else(|| self.slots.iter().find(|s| s.state == SlotState::Quarantined))
            .map(|s| s.id)
    }

    fn state_of(&self, id: usize) -> Option<SlotState> {
        self.slots.get(id).map(|s| s.state)
    }

    fn set_state(&mut self, id: usize, state: SlotState, mb: usize) {
        let slot = &mut self.slots[id];
        if state != SlotState::Active && slot.state == SlotState::Active {
            slot.left_at = Some(mb);
        }
        if state == SlotState::Active {
            slot.left_at = None;
        }
        // Stale timings must not poison post-churn straggler decisions.
        slot.window.clear();
        slot.state = state;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::DevStats;

    fn cfg_with(events: &[&str], extra: &[(&str, &str)]) -> Config {
        let mut overrides: Vec<(String, String)> = vec![(
            "elastic.events".into(),
            format!(
                "[{}]",
                events.iter().map(|e| format!("\"{e}\"")).collect::<Vec<_>>().join(", ")
            ),
        )];
        for (k, v) in extra {
            overrides.push((k.to_string(), v.to_string()));
        }
        Config::from_overrides(&overrides).unwrap()
    }

    fn report(busy_per_update: &[f64]) -> MegaBatchReport {
        let per_device = busy_per_update
            .iter()
            .map(|&b| DevStats { updates: 10, busy: b * 10.0, ..Default::default() })
            .collect();
        MegaBatchReport { per_device, wall: 1.0, batch_nnz: Vec::new() }
    }

    #[test]
    fn scripted_remove_takes_slowest_and_add_restores() {
        let cfg = cfg_with(&["at_mb=2 remove=2", "at_mb=4 add=2"], &[]);
        let mut pool = DevicePool::new(&cfg).unwrap();
        assert_eq!(pool.active_ids(), vec![0, 1, 2, 3]);

        assert!(pool.begin_mega_batch(0).is_empty());
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev.len(), 2);
        // Default speed factors rise with id, so 3 then 2 go first.
        assert_eq!(ev[0].device, 3);
        assert_eq!(ev[1].device, 2);
        assert!(ev.iter().all(|e| e.action == PoolAction::Remove));
        assert_eq!(pool.active_ids(), vec![0, 1]);

        let ev = pool.begin_mega_batch(4);
        assert_eq!(ev.len(), 2);
        assert!(ev.iter().all(|e| e.action == PoolAction::Add));
        assert_eq!(pool.active_ids(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn with_trace_overrides_the_elastic_events() {
        // Elastic trace says remove at 1; the explicit trace says remove at 2.
        let cfg = cfg_with(&["at_mb=1 remove=1"], &[]);
        let mut pool =
            DevicePool::with_trace(&cfg, &["at_mb=2 remove=1".to_string()]).unwrap();
        assert!(pool.begin_mega_batch(1).is_empty(), "elastic trace must be ignored");
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].action, PoolAction::Remove);
        assert!(DevicePool::with_trace(&cfg, &["garbage".to_string()]).is_err());
    }

    #[test]
    fn available_ids_overlays_leases_on_membership() {
        let cfg = cfg_with(&["at_mb=1 remove_id=3"], &[]);
        let mut pool = DevicePool::new(&cfg).unwrap();
        // Devices 0 and 2 leased: only 1 and 3 are grantable.
        let leased = [true, false, true, false];
        assert_eq!(pool.available_ids(|d| leased[d]), vec![1, 3]);
        // Physical removal wins over lease state: 3 leaves the pool.
        pool.begin_mega_batch(1);
        assert_eq!(pool.available_ids(|d| leased[d]), vec![1]);
        assert_eq!(pool.available_ids(|_| false), vec![0, 1, 2]);
    }

    #[test]
    fn min_devices_floor_truncates_removals() {
        let cfg = cfg_with(&["at_mb=1 remove=9"], &[("elastic.min_devices", "2")]);
        let mut pool = DevicePool::new(&cfg).unwrap();
        let ev = pool.begin_mega_batch(1);
        assert_eq!(ev.len(), 2, "only down to the floor");
        assert_eq!(pool.active_count(), 2);
    }

    #[test]
    fn remove_id_and_add_id_are_explicit() {
        let cfg = cfg_with(&["at_mb=1 remove_id=0", "at_mb=3 add_id=0"], &[]);
        let mut pool = DevicePool::new(&cfg).unwrap();
        let ev = pool.begin_mega_batch(1);
        assert_eq!(ev[0].device, 0);
        assert_eq!(pool.active_ids(), vec![1, 2, 3]);
        // Adding an already-active id is a no-op; removing twice too.
        assert!(pool.begin_mega_batch(2).is_empty());
        let ev = pool.begin_mega_batch(3);
        assert_eq!(ev[0].device, 0);
        assert_eq!(pool.active_ids(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn spares_extend_the_roster_and_hot_add() {
        let cfg = cfg_with(
            &["at_mb=2 add=1"],
            &[("elastic.spare_devices", "[1.05]"), ("devices.count", "2"),
              ("devices.speed_factors", "[1.0, 1.1]")],
        );
        let mut pool = DevicePool::new(&cfg).unwrap();
        assert_eq!(pool.roster_len(), 3);
        assert_eq!(pool.active_ids(), vec![0, 1]);
        let roster = DevicePool::roster(&cfg);
        assert_eq!(roster.len(), 3);
        assert_eq!(roster[2].id, 2);
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev[0].device, 2);
        assert_eq!(ev[0].action, PoolAction::Add);
        assert_eq!(pool.active_ids(), vec![0, 1, 2]);
    }

    #[test]
    fn remove_id_cancels_a_pending_quarantine_readmission() {
        let cfg = cfg_with(
            &["at_mb=3 remove_id=3"],
            &[
                ("elastic.straggler_factor", "2.0"),
                ("elastic.straggler_window", "2"),
                ("elastic.quarantine_mega_batches", "3"),
            ],
        );
        let mut pool = DevicePool::new(&cfg).unwrap();
        for _ in 0..2 {
            pool.observe(&report(&[1.0, 1.0, 1.0, 5.0]));
        }
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev[0].action, PoolAction::Quarantine);
        // The scripted removal applies to the quarantined device and logs.
        let ev = pool.begin_mega_batch(3);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].action, PoolAction::Remove);
        assert_eq!(ev[0].device, 3);
        // No auto-readmission fires once the device was explicitly removed.
        for mb in 4..10 {
            assert!(pool.begin_mega_batch(mb).is_empty(), "mb {mb}");
        }
        assert_eq!(pool.active_ids(), vec![0, 1, 2]);
    }

    #[test]
    fn scripted_add_prefers_healthy_spares_over_quarantined() {
        let cfg = cfg_with(
            &["at_mb=3 add=1"],
            &[
                ("elastic.spare_devices", "[1.05]"),
                ("elastic.straggler_factor", "2.0"),
                ("elastic.straggler_window", "2"),
                ("elastic.quarantine_mega_batches", "9"),
            ],
        );
        let mut pool = DevicePool::new(&cfg).unwrap();
        for _ in 0..2 {
            pool.observe(&report(&[1.0, 5.0, 1.0, 1.0]));
        }
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev[0].action, PoolAction::Quarantine);
        assert_eq!(ev[0].device, 1);
        // add=1 brings in the clean spare (id 4), not the straggler.
        let ev = pool.begin_mega_batch(3);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].action, PoolAction::Add);
        assert_eq!(ev[0].device, 4);
        assert_eq!(pool.active_ids(), vec![0, 2, 3, 4]);
    }

    #[test]
    fn straggler_quarantine_and_auto_readmit() {
        let cfg = cfg_with(
            &[],
            &[
                ("elastic.straggler_factor", "2.0"),
                ("elastic.straggler_window", "2"),
                ("elastic.quarantine_mega_batches", "3"),
            ],
        );
        let mut pool = DevicePool::new(&cfg).unwrap();
        // Device 3 runs 5x the others.
        for _ in 0..2 {
            pool.observe(&report(&[1.0, 1.0, 1.0, 5.0]));
        }
        let ev = pool.begin_mega_batch(2);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].device, 3);
        assert_eq!(ev[0].action, PoolAction::Quarantine);
        assert!(ev[0].reason.contains("median"), "{}", ev[0].reason);
        assert_eq!(pool.active_ids(), vec![0, 1, 2]);

        // Not yet served...
        assert!(pool.begin_mega_batch(4).is_empty());
        // ...served at mb 5 (2 + 3).
        let ev = pool.begin_mega_batch(5);
        assert_eq!(ev[0].action, PoolAction::Readmit);
        assert_eq!(pool.active_ids(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn straggler_policy_respects_floor_and_window() {
        let cfg = cfg_with(
            &[],
            &[
                ("elastic.straggler_factor", "1.5"),
                ("elastic.straggler_window", "3"),
                ("elastic.min_devices", "4"),
            ],
        );
        let mut pool = DevicePool::new(&cfg).unwrap();
        for _ in 0..3 {
            pool.observe(&report(&[1.0, 1.0, 1.0, 9.0]));
        }
        // Offender exists but the floor forbids shrinking.
        assert!(pool.begin_mega_batch(3).is_empty());

        // Partial windows never trigger.
        let cfg = cfg_with(&[], &[("elastic.straggler_factor", "1.5")]);
        let mut pool = DevicePool::new(&cfg).unwrap();
        pool.observe(&report(&[1.0, 1.0, 1.0, 9.0]));
        assert!(pool.begin_mega_batch(1).is_empty());
    }
}

//! Dispatch plans, mega-batch reports, and the [`ExecutionEngine`] trait —
//! the contract between the trainer (strategy logic), the device pool
//! (membership), and the execution engines.

use crate::config::{Config, Strategy};
use crate::data::batcher::Batcher;
use crate::model::ModelState;
use crate::runtime::CostModel;
use crate::Result;

/// How batches are routed to devices within one mega-batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Paper §3.1 dynamic scheduling: whenever a device finishes a batch it
    /// is handed the next one, until the mega-batch sample budget is
    /// consumed (Adaptive SGD, CROSSBOW).
    Dynamic,
    /// Static allocation: every device processes exactly `batches_per_device`
    /// batches of its configured size, then waits at the barrier (Elastic
    /// SGD, synchronous gradient aggregation).
    StaticQuota { batches_per_device: usize },
}

/// Work order for one mega-batch, covering the *active* subset of the
/// device roster. The three per-device vectors are parallel: entry `i`
/// belongs to global device id `device_ids[i]`.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub mode: DispatchMode,
    /// Global ids of the devices participating in this mega-batch.
    pub device_ids: Vec<usize>,
    /// Per-device batch size (a bucket-grid value), parallel to `device_ids`.
    pub batch_sizes: Vec<usize>,
    /// Per-device learning rate (linear scaling), parallel to `device_ids`.
    pub lrs: Vec<f32>,
    /// Sample budget for [`DispatchMode::Dynamic`].
    pub sample_budget: usize,
    /// CROSSBOW-style per-batch replica correction rate toward the fleet
    /// average (None for everything but CROSSBOW).
    pub crossbow_rate: Option<f64>,
}

impl DispatchPlan {
    /// Number of participating devices.
    pub fn devices(&self) -> usize {
        self.device_ids.len()
    }
}

/// Build the dispatch plan for one mega-batch of `strategy` over the active
/// device subset. `batch_sizes` / `lrs` are *roster-indexed* adaptive state;
/// the plan gathers the active entries. This is the hot-path recomputation
/// that runs after every pool event (benchmarked in `perf_hotpath`).
pub fn plan_for_strategy(
    cfg: &Config,
    strategy: Strategy,
    active: &[usize],
    batch_sizes: &[usize],
    lrs: &[f32],
) -> DispatchPlan {
    let g = active.len().max(1);
    match strategy {
        Strategy::Adaptive => DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: active.to_vec(),
            batch_sizes: active.iter().map(|&d| batch_sizes[d]).collect(),
            lrs: active.iter().map(|&d| lrs[d]).collect(),
            sample_budget: cfg.sgd.mega_batch_samples(),
            crossbow_rate: None,
        },
        Strategy::Elastic => {
            let b = cfg.sgd.b_max;
            DispatchPlan {
                mode: DispatchMode::StaticQuota {
                    batches_per_device: (cfg.sgd.mega_batch_samples() / (g * b)).max(1),
                },
                device_ids: active.to_vec(),
                batch_sizes: vec![b; active.len()],
                lrs: vec![cfg.lr_for_batch(b); active.len()],
                sample_budget: 0,
                crossbow_rate: None,
            }
        }
        Strategy::Crossbow => DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: active.to_vec(),
            batch_sizes: vec![cfg.sgd.b_max; active.len()],
            lrs: vec![cfg.lr_for_batch(cfg.sgd.b_max); active.len()],
            sample_budget: cfg.sgd.mega_batch_samples(),
            crossbow_rate: Some(cfg.strategy.crossbow_rate),
        },
        Strategy::SyncGradAgg => {
            // One synchronous round: per-device batch b_max/G, one batch each.
            let b_tf = crate::coordinator::scaling::round_to_grid(
                (cfg.sgd.b_max as f64 / g as f64).max(cfg.sgd.b_min as f64),
                &cfg.sgd,
            );
            DispatchPlan {
                mode: DispatchMode::StaticQuota { batches_per_device: 1 },
                device_ids: active.to_vec(),
                batch_sizes: vec![b_tf; active.len()],
                lrs: vec![cfg.lr_for_batch(b_tf); active.len()],
                sample_budget: 0,
                crossbow_rate: None,
            }
        }
    }
}

/// Per-device statistics for one mega-batch.
#[derive(Clone, Debug, Default)]
pub struct DevStats {
    /// Model replica updates (batches processed).
    pub updates: u64,
    /// Real (unpadded) samples processed.
    pub samples: u64,
    /// Busy time in seconds (simulated or stretched wall).
    pub busy: f64,
    /// Sum of per-batch losses (divide by updates for the mean).
    pub loss_sum: f64,
    /// True non-zeros processed.
    pub nnz: u64,
}

/// Aggregate outcome of one mega-batch. `per_device` is indexed by global
/// device id over the whole roster; devices outside the plan's active set
/// stay at their zero default.
#[derive(Clone, Debug)]
pub struct MegaBatchReport {
    pub per_device: Vec<DevStats>,
    /// Time from mega-batch start to the merge barrier (max device busy
    /// time in the sim engine; measured wall time in the threaded engine).
    pub wall: f64,
}

impl MegaBatchReport {
    pub fn total_samples(&self) -> u64 {
        self.per_device.iter().map(|d| d.samples).sum()
    }

    pub fn total_updates(&self) -> u64 {
        self.per_device.iter().map(|d| d.updates).sum()
    }

    pub fn updates(&self) -> Vec<u64> {
        self.per_device.iter().map(|d| d.updates).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        let (sum, n) = self
            .per_device
            .iter()
            .fold((0.0, 0u64), |(s, n), d| (s + d.loss_sum, n + d.updates));
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Straggler delay: how long the *least* busy participating device
    /// idled waiting for the barrier. Devices with zero updates (outside
    /// the active pool) don't count.
    pub fn max_idle(&self) -> f64 {
        let min_busy = self
            .per_device
            .iter()
            .filter(|d| d.updates > 0)
            .map(|d| d.busy)
            .fold(f64::INFINITY, f64::min);
        if min_busy.is_finite() {
            (self.wall - min_busy).max(0.0)
        } else {
            0.0
        }
    }
}

/// A mega-batch execution engine, unified behind one dispatch call.
///
/// `replicas` is indexed by global device id over the full roster (the
/// engine was constructed with the same roster); `plan.device_ids` selects
/// which replicas participate. Engines must leave non-participating
/// replicas untouched.
pub trait ExecutionEngine {
    fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        batcher: &mut Batcher<'_>,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport>;

    /// Number of roster slots this engine was built with.
    fn roster_len(&self) -> usize;

    /// Cost model used to charge merge/all-reduce transfer time.
    fn cost_model(&self) -> CostModel {
        CostModel::default()
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn plans_cover_only_the_active_subset() {
        let cfg = Config::default(); // 4 devices
        let batch_sizes = vec![128, 96, 72, 48];
        let lrs = vec![0.05, 0.04, 0.03, 0.02];
        let plan =
            plan_for_strategy(&cfg, Strategy::Adaptive, &[0, 2, 3], &batch_sizes, &lrs);
        assert_eq!(plan.device_ids, vec![0, 2, 3]);
        assert_eq!(plan.batch_sizes, vec![128, 72, 48]);
        assert_eq!(plan.lrs, vec![0.05, 0.03, 0.02]);
        assert_eq!(plan.devices(), 3);
    }

    #[test]
    fn elastic_quota_rescales_with_pool_size() {
        let cfg = Config::default(); // mega = 20 * 128 samples, b_max 128
        let b = vec![128; 4];
        let l = vec![0.05; 4];
        let p4 = plan_for_strategy(&cfg, Strategy::Elastic, &[0, 1, 2, 3], &b, &l);
        let p2 = plan_for_strategy(&cfg, Strategy::Elastic, &[0, 1], &b, &l);
        let q4 = match p4.mode {
            DispatchMode::StaticQuota { batches_per_device } => batches_per_device,
            _ => unreachable!(),
        };
        let q2 = match p2.mode {
            DispatchMode::StaticQuota { batches_per_device } => batches_per_device,
            _ => unreachable!(),
        };
        assert_eq!(q4 * 2, q2, "half the devices, twice the per-device quota");
    }

    #[test]
    fn max_idle_ignores_inactive_devices() {
        let report = MegaBatchReport {
            per_device: vec![
                DevStats { updates: 5, busy: 0.8, ..Default::default() },
                DevStats::default(), // inactive
                DevStats { updates: 5, busy: 1.0, ..Default::default() },
            ],
            wall: 1.0,
        };
        assert!((report.max_idle() - 0.2).abs() < 1e-12);
    }
}

//! Dispatch plans, mega-batch reports, and the [`ExecutionEngine`] trait —
//! the contract between the trainer (strategy logic), the device pool
//! (membership), and the execution engines.

use crate::config::{Config, Strategy};
use crate::data::pipeline::DataPlane;
use crate::model::ModelState;
use crate::runtime::CostModel;
use crate::Result;

/// How batches are routed to devices within one mega-batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Paper §3.1 dynamic scheduling: whenever a device finishes a batch it
    /// is handed the next one, until the mega-batch sample budget is
    /// consumed (Adaptive SGD, CROSSBOW).
    Dynamic,
    /// Static allocation: every device processes exactly `batches_per_device`
    /// batches of its configured size, then waits at the barrier (Elastic
    /// SGD, synchronous gradient aggregation).
    StaticQuota { batches_per_device: usize },
}

/// Work order for one mega-batch, covering the *active* subset of the
/// device roster. The three per-device vectors are parallel: entry `i`
/// belongs to global device id `device_ids[i]`.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub mode: DispatchMode,
    /// Global ids of the devices participating in this mega-batch.
    pub device_ids: Vec<usize>,
    /// Per-device batch size (a bucket-grid value), parallel to `device_ids`.
    pub batch_sizes: Vec<usize>,
    /// Per-device learning rate (linear scaling), parallel to `device_ids`.
    pub lrs: Vec<f32>,
    /// Sample budget for [`DispatchMode::Dynamic`].
    pub sample_budget: usize,
    /// CROSSBOW-style per-batch replica correction rate toward the fleet
    /// average (None for everything but CROSSBOW).
    pub crossbow_rate: Option<f64>,
    /// Expected nnz per sample (post-`max_nnz` clamping), read off the
    /// data plane's shard manifests. The plan consumes this so batch
    /// *cost* — not just count — is known at dispatch time.
    pub nnz_estimate: f64,
    /// Calibrated predicted seconds per full batch, parallel to
    /// `device_ids` (`[calibration]` plane). `Some` upgrades the dynamic
    /// scheduler from earliest-free to earliest-predicted-completion
    /// dispatch ([`crate::coordinator::dispatch::next_completion_device`]);
    /// `None` (the default everywhere) keeps the historical behavior
    /// bit-for-bit.
    pub predicted_step_secs: Option<Vec<f64>>,
    /// Per-device active-class sparsity ratios, parallel to `device_ids`
    /// (`[slide] adaptive`). A slot at `1.0` runs the exact dense kernel;
    /// below `1.0` the engine steps through the LSH active-class kernel at
    /// that ratio. `None` (the default everywhere) is dense on every slot
    /// and keeps the historical behavior bit-for-bit.
    pub sparsity_ratios: Option<Vec<f64>>,
}

impl DispatchPlan {
    /// Number of participating devices.
    pub fn devices(&self) -> usize {
        self.device_ids.len()
    }

    /// Attach calibrated per-slot step predictions (parallel to
    /// `device_ids`) — the trainer does this when `[calibration]` is
    /// enabled and an estimate view exists.
    pub fn with_predicted_step_secs(mut self, secs: Vec<f64>) -> DispatchPlan {
        assert_eq!(secs.len(), self.device_ids.len(), "predictions must parallel the slots");
        self.predicted_step_secs = Some(secs);
        self
    }

    /// Attach per-slot active-class sparsity ratios (parallel to
    /// `device_ids`) — the trainer does this when `[slide] adaptive` is on.
    pub fn with_sparsity_ratios(mut self, ratios: Vec<f64>) -> DispatchPlan {
        assert_eq!(ratios.len(), self.device_ids.len(), "ratios must parallel the slots");
        assert!(ratios.iter().all(|&r| r > 0.0), "sparsity ratios must be positive");
        self.sparsity_ratios = Some(ratios);
        self
    }

    /// Effective sparsity ratio of active slot `slot` (1.0 = dense).
    pub fn sparsity_ratio(&self, slot: usize) -> f64 {
        self.sparsity_ratios.as_ref().map(|r| r[slot]).unwrap_or(1.0)
    }

    /// Expected total nnz of one full batch on active slot `slot`.
    pub fn expected_batch_nnz(&self, slot: usize) -> f64 {
        self.nnz_estimate * self.batch_sizes[slot] as f64
    }

    /// Expected total nnz of the whole dynamic sample budget.
    pub fn expected_budget_nnz(&self) -> f64 {
        self.nnz_estimate * self.sample_budget as f64
    }
}

/// Build the dispatch plan for one mega-batch of `strategy` over the active
/// device subset. `batch_sizes` / `lrs` are *roster-indexed* adaptive state;
/// the plan gathers the active entries. This is the hot-path recomputation
/// that runs after every pool event (benchmarked in `perf_hotpath`).
pub fn plan_for_strategy(
    cfg: &Config,
    strategy: Strategy,
    active: &[usize],
    batch_sizes: &[usize],
    lrs: &[f32],
    nnz_estimate: f64,
) -> DispatchPlan {
    let g = active.len().max(1);
    match strategy {
        Strategy::Adaptive => DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: active.to_vec(),
            batch_sizes: active.iter().map(|&d| batch_sizes[d]).collect(),
            lrs: active.iter().map(|&d| lrs[d]).collect(),
            sample_budget: cfg.sgd.mega_batch_samples(),
            crossbow_rate: None,
            nnz_estimate,
            predicted_step_secs: None,
            sparsity_ratios: None,
        },
        Strategy::Elastic => {
            let b = cfg.sgd.b_max;
            DispatchPlan {
                mode: DispatchMode::StaticQuota {
                    batches_per_device: (cfg.sgd.mega_batch_samples() / (g * b)).max(1),
                },
                device_ids: active.to_vec(),
                batch_sizes: vec![b; active.len()],
                lrs: vec![cfg.lr_for_batch(b); active.len()],
                sample_budget: 0,
                crossbow_rate: None,
                nnz_estimate,
                predicted_step_secs: None,
                sparsity_ratios: None,
            }
        }
        Strategy::Crossbow => DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: active.to_vec(),
            batch_sizes: vec![cfg.sgd.b_max; active.len()],
            lrs: vec![cfg.lr_for_batch(cfg.sgd.b_max); active.len()],
            sample_budget: cfg.sgd.mega_batch_samples(),
            crossbow_rate: Some(cfg.strategy.crossbow_rate),
            nnz_estimate,
            predicted_step_secs: None,
            sparsity_ratios: None,
        },
        Strategy::SyncGradAgg => {
            // One synchronous round: per-device batch b_max/G, one batch each.
            let b_tf = crate::coordinator::scaling::round_to_grid(
                (cfg.sgd.b_max as f64 / g as f64).max(cfg.sgd.b_min as f64),
                &cfg.sgd,
            );
            DispatchPlan {
                mode: DispatchMode::StaticQuota { batches_per_device: 1 },
                device_ids: active.to_vec(),
                batch_sizes: vec![b_tf; active.len()],
                lrs: vec![cfg.lr_for_batch(b_tf); active.len()],
                sample_budget: 0,
                crossbow_rate: None,
                nnz_estimate,
                predicted_step_secs: None,
                sparsity_ratios: None,
            }
        }
    }
}

/// Per-device statistics for one mega-batch.
#[derive(Clone, Debug, Default)]
pub struct DevStats {
    /// Model replica updates (batches processed).
    pub updates: u64,
    /// Real (unpadded) samples processed.
    pub samples: u64,
    /// Busy time in seconds (simulated or stretched wall).
    pub busy: f64,
    /// Sum of per-batch losses (divide by updates for the mean).
    pub loss_sum: f64,
    /// True non-zeros processed.
    pub nnz: u64,
    /// Sum of per-step active output-class counts (divide by `updates` for
    /// the mean active-set size; equals `updates * classes` when dense).
    pub active_classes: u64,
}

/// Aggregate outcome of one mega-batch. `per_device` is indexed by global
/// device id over the whole roster; devices outside the plan's active set
/// stay at their zero default.
#[derive(Clone, Debug)]
pub struct MegaBatchReport {
    pub per_device: Vec<DevStats>,
    /// Time from mega-batch start to the merge barrier (max device busy
    /// time in the sim engine; measured wall time in the threaded engine).
    pub wall: f64,
    /// True nnz of every dispatched batch (dispatch/completion order) —
    /// the per-batch cost dispersion the paper ties to instability.
    pub batch_nnz: Vec<u64>,
}

impl MegaBatchReport {
    /// Mean and coefficient of variation of per-batch nnz. CV is the
    /// paper-relevant dispersion measure: the `NnzBalanced` composition
    /// policy exists to push it toward zero.
    pub fn nnz_dispersion(&self) -> (f64, f64) {
        if self.batch_nnz.is_empty() {
            return (0.0, 0.0);
        }
        let n = self.batch_nnz.len() as f64;
        let mean = self.batch_nnz.iter().map(|&x| x as f64).sum::<f64>() / n;
        if mean == 0.0 {
            return (0.0, 0.0);
        }
        let var = self
            .batch_nnz
            .iter()
            .map(|&x| {
                let d = x as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        (mean, var.sqrt() / mean)
    }
    pub fn total_samples(&self) -> u64 {
        self.per_device.iter().map(|d| d.samples).sum()
    }

    pub fn total_updates(&self) -> u64 {
        self.per_device.iter().map(|d| d.updates).sum()
    }

    pub fn updates(&self) -> Vec<u64> {
        self.per_device.iter().map(|d| d.updates).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        let (sum, n) = self
            .per_device
            .iter()
            .fold((0.0, 0u64), |(s, n), d| (s + d.loss_sum, n + d.updates));
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Straggler delay: how long the *least* busy participating device
    /// idled waiting for the barrier. Devices with zero updates (outside
    /// the active pool) don't count.
    pub fn max_idle(&self) -> f64 {
        let min_busy = self
            .per_device
            .iter()
            .filter(|d| d.updates > 0)
            .map(|d| d.busy)
            .fold(f64::INFINITY, f64::min);
        if min_busy.is_finite() {
            (self.wall - min_busy).max(0.0)
        } else {
            0.0
        }
    }
}

/// A mega-batch execution engine, unified behind one dispatch call.
///
/// `replicas` is indexed by global device id over the full roster (the
/// engine was constructed with the same roster); `plan.device_ids` selects
/// which replicas participate. Engines must leave non-participating
/// replicas untouched. Batches are pulled from (and their buffers recycled
/// back to) the [`DataPlane`] — engines no longer own a batch source.
pub trait ExecutionEngine {
    fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        plane: &DataPlane,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport>;

    /// Number of roster slots this engine was built with.
    fn roster_len(&self) -> usize;

    /// Cost model used to charge merge/all-reduce transfer time.
    fn cost_model(&self) -> CostModel {
        CostModel::default()
    }

    /// Apply a scripted drift multiplier to one roster device
    /// (`[calibration] events` — the trainer re-applies the trace value at
    /// every mega-batch boundary). Virtual-time engines forward this to
    /// [`SimDevice::set_drift`](crate::runtime::SimDevice::set_drift);
    /// the default is a no-op, so engines without a heterogeneity model
    /// (or with workers owning their devices) simply ignore drift traces.
    fn set_drift(&mut self, _device: usize, _multiplier: f64) {}

    /// Hand the engine an observability handle so it can emit per-device
    /// step spans (`engine.step`) onto the trace. The default is a no-op:
    /// engines without per-device timing (e.g. the null engine) simply
    /// never appear in the engine lanes.
    fn set_obs(&mut self, _obs: crate::obs::ObsHandle) {}

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn plans_cover_only_the_active_subset() {
        let cfg = Config::default(); // 4 devices
        let batch_sizes = vec![128, 96, 72, 48];
        let lrs = vec![0.05, 0.04, 0.03, 0.02];
        let plan =
            plan_for_strategy(&cfg, Strategy::Adaptive, &[0, 2, 3], &batch_sizes, &lrs, 12.0);
        assert_eq!(plan.device_ids, vec![0, 2, 3]);
        assert_eq!(plan.batch_sizes, vec![128, 72, 48]);
        assert_eq!(plan.lrs, vec![0.05, 0.03, 0.02]);
        assert_eq!(plan.devices(), 3);
        // The plan consumes the pipeline's nnz estimate: per-batch and
        // per-budget expected costs fall straight out.
        assert!((plan.expected_batch_nnz(1) - 72.0 * 12.0).abs() < 1e-9);
        assert!(
            (plan.expected_budget_nnz() - cfg.sgd.mega_batch_samples() as f64 * 12.0).abs() < 1e-9
        );
    }

    #[test]
    fn elastic_quota_rescales_with_pool_size() {
        let cfg = Config::default(); // mega = 20 * 128 samples, b_max 128
        let b = vec![128; 4];
        let l = vec![0.05; 4];
        let p4 = plan_for_strategy(&cfg, Strategy::Elastic, &[0, 1, 2, 3], &b, &l, 12.0);
        let p2 = plan_for_strategy(&cfg, Strategy::Elastic, &[0, 1], &b, &l, 12.0);
        let q4 = match p4.mode {
            DispatchMode::StaticQuota { batches_per_device } => batches_per_device,
            _ => unreachable!(),
        };
        let q2 = match p2.mode {
            DispatchMode::StaticQuota { batches_per_device } => batches_per_device,
            _ => unreachable!(),
        };
        assert_eq!(q4 * 2, q2, "half the devices, twice the per-device quota");
    }

    #[test]
    fn max_idle_ignores_inactive_devices() {
        let report = MegaBatchReport {
            per_device: vec![
                DevStats { updates: 5, busy: 0.8, ..Default::default() },
                DevStats::default(), // inactive
                DevStats { updates: 5, busy: 1.0, ..Default::default() },
            ],
            wall: 1.0,
            batch_nnz: Vec::new(),
        };
        assert!((report.max_idle() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn nnz_dispersion_mean_and_cv() {
        let mut report =
            MegaBatchReport { per_device: Vec::new(), wall: 0.0, batch_nnz: Vec::new() };
        assert_eq!(report.nnz_dispersion(), (0.0, 0.0));
        report.batch_nnz = vec![100, 100, 100];
        let (mean, cv) = report.nnz_dispersion();
        assert!((mean - 100.0).abs() < 1e-12);
        assert!(cv.abs() < 1e-12, "identical batches have zero dispersion");
        report.batch_nnz = vec![50, 150];
        let (mean, cv) = report.nnz_dispersion();
        assert!((mean - 100.0).abs() < 1e-12);
        assert!((cv - 0.5).abs() < 1e-12);
    }
}

//! Dispatch plans and mega-batch reports — the contract between the trainer
//! (strategy logic) and the two execution engines.

/// How batches are routed to devices within one mega-batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Paper §3.1 dynamic scheduling: whenever a device finishes a batch it
    /// is handed the next one, until the mega-batch sample budget is
    /// consumed (Adaptive SGD, CROSSBOW).
    Dynamic,
    /// Static allocation: every device processes exactly `batches_per_device`
    /// batches of its configured size, then waits at the barrier (Elastic
    /// SGD, synchronous gradient aggregation).
    StaticQuota { batches_per_device: usize },
}

/// Work order for one mega-batch.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub mode: DispatchMode,
    /// Per-device batch size (a bucket-grid value).
    pub batch_sizes: Vec<usize>,
    /// Per-device learning rate (linear scaling).
    pub lrs: Vec<f32>,
    /// Sample budget for [`DispatchMode::Dynamic`].
    pub sample_budget: usize,
    /// CROSSBOW-style per-batch replica correction rate toward the fleet
    /// average (None for everything but CROSSBOW).
    pub crossbow_rate: Option<f64>,
}

impl DispatchPlan {
    pub fn devices(&self) -> usize {
        self.batch_sizes.len()
    }
}

/// Per-device statistics for one mega-batch.
#[derive(Clone, Debug, Default)]
pub struct DevStats {
    /// Model replica updates (batches processed).
    pub updates: u64,
    /// Real (unpadded) samples processed.
    pub samples: u64,
    /// Busy time in seconds (simulated or stretched wall).
    pub busy: f64,
    /// Sum of per-batch losses (divide by updates for the mean).
    pub loss_sum: f64,
    /// True non-zeros processed.
    pub nnz: u64,
}

/// Aggregate outcome of one mega-batch.
#[derive(Clone, Debug)]
pub struct MegaBatchReport {
    pub per_device: Vec<DevStats>,
    /// Time from mega-batch start to the merge barrier (max device busy
    /// time in the sim engine; measured wall time in the threaded engine).
    pub wall: f64,
}

impl MegaBatchReport {
    pub fn total_samples(&self) -> u64 {
        self.per_device.iter().map(|d| d.samples).sum()
    }

    pub fn total_updates(&self) -> u64 {
        self.per_device.iter().map(|d| d.updates).sum()
    }

    pub fn updates(&self) -> Vec<u64> {
        self.per_device.iter().map(|d| d.updates).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        let (sum, n) = self
            .per_device
            .iter()
            .fold((0.0, 0u64), |(s, n), d| (s + d.loss_sum, n + d.updates));
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Straggler delay: barrier wall minus the busiest device's... i.e. how
    /// long the *least* busy device idled waiting for the barrier.
    pub fn max_idle(&self) -> f64 {
        let min_busy = self.per_device.iter().map(|d| d.busy).fold(f64::INFINITY, f64::min);
        (self.wall - min_busy).max(0.0)
    }
}

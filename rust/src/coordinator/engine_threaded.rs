//! Threaded real-time engine: one GPU-manager thread per simulated device.
//!
//! Mirrors the HeteroGPU architecture (paper §4, Fig. 5): stand-alone
//! asynchronous managers communicating with a central dynamic scheduler via
//! event messages. Each manager thread owns its device's model replica and
//! its *own* PJRT client (the `xla` crate client is `Rc`-based and the
//! paper's managers own their GPU context anyway); the scheduler owns the
//! batcher and routes batches dynamically on completion events.
//!
//! Heterogeneity is injected by stretching each measured step to what the
//! simulated device would have taken (`SimDevice::stretch`) and sleeping
//! the difference.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::data::batcher::Batcher;
use crate::data::PaddedBatch;
use crate::model::ModelState;
use crate::runtime::SimDevice;
use crate::Result;

use super::backend::StepBackend;
use super::plan::{DevStats, DispatchMode, DispatchPlan, MegaBatchReport};

/// Creates a device's backend *inside* its worker thread.
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn StepBackend>> + Send + Sync>;

enum Cmd {
    Step { batch: PaddedBatch, lr: f32, crossbow_rate: Option<f64> },
    SetReplica(Box<ModelState>),
    TakeReplica,
    Shutdown,
}

enum Reply {
    Ready { dev: usize },
    StepDone { dev: usize, loss: f32, valid: usize, nnz: usize, busy: f64 },
    Replica { dev: usize, model: Box<ModelState> },
    Fatal { dev: usize, error: String },
}

struct Worker {
    cmd: mpsc::Sender<Cmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Shared state for CROSSBOW-style corrections: the running *sum* of all
/// replicas (avg = sum / G), incrementally maintained by the workers.
struct CrossbowShared {
    sum: Mutex<ModelState>,
    devices: usize,
}

pub struct ThreadedEngine {
    workers: Vec<Worker>,
    replies: mpsc::Receiver<Reply>,
    crossbow: Option<Arc<CrossbowShared>>,
    template: ModelState,
}

impl ThreadedEngine {
    /// Spawn one manager thread per device. Blocks until every worker has
    /// constructed its backend (so compile errors surface here, not mid-run).
    pub fn spawn(
        factory: BackendFactory,
        devices: Vec<SimDevice>,
        template: &ModelState,
    ) -> Result<ThreadedEngine> {
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let mut workers = Vec::with_capacity(devices.len());
        let crossbow = Arc::new(CrossbowShared {
            sum: Mutex::new(ModelState::zeros(&template.dims)),
            devices: devices.len(),
        });
        for device in devices {
            let dev = device.id;
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let replies = reply_tx.clone();
            let factory = factory.clone();
            let shared = crossbow.clone();
            let template = template.clone();
            let handle = std::thread::Builder::new()
                .name(format!("gpu-manager-{dev}"))
                .spawn(move || worker_main(dev, device, factory, cmd_rx, replies, shared, template))
                .expect("spawning worker thread");
            workers.push(Worker { cmd: cmd_tx, handle: Some(handle) });
        }
        // Wait for all Ready (or Fatal) events.
        let mut ready = vec![false; workers.len()];
        while ready.iter().any(|r| !r) {
            match reply_rx.recv().map_err(|_| anyhow!("worker channel closed during startup"))? {
                Reply::Ready { dev } => ready[dev] = true,
                Reply::Fatal { dev, error } => bail!("device {dev} failed to start: {error}"),
                _ => bail!("unexpected reply during startup"),
            }
        }
        Ok(ThreadedEngine {
            workers,
            replies: reply_rx,
            crossbow: Some(crossbow),
            template: template.clone(),
        })
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    /// Run one mega-batch; protocol mirrors `SimEngine::run_mega_batch`.
    pub fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        batcher: &mut Batcher<'_>,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport> {
        let g = self.workers.len();
        assert_eq!(replicas.len(), g);
        assert_eq!(plan.batch_sizes.len(), g);

        // Install replicas (and the crossbow sum) for this mega-batch.
        if plan.crossbow_rate.is_some() {
            if let Some(shared) = &self.crossbow {
                let mut sum = shared.sum.lock().unwrap();
                *sum = ModelState::zeros(&self.template.dims);
                let refs: Vec<&ModelState> = replicas.iter().collect();
                let ones = vec![1.0; g];
                sum.set_weighted_sum(&refs, &ones);
            }
        }
        for (w, r) in self.workers.iter().zip(replicas.iter()) {
            w.cmd
                .send(Cmd::SetReplica(Box::new(r.clone())))
                .map_err(|_| anyhow!("worker died"))?;
        }

        let mut stats = vec![DevStats::default(); g];
        let t0 = Instant::now();

        // Per-device outstanding work accounting.
        let mut inflight = 0usize;
        let mut remaining = match plan.mode {
            DispatchMode::Dynamic => plan.sample_budget,
            DispatchMode::StaticQuota { .. } => 0,
        };
        let mut quota = match plan.mode {
            DispatchMode::Dynamic => vec![usize::MAX; g],
            DispatchMode::StaticQuota { batches_per_device } => vec![batches_per_device; g],
        };

        // Prime every device with one batch.
        for dev in 0..g {
            if self.try_dispatch(dev, plan, batcher, &mut remaining, &mut quota)? {
                inflight += 1;
            }
        }

        while inflight > 0 {
            match self.replies.recv().map_err(|_| anyhow!("worker channel closed"))? {
                Reply::StepDone { dev, loss, valid, nnz, busy } => {
                    let s = &mut stats[dev];
                    s.updates += 1;
                    s.samples += valid as u64;
                    s.loss_sum += loss as f64;
                    s.nnz += nnz as u64;
                    s.busy += busy;
                    if self.try_dispatch(dev, plan, batcher, &mut remaining, &mut quota)? {
                        // still inflight
                    } else {
                        inflight -= 1;
                    }
                }
                Reply::Fatal { dev, error } => bail!("device {dev} failed: {error}"),
                _ => bail!("unexpected reply during mega-batch"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        // Barrier: pull replicas back.
        for w in &self.workers {
            w.cmd.send(Cmd::TakeReplica).map_err(|_| anyhow!("worker died"))?;
        }
        let mut got = 0usize;
        while got < g {
            match self.replies.recv().map_err(|_| anyhow!("worker channel closed"))? {
                Reply::Replica { dev, model } => {
                    replicas[dev] = *model;
                    got += 1;
                }
                Reply::Fatal { dev, error } => bail!("device {dev} failed: {error}"),
                _ => bail!("unexpected reply at barrier"),
            }
        }

        Ok(MegaBatchReport { per_device: stats, wall })
    }

    fn try_dispatch(
        &self,
        dev: usize,
        plan: &DispatchPlan,
        batcher: &mut Batcher<'_>,
        remaining: &mut usize,
        quota: &mut [usize],
    ) -> Result<bool> {
        match plan.mode {
            DispatchMode::Dynamic => {
                if *remaining == 0 {
                    return Ok(false);
                }
                let bucket = plan.batch_sizes[dev];
                let valid = bucket.min(*remaining);
                *remaining -= valid;
                let batch = batcher.next_batch(bucket, valid);
                self.workers[dev]
                    .cmd
                    .send(Cmd::Step { batch, lr: plan.lrs[dev], crossbow_rate: plan.crossbow_rate })
                    .map_err(|_| anyhow!("worker died"))?;
                Ok(true)
            }
            DispatchMode::StaticQuota { .. } => {
                if quota[dev] == 0 {
                    return Ok(false);
                }
                quota[dev] -= 1;
                let bucket = plan.batch_sizes[dev];
                let batch = batcher.next_batch(bucket, bucket);
                self.workers[dev]
                    .cmd
                    .send(Cmd::Step { batch, lr: plan.lrs[dev], crossbow_rate: plan.crossbow_rate })
                    .map_err(|_| anyhow!("worker died"))?;
                Ok(true)
            }
        }
    }
}

impl Drop for ThreadedEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_main(
    dev: usize,
    mut device: SimDevice,
    factory: BackendFactory,
    cmd: mpsc::Receiver<Cmd>,
    replies: mpsc::Sender<Reply>,
    shared: Arc<CrossbowShared>,
    template: ModelState,
) {
    let backend = match factory(dev) {
        Ok(b) => {
            let _ = replies.send(Reply::Ready { dev });
            b
        }
        Err(e) => {
            let _ = replies.send(Reply::Fatal { dev, error: format!("{e:#}") });
            return;
        }
    };
    let mut replica = template;
    // Last version of this replica folded into the shared crossbow sum.
    let mut published: Option<Box<ModelState>> = None;
    loop {
        match cmd.recv() {
            Err(_) | Ok(Cmd::Shutdown) => return,
            Ok(Cmd::SetReplica(m)) => {
                replica = *m;
                published = Some(Box::new(replica.clone()));
            }
            Ok(Cmd::TakeReplica) => {
                if replies.send(Reply::Replica { dev, model: Box::new(replica.clone()) }).is_err() {
                    return;
                }
            }
            Ok(Cmd::Step { batch, lr, crossbow_rate }) => {
                let t0 = Instant::now();
                match backend.step(&mut replica, &batch, lr) {
                    Ok((loss, _)) => {
                        let real = t0.elapsed().as_secs_f64();
                        let target = device.stretch(real);
                        if target > real {
                            std::thread::sleep(Duration::from_secs_f64(target - real));
                        }
                        if let Some(rate) = crossbow_rate {
                            if let Some(pub_state) = published.as_mut() {
                                crossbow_correct(&shared, &mut replica, pub_state, rate);
                            }
                        }
                        let reply = Reply::StepDone {
                            dev,
                            loss,
                            valid: batch.valid,
                            nnz: batch.nnz,
                            busy: target.max(real),
                        };
                        if replies.send(reply).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = replies.send(Reply::Fatal { dev, error: format!("{e:#}") });
                        return;
                    }
                }
            }
        }
    }
}

/// CROSSBOW replica correction under the shared-sum lock.
///
/// Invariant: `shared.sum` always equals the sum of every worker's last
/// *published* replica. This worker computes the fleet average from the sum
/// (its own stale contribution included, exactly like CROSSBOW's central
/// average model), pulls its post-step replica toward it, then swaps its
/// published contribution for the corrected one — keeping the invariant.
fn crossbow_correct(
    shared: &Arc<CrossbowShared>,
    replica: &mut ModelState,
    published: &mut ModelState,
    rate: f64,
) {
    let g = shared.devices as f32;
    let r = rate as f32;
    let mut sum = shared.sum.lock().unwrap();
    for seg in 0..4 {
        let len = replica.segments()[seg].len();
        for p in 0..len {
            let (sum_seg, rep_seg, pub_seg) = match seg {
                0 => (&mut sum.w1, &mut replica.w1, &mut published.w1),
                1 => (&mut sum.b1, &mut replica.b1, &mut published.b1),
                2 => (&mut sum.w2, &mut replica.w2, &mut published.w2),
                _ => (&mut sum.b2, &mut replica.b2, &mut published.b2),
            };
            debug_assert_eq!(sum_seg.len(), len);
            let new = rep_seg[p];
            let avg = sum_seg[p] / g;
            let corrected = new + r * (avg - new);
            sum_seg[p] += corrected - pub_seg[p];
            pub_seg[p] = corrected;
            rep_seg[p] = corrected;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DataConfig, DeviceConfig, ModelDims};
    use crate::coordinator::backend::RefBackend;
    use crate::data::synthetic::Generator;

    fn setup() -> (Config, crate::data::SparseDataset) {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 4 };
        cfg.devices = DeviceConfig { count: 3, speed_factors: vec![1.0, 1.2, 1.4], ..Default::default() };
        let data_cfg = DataConfig { train_samples: 400, avg_nnz: 5.0, ..Default::default() };
        let ds = Generator::new(&cfg.model, &data_cfg).generate(400, 1);
        (cfg, ds)
    }

    fn ref_factory() -> BackendFactory {
        Arc::new(|_dev| Ok(Box::new(RefBackend) as Box<dyn StepBackend>))
    }

    #[test]
    fn dynamic_megabatch_conserves_budget() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 1);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let mut batcher = Batcher::new(&ds, &cfg.model, 5);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            batch_sizes: vec![16, 16, 16],
            lrs: vec![0.05; 3],
            sample_budget: 250,
            crossbow_rate: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &mut batcher, &plan).unwrap();
        assert_eq!(report.total_samples(), 250);
        assert!(report.wall > 0.0);
        // Replicas actually trained (diverged from the template).
        assert!(replicas[0].max_abs_diff(&template) > 0.0);
    }

    #[test]
    fn static_quota_equal_updates() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 2);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let mut batcher = Batcher::new(&ds, &cfg.model, 6);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::StaticQuota { batches_per_device: 4 },
            batch_sizes: vec![32; 3],
            lrs: vec![0.05; 3],
            sample_budget: 0,
            crossbow_rate: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &mut batcher, &plan).unwrap();
        assert!(report.updates().iter().all(|&u| u == 4), "{:?}", report.updates());
        assert_eq!(report.total_samples(), 3 * 4 * 32);
    }

    #[test]
    fn engine_survives_multiple_megabatches() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 3);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let mut batcher = Batcher::new(&ds, &cfg.model, 7);
        let mut replicas = vec![template.clone(); 3];
        for _ in 0..3 {
            let plan = DispatchPlan {
                mode: DispatchMode::Dynamic,
                batch_sizes: vec![16; 3],
                lrs: vec![0.05; 3],
                sample_budget: 96,
                crossbow_rate: None,
            };
            let report = engine.run_mega_batch(&mut replicas, &mut batcher, &plan).unwrap();
            assert_eq!(report.total_samples(), 96);
        }
    }

    #[test]
    fn crossbow_rate_contracts_replica_spread() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 4);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let mut batcher = Batcher::new(&ds, &cfg.model, 8);

        let run = |engine: &mut ThreadedEngine, batcher: &mut Batcher<'_>, rate| {
            let mut replicas = vec![template.clone(); 3];
            let plan = DispatchPlan {
                mode: DispatchMode::StaticQuota { batches_per_device: 12 },
                batch_sizes: vec![16; 3],
                lrs: vec![0.3; 3],
                sample_budget: 0,
                crossbow_rate: rate,
            };
            engine.run_mega_batch(&mut replicas, batcher, &plan).unwrap();
            let spread = replicas[0]
                .max_abs_diff(&replicas[1])
                .max(replicas[1].max_abs_diff(&replicas[2]));
            spread
        };
        // Thread interleaving varies the correction order, so average a few
        // repetitions of each variant before comparing.
        let free: f32 = (0..3).map(|_| run(&mut engine, &mut batcher, None)).sum();
        let corrected: f32 = (0..3).map(|_| run(&mut engine, &mut batcher, Some(0.9))).sum();
        assert!(corrected < free, "crossbow correction should contract spread: {corrected} vs {free}");
    }
}

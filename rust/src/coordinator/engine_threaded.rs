//! Threaded real-time engine: one GPU-manager thread per simulated device.
//!
//! Mirrors the HeteroGPU architecture (paper §4, Fig. 5): stand-alone
//! asynchronous managers communicating with a central dynamic scheduler via
//! event messages. Each manager thread owns its device's model replica and
//! its *own* PJRT client (the `xla` crate client is `Rc`-based and the
//! paper's managers own their GPU context anyway); the scheduler pulls
//! batches from the [`DataPlane`] (prefetched by its producer threads) and
//! routes them dynamically on completion events, recycling each consumed
//! batch's buffers back through the plane's pool.
//!
//! **Elastic membership:** the engine is constructed with the full device
//! roster but spawns no threads up front. A worker is spawned the first
//! time its device joins the active pool (hot-add); when a device leaves
//! the pool its worker simply receives no work and parks on its command
//! channel until the device re-joins — park/unpark instead of a fixed
//! spawn-per-run fleet.
//!
//! Heterogeneity is injected by stretching each measured step to what the
//! simulated device would have taken (`SimDevice::stretch`) and sleeping
//! the difference.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::data::pipeline::DataPlane;
use crate::data::PaddedBatch;
use crate::model::reference::StepScratch;
use crate::model::ModelState;
use crate::obs::{ArgVal, ObsHandle, Subsystem};
use crate::runtime::SimDevice;
use crate::slide::SparseStepper;
use crate::Result;

use super::backend::StepBackend;
use super::plan::{DevStats, DispatchMode, DispatchPlan, ExecutionEngine, MegaBatchReport};

/// Creates a device's backend *inside* its worker thread.
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn StepBackend>> + Send + Sync>;

enum Cmd {
    Step { batch: PaddedBatch, lr: f32, crossbow_rate: Option<f64>, ratio: f64 },
    SetReplica(Box<ModelState>),
    TakeReplica,
    Shutdown,
}

enum Reply {
    Ready { dev: usize },
    /// The consumed batch rides back with the completion event so the
    /// scheduler can recycle its buffers through the data plane.
    StepDone { dev: usize, loss: f32, busy: f64, active: usize, batch: PaddedBatch },
    Replica { dev: usize, model: Box<ModelState> },
    Fatal { dev: usize, error: String },
}

struct Worker {
    cmd: mpsc::Sender<Cmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Shared state for CROSSBOW-style corrections: the running *sum* of the
/// active replicas (avg = sum / active count), incrementally maintained by
/// the workers. The active count changes with pool membership.
struct CrossbowShared {
    sum: Mutex<ModelState>,
    devices: AtomicUsize,
}

pub struct ThreadedEngine {
    factory: BackendFactory,
    roster: Vec<SimDevice>,
    /// Lazily-spawned workers, indexed by device id (None = never joined).
    workers: Vec<Option<Worker>>,
    reply_tx: mpsc::Sender<Reply>,
    replies: mpsc::Receiver<Reply>,
    crossbow: Arc<CrossbowShared>,
    template: ModelState,
    /// `[slide]` section the workers build their sparse steppers from.
    slide: crate::config::SlideConfig,
    /// Trace sink for per-device step spans. Workers stay obs-free: the
    /// coordinator stamps each span on the wall clock when the completion
    /// event arrives (`ts = now - busy`), so no handle crosses a thread.
    obs: ObsHandle,
}

impl ThreadedEngine {
    /// Create the engine over the device roster. No threads start here;
    /// each worker is spawned (and its backend constructed) the first time
    /// its device joins the active pool.
    pub fn spawn(
        factory: BackendFactory,
        devices: Vec<SimDevice>,
        template: &ModelState,
    ) -> Result<ThreadedEngine> {
        Self::spawn_with_slide(factory, devices, template, crate::config::SlideConfig::default())
    }

    /// [`spawn`](ThreadedEngine::spawn) with an explicit `[slide]` section
    /// — plans carrying sparsity ratios step through LSH active-class
    /// kernels built from it.
    pub fn spawn_with_slide(
        factory: BackendFactory,
        devices: Vec<SimDevice>,
        template: &ModelState,
        slide: crate::config::SlideConfig,
    ) -> Result<ThreadedEngine> {
        assert!(!devices.is_empty());
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let crossbow = Arc::new(CrossbowShared {
            sum: Mutex::new(ModelState::zeros(&template.dims)),
            devices: AtomicUsize::new(devices.len()),
        });
        let workers = devices.iter().map(|_| None).collect();
        Ok(ThreadedEngine {
            factory,
            roster: devices,
            workers,
            reply_tx,
            replies: reply_rx,
            crossbow,
            template: template.clone(),
            slide,
            obs: ObsHandle::disabled(),
        })
    }

    /// Roster size (spawned or not).
    pub fn devices(&self) -> usize {
        self.roster.len()
    }

    /// Number of workers actually spawned so far (telemetry / tests).
    pub fn spawned_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.is_some()).count()
    }

    /// Spawn workers for any active device that doesn't have one yet, then
    /// block until every fresh worker reports Ready (so backend construction
    /// errors surface at the join boundary, not mid-mega-batch).
    fn ensure_workers(&mut self, active: &[usize]) -> Result<()> {
        let mut pending = Vec::new();
        for &dev in active {
            anyhow::ensure!(dev < self.roster.len(), "device {dev} outside the roster");
            if self.workers[dev].is_some() {
                continue;
            }
            let device = self.roster[dev].clone();
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let replies = self.reply_tx.clone();
            let factory = self.factory.clone();
            let shared = self.crossbow.clone();
            let template = self.template.clone();
            let slide = self.slide.clone();
            let handle = std::thread::Builder::new()
                .name(format!("gpu-manager-{dev}"))
                .spawn(move || {
                    worker_main(dev, device, factory, cmd_rx, replies, shared, template, slide)
                })
                .expect("spawning worker thread");
            self.workers[dev] = Some(Worker { cmd: cmd_tx, handle: Some(handle) });
            pending.push(dev);
        }
        let mut ready = vec![false; pending.len()];
        while ready.iter().any(|r| !r) {
            match self.replies.recv().map_err(|_| anyhow!("worker channel closed during startup"))? {
                Reply::Ready { dev } => {
                    let i = pending
                        .iter()
                        .position(|&p| p == dev)
                        .ok_or_else(|| anyhow!("unexpected ready from device {dev}"))?;
                    ready[i] = true;
                }
                Reply::Fatal { dev, error } => bail!("device {dev} failed to start: {error}"),
                _ => bail!("unexpected reply during startup"),
            }
        }
        Ok(())
    }

    fn worker(&self, dev: usize) -> &Worker {
        self.workers[dev].as_ref().expect("worker not spawned")
    }

    fn try_dispatch(
        &self,
        slot: usize,
        plan: &DispatchPlan,
        plane: &DataPlane,
        remaining: &mut usize,
        quota: &mut [usize],
    ) -> Result<bool> {
        let dev = plan.device_ids[slot];
        match plan.mode {
            DispatchMode::Dynamic => {
                if *remaining == 0 {
                    return Ok(false);
                }
                let bucket = plan.batch_sizes[slot];
                let valid = bucket.min(*remaining);
                *remaining -= valid;
                let batch = plane.next_batch_for(slot, bucket, valid);
                let cmd = Cmd::Step {
                    batch,
                    lr: plan.lrs[slot],
                    crossbow_rate: plan.crossbow_rate,
                    ratio: plan.sparsity_ratio(slot),
                };
                self.worker(dev).cmd.send(cmd).map_err(|_| anyhow!("worker died"))?;
                Ok(true)
            }
            DispatchMode::StaticQuota { .. } => {
                if quota[slot] == 0 {
                    return Ok(false);
                }
                quota[slot] -= 1;
                let bucket = plan.batch_sizes[slot];
                let batch = plane.next_batch_for(slot, bucket, bucket);
                let cmd = Cmd::Step {
                    batch,
                    lr: plan.lrs[slot],
                    crossbow_rate: plan.crossbow_rate,
                    ratio: plan.sparsity_ratio(slot),
                };
                self.worker(dev).cmd.send(cmd).map_err(|_| anyhow!("worker died"))?;
                Ok(true)
            }
        }
    }
}

impl ExecutionEngine for ThreadedEngine {
    /// Run one mega-batch over the plan's active devices; workers for
    /// devices outside the pool stay parked on their channels. Batches are
    /// pulled from the data plane's per-slot prefetch queues (filled by
    /// its producer threads when configured) and recycled on completion.
    fn run_mega_batch(
        &mut self,
        replicas: &mut [ModelState],
        plane: &DataPlane,
        plan: &DispatchPlan,
    ) -> Result<MegaBatchReport> {
        let roster = self.roster.len();
        let g = plan.devices();
        assert_eq!(replicas.len(), roster);
        assert_eq!(plan.batch_sizes.len(), g);
        assert!(g > 0, "plan has no active devices");

        self.ensure_workers(&plan.device_ids)?;
        plane.begin_window(&plan.batch_sizes);

        // Map global device id -> active slot for reply routing.
        let mut slot_of = vec![usize::MAX; roster];
        for (slot, &dev) in plan.device_ids.iter().enumerate() {
            slot_of[dev] = slot;
        }

        // Install replicas (and the crossbow sum) for this mega-batch.
        if plan.crossbow_rate.is_some() {
            self.crossbow.devices.store(g, Ordering::Relaxed);
            let mut sum = self.crossbow.sum.lock().unwrap();
            *sum = ModelState::zeros(&self.template.dims);
            let refs: Vec<&ModelState> = plan.device_ids.iter().map(|&d| &replicas[d]).collect();
            let ones = vec![1.0; g];
            sum.set_weighted_sum(&refs, &ones);
        }
        for &dev in &plan.device_ids {
            self.worker(dev)
                .cmd
                .send(Cmd::SetReplica(Box::new(replicas[dev].clone())))
                .map_err(|_| anyhow!("worker died"))?;
        }

        let mut stats = vec![DevStats::default(); roster];
        let mut batch_nnz = Vec::new();
        let t0 = Instant::now();
        // Wall-clock scoped span covering the whole dispatch window.
        let window_span = self.obs.begin(Subsystem::Engine, "engine.megabatch.wall", 0);

        // Per-slot outstanding work accounting.
        let mut inflight = 0usize;
        let mut remaining = match plan.mode {
            DispatchMode::Dynamic => plan.sample_budget,
            DispatchMode::StaticQuota { .. } => 0,
        };
        let mut quota = match plan.mode {
            DispatchMode::Dynamic => vec![usize::MAX; g],
            DispatchMode::StaticQuota { batches_per_device } => vec![batches_per_device; g],
        };

        // Prime every active device with one batch.
        for slot in 0..g {
            if self.try_dispatch(slot, plan, plane, &mut remaining, &mut quota)? {
                inflight += 1;
            }
        }

        while inflight > 0 {
            match self.replies.recv().map_err(|_| anyhow!("worker channel closed"))? {
                Reply::StepDone { dev, loss, busy, active, batch } => {
                    let slot = slot_of[dev];
                    anyhow::ensure!(slot != usize::MAX, "step reply from inactive device {dev}");
                    let s = &mut stats[dev];
                    s.updates += 1;
                    s.samples += batch.valid as u64;
                    s.loss_sum += loss as f64;
                    s.nnz += batch.nnz as u64;
                    s.active_classes += active as u64;
                    s.busy += busy;
                    if self.obs.enabled() {
                        // Wall-clock stamp reconstructed from the completion
                        // event: the step ended now and ran for `busy`.
                        self.obs.span(
                            Subsystem::Engine,
                            "engine.step",
                            1 + dev as u32,
                            self.obs.now() - busy,
                            busy,
                            vec![
                                ("batch", ArgVal::U(batch.valid as u64)),
                                ("nnz", ArgVal::U(batch.nnz as u64)),
                            ],
                        );
                    }
                    batch_nnz.push(batch.nnz as u64);
                    plane.recycle(batch);
                    if self.try_dispatch(slot, plan, plane, &mut remaining, &mut quota)? {
                        // still inflight
                    } else {
                        inflight -= 1;
                    }
                }
                Reply::Fatal { dev, error } => bail!("device {dev} failed: {error}"),
                _ => bail!("unexpected reply during mega-batch"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        if let Some(g) = window_span {
            self.obs.end(g, vec![("devices", ArgVal::U(plan.devices() as u64))]);
        }

        // Barrier: pull the active replicas back.
        for &dev in &plan.device_ids {
            self.worker(dev).cmd.send(Cmd::TakeReplica).map_err(|_| anyhow!("worker died"))?;
        }
        let mut got = 0usize;
        while got < g {
            match self.replies.recv().map_err(|_| anyhow!("worker channel closed"))? {
                Reply::Replica { dev, model } => {
                    replicas[dev] = *model;
                    got += 1;
                }
                Reply::Fatal { dev, error } => bail!("device {dev} failed: {error}"),
                _ => bail!("unexpected reply at barrier"),
            }
        }

        Ok(MegaBatchReport { per_device: stats, wall, batch_nnz })
    }

    fn roster_len(&self) -> usize {
        self.roster.len()
    }

    fn set_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    fn name(&self) -> &'static str {
        "threaded"
    }
}

impl Drop for ThreadedEngine {
    fn drop(&mut self) {
        for w in self.workers.iter().flatten() {
            let _ = w.cmd.send(Cmd::Shutdown);
        }
        for w in self.workers.iter_mut().flatten() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    dev: usize,
    mut device: SimDevice,
    factory: BackendFactory,
    cmd: mpsc::Receiver<Cmd>,
    replies: mpsc::Sender<Reply>,
    shared: Arc<CrossbowShared>,
    template: ModelState,
    slide: crate::config::SlideConfig,
) {
    let backend = match factory(dev) {
        Ok(b) => {
            let _ = replies.send(Reply::Ready { dev });
            b
        }
        Err(e) => {
            let _ = replies.send(Reply::Fatal { dev, error: format!("{e:#}") });
            return;
        }
    };
    let mut replica = template;
    // Last version of this replica folded into the shared crossbow sum.
    let mut published: Option<Box<ModelState>> = None;
    // Pooled step buffers + a lazily-built LSH stepper (sparse plans only).
    let mut scratch = StepScratch::new();
    let mut stepper: Option<SparseStepper> = None;
    loop {
        // A worker whose device is out of the pool parks right here — the
        // blocking recv *is* the park; re-admission unparks it with the next
        // SetReplica.
        match cmd.recv() {
            Err(_) | Ok(Cmd::Shutdown) => return,
            Ok(Cmd::SetReplica(m)) => {
                replica = *m;
                published = Some(Box::new(replica.clone()));
            }
            Ok(Cmd::TakeReplica) => {
                if replies.send(Reply::Replica { dev, model: Box::new(replica.clone()) }).is_err() {
                    return;
                }
            }
            Ok(Cmd::Step { batch, lr, crossbow_rate, ratio }) => {
                let t0 = Instant::now();
                let outcome = if ratio >= 1.0 {
                    backend
                        .step_scratch(&mut replica, &batch, lr, &mut scratch)
                        .map(|(loss, _)| (loss, replica.dims.classes))
                } else {
                    let st =
                        stepper.get_or_insert_with(|| SparseStepper::new(&slide, dev as u64));
                    st.set_ratio(ratio);
                    Ok(st.step(&mut replica, &batch, lr, &mut scratch))
                };
                match outcome {
                    Ok((loss, active)) => {
                        let real = t0.elapsed().as_secs_f64();
                        let target = device.stretch(real);
                        if target > real {
                            std::thread::sleep(Duration::from_secs_f64(target - real));
                        }
                        if let Some(rate) = crossbow_rate {
                            if let Some(pub_state) = published.as_mut() {
                                crossbow_correct(&shared, &mut replica, pub_state, rate);
                            }
                        }
                        // The batch rides back so the scheduler can recycle
                        // its buffers through the data plane's pool.
                        let reply =
                            Reply::StepDone { dev, loss, busy: target.max(real), active, batch };
                        if replies.send(reply).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = replies.send(Reply::Fatal { dev, error: format!("{e:#}") });
                        return;
                    }
                }
            }
        }
    }
}

/// CROSSBOW replica correction under the shared-sum lock.
///
/// Invariant: `shared.sum` always equals the sum of every active worker's
/// last *published* replica. This worker computes the fleet average from
/// the sum (its own stale contribution included, exactly like CROSSBOW's
/// central average model), pulls its post-step replica toward it, then
/// swaps its published contribution for the corrected one — keeping the
/// invariant. The divisor tracks the pool's current active count.
fn crossbow_correct(
    shared: &Arc<CrossbowShared>,
    replica: &mut ModelState,
    published: &mut ModelState,
    rate: f64,
) {
    let g = shared.devices.load(Ordering::Relaxed).max(1) as f32;
    let r = rate as f32;
    let mut sum = shared.sum.lock().unwrap();
    for seg in 0..4 {
        let len = replica.segments()[seg].len();
        for p in 0..len {
            let (sum_seg, rep_seg, pub_seg) = match seg {
                0 => (&mut sum.w1, &mut replica.w1, &mut published.w1),
                1 => (&mut sum.b1, &mut replica.b1, &mut published.b1),
                2 => (&mut sum.w2, &mut replica.w2, &mut published.w2),
                _ => (&mut sum.b2, &mut replica.b2, &mut published.b2),
            };
            debug_assert_eq!(sum_seg.len(), len);
            let new = rep_seg[p];
            let avg = sum_seg[p] / g;
            let corrected = new + r * (avg - new);
            sum_seg[p] += corrected - pub_seg[p];
            pub_seg[p] = corrected;
            rep_seg[p] = corrected;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        CompositionPolicy, Config, DataConfig, DeviceConfig, ModelDims, PipelineConfig,
    };
    use crate::coordinator::backend::RefBackend;
    use crate::data::pipeline::ShardedDataset;
    use crate::data::synthetic::Generator;

    fn setup() -> (Config, Arc<ShardedDataset>) {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 128, hidden: 8, classes: 32, max_nnz: 8, max_labels: 4 };
        cfg.devices = DeviceConfig { count: 3, speed_factors: vec![1.0, 1.2, 1.4], ..Default::default() };
        let data_cfg = DataConfig { train_samples: 400, avg_nnz: 5.0, ..Default::default() };
        let ds = Generator::new(&cfg.model, &data_cfg).generate(400, 1);
        (cfg, Arc::new(ShardedDataset::from_dataset(&ds, 128)))
    }

    /// Async plane with two producers — the production shape for this
    /// engine.
    fn async_plane(cfg: &Config, data: &Arc<ShardedDataset>, seed: u64) -> DataPlane {
        let pcfg = PipelineConfig {
            queue_depth: 2,
            producer_threads: 2,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 128,
        };
        DataPlane::new(data.clone(), &cfg.model, &pcfg, pcfg.producer_threads, seed)
    }

    fn ref_factory() -> BackendFactory {
        Arc::new(|_dev| Ok(Box::new(RefBackend) as Box<dyn StepBackend>))
    }

    fn all_active(g: usize) -> Vec<usize> {
        (0..g).collect()
    }

    #[test]
    fn dynamic_megabatch_conserves_budget() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 1);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let plane = async_plane(&cfg, &ds, 5);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: all_active(3),
            batch_sizes: vec![16, 16, 16],
            lrs: vec![0.05; 3],
            sample_budget: 250,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(report.total_samples(), 250);
        assert!(report.wall > 0.0);
        assert_eq!(report.batch_nnz.len() as u64, report.total_updates());
        // Replicas actually trained (diverged from the template).
        assert!(replicas[0].max_abs_diff(&template) > 0.0);
    }

    #[test]
    fn static_quota_equal_updates() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 2);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let plane = async_plane(&cfg, &ds, 6);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::StaticQuota { batches_per_device: 4 },
            device_ids: all_active(3),
            batch_sizes: vec![32; 3],
            lrs: vec![0.05; 3],
            sample_budget: 0,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert!(report.updates().iter().all(|&u| u == 4), "{:?}", report.updates());
        assert_eq!(report.total_samples(), 3 * 4 * 32);
    }

    #[test]
    fn workers_spawn_lazily_on_pool_join() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 3);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        assert_eq!(engine.spawned_workers(), 0, "no threads before the first mega-batch");
        let plane = async_plane(&cfg, &ds, 9);
        let mut replicas = vec![template.clone(); 3];

        // First mega-batch on a 2-device subset: only those workers spawn.
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: vec![0, 1],
            batch_sizes: vec![16; 2],
            lrs: vec![0.05; 2],
            sample_budget: 96,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(engine.spawned_workers(), 2);
        assert_eq!(replicas[2].max_abs_diff(&template), 0.0, "inactive replica untouched");

        // Device 2 joins (hot-add): its worker spawns now; device 0 parks.
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: vec![1, 2],
            batch_sizes: vec![16; 2],
            lrs: vec![0.05; 2],
            sample_budget: 96,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(engine.spawned_workers(), 3);
        assert_eq!(report.per_device[0].updates, 0, "parked device does no work");
        assert!(report.per_device[2].updates > 0);
    }

    #[test]
    fn engine_survives_multiple_megabatches() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 3);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let plane = async_plane(&cfg, &ds, 7);
        let mut replicas = vec![template.clone(); 3];
        for _ in 0..3 {
            let plan = DispatchPlan {
                mode: DispatchMode::Dynamic,
                device_ids: all_active(3),
                batch_sizes: vec![16; 3],
                lrs: vec![0.05; 3],
                sample_budget: 96,
                crossbow_rate: None,
                nnz_estimate: 5.0,
                predicted_step_secs: None,
                sparsity_ratios: None,
            };
            let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
            assert_eq!(report.total_samples(), 96);
        }
        // Every consumed batch came through the plane (prefetched or
        // synchronous fallback), and recycled buffers got reused.
        let s = plane.stats();
        assert_eq!(s.prefetched + s.synchronous, 18, "{s:?}"); // 3 mega-batches x 96/16
        assert!(s.pool.hits > 0, "recycled buffers must be reused: {s:?}");
    }

    #[test]
    fn sparse_plan_runs_and_reports_truncated_class_sets() {
        let (cfg, ds) = setup(); // classes = 32
        let template = ModelState::init(&cfg.model, 5);
        let mut engine = ThreadedEngine::spawn_with_slide(
            ref_factory(),
            SimDevice::fleet(&cfg.devices),
            &template,
            cfg.slide.clone(),
        )
        .unwrap();
        let plane = async_plane(&cfg, &ds, 11);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: all_active(3),
            batch_sizes: vec![16; 3],
            lrs: vec![0.05; 3],
            sample_budget: 240,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: Some(vec![0.25; 3]),
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        assert_eq!(report.total_samples(), 240);
        let classes = cfg.model.classes as u64;
        for d in report.per_device.iter().filter(|d| d.updates > 0) {
            assert!(d.active_classes > 0);
            assert!(d.active_classes < d.updates * classes, "workers must run the sparse kernel");
        }
        // Sparse steps still move the replicas.
        assert!(replicas[0].max_abs_diff(&template) > 0.0);
    }

    #[test]
    fn threaded_steps_emit_wall_clock_spans() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 1);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let obs = ObsHandle::from_config(
            &crate::config::ObsConfig { enabled: true, ..Default::default() },
            false,
        );
        engine.set_obs(obs.clone());
        let plane = async_plane(&cfg, &ds, 5);
        let mut replicas = vec![template.clone(); 3];
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: all_active(3),
            batch_sizes: vec![16, 16, 16],
            lrs: vec![0.05; 3],
            sample_budget: 160,
            crossbow_rate: None,
            nnz_estimate: 5.0,
            predicted_step_secs: None,
            sparsity_ratios: None,
        };
        let report = engine.run_mega_batch(&mut replicas, &plane, &plan).unwrap();
        let evs = obs.sink().events();
        let steps = evs.iter().filter(|e| e.name == "engine.step").count();
        assert_eq!(steps as u64, report.total_updates(), "one span per completed step");
        assert!(
            evs.iter().any(|e| e.name == "engine.megabatch.wall" && e.tid == 0),
            "window guard span on the coordinator lane"
        );
        assert!(evs.iter().all(|e| e.ts >= 0.0 && e.dur >= 0.0));
        let (opened, closed) = obs.sink().balance();
        assert_eq!(opened, closed, "guard spans all closed");
    }

    #[test]
    fn crossbow_rate_contracts_replica_spread() {
        let (cfg, ds) = setup();
        let template = ModelState::init(&cfg.model, 4);
        let mut engine =
            ThreadedEngine::spawn(ref_factory(), SimDevice::fleet(&cfg.devices), &template).unwrap();
        let plane = async_plane(&cfg, &ds, 8);

        let run = |engine: &mut ThreadedEngine, plane: &DataPlane, rate| {
            let mut replicas = vec![template.clone(); 3];
            let plan = DispatchPlan {
                mode: DispatchMode::StaticQuota { batches_per_device: 12 },
                device_ids: all_active(3),
                batch_sizes: vec![16; 3],
                lrs: vec![0.3; 3],
                sample_budget: 0,
                crossbow_rate: rate,
                nnz_estimate: 5.0,
                predicted_step_secs: None,
                sparsity_ratios: None,
            };
            engine.run_mega_batch(&mut replicas, plane, &plan).unwrap();
            let spread = replicas[0]
                .max_abs_diff(&replicas[1])
                .max(replicas[1].max_abs_diff(&replicas[2]));
            spread
        };
        // Thread interleaving varies the correction order, so average a few
        // repetitions of each variant before comparing.
        let free: f32 = (0..3).map(|_| run(&mut engine, &plane, None)).sum();
        let corrected: f32 = (0..3).map(|_| run(&mut engine, &plane, Some(0.9))).sum();
        assert!(corrected < free, "crossbow correction should contract spread: {corrected} vs {free}");
    }
}

//! The earliest-virtual-free-time dispatch rule, in one place.
//!
//! Training's dynamic scheduler ([`crate::coordinator::engine_sim`]) and
//! the serving router ([`crate::serve::router`]) route the next unit of
//! work with the same rule: among the eligible devices, pick the one whose
//! effective free time `max(free_time, now)` is earliest, breaking ties
//! toward the lower index. Both call sites used to carry their own copy;
//! this helper is the shared implementation, so a change to the rule (or a
//! bug in it) cannot fork the two planes' behavior.
//!
//! When the calibration plane ([`crate::tuning`]) is on, both planes
//! upgrade to [`next_completion_device`]: the same rule keyed on predicted
//! *completion* time (`free + estimated cost on that device`) instead of
//! free time alone. With homogeneous work the two rules agree; with
//! per-device batch sizes or drifted speeds, completion-keyed dispatch
//! stops handing work to a device that frees first but finishes last.

/// Index of the eligible slot with the earliest effective free time
/// (`max(free_time[i], now)`), ties toward the lower index. `None` when no
/// slot is eligible.
pub fn next_free_device(
    free_time: &[f64],
    now: f64,
    eligible: impl Fn(usize) -> bool,
) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..free_time.len() {
        if !eligible(i) {
            continue;
        }
        let key = free_time[i].max(now);
        match best {
            Some(b) if free_time[b].max(now) <= key => {}
            _ => best = Some(i),
        }
    }
    best
}

/// Index of the eligible slot with the earliest *predicted completion*
/// (`max(free_time[i], now) + step_secs[i]`), ties toward the lower
/// index. `step_secs` is the calibrated per-slot cost of the next unit of
/// work (parallel to `free_time`). `None` when no slot is eligible.
pub fn next_completion_device(
    free_time: &[f64],
    now: f64,
    step_secs: &[f64],
    eligible: impl Fn(usize) -> bool,
) -> Option<usize> {
    assert_eq!(free_time.len(), step_secs.len(), "step_secs must parallel free_time");
    let mut best: Option<(usize, f64)> = None;
    for i in 0..free_time.len() {
        if !eligible(i) {
            continue;
        }
        let key = free_time[i].max(now) + step_secs[i];
        match best {
            Some((_, b)) if b <= key => {}
            _ => best = Some((i, key)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_earliest_free_slot() {
        let ft = [3.0, 1.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |_| true), Some(1));
    }

    #[test]
    fn ties_break_toward_the_lower_index() {
        let ft = [2.0, 2.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |_| true), Some(0));
        // `now` past every free time makes all keys equal: still the lowest.
        let ft = [0.5, 0.1, 0.3];
        assert_eq!(next_free_device(&ft, 9.0, |_| true), Some(0));
    }

    #[test]
    fn eligibility_filters_and_empty_is_none() {
        let ft = [3.0, 1.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |i| i != 1), Some(2));
        assert_eq!(next_free_device(&ft, 0.0, |_| false), None);
        assert_eq!(next_free_device(&[], 0.0, |_| true), None);
    }

    #[test]
    fn completion_rule_accounts_for_per_device_cost() {
        // Device 0 frees first but is slow on the next unit; device 1
        // finishes it sooner overall. Earliest-free would pick 0.
        let ft = [1.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |_| true), Some(0));
        assert_eq!(next_completion_device(&ft, 0.0, &[5.0, 1.0], |_| true), Some(1));
        // Uniform costs reduce to the earliest-free rule (ties included).
        assert_eq!(next_completion_device(&ft, 0.0, &[2.0, 2.0], |_| true), Some(0));
        let ties = [3.0, 3.0];
        assert_eq!(next_completion_device(&ties, 0.0, &[1.0, 1.0], |_| true), Some(0));
        // `now` floors idle devices, same as the free-time rule.
        assert_eq!(next_completion_device(&[0.1, 9.0], 5.0, &[1.0, 1.0], |_| true), Some(0));
        // Eligibility filters; empty is None.
        assert_eq!(next_completion_device(&ft, 0.0, &[5.0, 1.0], |i| i != 1), Some(0));
        assert_eq!(next_completion_device(&[], 0.0, &[], |_| true), None);
    }

    #[test]
    fn now_floors_idle_devices_to_a_common_key() {
        // Device 2 idle since 0.2; device 0 busy until 1.0. At now=0.5 the
        // idle device wins even though another idle device has a *lower*
        // stale free time — keys are floored at now, so ties go by index.
        let ft = [1.0, 0.2, 0.4];
        assert_eq!(next_free_device(&ft, 0.5, |_| true), Some(1));
    }
}

//! The earliest-virtual-free-time dispatch rule, in one place.
//!
//! Training's dynamic scheduler ([`crate::coordinator::engine_sim`]) and
//! the serving router ([`crate::serve::router`]) route the next unit of
//! work with the same rule: among the eligible devices, pick the one whose
//! effective free time `max(free_time, now)` is earliest, breaking ties
//! toward the lower index. Both call sites used to carry their own copy;
//! this helper is the shared implementation, so a change to the rule (or a
//! bug in it) cannot fork the two planes' behavior.

/// Index of the eligible slot with the earliest effective free time
/// (`max(free_time[i], now)`), ties toward the lower index. `None` when no
/// slot is eligible.
pub fn next_free_device(
    free_time: &[f64],
    now: f64,
    eligible: impl Fn(usize) -> bool,
) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..free_time.len() {
        if !eligible(i) {
            continue;
        }
        let key = free_time[i].max(now);
        match best {
            Some(b) if free_time[b].max(now) <= key => {}
            _ => best = Some(i),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_earliest_free_slot() {
        let ft = [3.0, 1.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |_| true), Some(1));
    }

    #[test]
    fn ties_break_toward_the_lower_index() {
        let ft = [2.0, 2.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |_| true), Some(0));
        // `now` past every free time makes all keys equal: still the lowest.
        let ft = [0.5, 0.1, 0.3];
        assert_eq!(next_free_device(&ft, 9.0, |_| true), Some(0));
    }

    #[test]
    fn eligibility_filters_and_empty_is_none() {
        let ft = [3.0, 1.0, 2.0];
        assert_eq!(next_free_device(&ft, 0.0, |i| i != 1), Some(2));
        assert_eq!(next_free_device(&ft, 0.0, |_| false), None);
        assert_eq!(next_free_device(&[], 0.0, |_| true), None);
    }

    #[test]
    fn now_floors_idle_devices_to_a_common_key() {
        // Device 2 idle since 0.2; device 0 busy until 1.0. At now=0.5 the
        // idle device wins even though another idle device has a *lower*
        // stale free time — keys are floored at now, so ties go by index.
        let ft = [1.0, 0.2, 0.4];
        assert_eq!(next_free_device(&ft, 0.5, |_| true), Some(1));
    }
}

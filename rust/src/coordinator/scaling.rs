//! **Algorithm 1 — Batch Size Scaling** (paper §3.2).
//!
//! Goal: steady state in which every device performs the same number of
//! model updates per mega-batch. After each merge, a device whose update
//! count `u_i` exceeded the fleet average `μ̃` gets its batch enlarged by
//! `β · (u_i − μ̃)` (and its learning rate linearly rescaled); a device that
//! fell behind gets it shrunk — both only while the result stays inside
//! `[b_min, b_max]`.
//!
//! One deviation from the paper's pseudo-code, forced by AOT static shapes:
//! batch sizes are quantized to the grid `{b_min, b_min+β, …, b_max}`
//! (DESIGN.md §3). The proposed size is computed exactly as in the paper and
//! then rounded to the nearest grid point; since `β` is the grid pitch this
//! changes a proposal by at most `β/2`.

use crate::config::SgdConfig;

/// Outcome of one scaling pass (Fig. 12a trace material).
#[derive(Clone, Debug, PartialEq)]
pub struct ScalingOutcome {
    /// Whether any device's batch size changed.
    pub changed: bool,
    /// Average update count used as the target.
    pub mean_updates: f64,
}

/// Round `b` to the nearest point of the grid {b_min + k·β} within bounds.
pub fn round_to_grid(b: f64, cfg: &SgdConfig) -> usize {
    let beta = cfg.beta as f64;
    let k = ((b - cfg.b_min as f64) / beta).round().max(0.0);
    let snapped = cfg.b_min + (k as usize) * cfg.beta;
    snapped.min(cfg.b_max)
}

/// Algorithm 1. `batch_sizes`, `lrs` and `updates` are indexed by device.
pub fn rescale(
    batch_sizes: &mut [usize],
    lrs: &mut [f32],
    updates: &[u64],
    cfg: &SgdConfig,
) -> ScalingOutcome {
    assert_eq!(batch_sizes.len(), lrs.len());
    assert_eq!(batch_sizes.len(), updates.len());
    assert!(!batch_sizes.is_empty());

    // Line 1: average number of model updates per device.
    let mean = updates.iter().sum::<u64>() as f64 / updates.len() as f64;
    let mut changed = false;

    for i in 0..batch_sizes.len() {
        let u = updates[i] as f64;
        let b = batch_sizes[i] as f64;
        let beta = cfg.beta as f64;
        let proposal = if u > mean {
            // Lines 3–5: faster device → larger batches (and larger lr).
            let p = b + beta * (u - mean);
            if p > cfg.b_max as f64 {
                continue;
            }
            p
        } else if u < mean {
            // Lines 6–8: slower device → smaller batches.
            let p = b - beta * (mean - u);
            if p < cfg.b_min as f64 {
                continue;
            }
            p
        } else {
            continue;
        };
        let new_b = round_to_grid(proposal, cfg);
        if new_b != batch_sizes[i] {
            // Linear-scaling rule: lr follows the batch size ratio.
            lrs[i] *= new_b as f32 / batch_sizes[i] as f32;
            batch_sizes[i] = new_b;
            changed = true;
        }
    }
    ScalingOutcome { changed, mean_updates: mean }
}

/// Calibration-plane re-targeting: batch sizes that equalize *predicted*
/// per-batch step time across devices, given estimated per-device speed
/// multipliers ([`crate::tuning`]) and the expected nnz per sample.
///
/// Algorithm 1 reaches the same steady state from *measured* update
/// counts, but only at one β-step per device per merge — and its
/// stability controller deliberately pauses scaling once the fleet looks
/// settled, which is exactly when a step drift (thermal throttle, a
/// co-tenant landing) hurts most. This function is the fast path the
/// trainer takes when the drift detector fires: jump every active device
/// straight to the grid size whose predicted step time matches the
/// fastest device at `b_max`, and let Algorithm 1 fine-tune from there.
///
/// `speeds` are effective slowdown multipliers (the `speed_factor`
/// convention), one per device being re-targeted; the result is parallel
/// to it, always on the grid and inside `[b_min, b_max]`.
pub fn calibrated_targets(
    speeds: &[f64],
    nnz_per_sample: f64,
    cost: &crate::runtime::CostModel,
    cfg: &SgdConfig,
) -> Vec<usize> {
    assert!(!speeds.is_empty());
    assert!(speeds.iter().all(|&s| s > 0.0), "speed multipliers must be positive");
    // Per-sample variable cost; per-batch cost is linear in b.
    let per_sample = cost.t_per_nnz * nnz_per_sample + cost.t_per_sample;
    let fastest = speeds.iter().copied().fold(f64::INFINITY, f64::min);
    // Common per-batch time target: the fastest device running b_max.
    let target = fastest * (cost.t_fixed + per_sample * cfg.b_max as f64);
    speeds
        .iter()
        .map(|&s| {
            let b = (target / s - cost.t_fixed) / per_sample;
            round_to_grid(b, cfg)
        })
        .collect()
}

/// Joint batch-size × sparsity re-targeting — the two-knob version of
/// [`calibrated_targets`] the trainer uses when `[slide] adaptive` is on.
///
/// Batch size alone bottoms out: once a drifted device needs `b < b_min`
/// to keep pace, [`calibrated_targets`] clamps it to `b_min` and the
/// device stays a straggler. The sparsity ratio is the second knob —
/// shrinking the active output-class set cuts the per-sample term by
/// [`CostModel::sparsity_factor`](crate::runtime::CostModel::sparsity_factor)
/// without leaving the batch grid. Per device: solve for the batch size
/// that matches the fastest device's `b_max` step time at full sparsity;
/// if that lands on the grid, keep `ratio = 1.0`. Otherwise walk the
/// configured ratio ladder downward and take the first ratio whose
/// equal-time batch size is grid-feasible; a device too slow even at
/// `min_ratio` floors at `(b_min, min_ratio)`.
///
/// Returns `(batch_sizes, ratios)`, both parallel to `speeds`.
pub fn joint_targets(
    speeds: &[f64],
    nnz_per_sample: f64,
    cost: &crate::runtime::CostModel,
    cfg: &SgdConfig,
    slide: &crate::config::SlideConfig,
) -> (Vec<usize>, Vec<f64>) {
    assert!(!speeds.is_empty());
    assert!(speeds.iter().all(|&s| s > 0.0), "speed multipliers must be positive");
    let gather = cost.t_per_nnz * nnz_per_sample;
    let fastest = speeds.iter().copied().fold(f64::INFINITY, f64::min);
    // Common per-batch time target: the fastest device, dense, at b_max.
    let target = fastest * (cost.t_fixed + (gather + cost.t_per_sample) * cfg.b_max as f64);
    let ladder = slide.ratio_ladder();
    let mut batches = Vec::with_capacity(speeds.len());
    let mut ratios = Vec::with_capacity(speeds.len());
    for &s in speeds {
        let mut chosen = (cfg.b_min, *ladder.last().expect("ladder is never empty"));
        for &r in &ladder {
            let per_sample = gather + cost.t_per_sample * cost.sparsity_factor(r);
            let b = (target / s - cost.t_fixed) / per_sample;
            if b >= cfg.b_min as f64 {
                chosen = (round_to_grid(b, cfg), r);
                break;
            }
        }
        batches.push(chosen.0);
        ratios.push(chosen.1);
    }
    (batches, ratios)
}

/// Sparsity-only re-targeting: the batch grid is held fixed (the
/// `batch_scaling = false` ablation) and the ratio ladder alone absorbs
/// heterogeneity. Per device: keep `ratio = 1.0` if its dense step at its
/// *current* batch size already matches the fastest device's dense time,
/// otherwise take the first ladder rung whose predicted step time reaches
/// that target; a device too slow even at `min_ratio` floors there.
///
/// Returns ratios parallel to `speeds`/`batch_sizes`.
pub fn sparsity_targets(
    speeds: &[f64],
    batch_sizes: &[usize],
    nnz_per_sample: f64,
    cost: &crate::runtime::CostModel,
    slide: &crate::config::SlideConfig,
) -> Vec<f64> {
    assert_eq!(speeds.len(), batch_sizes.len());
    assert!(!speeds.is_empty());
    assert!(speeds.iter().all(|&s| s > 0.0), "speed multipliers must be positive");
    let gather = cost.t_per_nnz * nnz_per_sample;
    // Target: the fastest device, dense, at its own (fixed) batch size.
    let target = speeds
        .iter()
        .zip(batch_sizes)
        .map(|(&s, &b)| s * (cost.t_fixed + (gather + cost.t_per_sample) * b as f64))
        .fold(f64::INFINITY, f64::min);
    let ladder = slide.ratio_ladder();
    speeds
        .iter()
        .zip(batch_sizes)
        .map(|(&s, &b)| {
            let mut chosen = *ladder.last().expect("ladder is never empty");
            for &r in &ladder {
                let per_sample = gather + cost.t_per_sample * cost.sparsity_factor(r);
                if s * (cost.t_fixed + per_sample * b as f64) <= target {
                    chosen = r;
                    break;
                }
            }
            chosen
        })
        .collect()
}

/// Scaling-frequency controller (paper §3.2: "if stability is achieved or
/// the system enters an oscillatory state, the frequency at which scaling
/// is performed can be increased").
///
/// Tracks recent batch-size vectors; [`ScalingState::should_scale`] goes
/// false while the fleet is stable (three identical snapshots) or
/// oscillating (an a,b,a,b flip on any device), then re-arms after a
/// cool-down so the controller keeps responding to genuine drift.
///
/// The history length and cool-down come from `SgdConfig`
/// (`scaling_window` / `scaling_cooldown`) so multi-tenant fleet
/// experiments can tune stability detection per tenant; `Default` keeps
/// the historical 4/3 constants. The window is how much history must
/// accumulate before oscillation is judged — the pattern check itself is
/// fixed at the last four snapshots (and stability at the last three), so
/// a larger window slows the judgment rather than deepening it.
#[derive(Clone, Debug)]
pub struct ScalingState {
    history: Vec<Vec<usize>>,
    cooldown: usize,
    window: usize,
    cooldown_len: usize,
}

impl Default for ScalingState {
    fn default() -> Self {
        let d = SgdConfig::default();
        ScalingState::new(d.scaling_window, d.scaling_cooldown)
    }
}

impl ScalingState {
    /// `window` is the history length (config validation enforces >= 4:
    /// the oscillation pattern needs four snapshots); `cooldown` is how
    /// many merges scaling stays paused after a stability/oscillation hit.
    pub fn new(window: usize, cooldown: usize) -> ScalingState {
        assert!(window >= 4, "scaling window must hold the 4-snapshot oscillation pattern");
        ScalingState { history: Vec::new(), cooldown: 0, window, cooldown_len: cooldown.max(1) }
    }

    /// Controller for the configured SGD hyperparameters.
    pub fn from_config(cfg: &SgdConfig) -> ScalingState {
        ScalingState::new(cfg.scaling_window, cfg.scaling_cooldown)
    }

    pub fn observe(&mut self, sizes: &[usize]) {
        self.history.push(sizes.to_vec());
        if self.history.len() > self.window {
            self.history.remove(0);
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
    }

    /// Last three observed vectors identical.
    pub fn stable(&self) -> bool {
        self.history.len() >= 3 && self.history.iter().rev().take(3).all(|v| v == &self.history[self.history.len() - 1])
    }

    /// Any device flip-flopping a,b,a,b with a != b over the last four
    /// snapshots (only judged once the configured window has filled).
    pub fn oscillating(&self) -> bool {
        if self.history.len() < self.window {
            return false;
        }
        let h = &self.history[self.history.len() - 4..];
        let devices = h[0].len();
        (0..devices).any(|d| h[0][d] == h[2][d] && h[1][d] == h[3][d] && h[0][d] != h[1][d])
    }

    /// Whether Algorithm 1 should run at this merge point.
    pub fn should_scale(&mut self) -> bool {
        if self.cooldown > 0 {
            return false;
        }
        if self.oscillating() || self.stable() {
            self.cooldown = self.cooldown_len;
            return false;
        }
        true
    }
}

// ---- decision-record formatting ------------------------------------------
//
// The trainer's `train.retarget` / `train.scale` instants carry their
// inputs and outputs as stable comma-joined strings, so the analyze
// plane (and a human in Perfetto) can read the decision without the
// RunLog. Fixed formats keep the trace bit-deterministic.

/// `"128,96,72"` — a batch grid as a stable argument string.
pub fn fmt_grid(sizes: &[usize]) -> String {
    sizes.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
}

/// `"1.00,1.82"` — speed multipliers (or sparsity ratios) as a stable
/// argument string.
pub fn fmt_speeds(speeds: &[f64]) -> String {
    speeds.iter().map(|s| format!("{s:.2}")).collect::<Vec<_>>().join(",")
}

/// Human-readable "why" for a drift re-target: per device whose batch
/// size changed, the calibrated slowdown that drove the move. `active`
/// carries the global device ids matching `speeds`/`from`/`to`.
pub fn describe_retarget(
    active: &[usize],
    speeds: &[f64],
    from: &[usize],
    to: &[usize],
) -> String {
    assert_eq!(active.len(), speeds.len());
    assert_eq!(from.len(), to.len());
    assert_eq!(active.len(), from.len());
    let fastest = speeds.iter().copied().fold(f64::INFINITY, f64::min).max(1e-12);
    let moves: Vec<String> = active
        .iter()
        .zip(speeds)
        .zip(from.iter().zip(to))
        .filter(|&((_, _), (f, t))| f != t)
        .map(|((&d, &s), (&f, &t))| {
            format!("device {d}: b {f} -> {t} (calibrated slope {:.2}x nominal)", s / fastest)
        })
        .collect();
    if moves.is_empty() {
        "no grid change (targets already met)".to_string()
    } else {
        moves.join("; ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Gen};
    use crate::util::rng::Rng;

    #[test]
    fn decision_formatting_is_stable() {
        assert_eq!(fmt_grid(&[128, 96, 72]), "128,96,72");
        assert_eq!(fmt_speeds(&[1.0, 1.82]), "1.00,1.82");
        assert_eq!(fmt_grid(&[]), "");
    }

    #[test]
    fn describe_retarget_names_changed_devices_and_slopes() {
        let why = describe_retarget(&[0, 2], &[1.0, 1.8], &[128, 128], &[128, 72]);
        assert_eq!(why, "device 2: b 128 -> 72 (calibrated slope 1.80x nominal)");
        let none = describe_retarget(&[0], &[1.0], &[128], &[128]);
        assert!(none.contains("no grid change"), "{none}");
    }

    fn cfg() -> SgdConfig {
        SgdConfig { b_min: 16, b_max: 128, beta: 8, ..Default::default() }
    }

    #[test]
    fn equal_updates_is_a_fixed_point() {
        let c = cfg();
        let mut b = vec![64, 64, 64, 64];
        let mut lr = vec![0.05f32; 4];
        let out = rescale(&mut b, &mut lr, &[10, 10, 10, 10], &c);
        assert!(!out.changed);
        assert_eq!(b, vec![64, 64, 64, 64]);
        assert_eq!(lr, vec![0.05; 4]);
    }

    #[test]
    fn faster_device_gets_larger_batch_and_lr() {
        let c = cfg();
        let mut b = vec![64, 64];
        let mut lr = vec![0.05f32, 0.05];
        // Device 0 did 12 updates, device 1 did 8 -> mean 10.
        let out = rescale(&mut b, &mut lr, &[12, 8], &c);
        assert!(out.changed);
        // 64 + 8*(12-10) = 80 ; 64 - 8*(10-8) = 48.
        assert_eq!(b, vec![80, 48]);
        assert!((lr[0] - 0.05 * 80.0 / 64.0).abs() < 1e-7);
        assert!((lr[1] - 0.05 * 48.0 / 64.0).abs() < 1e-7);
    }

    #[test]
    fn bounds_freeze_out_of_range_proposals() {
        let c = cfg();
        // Proposal above b_max: unchanged (paper's guard, not clamping).
        let mut b = vec![120, 64];
        let mut lr = vec![0.05f32, 0.05];
        rescale(&mut b, &mut lr, &[20, 0], &c);
        assert_eq!(b[0], 120, "over-max proposal must leave size unchanged");
        // Proposal below b_min: unchanged.
        let mut b = vec![24, 64];
        let mut lr = vec![0.05f32, 0.05];
        rescale(&mut b, &mut lr, &[0, 20], &c);
        assert_eq!(b[0], 24);
    }

    #[test]
    fn fractional_mean_rounds_to_grid() {
        let c = cfg();
        let mut b = vec![64, 64, 64];
        let mut lr = vec![0.05f32; 3];
        // mean = 10.3333…; deviations ±fractional.
        rescale(&mut b, &mut lr, &[11, 10, 10], &c);
        for &bb in &b {
            assert_eq!((bb - c.b_min) % c.beta, 0, "batch {bb} off-grid");
        }
    }

    #[test]
    fn round_to_grid_snaps_and_clamps() {
        let c = cfg();
        assert_eq!(round_to_grid(63.9, &c), 64);
        assert_eq!(round_to_grid(68.0, &c), 72); // 68 is 4 from 64, 4 from 72 -> round half up
        assert_eq!(round_to_grid(10.0, &c), 16);
        assert_eq!(round_to_grid(1000.0, &c), 128);
    }

    /// Property: scaling never leaves the grid or the [b_min, b_max] bounds,
    /// and preserves the lr/batch linear-scaling coupling.
    #[test]
    fn prop_invariants_hold() {
        let c = cfg();
        let gen = prop::VecU64 { min_len: 1, max_len: 9, item_lo: 0, item_hi: 60 };
        prop::check(300, 0xC0FFEE, gen, |updates| {
            let n = updates.len();
            let mut rng = Rng::new(updates.iter().sum::<u64>() ^ n as u64);
            let grid: Vec<usize> = (c.b_min..=c.b_max).step_by(c.beta).collect();
            let mut b: Vec<usize> =
                (0..n).map(|_| grid[rng.range(0, grid.len())]).collect();
            let mut lr: Vec<f32> = b.iter().map(|&bb| 0.05 * bb as f32 / 128.0).collect();
            let before = b.clone();
            rescale(&mut b, &mut lr, updates, &c);
            for (i, &bb) in b.iter().enumerate() {
                if !(c.b_min..=c.b_max).contains(&bb) {
                    return Err(format!("device {i} batch {bb} out of bounds"));
                }
                if (bb - c.b_min) % c.beta != 0 {
                    return Err(format!("device {i} batch {bb} off-grid"));
                }
                let expect_lr = 0.05 * before[i] as f32 / 128.0 * bb as f32 / before[i] as f32;
                if (lr[i] - expect_lr).abs() > 1e-6 {
                    return Err(format!("device {i} lr decoupled: {} vs {expect_lr}", lr[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn calibrated_targets_equalize_predicted_batch_time() {
        let c = cfg(); // grid 16..128 step 8
        let cost = crate::runtime::CostModel::default();
        // Homogeneous fleet: everyone runs b_max.
        assert_eq!(calibrated_targets(&[1.0, 1.0, 1.0], 12.0, &cost, &c), vec![128, 128, 128]);
        // Heterogeneous fleet: the fastest holds b_max, slower devices get
        // strictly smaller grid sizes in speed order.
        let t = calibrated_targets(&[1.0, 1.32, 2.0], 12.0, &cost, &c);
        assert_eq!(t[0], 128);
        assert!(t[0] > t[1] && t[1] > t[2], "{t:?}");
        for &b in &t {
            assert!((c.b_min..=c.b_max).contains(&b));
            assert_eq!((b - c.b_min) % c.beta, 0, "off-grid {b}");
        }
        // The targets really do equalize predicted per-batch time (within
        // one grid pitch of slack per device).
        let per_sample = cost.t_per_nnz * 12.0 + cost.t_per_sample;
        let times: Vec<f64> = t
            .iter()
            .zip([1.0, 1.32, 2.0])
            .map(|(&b, s)| s * (cost.t_fixed + per_sample * b as f64))
            .collect();
        let spread = crate::util::stats::max(&times) / crate::util::stats::min(&times);
        assert!(spread < 1.15, "predicted times should be near-equal: {times:?}");
        // An extreme straggler clamps to b_min instead of leaving the grid.
        let t = calibrated_targets(&[1.0, 50.0], 12.0, &cost, &c);
        assert_eq!(t[1], c.b_min);
    }

    #[test]
    fn joint_targets_trade_batch_against_sparsity() {
        let c = cfg(); // grid 16..128 step 8
        let cost = crate::runtime::CostModel::default();
        let slide = crate::config::SlideConfig::default(); // ladder 1.0..0.05

        // While batch size alone can equalize, sparsity stays at 1.0 and
        // the batches match the single-knob path exactly.
        let speeds = [1.0, 1.32, 2.0];
        let (b, r) = joint_targets(&speeds, 12.0, &cost, &c, &slide);
        assert_eq!(b, calibrated_targets(&speeds, 12.0, &cost, &c));
        assert!(r.iter().all(|&x| x == 1.0), "{r:?}");

        // A hard throttle that would need b < b_min dense drops down the
        // ratio ladder instead of just clamping to b_min.
        let (b, r) = joint_targets(&[1.0, 8.0], 12.0, &cost, &c, &slide);
        assert_eq!(b[0], c.b_max);
        assert_eq!(r[0], 1.0);
        assert!(r[1] < 1.0, "throttled device must shed classes: {r:?}");
        assert!(b[1] >= c.b_min && (b[1] - c.b_min) % c.beta == 0);
        // The chosen (b, ratio) really is feasible: predicted step time at
        // that sparsity is within a grid pitch of the fleet target.
        let gather = cost.t_per_nnz * 12.0;
        let target = cost.t_fixed + (gather + cost.t_per_sample) * c.b_max as f64;
        let per_sample = gather + cost.t_per_sample * cost.sparsity_factor(r[1]);
        let t1 = 8.0 * (cost.t_fixed + per_sample * b[1] as f64);
        assert!(
            t1 <= target * (1.0 + c.beta as f64 / c.b_min as f64),
            "joint target overshoots: {t1} vs {target}"
        );

        // A hopeless straggler floors at (b_min, min_ratio) instead of
        // leaving the grid or the ladder.
        let (b, r) = joint_targets(&[1.0, 1000.0], 12.0, &cost, &c, &slide);
        assert_eq!(b[1], c.b_min);
        assert_eq!(r[1], slide.min_ratio);
    }

    #[test]
    fn sparsity_targets_hold_batches_and_walk_the_ladder() {
        let cost = crate::runtime::CostModel::default();
        let slide = crate::config::SlideConfig::default();

        // Homogeneous fleet at a common batch: everyone stays dense.
        let r = sparsity_targets(&[1.0, 1.0, 1.0], &[128, 128, 128], 12.0, &cost, &slide);
        assert!(r.iter().all(|&x| x == 1.0), "{r:?}");

        // A throttled device sheds classes; the fast one stays dense, and
        // the chosen rung's predicted step time beats the dense one.
        let batches = [128usize, 128];
        let r = sparsity_targets(&[1.0, 3.0], &batches, 12.0, &cost, &slide);
        assert_eq!(r[0], 1.0);
        assert!(r[1] < 1.0, "throttled device must shed classes: {r:?}");
        let gather = cost.t_per_nnz * 12.0;
        let dense = 3.0 * (cost.t_fixed + (gather + cost.t_per_sample) * 128.0);
        let sparse = 3.0
            * (cost.t_fixed
                + (gather + cost.t_per_sample * cost.sparsity_factor(r[1])) * 128.0);
        assert!(sparse < dense);

        // A hopeless straggler floors at min_ratio, never off the ladder.
        let r = sparsity_targets(&[1.0, 1000.0], &batches, 12.0, &cost, &slide);
        assert_eq!(r[1], slide.min_ratio);
    }

    #[test]
    fn scaling_state_detects_oscillation() {
        let mut s = ScalingState::default();
        for _ in 0..2 {
            s.observe(&[64, 48]);
            s.observe(&[72, 48]);
        }
        assert!(s.oscillating());
        assert!(!s.should_scale(), "oscillation must pause scaling");
        // Cooldown elapses, new drifting observations re-arm the controller.
        s.observe(&[64, 48]);
        s.observe(&[80, 40]);
        s.observe(&[88, 32]);
        assert!(!s.oscillating());
        assert!(s.should_scale());
    }

    #[test]
    fn scaling_state_detects_stability() {
        let mut s = ScalingState::default();
        s.observe(&[64, 64]);
        assert!(!s.stable(), "needs three snapshots");
        s.observe(&[64, 64]);
        s.observe(&[64, 64]);
        assert!(s.stable());
        assert!(!s.should_scale());
    }

    #[test]
    fn scaling_state_allows_drift() {
        let mut s = ScalingState::default();
        s.observe(&[128, 128]);
        s.observe(&[120, 128]);
        s.observe(&[112, 120]);
        s.observe(&[104, 112]);
        assert!(!s.oscillating());
        assert!(!s.stable());
        assert!(s.should_scale());
    }

    #[test]
    fn scaling_state_window_and_cooldown_are_configurable() {
        // A 6-snapshot window delays oscillation detection until it fills.
        let mut s = ScalingState::new(6, 1);
        for _ in 0..2 {
            s.observe(&[64, 48]);
            s.observe(&[72, 48]);
        }
        assert!(!s.oscillating(), "4 snapshots must not fill a 6-window");
        s.observe(&[64, 48]);
        s.observe(&[72, 48]);
        assert!(s.oscillating(), "the filled window sees the a,b,a,b flip");
        assert!(!s.should_scale());
        // Cooldown of 1 re-arms after a single observation.
        s.observe(&[80, 40]);
        assert!(!s.oscillating() || !s.stable());
        // from_config mirrors the SgdConfig knobs.
        let cfg = SgdConfig { scaling_window: 5, scaling_cooldown: 2, ..Default::default() };
        let s2 = ScalingState::from_config(&cfg);
        assert_eq!(s2.window, 5);
        assert_eq!(s2.cooldown_len, 2);
    }

    /// Property: iterating scaling with update counts proportional to an
    /// (inverse) speed model converges to a steady state where faster
    /// devices hold strictly-no-smaller batches.
    #[test]
    fn converges_to_speed_ordered_steady_state() {
        let c = cfg();
        let speeds = [1.0f64, 1.1, 1.21, 1.32]; // slowdown factors
        let mut b = vec![c.b_max; 4];
        let mut lr = vec![0.05f32; 4];
        let mega = 100 * c.b_max; // samples per mega-batch
        for _ in 0..40 {
            // Updates ∝ share of the mega-batch each device wins when its
            // throughput is batch/(slowdown * batch-time). With per-sample-
            // dominated cost, update rate ∝ 1/(speed * b) and samples/s ∝
            // 1/speed; devices split the budget by sample rate.
            let rate: Vec<f64> = speeds.iter().map(|s| 1.0 / s).collect();
            let total_rate: f64 = rate.iter().sum();
            let updates: Vec<u64> = (0..4)
                .map(|i| {
                    let samples = mega as f64 * rate[i] / total_rate;
                    (samples / b[i] as f64).round() as u64
                })
                .collect();
            rescale(&mut b, &mut lr, &updates, &c);
        }
        // Fastest device ends with the largest batch, slowest the smallest.
        assert!(b[0] >= b[1] && b[1] >= b[2] && b[2] >= b[3], "{b:?}");
        assert!(b[0] > b[3], "scaling failed to differentiate: {b:?}");
    }
}

//! **Algorithm 2 — Normalized Model Merging** (paper §3.3).
//!
//! Weighted model averaging where the weights prioritize replicas updated
//! more frequently and, secondarily, replicas fed larger batches:
//!
//! * equal update counts  → `α_i = b_i / Σb`   (batch-size normalization),
//! * unequal update counts → `α_i = u_i / Σu`  (update-count normalization);
//! * if **all** replicas are well-regularized (L2-norm per parameter below
//!   `pert_thr`), perturb: `α_argmax(u) *= 1+δ`, `α_argmin(u) *= 1−δ`
//!   (deliberately denormalizing, bounded by δ);
//! * momentum global update: `w' = Σ α_i w_i + γ (w − w_p)`, `w_p ← w`.
//!
//! # Invariants
//!
//! * Normalized weights always sum to 1 over whatever *active* subset
//!   they were computed for — pool shrink/grow renormalizes implicitly —
//!   and perturbation denormalizes by at most ±δ (property-tested in
//!   `integration_elastic.rs`).
//! * Equal update counts yield the batch-size normalization branch; any
//!   inequality switches to update counts. Zero total updates degrades to
//!   uniform weights instead of dividing by zero.

use crate::config::{MergeConfig, Normalization};
use crate::model::ModelState;

/// What happened at one merge (Fig. 12b trace material).
#[derive(Clone, Debug, PartialEq)]
pub struct MergeOutcome {
    pub weights: Vec<f64>,
    pub perturbed: bool,
    /// Which normalization branch ran.
    pub by_updates: bool,
}

/// Lines 1–6: normalization weights.
pub fn normalized_weights(
    updates: &[u64],
    batch_sizes: &[usize],
    norm: Normalization,
) -> (Vec<f64>, bool) {
    assert_eq!(updates.len(), batch_sizes.len());
    assert!(!updates.is_empty());
    let equal = updates.windows(2).all(|w| w[0] == w[1]);
    if equal {
        let total: f64 = batch_sizes.iter().map(|&b| b as f64).sum();
        (batch_sizes.iter().map(|&b| b as f64 / total).collect(), false)
    } else {
        let raw: Vec<f64> = match norm {
            Normalization::Updates => updates.iter().map(|&u| u as f64).collect(),
            // The paper's discussed-and-rejected alternative, kept for the
            // ablation benches.
            Normalization::UpdatesTimesBatch => updates
                .iter()
                .zip(batch_sizes)
                .map(|(&u, &b)| u as f64 * b as f64)
                .collect(),
        };
        let total: f64 = raw.iter().sum();
        if total == 0.0 {
            let g = updates.len() as f64;
            return (vec![1.0 / g; updates.len()], true);
        }
        (raw.iter().map(|&w| w / total).collect(), true)
    }
}

/// Lines 7–10: perturbation, gated on every replica being regularized.
/// Returns true when applied.
pub fn apply_perturbation(
    weights: &mut [f64],
    updates: &[u64],
    replica_l2_per_param: &[f64],
    cfg: &MergeConfig,
) -> bool {
    if !cfg.perturbation || weights.len() < 2 {
        return false;
    }
    if !replica_l2_per_param.iter().all(|&n| n < cfg.pert_thr) {
        return false;
    }
    // argmax / argmin of the update counts (first occurrence, as in the
    // paper's argmax/argmin notation).
    let mut r = 0usize;
    let mut s = 0usize;
    for (i, &u) in updates.iter().enumerate() {
        if u > updates[r] {
            r = i;
        }
        if u < updates[s] {
            s = i;
        }
    }
    if r == s {
        return false;
    }
    weights[r] *= 1.0 + cfg.delta;
    weights[s] *= 1.0 - cfg.delta;
    true
}

/// Lines 11–12: momentum global-model update.
///
/// `global` and `global_prev` are updated in place:
/// `w' = Σ α_i w_i + γ (w − w_p)`, then `w_p ← w`, `w ← w'`.
pub fn momentum_update(
    global: &mut ModelState,
    global_prev: &mut ModelState,
    merged: &ModelState,
    momentum: f64,
) {
    // w' = merged + γ (w − w_p)
    let mut new = merged.clone();
    new.add_scaled_diff(global, global_prev, momentum);
    // w_p ← w ; w ← w'
    std::mem::swap(global_prev, global);
    *global = new;
}

/// Full Algorithm 2 over replica references. Returns the outcome trace.
/// The caller supplies the weighted-average result destination separately
/// (typically through `allreduce::allreduce_merge` to charge transfer time).
pub fn compute_weights(
    updates: &[u64],
    batch_sizes: &[usize],
    replica_l2_per_param: &[f64],
    cfg: &MergeConfig,
) -> MergeOutcome {
    let (mut weights, by_updates) = normalized_weights(updates, batch_sizes, cfg.normalization);
    let perturbed = apply_perturbation(&mut weights, updates, replica_l2_per_param, cfg);
    MergeOutcome { weights, perturbed, by_updates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;
    use crate::util::prop;

    fn dims() -> ModelDims {
        ModelDims { features: 32, hidden: 8, classes: 16, max_nnz: 4, max_labels: 2 }
    }

    #[test]
    fn equal_updates_normalizes_by_batch_size() {
        let (w, by_updates) = normalized_weights(&[5, 5, 5], &[128, 64, 64], Normalization::Updates);
        assert!(!by_updates);
        assert!((w[0] - 0.5).abs() < 1e-12);
        assert!((w[1] - 0.25).abs() < 1e-12);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unequal_updates_normalizes_by_updates() {
        let (w, by_updates) = normalized_weights(&[6, 2], &[128, 128], Normalization::Updates);
        assert!(by_updates);
        assert!((w[0] - 0.75).abs() < 1e-12);
        assert!((w[1] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn updates_times_batch_normalization_variant() {
        let (w, by_updates) =
            normalized_weights(&[6, 2], &[64, 128], Normalization::UpdatesTimesBatch);
        assert!(by_updates);
        // raw = [384, 256] -> [0.6, 0.4]
        assert!((w[0] - 0.6).abs() < 1e-12);
        assert!((w[1] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn zero_updates_fall_back_to_equal_weights() {
        let (w, _) = normalized_weights(&[0, 0, 3], &[64, 64, 64], Normalization::Updates);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(w[0], 0.0);
        let (w, _) = normalized_weights(&[0, 1], &[0, 0], Normalization::UpdatesTimesBatch);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perturbation_requires_all_replicas_regularized() {
        let cfg = MergeConfig::default(); // thr 0.1, delta 0.1
        let mut w = vec![0.6, 0.4];
        // One replica unregularized -> no perturbation.
        assert!(!apply_perturbation(&mut w, &[6, 2], &[0.05, 0.2], &cfg));
        assert_eq!(w, vec![0.6, 0.4]);
        // All regularized -> applied.
        assert!(apply_perturbation(&mut w, &[6, 2], &[0.05, 0.02], &cfg));
        assert!((w[0] - 0.66).abs() < 1e-12);
        assert!((w[1] - 0.36).abs() < 1e-12);
    }

    #[test]
    fn perturbation_denormalization_is_bounded_by_delta() {
        let cfg = MergeConfig::default();
        let gen = prop::VecU64 { min_len: 2, max_len: 8, item_lo: 0, item_hi: 50 };
        prop::check(300, 0xBEEF, gen, |updates| {
            let b = vec![64usize; updates.len()];
            let l2 = vec![0.01f64; updates.len()];
            let out = compute_weights(updates, &b, &l2, &cfg);
            let sum: f64 = out.weights.iter().sum();
            // Without perturbation weights sum to exactly 1; perturbation
            // shifts the sum by at most δ·(α_r − α_s) ⊆ [−δ, +δ].
            if (sum - 1.0).abs() > cfg.delta + 1e-9 {
                return Err(format!("weight sum {sum} drifted beyond delta"));
            }
            if out.weights.iter().any(|&w| w < 0.0) {
                return Err("negative weight".into());
            }
            Ok(())
        });
    }

    #[test]
    fn perturbation_can_be_disabled() {
        let cfg = MergeConfig { perturbation: false, ..Default::default() };
        let mut w = vec![0.6, 0.4];
        assert!(!apply_perturbation(&mut w, &[6, 2], &[0.01, 0.01], &cfg));
    }

    #[test]
    fn all_equal_updates_never_perturbs() {
        let cfg = MergeConfig::default();
        let mut w = vec![0.5, 0.5];
        // argmax == argmin when all counts equal.
        assert!(!apply_perturbation(&mut w, &[4, 4], &[0.01, 0.01], &cfg));
    }

    /// Property: without perturbation, normalized weights always sum to
    /// exactly 1 and are non-negative, whichever branch runs.
    #[test]
    fn prop_normalized_weights_sum_to_one() {
        let gen = prop::Pair(
            prop::VecU64 { min_len: 1, max_len: 9, item_lo: 0, item_hi: 40 },
            prop::VecU64 { min_len: 1, max_len: 9, item_lo: 1, item_hi: 17 },
        );
        prop::check(400, 0x5EED, gen, |(updates, size_picks)| {
            let n = updates.len().min(size_picks.len());
            let updates = &updates[..n];
            let batches: Vec<usize> = size_picks[..n].iter().map(|&p| 8 * p as usize).collect();
            for norm in [Normalization::Updates, Normalization::UpdatesTimesBatch] {
                let (w, _) = normalized_weights(updates, &batches, norm);
                let sum: f64 = w.iter().sum();
                if (sum - 1.0).abs() > 1e-9 {
                    return Err(format!("{norm:?}: weight sum {sum}"));
                }
                if w.iter().any(|&x| x < 0.0) {
                    return Err(format!("{norm:?}: negative weight"));
                }
            }
            Ok(())
        });
    }

    /// Property: equal update counts and equal batch sizes give the uniform
    /// 1/G weighting, for any active pool size G.
    #[test]
    fn prop_equal_work_is_uniform() {
        let gen = prop::Pair(
            prop::U64Range { lo: 1, hi: 12 },
            prop::U64Range { lo: 0, hi: 30 },
        );
        prop::check(200, 0xFACE, gen, |&(g, u)| {
            let g = g as usize;
            let (w, by_updates) =
                normalized_weights(&vec![u; g], &vec![64; g], Normalization::Updates);
            if by_updates {
                return Err("equal updates must take the batch-size branch".into());
            }
            for &x in &w {
                if (x - 1.0 / g as f64).abs() > 1e-12 {
                    return Err(format!("non-uniform weight {x} for G={g}"));
                }
            }
            Ok(())
        });
    }

    /// Property: weights stay a valid distribution when the active device
    /// subset shrinks or grows between consecutive mega-batches — the merge
    /// must renormalize over whatever subset is active *now*, with no
    /// residue from the previous membership.
    #[test]
    fn prop_weights_valid_across_membership_churn() {
        let gen = prop::Pair(
            prop::VecU64 { min_len: 2, max_len: 9, item_lo: 0, item_hi: 40 },
            prop::U64Range { lo: 0, hi: u64::MAX },
        );
        prop::check(300, 0xE1A5, gen, |(updates, mask_seed)| {
            let roster = updates.len();
            // Two consecutive memberships derived from the mask bits; always
            // keep at least one device (min_devices floor).
            let subset = |bits: u64| -> Vec<usize> {
                let s: Vec<usize> =
                    (0..roster).filter(|&d| bits >> d & 1 == 1).collect();
                if s.is_empty() {
                    vec![0]
                } else {
                    s
                }
            };
            for active in [subset(*mask_seed), subset(mask_seed >> 16)] {
                let u: Vec<u64> = active.iter().map(|&d| updates[d]).collect();
                let b: Vec<usize> = active.iter().map(|&d| 16 + 8 * d).collect();
                let (w, _) = normalized_weights(&u, &b, Normalization::Updates);
                if w.len() != active.len() {
                    return Err("weight count != active count".into());
                }
                let sum: f64 = w.iter().sum();
                if (sum - 1.0).abs() > 1e-9 {
                    return Err(format!(
                        "subset {active:?} of {roster}: weight sum {sum}"
                    ));
                }
                if w.iter().any(|&x| x < 0.0 || !x.is_finite()) {
                    return Err(format!("subset {active:?}: invalid weight"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn momentum_update_algebra() {
        let d = dims();
        let merged = ModelState::init(&d, 1);
        let mut global = ModelState::init(&d, 2);
        let mut prev = ModelState::init(&d, 3);
        let g0 = global.clone();
        let p0 = prev.clone();
        momentum_update(&mut global, &mut prev, &merged, 0.9);
        // w_p became the old w.
        assert!(prev.max_abs_diff(&g0) == 0.0);
        // w' = merged + 0.9 (g0 - p0), check one coordinate.
        let expect = merged.w1[5] + 0.9 * (g0.w1[5] - p0.w1[5]);
        assert!((global.w1[5] - expect).abs() < 1e-6);
    }

    #[test]
    fn zero_momentum_reduces_to_plain_average() {
        let d = dims();
        let merged = ModelState::init(&d, 4);
        let mut global = ModelState::init(&d, 5);
        let mut prev = ModelState::init(&d, 6);
        momentum_update(&mut global, &mut prev, &merged, 0.0);
        assert!(global.max_abs_diff(&merged) == 0.0);
    }
}

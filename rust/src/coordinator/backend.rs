//! Step/eval numerics providers.
//!
//! The coordinator logic is independent of *where* the math runs:
//! [`PjrtBackend`] executes the AOT artifacts through PJRT (the production
//! path), [`RefBackend`] runs the pure-Rust twin (hermetic unit tests, the
//! SLIDE baseline's building block, and CI machines without artifacts).

use std::time::Instant;

use crate::data::PaddedBatch;
use crate::model::reference;
use crate::model::ModelState;
use crate::runtime::Runtime;
use crate::Result;

/// One SGD step / one eval pass. `step` returns (loss, real execution
/// seconds) — engines combine the latter with the heterogeneity model.
///
/// The `_scratch` variants let callers that step in a loop (both engines,
/// the serve replay) reuse one [`reference::StepScratch`] across calls
/// instead of allocating per step. Backends whose buffers live elsewhere
/// (PJRT holds device memory) ignore the scratch and delegate to the
/// plain methods — the defaults here — so the variants are always safe to
/// call and bit-identical to the originals.
pub trait StepBackend {
    fn step(&self, model: &mut ModelState, batch: &PaddedBatch, lr: f32) -> Result<(f32, f64)>;
    fn eval(&self, model: &ModelState, batch: &PaddedBatch) -> Result<Vec<i32>>;

    /// [`step`](StepBackend::step) with caller-pooled buffers.
    fn step_scratch(
        &self,
        model: &mut ModelState,
        batch: &PaddedBatch,
        lr: f32,
        _scratch: &mut reference::StepScratch,
    ) -> Result<(f32, f64)> {
        self.step(model, batch, lr)
    }

    /// [`eval`](StepBackend::eval) with caller-pooled buffers.
    fn eval_scratch(
        &self,
        model: &ModelState,
        batch: &PaddedBatch,
        _scratch: &mut reference::StepScratch,
    ) -> Result<Vec<i32>> {
        self.eval(model, batch)
    }

    fn name(&self) -> &'static str;
}

/// PJRT-backed numerics (loads `artifacts/`).
pub struct PjrtBackend {
    pub runtime: Runtime,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime) -> Self {
        PjrtBackend { runtime }
    }
}

impl StepBackend for PjrtBackend {
    fn step(&self, model: &mut ModelState, batch: &PaddedBatch, lr: f32) -> Result<(f32, f64)> {
        let (loss, dt) = self.runtime.step(model, batch, lr)?;
        Ok((loss, dt.as_secs_f64()))
    }

    fn eval(&self, model: &ModelState, batch: &PaddedBatch) -> Result<Vec<i32>> {
        self.runtime.eval(model, batch)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Pure-Rust reference numerics (no artifacts needed).
pub struct RefBackend;

impl StepBackend for RefBackend {
    fn step(&self, model: &mut ModelState, batch: &PaddedBatch, lr: f32) -> Result<(f32, f64)> {
        let t0 = Instant::now();
        let loss = reference::sgd_step_ref(model, batch, lr);
        Ok((loss, t0.elapsed().as_secs_f64()))
    }

    fn eval(&self, model: &ModelState, batch: &PaddedBatch) -> Result<Vec<i32>> {
        Ok(reference::eval_ref(model, batch))
    }

    fn step_scratch(
        &self,
        model: &mut ModelState,
        batch: &PaddedBatch,
        lr: f32,
        scratch: &mut reference::StepScratch,
    ) -> Result<(f32, f64)> {
        let t0 = Instant::now();
        let loss = reference::sgd_step_scratch(model, batch, lr, scratch);
        Ok((loss, t0.elapsed().as_secs_f64()))
    }

    fn eval_scratch(
        &self,
        model: &ModelState,
        batch: &PaddedBatch,
        scratch: &mut reference::StepScratch,
    ) -> Result<Vec<i32>> {
        Ok(reference::eval_scratch(model, batch, scratch))
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}
